//! Offline workalike of the `anyhow` crate — the subset radpipe uses.
//!
//! Provides [`Error`] (a context-chain error), [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros and the [`Context`] extension trait for
//! `Result` and `Option`. Formatting matches anyhow's conventions:
//! `{}` shows the outermost message, `{:#}` shows the whole chain joined
//! with `": "`, `{:?}` shows the message plus a `Caused by:` list.

use std::fmt;

/// A dynamic error with a chain of context messages.
///
/// `chain[0]` is the outermost (most recently attached) context; the last
/// element is the root cause. Like `anyhow::Error`, this type deliberately
/// does **not** implement `std::error::Error`, which is what makes the
/// blanket `From<E: std::error::Error>` impl below coherent.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build from a single displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_msg(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        // Fold the source chain into context entries so `{:#}` shows it.
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result` — defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(e.to_string(), "reading config");
        let full = format!("{e:#}");
        assert!(full.contains("reading config"));
        assert!(full.contains("file missing"));
        assert!(full.contains(": "));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_on_option() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        let v2: Option<i32> = Some(3);
        assert_eq!(v2.with_context(|| "unused").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        fn inner() -> Result<()> {
            bail!("root cause")
        }
        fn outer() -> Result<()> {
            inner().context("outer layer")
        }
        let e = outer().unwrap_err();
        assert_eq!(e.to_string(), "outer layer");
        assert_eq!(e.root_cause(), "root cause");
        assert_eq!(format!("{e:#}"), "outer layer: root cause");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            ensure!(x != 1);
            if x == 2 {
                bail!("two is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative input -1"));
        assert!(f(1).unwrap_err().to_string().contains("condition failed"));
        assert!(f(2).unwrap_err().to_string().contains("two"));
        let msg = String::from("owned message");
        let e = anyhow!(msg);
        assert_eq!(e.to_string(), "owned message");
    }

    #[test]
    fn debug_format_lists_causes() {
        let e: Error = io_err().into();
        let e = e.context("ctx");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx"));
        assert!(dbg.contains("Caused by"));
    }
}
