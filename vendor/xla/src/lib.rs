//! PJRT binding surface **stub**.
//!
//! The real `xla` crate links the XLA/PJRT C++ runtime, which cannot be
//! built in the offline mirror. This stub exposes the exact API surface the
//! radpipe engine uses so the crate compiles and the dispatcher's probe
//! fails *cleanly*: [`PjRtClient::cpu`] returns an error, the engine thread
//! reports it per-request, and the `auto` backend falls back to the CPU
//! path — the paper's graceful-degradation behaviour, exercised end to end.
//!
//! Swapping this path dependency for the real crate re-enables the
//! accelerated path with no source changes in radpipe.

use std::fmt;

/// Error type: a plain message (the real crate's `Error` is also opaque and
/// only ever formatted by radpipe).
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "XLA/PJRT runtime not linked in this build (vendor/xla stub); \
         the accelerated path is unavailable"
            .to_string(),
    )
}

/// PJRT client handle (never constructible through the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real binding constructs a CPU PJRT client; the stub reports the
    /// runtime as missing, which the engine surfaces on first use.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable())
    }
}

/// Parsed HLO module (text form).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A host literal (tensor value).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_vec<T: Copy + Default>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("unavailable") || msg.contains("not linked"), "{msg}");
    }

    #[test]
    fn hlo_parse_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("/tmp/whatever.hlo.txt").is_err());
    }
}
