//! DEFLATE compression: one fixed-Huffman block (RFC 1951 §3.2.6) over a
//! greedy LZ77 token stream with a single-candidate 3-byte hash matcher.

/// Length-code bases for symbols 257..=285 (index 0 = symbol 257).
pub(crate) const LEN_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59, 67, 83, 99,
    115, 131, 163, 195, 227, 258,
];
pub(crate) const LEN_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4, 5, 5, 5, 5, 0,
];

/// Distance-code bases for symbols 0..=29.
pub(crate) const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513, 769, 1025,
    1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
pub(crate) const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10, 11, 11, 12,
    12, 13, 13,
];

/// LSB-first bit accumulator (DEFLATE bit order); Huffman codes are pushed
/// through [`BitWriter::huff`], which bit-reverses them as the spec requires.
struct BitWriter {
    out: Vec<u8>,
    acc: u32,
    n: u32,
}

impl BitWriter {
    fn new() -> BitWriter {
        BitWriter { out: Vec::new(), acc: 0, n: 0 }
    }

    fn bits(&mut self, v: u32, n: u32) {
        debug_assert!(n <= 16);
        self.acc |= (v & ((1u32 << n) - 1)) << self.n;
        self.n += n;
        while self.n >= 8 {
            self.out.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.n -= 8;
        }
    }

    /// Huffman codes are packed most-significant-bit first.
    fn huff(&mut self, code: u32, n: u32) {
        let mut rev = 0u32;
        let mut c = code;
        for _ in 0..n {
            rev = (rev << 1) | (c & 1);
            c >>= 1;
        }
        self.bits(rev, n);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.n > 0 {
            self.out.push((self.acc & 0xFF) as u8);
        }
        self.out
    }
}

/// Emit one literal/length symbol with the fixed code assignment.
fn fixed_lit(w: &mut BitWriter, sym: u32) {
    if sym <= 143 {
        w.huff(0x30 + sym, 8);
    } else if sym <= 255 {
        w.huff(0x190 + sym - 144, 9);
    } else if sym <= 279 {
        w.huff(sym - 256, 7);
    } else {
        w.huff(0xC0 + sym - 280, 8);
    }
}

/// (symbol offset from 257, extra value, extra bits) for a match length.
fn len_sym(len: usize) -> (u32, u32, u8) {
    for i in (0..29).rev() {
        if len >= LEN_BASE[i] as usize {
            return (i as u32, (len - LEN_BASE[i] as usize) as u32, LEN_EXTRA[i]);
        }
    }
    unreachable!("match length below 3")
}

/// (distance symbol, extra value, extra bits) for a match distance.
fn dist_sym(dist: usize) -> (u32, u32, u8) {
    for i in (0..30).rev() {
        if dist >= DIST_BASE[i] as usize {
            return (i as u32, (dist - DIST_BASE[i] as usize) as u32, DIST_EXTRA[i]);
        }
    }
    unreachable!("match distance below 1")
}

const HASH_BITS: u32 = 15;
const HASH_SIZE: usize = 1 << HASH_BITS;
const MAX_DIST: usize = 32768;
const MAX_LEN: usize = 258;

#[inline]
fn hash3(data: &[u8], p: usize) -> usize {
    (((data[p] as usize) << 10) ^ ((data[p + 1] as usize) << 5) ^ data[p + 2] as usize)
        & (HASH_SIZE - 1)
}

/// Compress `data` into a single BFINAL fixed-Huffman DEFLATE block.
pub(crate) fn deflate_fixed(data: &[u8]) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.bits(1, 1); // BFINAL
    w.bits(1, 2); // BTYPE = 01 (fixed Huffman)

    let n = data.len();
    let mut head = vec![usize::MAX; HASH_SIZE];
    let mut pos = 0usize;
    while pos < n {
        let mut match_len = 0usize;
        let mut match_dist = 0usize;
        if pos + 3 <= n {
            let h = hash3(data, pos);
            let cand = head[h];
            if cand != usize::MAX
                && pos - cand <= MAX_DIST
                && data[cand..cand + 3] == data[pos..pos + 3]
            {
                let limit = MAX_LEN.min(n - pos);
                let mut l = 3usize;
                while l < limit && data[cand + l] == data[pos + l] {
                    l += 1;
                }
                match_len = l;
                match_dist = pos - cand;
            }
            head[h] = pos;
        }
        if match_len >= 3 {
            let (si, extra, eb) = len_sym(match_len);
            fixed_lit(&mut w, 257 + si);
            if eb > 0 {
                w.bits(extra, eb as u32);
            }
            let (ds, dextra, deb) = dist_sym(match_dist);
            w.huff(ds, 5);
            if deb > 0 {
                w.bits(dextra, deb as u32);
            }
            // index the positions the match skipped over
            let end = pos + match_len;
            let mut p = pos + 1;
            while p < end && p + 3 <= n {
                head[hash3(data, p)] = p;
                p += 1;
            }
            pos = end;
        } else {
            fixed_lit(&mut w, data[pos] as u32);
            pos += 1;
        }
    }
    fixed_lit(&mut w, 256); // end of block
    w.finish()
}
