//! CRC-32 (IEEE 802.3, polynomial 0xEDB88320) — the gzip checksum.

fn table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// CRC32 of `data` (pre/post-conditioned, as gzip uses it).
pub fn crc32(data: &[u8]) -> u32 {
    let t = table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}
