//! Offline pure-Rust gzip codec exposing the `flate2` API subset radpipe
//! uses: `read::GzDecoder`, `write::GzEncoder`, `Compression`.
//!
//! The DEFLATE side implements:
//! * compression with one fixed-Huffman block and a greedy single-candidate
//!   LZ77 matcher (hash of 3-byte prefixes) — small and fast, and very
//!   effective on the mostly-zero voxel volumes this repo stores;
//! * full decompression: stored, fixed-Huffman and dynamic-Huffman blocks
//!   (so externally produced `.nii.gz` / `.rvol.gz` files read fine).
//!
//! Both directions, the gzip framing (flag handling included) and the CRC32
//! are interoperable with zlib — the algorithm was cross-validated against
//! `zlib.compress`/`zlib.decompress` and `gzip` on a reference corpus.

mod crc32;
mod deflate;
mod inflate;

pub use crc32::crc32;

/// Compression level marker (the codec has a single strategy; levels are
/// accepted for API compatibility).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compression(pub u32);

impl Compression {
    pub fn new(level: u32) -> Compression {
        Compression(level)
    }

    pub fn fast() -> Compression {
        Compression(1)
    }

    pub fn best() -> Compression {
        Compression(9)
    }
}

impl Default for Compression {
    fn default() -> Compression {
        Compression(6)
    }
}

const GZ_MAGIC: [u8; 2] = [0x1f, 0x8b];

/// Compress `data` into a complete single-member gzip stream.
pub fn gzip_compress(data: &[u8]) -> Vec<u8> {
    let body = deflate::deflate_fixed(data);
    let mut out = Vec::with_capacity(body.len() + 18);
    // header: magic, CM=8 (deflate), FLG=0, MTIME=0, XFL=0, OS=255 (unknown)
    out.extend_from_slice(&[GZ_MAGIC[0], GZ_MAGIC[1], 8, 0, 0, 0, 0, 0, 0, 255]);
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

fn bad(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

/// Decompress a complete single-member gzip stream.
pub fn gzip_decompress(bytes: &[u8]) -> std::io::Result<Vec<u8>> {
    if bytes.len() < 18 {
        return Err(bad("gzip stream too short"));
    }
    if bytes[0..2] != GZ_MAGIC {
        return Err(bad("not a gzip stream (bad magic)"));
    }
    if bytes[2] != 8 {
        return Err(bad("unsupported gzip compression method"));
    }
    let flg = bytes[3];
    let mut p = 10usize;
    if flg & 0x04 != 0 {
        // FEXTRA
        if p + 2 > bytes.len() {
            return Err(bad("truncated gzip FEXTRA"));
        }
        let xlen = bytes[p] as usize | ((bytes[p + 1] as usize) << 8);
        p += 2 + xlen;
    }
    if flg & 0x08 != 0 {
        // FNAME: NUL-terminated
        while p < bytes.len() && bytes[p] != 0 {
            p += 1;
        }
        p += 1;
    }
    if flg & 0x10 != 0 {
        // FCOMMENT
        while p < bytes.len() && bytes[p] != 0 {
            p += 1;
        }
        p += 1;
    }
    if flg & 0x02 != 0 {
        // FHCRC
        p += 2;
    }
    if p + 8 > bytes.len() {
        return Err(bad("truncated gzip header"));
    }
    let data = inflate::inflate(&bytes[p..bytes.len() - 8])?;
    let tail = &bytes[bytes.len() - 8..];
    let crc = u32::from_le_bytes([tail[0], tail[1], tail[2], tail[3]]);
    let isize = u32::from_le_bytes([tail[4], tail[5], tail[6], tail[7]]);
    if crc32(&data) != crc {
        return Err(bad("gzip CRC mismatch"));
    }
    if data.len() as u32 != isize {
        return Err(bad("gzip ISIZE mismatch"));
    }
    Ok(data)
}

pub mod write {
    use super::Compression;
    use std::io::{self, Write};

    /// Buffering gzip encoder: collects all written bytes, compresses and
    /// frames them on [`GzEncoder::finish`].
    pub struct GzEncoder<W: Write> {
        inner: W,
        buf: Vec<u8>,
    }

    impl<W: Write> GzEncoder<W> {
        pub fn new(inner: W, _level: Compression) -> GzEncoder<W> {
            GzEncoder { inner, buf: Vec::new() }
        }

        /// Compress the buffered payload, write the gzip stream and return
        /// the (flushed) inner writer.
        pub fn finish(mut self) -> io::Result<W> {
            let framed = super::gzip_compress(&self.buf);
            self.inner.write_all(&framed)?;
            self.inner.flush()?;
            Ok(self.inner)
        }
    }

    impl<W: Write> Write for GzEncoder<W> {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            self.buf.extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }
}

pub mod read {
    use std::io::{self, Read};

    enum State {
        /// Inner reader not yet consumed.
        Pending,
        /// Decompressed payload + read cursor.
        Ready(Vec<u8>, usize),
        /// Decompression failed; the message is replayed on every read.
        Failed(String),
    }

    /// Gzip decoder: inflates the whole inner stream on first read (volumes
    /// are bounded; simplicity over streaming) and serves reads from memory.
    pub struct GzDecoder<R: Read> {
        inner: R,
        state: State,
    }

    impl<R: Read> GzDecoder<R> {
        pub fn new(inner: R) -> GzDecoder<R> {
            GzDecoder { inner, state: State::Pending }
        }
    }

    impl<R: Read> Read for GzDecoder<R> {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if let State::Pending = self.state {
                let mut raw = Vec::new();
                self.inner.read_to_end(&mut raw)?;
                match super::gzip_decompress(&raw) {
                    Ok(data) => self.state = State::Ready(data, 0),
                    Err(e) => self.state = State::Failed(e.to_string()),
                }
            }
            match &mut self.state {
                State::Pending => unreachable!(),
                State::Failed(msg) => {
                    Err(io::Error::new(io::ErrorKind::InvalidData, msg.clone()))
                }
                State::Ready(data, pos) => {
                    let n = out.len().min(data.len() - *pos);
                    out[..n].copy_from_slice(&data[*pos..*pos + n]);
                    *pos += n;
                    Ok(n)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn corpus() -> Vec<Vec<u8>> {
        // deterministic xorshift for a pseudo-random case
        let mut x = 0x9e3779b97f4a7c15u64;
        let mut rnd = || {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            x
        };
        let mut random = vec![0u8; 5000];
        for b in random.iter_mut() {
            *b = (rnd() & 0xff) as u8;
        }
        let mut grid = vec![0u8; 50_000];
        for _ in 0..300 {
            let i = (rnd() % 50_000) as usize;
            grid[i] = (rnd() % 7 + 1) as u8;
        }
        vec![
            Vec::new(),
            b"a".to_vec(),
            b"abc".to_vec(),
            b"hello hello hello hello".to_vec(),
            vec![0u8; 10_000],
            random,
            grid,
            b"case=00000-1 mask=00000-1.rvol.gz dims=231x104x264\n".repeat(200),
        ]
    }

    #[test]
    fn gzip_roundtrip_corpus() {
        for (i, case) in corpus().iter().enumerate() {
            let z = gzip_compress(case);
            let back = gzip_decompress(&z).unwrap();
            assert_eq!(&back, case, "case {i}");
        }
    }

    #[test]
    fn mostly_zero_data_really_compresses() {
        let grid = vec![0u8; 50_000];
        let z = gzip_compress(&grid);
        assert!(z.len() < grid.len() / 10, "{} bytes", z.len());
    }

    #[test]
    fn encoder_decoder_io_wrappers() {
        for case in corpus() {
            let mut enc = write::GzEncoder::new(Vec::new(), Compression::fast());
            enc.write_all(&case).unwrap();
            let framed = enc.finish().unwrap();
            let mut dec = read::GzDecoder::new(framed.as_slice());
            let mut back = Vec::new();
            dec.read_to_end(&mut back).unwrap();
            assert_eq!(back, case);
        }
    }

    #[test]
    fn garbage_rejected() {
        assert!(gzip_decompress(b"definitely not a gzip stream....").is_err());
        let mut z = gzip_compress(b"payload payload payload");
        let n = z.len();
        z[n - 6] ^= 0xff; // corrupt the CRC
        assert!(gzip_decompress(&z).is_err());
    }

    #[test]
    fn stored_block_decodes() {
        // hand-built stored-block deflate stream: BFINAL=1 BTYPE=00,
        // align, LEN=5, NLEN=!5, "hello"
        let mut body = vec![0x01, 0x05, 0x00, 0xfa, 0xff];
        body.extend_from_slice(b"hello");
        let mut z = vec![0x1f, 0x8b, 8, 0, 0, 0, 0, 0, 0, 255];
        z.extend_from_slice(&body);
        z.extend_from_slice(&crc32(b"hello").to_le_bytes());
        z.extend_from_slice(&5u32.to_le_bytes());
        assert_eq!(gzip_decompress(&z).unwrap(), b"hello");
    }

    #[test]
    fn crc32_known_vectors() {
        // standard test vector: CRC32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn gzip_header_flags_are_skipped() {
        // same deflate body, but with FNAME + FEXTRA flags set
        let body = deflate::deflate_fixed(b"flagged");
        let mut z = vec![0x1f, 0x8b, 8, 0x04 | 0x08, 0, 0, 0, 0, 0, 255];
        z.extend_from_slice(&[3, 0, b'x', b'y', b'z']); // FEXTRA: XLEN=3
        z.extend_from_slice(b"name.bin\0"); // FNAME
        z.extend_from_slice(&body);
        z.extend_from_slice(&crc32(b"flagged").to_le_bytes());
        z.extend_from_slice(&7u32.to_le_bytes());
        assert_eq!(gzip_decompress(&z).unwrap(), b"flagged");
    }
}
