//! DEFLATE decompression (RFC 1951): stored, fixed-Huffman and
//! dynamic-Huffman blocks, using the canonical per-bit Huffman walk
//! (the `puff.c` reference structure).

use std::io;

use super::deflate::{DIST_BASE, DIST_EXTRA, LEN_BASE, LEN_EXTRA};

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

/// LSB-first bit reader over a byte slice.
struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u32,
    n: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, pos: 0, acc: 0, n: 0 }
    }

    fn bits(&mut self, need: u32) -> io::Result<u32> {
        debug_assert!(need <= 16);
        while self.n < need {
            let Some(&b) = self.data.get(self.pos) else {
                return Err(bad("deflate stream truncated"));
            };
            self.acc |= (b as u32) << self.n;
            self.pos += 1;
            self.n += 8;
        }
        let v = self.acc & ((1u32 << need) - 1);
        self.acc >>= need;
        self.n -= need;
        Ok(v)
    }

    /// Drop partial bits to re-align on a byte boundary (stored blocks).
    fn align(&mut self) {
        self.acc = 0;
        self.n = 0;
    }
}

/// Canonical Huffman decoding table: per-length symbol counts + the
/// symbols sorted by (length, symbol order).
struct Huffman {
    count: [u16; 16],
    symbol: Vec<u16>,
}

impl Huffman {
    fn new(lengths: &[u8]) -> io::Result<Huffman> {
        let mut count = [0u16; 16];
        for &l in lengths {
            if l > 15 {
                return Err(bad("code length > 15"));
            }
            count[l as usize] += 1;
        }
        count[0] = 0;
        let mut offs = [0u16; 16];
        for l in 1..16 {
            offs[l] = offs[l - 1] + count[l - 1];
        }
        let total: usize = count.iter().map(|&c| c as usize).sum();
        let mut symbol = vec![0u16; total];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbol[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbol })
    }

    /// Walk the code one bit at a time (MSB-first code order).
    fn decode(&self, br: &mut BitReader) -> io::Result<u16> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..16 {
            code |= br.bits(1)? as i32;
            let cnt = self.count[len] as i32;
            if code - first < cnt {
                return Ok(self.symbol[(index + (code - first)) as usize]);
            }
            index += cnt;
            first += cnt;
            first <<= 1;
            code <<= 1;
        }
        Err(bad("invalid huffman code"))
    }
}

fn fixed_tables() -> io::Result<(Huffman, Huffman)> {
    let mut litlen = [0u8; 288];
    for (sym, l) in litlen.iter_mut().enumerate() {
        *l = match sym {
            0..=143 => 8,
            144..=255 => 9,
            256..=279 => 7,
            _ => 8,
        };
    }
    let lit = Huffman::new(&litlen)?;
    let dist = Huffman::new(&[5u8; 30])?;
    Ok((lit, dist))
}

/// Order in which dynamic-block code-length code lengths are stored.
const CLCL_ORDER: [usize; 19] =
    [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

fn dynamic_tables(br: &mut BitReader) -> io::Result<(Huffman, Huffman)> {
    let hlit = br.bits(5)? as usize + 257;
    let hdist = br.bits(5)? as usize + 1;
    let hclen = br.bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(bad("dynamic block header out of range"));
    }
    let mut clcl = [0u8; 19];
    for &idx in CLCL_ORDER.iter().take(hclen) {
        clcl[idx] = br.bits(3)? as u8;
    }
    let clh = Huffman::new(&clcl)?;
    let mut lengths: Vec<u8> = Vec::with_capacity(hlit + hdist);
    while lengths.len() < hlit + hdist {
        let sym = clh.decode(br)?;
        match sym {
            0..=15 => lengths.push(sym as u8),
            16 => {
                let Some(&prev) = lengths.last() else {
                    return Err(bad("repeat with no previous length"));
                };
                let n = 3 + br.bits(2)? as usize;
                lengths.extend(std::iter::repeat(prev).take(n));
            }
            17 => {
                let n = 3 + br.bits(3)? as usize;
                lengths.extend(std::iter::repeat(0u8).take(n));
            }
            18 => {
                let n = 11 + br.bits(7)? as usize;
                lengths.extend(std::iter::repeat(0u8).take(n));
            }
            _ => return Err(bad("bad code-length symbol")),
        }
    }
    if lengths.len() != hlit + hdist {
        return Err(bad("code lengths overflow the header counts"));
    }
    let lit = Huffman::new(&lengths[..hlit])?;
    let dist = Huffman::new(&lengths[hlit..])?;
    Ok((lit, dist))
}

/// Inflate a raw DEFLATE stream (no gzip/zlib framing).
pub(crate) fn inflate(data: &[u8]) -> io::Result<Vec<u8>> {
    let mut br = BitReader::new(data);
    let mut out: Vec<u8> = Vec::new();
    loop {
        let bfinal = br.bits(1)?;
        let btype = br.bits(2)?;
        match btype {
            0 => {
                // stored block
                br.align();
                if br.pos + 4 > br.data.len() {
                    return Err(bad("truncated stored-block header"));
                }
                let len =
                    br.data[br.pos] as usize | ((br.data[br.pos + 1] as usize) << 8);
                let nlen =
                    br.data[br.pos + 2] as usize | ((br.data[br.pos + 3] as usize) << 8);
                br.pos += 4;
                if len ^ 0xFFFF != nlen {
                    return Err(bad("stored-block length check failed"));
                }
                if br.pos + len > br.data.len() {
                    return Err(bad("stored block truncated"));
                }
                out.extend_from_slice(&br.data[br.pos..br.pos + len]);
                br.pos += len;
            }
            1 | 2 => {
                let (lit, dist) = if btype == 1 {
                    fixed_tables()?
                } else {
                    dynamic_tables(&mut br)?
                };
                loop {
                    let sym = lit.decode(&mut br)?;
                    if sym < 256 {
                        out.push(sym as u8);
                    } else if sym == 256 {
                        break;
                    } else {
                        let i = sym as usize - 257;
                        if i >= 29 {
                            return Err(bad("invalid length symbol"));
                        }
                        let len = LEN_BASE[i] as usize
                            + br.bits(LEN_EXTRA[i] as u32)? as usize;
                        let ds = dist.decode(&mut br)? as usize;
                        if ds >= 30 {
                            return Err(bad("invalid distance symbol"));
                        }
                        let d = DIST_BASE[ds] as usize
                            + br.bits(DIST_EXTRA[ds] as u32)? as usize;
                        if d > out.len() {
                            return Err(bad("distance beyond output start"));
                        }
                        for _ in 0..len {
                            let b = out[out.len() - d];
                            out.push(b);
                        }
                    }
                }
            }
            _ => return Err(bad("reserved block type")),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}
