//! Shared bench harness utilities (the offline mirror has no criterion —
//! this is the in-repo measurement kit used by all `cargo bench` targets).
//!
//! Measurement statistics, strict environment parsing and the
//! schema-versioned `BENCH_<name>.json` emission live in
//! [`radpipe::bench`]; this module adapts them for the bench targets:
//! dataset generation, artifact discovery and the report plumbing every
//! target shares. Environment knobs are *strict* — a malformed
//! `RADPIPE_BENCH_QUICK` or `RADPIPE_BENCH_SCALE` aborts the bench with a
//! located error instead of silently measuring the wrong dataset.

use std::path::PathBuf;

use anyhow::{Context, Result};

use radpipe::bench::BenchReport;
use radpipe::io::DatasetManifest;
use radpipe::synth::{generate_dataset, GenOptions};

pub use radpipe::bench::{measure, Measurement};

/// True under the CI quick budget (`RADPIPE_BENCH_QUICK`): benches shrink
/// their iteration budgets and problem sizes so every target *runs* (not
/// just compiles) in seconds.
pub fn quick() -> Result<bool> {
    radpipe::bench::quick_mode()
}

/// Iteration budget: `full` normally, 1 in quick mode.
pub fn iters(full: usize) -> Result<usize> {
    Ok(if quick()? { 1 } else { full })
}

/// Vertex-count scale for bench datasets; override with
/// `RADPIPE_BENCH_SCALE` (1.0 = paper scale — hours on this testbed).
/// Quick mode defaults to a much smaller dataset.
pub fn bench_scale() -> Result<f64> {
    radpipe::bench::bench_scale()
}

/// Generate (or reuse) the deterministic bench dataset.
pub fn bench_dataset() -> Result<DatasetManifest> {
    let scale = bench_scale()?;
    let root = PathBuf::from(format!("target/bench-data-{scale}"));
    if root.join("cases.txt").exists() {
        radpipe::io::scan_dataset(&root).context("rescan bench dataset")
    } else {
        eprintln!("generating bench dataset at scale {scale} (once)…");
        generate_dataset(&root, &GenOptions { scale, seed: 7 }).context("generate dataset")
    }
}

/// Artifact dir if `make artifacts` has produced one.
pub fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: no artifacts/ bundle — accelerated columns skipped");
        None
    }
}

/// Simple section banner so `cargo bench | tee` output reads well.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Start this target's schema-versioned report (`name` becomes
/// `BENCH_<name>.json`).
pub fn report(name: &str) -> Result<BenchReport> {
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    Ok(BenchReport::new(name, quick()?, bench_scale()?, threads))
}

/// Write the finished report where CI collects it (`RADPIPE_BENCH_OUT`,
/// default `target/bench-reports`).
pub fn finish(report: &BenchReport) -> Result<()> {
    let path = report.write(&radpipe::bench::out_dir())?;
    println!("bench report: {}", path.display());
    Ok(())
}
