//! Shared bench harness utilities (the offline mirror has no criterion —
//! this is the in-repo measurement kit used by all `cargo bench` targets).

use std::path::PathBuf;
use std::time::Instant;

use radpipe::io::DatasetManifest;
use radpipe::synth::{generate_dataset, GenOptions};

/// True when `RADPIPE_BENCH_QUICK` is set to a non-empty, non-`0` value:
/// the CI bench-smoke mode. Benches shrink their iteration budgets and
/// problem sizes so every target *runs* (not just compiles) in seconds.
pub fn quick() -> bool {
    std::env::var("RADPIPE_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Iteration budget: `full` normally, 1 in quick mode.
pub fn iters(full: usize) -> usize {
    if quick() {
        1
    } else {
        full
    }
}

/// Vertex-count scale for bench datasets; override with
/// `RADPIPE_BENCH_SCALE` (1.0 = paper scale — hours on this testbed).
/// Quick mode defaults to a much smaller dataset.
pub fn bench_scale() -> f64 {
    std::env::var("RADPIPE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick() { 0.004 } else { 0.05 })
}

/// Generate (or reuse) the deterministic bench dataset.
pub fn bench_dataset() -> DatasetManifest {
    let scale = bench_scale();
    let root = PathBuf::from(format!("target/bench-data-{scale}"));
    if root.join("cases.txt").exists() {
        radpipe::io::scan_dataset(&root).expect("rescan bench dataset")
    } else {
        eprintln!("generating bench dataset at scale {scale} (once)…");
        generate_dataset(&root, &GenOptions { scale, seed: 7 }).expect("generate dataset")
    }
}

/// Artifact dir if `make artifacts` has produced one.
pub fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: no artifacts/ bundle — accelerated columns skipped");
        None
    }
}

/// Measure a closure `iters` times; returns (best, mean) seconds.
pub fn measure<F: FnMut()>(iters: usize, mut f: F) -> (f64, f64) {
    let mut best = f64::INFINITY;
    let mut sum = 0.0;
    for _ in 0..iters.max(1) {
        let t = Instant::now();
        f();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt);
        sum += dt;
    }
    (best, sum / iters.max(1) as f64)
}

/// Simple section banner so `cargo bench | tee` output reads well.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}
