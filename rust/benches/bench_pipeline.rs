//! Pipeline ablations (DESIGN.md design choices): channel capacity
//! (backpressure) and worker counts vs end-to-end throughput, CPU path.
//! Results land in `BENCH_bench_pipeline.json` for `radpipe bench-check`.
//!
//! Run: `cargo bench --offline --bench bench_pipeline`

mod common;

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::pipeline::run_pipeline;
use radpipe::report::Table;

fn main() -> anyhow::Result<()> {
    let manifest = common::bench_dataset()?;
    let quick = common::quick()?;
    let queues: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let mut bench = common::report("bench_pipeline")?;

    common::banner("PIPELINE — queue capacity × workers (CPU path, 20 cases)");
    let mut t = Table::new(vec![
        "queue", "read-workers", "feat-workers", "wall[s]", "cases/s",
    ]);
    for &queue in queues {
        for &workers in worker_counts {
            let cfg = PipelineConfig {
                backend: Backend::Cpu,
                cpu_threads: 1,
                queue_capacity: queue,
                read_workers: workers,
                feature_workers: workers,
                ..Default::default()
            };
            let ex = FeatureExtractor::new(&cfg)?;
            let report = run_pipeline(&manifest, &cfg, &ex)?;
            anyhow::ensure!(report.failures.is_empty());
            let wall = report.wall.as_secs_f64();
            let sec = format!("pipeline/queue{queue}/workers{workers}");
            bench.section(&sec, common::Measurement::single(wall));
            t.row(vec![
                queue.to_string(),
                workers.to_string(),
                workers.to_string(),
                format!("{wall:.2}"),
                format!("{:.2}", report.results.len() as f64 / wall),
            ]);
        }
    }
    print!("{}", t.to_text());
    println!("\n(single-core testbed: worker scaling saturates immediately; the");
    println!("ablation exists to show the backpressure knobs work — queue=1 must");
    println!("not deadlock and must stay within ~2x of queue=16)");
    common::finish(&bench)?;
    Ok(())
}
