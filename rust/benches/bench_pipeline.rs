//! Pipeline ablations (DESIGN.md design choices): channel capacity
//! (backpressure) and worker counts vs end-to-end throughput, CPU path;
//! plus the out-of-core leg — slab-streamed vs whole-grid reads on a
//! large-grid/small-ROI dataset, with `mem.peak_pipeline_bytes` recorded
//! per section and the crop-proportional bound hard-asserted.
//! Results land in `BENCH_bench_pipeline.json` for `radpipe bench-check`.
//!
//! Run: `cargo bench --offline --bench bench_pipeline`

mod common;

use std::path::PathBuf;

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::geometry::Vec3;
use radpipe::io::{write_rvol, CaseEntry, DatasetManifest};
use radpipe::pipeline::run_pipeline;
use radpipe::report::Table;
use radpipe::volume::{Dims, VoxelGrid};

/// The slab-IO worst-case-for-whole-reads dataset: big on-disk grids
/// whose ROI crops to a tiny fraction. Three cases, `.rvol.gz`, each with
/// a paired intensity image on the mask grid. Deterministic; generated
/// once and reused across runs.
fn slab_dataset(quick: bool) -> anyhow::Result<DatasetManifest> {
    let dims = if quick {
        Dims::new(96, 96, 120)
    } else {
        Dims::new(144, 144, 192)
    };
    let root = PathBuf::from(format!("target/bench-slab-{}x{}x{}", dims.x, dims.y, dims.z));
    if root.join("cases.txt").exists() {
        return radpipe::io::scan_dataset(&root);
    }
    eprintln!("generating slab bench dataset {} (once)…", dims);
    std::fs::create_dir_all(&root)?;
    let r = 7i64;
    let mut cases = Vec::new();
    for i in 0..3usize {
        let c = ((dims.x / 4 + 9 * i) as i64, (dims.y / 2) as i64, (dims.z / 2 + 5 * i) as i64);
        let mut mask: VoxelGrid<u8> = VoxelGrid::zeros(dims, Vec3::new(0.8, 0.8, 1.5));
        let mut img: VoxelGrid<f32> = VoxelGrid::zeros(dims, Vec3::new(0.8, 0.8, 1.5));
        for z in (c.2 - r)..=(c.2 + r) {
            for y in (c.1 - r)..=(c.1 + r) {
                for x in (c.0 - r)..=(c.0 + r) {
                    let d2 = (x - c.0).pow(2) + (y - c.1).pow(2) + (z - c.2).pow(2);
                    if d2 <= r * r {
                        mask.set(x as usize, y as usize, z as usize, 1);
                    }
                    // integer-valued intensities near the ROI, zero
                    // elsewhere: compresses well, stays bit-exact in f32
                    let v = ((7 * x + 3 * y + 11 * z).rem_euclid(61) - 14) as f32;
                    img.set(x as usize, y as usize, z as usize, v);
                }
            }
        }
        let case_id = format!("slab-{i}");
        let mask_name = format!("{case_id}.rvol.gz");
        let img_name = format!("{case_id}.img.rvol.gz");
        write_rvol(&root.join(&mask_name), &mask)?;
        write_rvol(&root.join(&img_name), &img)?;
        cases.push(CaseEntry {
            case_id,
            mask: mask_name.into(),
            image: Some(img_name.into()),
            dims: Some(dims),
            target_vertices: 0,
            labels: Vec::new(),
        });
    }
    let manifest = DatasetManifest { root, cases };
    manifest.save()?;
    Ok(manifest)
}

fn main() -> anyhow::Result<()> {
    let manifest = common::bench_dataset()?;
    let quick = common::quick()?;
    let queues: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let mut bench = common::report("bench_pipeline")?;

    common::banner("PIPELINE — queue capacity × workers (CPU path, 20 cases)");
    let mut t = Table::new(vec![
        "queue", "read-workers", "feat-workers", "wall[s]", "cases/s",
    ]);
    for &queue in queues {
        for &workers in worker_counts {
            let cfg = PipelineConfig {
                backend: Backend::Cpu,
                cpu_threads: 1,
                queue_capacity: queue,
                read_workers: workers,
                feature_workers: workers,
                ..Default::default()
            };
            let ex = FeatureExtractor::new(&cfg)?;
            let report = run_pipeline(&manifest, &cfg, &ex)?;
            anyhow::ensure!(report.failures.is_empty());
            let wall = report.wall.as_secs_f64();
            let sec = format!("pipeline/queue{queue}/workers{workers}");
            bench.section(&sec, common::Measurement::single(wall));
            t.row(vec![
                queue.to_string(),
                workers.to_string(),
                workers.to_string(),
                format!("{wall:.2}"),
                format!("{:.2}", report.results.len() as f64 / wall),
            ]);
        }
    }
    print!("{}", t.to_text());
    println!("\n(single-core testbed: worker scaling saturates immediately; the");
    println!("ablation exists to show the backpressure knobs work — queue=1 must");
    println!("not deadlock and must stay within ~2x of queue=16)");

    common::banner("PIPELINE — slab-streamed read vs whole-grid read (out-of-core)");
    let slab_manifest = slab_dataset(quick)?;
    let slab_cfg = |slab: bool| PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        feature_classes: radpipe::config::FeatureClasses::parse("shape,firstorder")
            .expect("feature classes"),
        slab_io: slab,
        ..Default::default()
    };

    let whole_cfg = slab_cfg(false);
    let whole_report =
        run_pipeline(&slab_manifest, &whole_cfg, &FeatureExtractor::new(&whole_cfg)?)?;
    anyhow::ensure!(whole_report.failures.is_empty(), "whole-read run failed");
    let whole_wall = whole_report.wall.as_secs_f64();
    let whole_peak = whole_report.metrics.counter("mem.peak_pipeline_bytes").unwrap_or(0);

    let streamed_cfg = slab_cfg(true);
    streamed_cfg.validate()?;
    let slab_report =
        run_pipeline(&slab_manifest, &streamed_cfg, &FeatureExtractor::new(&streamed_cfg)?)?;
    anyhow::ensure!(slab_report.failures.is_empty(), "slab-read run failed");
    let slab_wall = slab_report.wall.as_secs_f64();
    let slab_peak = slab_report.metrics.counter("mem.peak_pipeline_bytes").unwrap_or(0);

    // bit-identity between the two read paths is the bench's correctness
    // gate: it feeds the `bit_exact` flag the baseline insists on
    let identical = whole_report.results.len() == slab_report.results.len()
        && whole_report.results.iter().zip(&slab_report.results).all(|(a, b)| {
            a.case_id == b.case_id
                && a.features == b.features
                && a.first_order == b.first_order
                && a.derived == b.derived
        });
    anyhow::ensure!(identical, "slab-read features diverged from whole-read features");

    // the paper's out-of-core claim, hard-asserted: streaming only the ROI
    // crop must bound the in-flight footprint far below the whole grid
    // (the gate in `bench-check` records peak_bytes but compares walls, so
    // the proportionality bound lives here)
    anyhow::ensure!(whole_peak > 0 && slab_peak > 0, "peak gauge missing");
    anyhow::ensure!(
        slab_peak <= whole_peak / 4,
        "slab peak {slab_peak} B not crop-proportional vs whole {whole_peak} B"
    );

    let mut st = Table::new(vec!["read path", "wall[s]", "peak bytes", "bit-exact"]);
    st.row(vec![
        "whole-grid".into(),
        format!("{whole_wall:.2}"),
        whole_peak.to_string(),
        "-".into(),
    ]);
    st.row(vec![
        "slab-streamed".into(),
        format!("{slab_wall:.2}"),
        slab_peak.to_string(),
        identical.to_string(),
    ]);
    print!("{}", st.to_text());
    println!(
        "\n(slab path materialises only the ROI crop: peak footprint {:.1}x below whole-read)",
        whole_peak as f64 / slab_peak as f64
    );

    bench
        .section("pipeline/read-whole", common::Measurement::single(whole_wall))
        .peak_bytes(whole_peak);
    bench
        .section("pipeline/read-slab", common::Measurement::single(slab_wall))
        .bit_exact(identical)
        .peak_bytes(slab_peak);

    common::finish(&bench)?;
    Ok(())
}
