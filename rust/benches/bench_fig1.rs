//! Fig. 1 regeneration: the five kernel strategies × three GPU models,
//! total time over all input files (the paper plots this on a log axis).
//!
//! Run: `cargo bench --offline --bench bench_fig1`

mod common;

use radpipe::experiments::{fig1, run_fig1};

fn main() -> anyhow::Result<()> {
    // Fig 1's winner pattern is scale-sensitive (H100's memory-term
    // advantage needs ≥ ~30k-vertex cases); use at least 1/8 paper scale.
    // Quick mode keeps the tiny smoke dataset instead (winners are then
    // not meaningful; the run only proves the harness works).
    let scale = if common::quick()? {
        common::bench_scale()?
    } else {
        common::bench_scale()?.max(0.125)
    };
    std::env::set_var("RADPIPE_BENCH_SCALE", scale.to_string());
    // built after the scale override so the report records the real scale
    let mut report = common::report("bench_fig1")?;
    let manifest = common::bench_dataset()?;
    common::banner(&format!(
        "FIG 1 — strategy comparison (scale {scale}, sum over 20 cases)"
    ));
    let t0 = std::time::Instant::now();
    let rows = run_fig1(&manifest, 0)?;
    report.section("fig1/total", common::Measurement::single(t0.elapsed().as_secs_f64()));
    print!("{}", fig1::to_table(&rows).to_text());
    println!("\nwinners (paper: H100→memory-careful, 4070→local accumulators, T4→block reduction):");
    for (dev, s) in fig1::winners(&rows) {
        println!("  {dev}: {}", s.label());
    }
    common::finish(&report)?;
    Ok(())
}
