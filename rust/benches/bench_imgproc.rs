//! Derived-image preprocessing: serial vs parallel filtering on a ≥ 96³
//! volume, plus the end-to-end cost multiplier each added image type puts
//! on a case. The filter passes are line-parallel through
//! `parallel::fold_chunks`; this bench measures how they scale and
//! verifies the determinism contract (parallel == serial bit-for-bit).
//! Results land in `BENCH_bench_imgproc.json` for `radpipe bench-check`.
//!
//! Run: `cargo bench --offline --bench bench_imgproc`
//! Quick mode: `RADPIPE_BENCH_QUICK=1` (CI smoke budget).

mod common;

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::geometry::Vec3;
use radpipe::imgproc::{
    derive_images, for_each_derived_image, gaussian_smooth, haar_decompose, log_filter,
    peak_derived_bytes, reset_peak_derived_bytes, DerivedImage, ImageTypes, ImgprocOptions,
};
use radpipe::parallel::Strategy;
use radpipe::report::Table;
use radpipe::testkit::Pcg32;
use radpipe::volume::{Dims, VoxelGrid};

/// Banded + noisy synthetic volume (structure at several scales, so the
/// filters do representative work).
fn synthetic_volume(n: usize) -> VoxelGrid<f32> {
    let mut img = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
    let mut rng = Pcg32::new(11);
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let v = ((x / 4 + y / 3 + z / 2) % 19) as f64 * 12.0 + rng.normal() * 5.0;
                img.set(x, y, z, v as f32);
            }
        }
    }
    img
}

/// Spherical mask over the central part of an n³ grid.
fn sphere_mask(n: usize) -> VoxelGrid<u8> {
    let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
    let c = n as f64 / 2.0;
    let r = n as f64 * 0.4;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    m.set(x, y, z, 1);
                }
            }
        }
    }
    m
}

fn main() -> anyhow::Result<()> {
    let quick = common::quick()?;
    let n = if quick { 48 } else { 96 };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    let iters = 3; // best-of-3: one-sample timings are flaky on shared CI
    let sigma = 2.0;
    let mut report = common::report("bench_imgproc")?;

    let img = synthetic_volume(n);
    common::banner(&format!(
        "DERIVED-IMAGE FILTERING — {n}³ volume, sigma {sigma} mm, {threads} threads"
    ));

    // serial references (also the determinism baselines)
    let smooth_ref = gaussian_smooth(&img, sigma, Strategy::EqualSplit, 1)?;
    let log_ref = log_filter(&img, sigma, Strategy::EqualSplit, 1)?;
    let haar_ref = haar_decompose(&img, 1, Strategy::EqualSplit, 1)?;
    let m_smooth = common::measure(iters, || {
        std::hint::black_box(gaussian_smooth(&img, sigma, Strategy::EqualSplit, 1).unwrap());
    });
    let m_log = common::measure(iters, || {
        std::hint::black_box(log_filter(&img, sigma, Strategy::EqualSplit, 1).unwrap());
    });
    let m_haar = common::measure(iters, || {
        std::hint::black_box(haar_decompose(&img, 1, Strategy::EqualSplit, 1).unwrap());
    });
    let (s_smooth, s_log, s_haar) = (m_smooth.best, m_log.best, m_haar.best);
    let serial = s_smooth + s_log + s_haar;
    report.section("gauss/serial", m_smooth);
    report.section("log/serial", m_log);
    report.section("haar/serial", m_haar);

    let mut t = Table::new(vec![
        "strategy", "threads", "gauss[ms]", "log[ms]", "haar[ms]", "total[ms]",
        "speedup-vs-serial",
    ]);
    t.row(vec![
        "serial-reference".to_string(),
        "1".to_string(),
        format!("{:.1}", s_smooth * 1e3),
        format!("{:.1}", s_log * 1e3),
        format!("{:.1}", s_haar * 1e3),
        format!("{:.1}", serial * 1e3),
        "1.00".to_string(),
    ]);

    let mut best_parallel = f64::INFINITY;
    for strategy in Strategy::ALL {
        let p_smooth = common::measure(iters, || {
            std::hint::black_box(gaussian_smooth(&img, sigma, strategy, threads).unwrap());
        })
        .best;
        let p_log = common::measure(iters, || {
            std::hint::black_box(log_filter(&img, sigma, strategy, threads).unwrap());
        })
        .best;
        let p_haar = common::measure(iters, || {
            std::hint::black_box(haar_decompose(&img, 1, strategy, threads).unwrap());
        })
        .best;
        let total = p_smooth + p_log + p_haar;
        best_parallel = best_parallel.min(total);
        t.row(vec![
            strategy.label().to_string(),
            threads.to_string(),
            format!("{:.1}", p_smooth * 1e3),
            format!("{:.1}", p_log * 1e3),
            format!("{:.1}", p_haar * 1e3),
            format!("{:.1}", total * 1e3),
            format!("{:.2}", serial / total),
        ]);

        // determinism contract: parallel output equals serial bit-for-bit
        anyhow::ensure!(
            gaussian_smooth(&img, sigma, strategy, threads)? == smooth_ref,
            "gaussian diverged under {strategy:?}"
        );
        anyhow::ensure!(
            log_filter(&img, sigma, strategy, threads)? == log_ref,
            "LoG diverged under {strategy:?}"
        );
        anyhow::ensure!(
            haar_decompose(&img, 1, strategy, threads)? == haar_ref,
            "Haar diverged under {strategy:?}"
        );
        let sec = format!("filters/parallel/{}", strategy.label());
        report.section(&sec, common::Measurement::single(total)).bit_exact(true);
    }
    print!("{}", t.to_text());
    println!("parallel == serial verified bit-for-bit for all 5 strategies");

    if threads >= 2 {
        // quick mode runs on contended shared CI runners where a wall-clock
        // comparison can invert spuriously — report there, assert locally
        if best_parallel < serial {
            println!(
                "best parallel beats serial: {:.1} ms vs {:.1} ms ({:.2}x)",
                best_parallel * 1e3,
                serial * 1e3,
                serial / best_parallel
            );
        } else if quick {
            println!(
                "WARNING: parallel ({:.1} ms) did not beat serial ({:.1} ms) on this \
                 contended quick-mode run",
                best_parallel * 1e3,
                serial * 1e3
            );
        } else {
            anyhow::bail!(
                "expected parallel filtering ({:.1} ms) to beat serial ({:.1} ms) \
                 with {threads} threads",
                best_parallel * 1e3,
                serial * 1e3
            );
        }
    } else {
        println!("single-core machine: speedup assertion skipped");
    }

    // ---- streaming vs materialised derived-image flow -------------------
    let opts = ImgprocOptions {
        image_types: ImageTypes::parse("all")?,
        log_sigmas: vec![1.0, 2.0],
        wavelet_levels: 2,
        strategy: Strategy::LocalAccumulators,
        threads,
    };
    let n_derived = opts.image_types.image_count(opts.log_sigmas.len(), opts.wavelet_levels);
    let vol_bytes = (img.dims.len() * std::mem::size_of::<f32>()) as u64;
    let mib = |b: u64| b as f64 / (1024.0 * 1024.0);
    common::banner(&format!(
        "STREAMING VS MATERIALISED — {n}³ volume, {n_derived} derived images \
         (all types, 2 sigmas, 2 wavelet levels), one volume = {:.1} MiB",
        mib(vol_bytes)
    ));

    // the visitor must emit exactly the collect-based list, bit for bit
    let want = derive_images(&img, &opts)?;
    let mut got: Vec<DerivedImage> = Vec::new();
    let stats = for_each_derived_image(&img, &opts, |d| {
        got.push(DerivedImage { name: d.name, image: d.image.clone() });
        Ok(())
    })?;
    anyhow::ensure!(got == want, "streaming must match materialised bit-for-bit");
    drop(got);
    drop(want);

    reset_peak_derived_bytes();
    let m_mat = common::measure(iters, || {
        std::hint::black_box(derive_images(&img, &opts).unwrap());
    });
    let t_mat = m_mat.best;
    let peak_mat = peak_derived_bytes();

    reset_peak_derived_bytes();
    let mut sink = 0.0f64;
    let m_stream = common::measure(iters, || {
        // touch each volume the way a feature pass would, then drop it
        for_each_derived_image(&img, &opts, |d| {
            sink += d.image.data()[d.image.dims.len() / 2] as f64;
            Ok(())
        })
        .unwrap();
    });
    let t_stream = m_stream.best;
    let peak_stream = peak_derived_bytes();
    std::hint::black_box(sink);
    report.section("derived/materialised", m_mat).peak_bytes(peak_mat);
    report.section("derived/streaming", m_stream).peak_bytes(peak_stream).bit_exact(true);

    let mut t = Table::new(vec!["mode", "wall[ms]", "peak derived[MiB]", "volumes"]);
    t.row(vec![
        "materialised".to_string(),
        format!("{:.1}", t_mat * 1e3),
        format!("{:.1}", mib(peak_mat)),
        format!("{:.1}", peak_mat as f64 / vol_bytes as f64),
    ]);
    t.row(vec![
        "streaming".to_string(),
        format!("{:.1}", t_stream * 1e3),
        format!("{:.1}", mib(peak_stream)),
        format!("{:.1}", peak_stream as f64 / vol_bytes as f64),
    ]);
    print!("{}", t.to_text());
    println!(
        "streaming caps residency at {:.1} volumes (target <= 3) vs {:.1} materialised",
        peak_stream as f64 / vol_bytes as f64,
        peak_mat as f64 / vol_bytes as f64
    );
    // the memory contract, measured (the bench runs single-threaded, so
    // the process-wide meter is exactly this leg's residency)
    anyhow::ensure!(
        stats.peak_resident_bytes <= 3 * vol_bytes,
        "streaming residency {} bytes exceeds 3 volumes ({})",
        stats.peak_resident_bytes,
        3 * vol_bytes
    );
    anyhow::ensure!(
        peak_stream <= 3 * vol_bytes,
        "streaming peak {} bytes exceeds 3 volumes ({})",
        peak_stream,
        3 * vol_bytes
    );
    anyhow::ensure!(
        peak_mat >= n_derived as u64 * vol_bytes,
        "materialised peak {} bytes should cover the whole {n_derived}-volume bank",
        peak_mat
    );

    // ---- end-to-end cost multiplier per added image type ----------------
    let roi = if quick { 24 } else { 40 };
    let mask = sphere_mask(roi);
    common::banner(&format!(
        "END-TO-END COST PER IMAGE TYPE — {roi}³ case, features=all, 2 LoG sigmas"
    ));
    let mut t = Table::new(vec![
        "image_types", "derived", "preprocess[ms]", "texture[ms]", "total[ms]",
        "vs-original",
    ]);
    let mut base = 0.0f64;
    for types in ["original", "original,log", "all"] {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            feature_classes: radpipe::config::FeatureClasses::parse("all").unwrap(),
            image_types: radpipe::imgproc::ImageTypes::parse(types).unwrap(),
            log_sigmas: vec![1.0, 2.0],
            cpu_threads: threads,
            // this bench drives a bare mask; the stand-in needs the opt-in
            synthetic_image: true,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg)?;
        let mut derived = 0usize;
        let mut preprocess = 0.0f64;
        let mut texture = 0.0f64;
        let m_wall = common::measure(iters, || {
            let out = ex.execute_mask(&mask).unwrap();
            derived = out.derived.len();
            preprocess = out.timing.preprocess.as_secs_f64();
            texture = out.timing.texture.as_secs_f64();
        });
        let wall = m_wall.best;
        report.section(&format!("endtoend/{types}"), m_wall);
        if types == "original" {
            base = wall;
        }
        t.row(vec![
            types.to_string(),
            derived.to_string(),
            format!("{:.1}", preprocess * 1e3),
            format!("{:.1}", texture * 1e3),
            format!("{:.1}", wall * 1e3),
            format!("{:.2}x", wall / base),
        ]);
    }
    print!("{}", t.to_text());
    println!(
        "each added image type re-runs first-order + all five texture classes on its \
         derived images"
    );
    common::finish(&report)?;
    Ok(())
}
