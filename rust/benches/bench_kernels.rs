//! Micro-benchmarks feeding EXPERIMENTS.md §Perf:
//!   * mesher throughput (fused MT walk) vs volume size,
//!   * CPU diameter strategies vs vertex count,
//!   * PJRT artifact execution per bucket (transfer vs execute split).
//!
//! Results land in `BENCH_bench_kernels.json` for `radpipe bench-check`
//! (PJRT sections only when an `artifacts/` bundle is present).
//!
//! Run: `cargo bench --offline --bench bench_kernels`

mod common;

use radpipe::features::brute_force_diameters;
use radpipe::geometry::Vec3;
use radpipe::mc::mesh_roi;
use radpipe::parallel::{compute_diameters, Strategy};
use radpipe::report::Table;
use radpipe::runtime::Engine;
use radpipe::testkit::Pcg32;
use radpipe::volume::{Dims, VoxelGrid};

fn sphere(n: usize, r: f64) -> VoxelGrid<u8> {
    let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
    let c = n as f64 / 2.0;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    m.set(x, y, z, 1);
                }
            }
        }
    }
    m
}

fn cloud(n: usize) -> Vec<Vec3> {
    let mut rng = Pcg32::new(42);
    (0..n)
        .map(|_| {
            Vec3::new(
                rng.range_f64(0.0, 100.0),
                rng.range_f64(0.0, 100.0),
                (rng.below(64) as f64) * 1.5, // quantised z planes
            )
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let quick = common::quick()?;
    let mesher_sizes: &[usize] = if quick { &[16, 24] } else { &[32, 64, 96] };
    let diam_sizes: &[usize] = if quick { &[500, 1500] } else { &[2000, 8000, 16000] };
    let mut report = common::report("bench_kernels")?;

    common::banner("MESHER — fused marching-tetrahedra walk");
    let mut t = Table::new(vec!["volume", "voxels", "verts", "best[ms]", "Mcells/s"]);
    for &n in mesher_sizes {
        let mask = sphere(n, n as f64 * 0.4);
        let mesh = mesh_roi(&mask); // warm result for the verts column
        let m = common::measure(common::iters(3)?, || {
            std::hint::black_box(mesh_roi(&mask));
        });
        let best = m.best;
        report.section(&format!("mesher/{n}^3"), m);
        let cells = (n - 1).pow(3) as f64;
        t.row(vec![
            format!("{n}^3"),
            n.pow(3).to_string(),
            mesh.vertices.len().to_string(),
            format!("{:.1}", best * 1e3),
            format!("{:.1}", cells / best / 1e6),
        ]);
    }
    print!("{}", t.to_text());

    common::banner("DIAMETER — CPU strategies (Mpairs/s, this machine)");
    let mut t = Table::new(vec!["N", "strategy", "best[ms]", "Mpairs/s"]);
    for &n in diam_sizes {
        let v = cloud(n);
        let pairs = (n as f64) * (n as f64 + 1.0) / 2.0;
        // brute-force single-thread reference first
        let m = common::measure(common::iters(2)?, || {
            std::hint::black_box(brute_force_diameters(&v));
        });
        let best = m.best;
        report.section(&format!("diam/{n}/brute"), m);
        t.row(vec![
            n.to_string(),
            "0-brute-single-thread".into(),
            format!("{:.1}", best * 1e3),
            format!("{:.1}", pairs / best / 1e6),
        ]);
        for s in Strategy::ALL {
            let m = common::measure(common::iters(2)?, || {
                std::hint::black_box(compute_diameters(s, &v, 0));
            });
            let best = m.best;
            report.section(&format!("diam/{n}/{}", s.label()), m);
            t.row(vec![
                n.to_string(),
                s.label().into(),
                format!("{:.1}", best * 1e3),
                format!("{:.1}", pairs / best / 1e6),
            ]);
        }
    }
    print!("{}", t.to_text());

    if let Some(dir) = common::artifact_dir() {
        common::banner("PJRT ARTIFACTS — diameter kernel per bucket");
        let engine = Engine::start(&dir)?;
        let mut t = Table::new(vec![
            "bucket", "compile[ms]", "transfer[ms]", "execute[ms]", "Mpairs/s",
        ]);
        for bucket in [512usize, 2048, 8192, 16384] {
            let v = cloud(bucket);
            let verts: Vec<f32> = v.iter().flat_map(|p| p.to_f32()).collect();
            let (_, first) = engine.handle().diameters(verts.clone())?;
            // measured run (cache warm)
            let (_, timing) = engine.handle().diameters(verts.clone())?;
            let exec = timing.execute.as_secs_f64();
            let sec = format!("pjrt-diam/{bucket}");
            report.section(&sec, common::Measurement::single(exec));
            let pairs = (bucket as f64) * (bucket as f64 + 1.0) / 2.0;
            t.row(vec![
                bucket.to_string(),
                format!("{:.0}", first.compile.as_secs_f64() * 1e3),
                format!("{:.2}", timing.transfer.as_secs_f64() * 1e3),
                format!("{:.1}", timing.execute.as_secs_f64() * 1e3),
                format!("{:.1}", pairs / timing.execute.as_secs_f64() / 1e6),
            ]);
        }
        print!("{}", t.to_text());

        common::banner("PJRT ARTIFACTS — mesh_stats kernel per bucket");
        let mut t = Table::new(vec!["bucket", "transfer[ms]", "execute[ms]", "Mtris/s"]);
        for bucket in [1024usize, 16384, 65536] {
            let tris = vec![0.5f32; bucket * 9];
            let _ = engine.handle().mesh_stats(tris.clone())?;
            let (_, timing) = engine.handle().mesh_stats(tris.clone())?;
            let exec = timing.execute.as_secs_f64();
            let sec = format!("pjrt-mesh/{bucket}");
            report.section(&sec, common::Measurement::single(exec));
            t.row(vec![
                bucket.to_string(),
                format!("{:.2}", timing.transfer.as_secs_f64() * 1e3),
                format!("{:.2}", timing.execute.as_secs_f64() * 1e3),
                format!("{:.1}", bucket as f64 / timing.execute.as_secs_f64() / 1e6),
            ]);
        }
        print!("{}", t.to_text());
    }
    common::finish(&report)?;
    Ok(())
}
