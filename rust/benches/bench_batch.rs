//! Batched vs per-case dispatch overhead (the Table-2 "small ROI" gap).
//!
//! The engine round-trip has a fixed per-request cost (channel hop, request
//! bookkeeping, launch latency). This bench drives the real batch scheduler
//! with a CPU loopback backend whose per-*group* overhead stands in for
//! that fixed cost, and measures end-to-end wall time for a stream of small
//! cases dispatched per-case (batch=1) vs batched (batch ≥ 4).
//! Results land in `BENCH_bench_batch.json` for `radpipe bench-check`.
//!
//! Run: `cargo bench --offline --bench bench_batch`

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};

use radpipe::features::brute_force_diameters;
use radpipe::geometry::Vec3;
use radpipe::report::Table;
use radpipe::runtime::{BatchConfig, Batcher, CpuLoopbackBackend};
use radpipe::testkit::Pcg32;

/// Synthetic small-ROI vertex sets (f32[n,3] flattened).
fn cases(count: usize, verts_per_case: usize) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(2024);
    (0..count)
        .map(|_| {
            (0..verts_per_case * 3)
                .map(|_| (rng.below(200) as f32) * 0.5)
                .collect()
        })
        .collect()
}

/// Run every case through a batcher from `workers` submitter threads;
/// returns (wall seconds, per-case diameters).
fn run(
    batch_size: usize,
    workers: usize,
    overhead: Duration,
    inputs: &[Vec<f32>],
) -> (f64, Vec<[f64; 4]>) {
    let batcher = Batcher::new(
        Arc::new(CpuLoopbackBackend::new(overhead)),
        BatchConfig { batch_size, linger: Duration::from_millis(2) },
    );
    let next = std::sync::atomic::AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut results: Vec<(usize, [f64; 4])> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let batcher = &batcher;
                let next = &next;
                scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= inputs.len() {
                            break;
                        }
                        let (d, _) = batcher.diameters(inputs[i].clone()).unwrap();
                        out.push((i, d.as_array()));
                    }
                    out
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    results.sort_by_key(|(i, _)| *i);
    (wall, results.into_iter().map(|(_, d)| d).collect())
}

fn main() -> anyhow::Result<()> {
    let quick = common::quick()?;
    let n_cases = if quick { 32 } else { 64 };
    let verts = if quick { 150 } else { 300 }; // small-ROI regime
    let overhead = Duration::from_micros(500);
    let workers = 8;
    let inputs = cases(n_cases, verts);
    let mut report = common::report("bench_batch")?;

    // ground truth for the conformance check
    let oracle: Vec<[f64; 4]> = inputs
        .iter()
        .map(|v| {
            let pts: Vec<Vec3> =
                v.chunks_exact(3).map(|c| Vec3::from([c[0], c[1], c[2]])).collect();
            brute_force_diameters(&pts).as_array()
        })
        .collect();

    common::banner(&format!(
        "BATCH DISPATCH — {n_cases} cases × {verts} verts, {workers} workers, \
         {:.0} µs fixed cost per engine round-trip",
        overhead.as_secs_f64() * 1e6
    ));
    let mut t = Table::new(vec![
        "batch-size", "wall[ms]", "per-case[ms]", "round-trips", "speedup-vs-1",
    ]);
    let (base_wall, base_out) = run(1, workers, overhead, &inputs);
    anyhow::ensure!(base_out == oracle, "per-case dispatch diverged from brute force");
    report.section("batch/size-1", common::Measurement::single(base_wall)).bit_exact(true);
    t.row(vec![
        "1".to_string(),
        format!("{:.1}", base_wall * 1e3),
        format!("{:.3}", base_wall * 1e3 / n_cases as f64),
        n_cases.to_string(),
        "1.00".to_string(),
    ]);

    let mut batched_beats_per_case = false;
    for batch in [4usize, 8, 16] {
        let (wall, out) = run(batch, workers, overhead, &inputs);
        anyhow::ensure!(out == oracle, "batched dispatch diverged (batch={batch})");
        report
            .section(&format!("batch/size-{batch}"), common::Measurement::single(wall))
            .bit_exact(true)
            .speedup(base_wall / wall);
        if batch >= 4 && wall < base_wall {
            batched_beats_per_case = true;
        }
        t.row(vec![
            batch.to_string(),
            format!("{:.1}", wall * 1e3),
            format!("{:.3}", wall * 1e3 / n_cases as f64),
            n_cases.div_ceil(batch).to_string(),
            format!("{:.2}", base_wall / wall),
        ]);
    }
    print!("{}", t.to_text());
    println!(
        "\nbatched == unbatched verified bit-for-bit on all {n_cases} cases; \
         batching amortises the fixed round-trip across each pad-bucket group"
    );
    anyhow::ensure!(
        batched_beats_per_case,
        "expected batch sizes >= 4 to beat per-case dispatch"
    );
    common::finish(&report)?;
    Ok(())
}
