//! Fig. 2 regeneration. LEFT: per-case 3D-feature time across the six
//! machine configurations (log-log in the paper). RIGHT: speedup over the
//! Intel Xeon baseline.
//!
//! Run: `cargo bench --offline --bench bench_fig2`

mod common;

use radpipe::experiments::{fig2, run_fig2};

fn main() -> anyhow::Result<()> {
    let manifest = common::bench_dataset()?;
    let mut report = common::report("bench_fig2")?;
    common::banner(&format!("FIG 2 LEFT+RIGHT (scale {})", common::bench_scale()?));
    let t0 = std::time::Instant::now();
    let rows = run_fig2(&manifest)?;
    report.section("fig2/total", common::Measurement::single(t0.elapsed().as_secs_f64()));
    print!("{}", fig2::to_table(&rows).to_text());

    // summary: speedup bands per GPU (the paper's 8–24× T4, ≥50×/2000× H100)
    common::banner("speedup bands vs Intel Xeon (paper: T4 8-24x, H100 50-2000x)");
    for dev in ["NVIDIA T4", "NVIDIA RTX 4070", "NVIDIA H100"] {
        let s: Vec<f64> = rows
            .iter()
            .filter(|r| r.machine.contains(dev))
            .map(|r| r.speedup_vs_xeon)
            .collect();
        let min = s.iter().copied().fold(f64::INFINITY, f64::min);
        let max = s.iter().copied().fold(0.0f64, f64::max);
        println!("  {dev}: {min:.1}x .. {max:.1}x");
    }
    common::finish(&report)?;
    Ok(())
}
