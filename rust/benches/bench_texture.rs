//! Texture-matrix accumulation: serial vs parallel on a ≥ 64³ synthetic
//! ROI, across all five matrix classes (GLCM, GLRLM, GLSZM, GLDM, NGTDM).
//! The per-voxel matrix loops are the workload PRs 2 and 5 open for
//! acceleration; this bench measures how the chunked per-thread partial
//! matrices scale and verifies the deterministic-accumulation contract
//! (parallel == serial bit-for-bit; GLSZM's serial flood fill is repeated
//! to confirm run-to-run identity).
//!
//! Run: `cargo bench --offline --bench bench_texture`
//! Quick mode: `RADPIPE_BENCH_QUICK=1` (CI smoke budget).

mod common;

use radpipe::features::texture::{
    accumulate_glcm, accumulate_gldm, accumulate_glrlm, accumulate_glszm,
    accumulate_ngtdm, discretize, glcm_features, gldm_features, glrlm_features,
    glszm_features, ngtdm_features, Discretization,
};
use radpipe::geometry::Vec3;
use radpipe::parallel::Strategy;
use radpipe::report::Table;
use radpipe::testkit::Pcg32;
use radpipe::volume::{Dims, VoxelGrid};

/// Spherical ROI of edge `n` with a banded + noisy intensity pattern —
/// enough gray-level structure that the matrices are dense.
fn synthetic_case(n: usize) -> (VoxelGrid<f32>, VoxelGrid<u8>) {
    let dims = Dims::new(n, n, n);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut rng = Pcg32::new(7);
    let c = n as f64 / 2.0;
    let r = n as f64 * 0.45;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let v = ((x / 3 + y / 2 + z) % 24) as f64 * 10.0 + rng.normal() * 6.0;
                img.set(x, y, z, v as f32);
                let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    mask.set(x, y, z, 1);
                }
            }
        }
    }
    (img, mask)
}

fn main() -> anyhow::Result<()> {
    let n = if common::quick() { 64 } else { 96 };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    // best-of-3 even in quick mode: the serial-vs-parallel assertion below
    // would be flaky on one-sample timings from a contended CI runner, and
    // the quick volume keeps three iterations well under a second
    let iters = 3;
    let distances = [1usize, 2];
    let gldm_alpha = 0.0;

    let (img, mask) = synthetic_case(n);
    let roi = discretize(&img, &mask, Discretization::BinCount(16))?
        .expect("non-empty synthetic ROI");
    common::banner(&format!(
        "TEXTURE ACCUMULATION — {n}³ volume, {} ROI voxels, Ng={}, {} angles × {} \
         distances, {threads} threads, 5 matrix classes",
        roi.n_voxels,
        roi.ng,
        radpipe::features::texture::ANGLES_13.len(),
        distances.len(),
    ));

    // serial reference (1 thread, static split)
    let glcm_ref = accumulate_glcm(&roi, &distances, Strategy::EqualSplit, 1);
    let glrlm_ref = accumulate_glrlm(&roi, Strategy::EqualSplit, 1);
    let glszm_ref = accumulate_glszm(&roi);
    let gldm_ref = accumulate_gldm(&roi, gldm_alpha, Strategy::EqualSplit, 1);
    let ngtdm_ref = accumulate_ngtdm(&roi, Strategy::EqualSplit, 1);
    let (serial_glcm, _) = common::measure(iters, || {
        std::hint::black_box(accumulate_glcm(&roi, &distances, Strategy::EqualSplit, 1));
    });
    let (serial_glrlm, _) = common::measure(iters, || {
        std::hint::black_box(accumulate_glrlm(&roi, Strategy::EqualSplit, 1));
    });
    let (serial_gldm, _) = common::measure(iters, || {
        std::hint::black_box(accumulate_gldm(&roi, gldm_alpha, Strategy::EqualSplit, 1));
    });
    let (serial_ngtdm, _) = common::measure(iters, || {
        std::hint::black_box(accumulate_ngtdm(&roi, Strategy::EqualSplit, 1));
    });
    // GLSZM is serial-by-design (deterministic flood fill): measured once
    // here, outside the strategy table
    let (glszm_wall, _) = common::measure(iters, || {
        std::hint::black_box(accumulate_glszm(&roi));
    });
    let serial = serial_glcm + serial_glrlm + serial_gldm + serial_ngtdm;

    let mut t = Table::new(vec![
        "strategy",
        "threads",
        "glcm[ms]",
        "glrlm[ms]",
        "gldm[ms]",
        "ngtdm[ms]",
        "total[ms]",
        "speedup-vs-serial",
    ]);
    t.row(vec![
        "serial-reference".to_string(),
        "1".to_string(),
        format!("{:.1}", serial_glcm * 1e3),
        format!("{:.1}", serial_glrlm * 1e3),
        format!("{:.1}", serial_gldm * 1e3),
        format!("{:.1}", serial_ngtdm * 1e3),
        format!("{:.1}", serial * 1e3),
        "1.00".to_string(),
    ]);

    let mut best_parallel = f64::INFINITY;
    for strategy in Strategy::ALL {
        let (p_glcm, _) = common::measure(iters, || {
            std::hint::black_box(accumulate_glcm(&roi, &distances, strategy, threads));
        });
        let (p_glrlm, _) = common::measure(iters, || {
            std::hint::black_box(accumulate_glrlm(&roi, strategy, threads));
        });
        let (p_gldm, _) = common::measure(iters, || {
            std::hint::black_box(accumulate_gldm(&roi, gldm_alpha, strategy, threads));
        });
        let (p_ngtdm, _) = common::measure(iters, || {
            std::hint::black_box(accumulate_ngtdm(&roi, strategy, threads));
        });
        let total = p_glcm + p_glrlm + p_gldm + p_ngtdm;
        best_parallel = best_parallel.min(total);
        t.row(vec![
            strategy.label().to_string(),
            threads.to_string(),
            format!("{:.1}", p_glcm * 1e3),
            format!("{:.1}", p_glrlm * 1e3),
            format!("{:.1}", p_gldm * 1e3),
            format!("{:.1}", p_ngtdm * 1e3),
            format!("{:.1}", total * 1e3),
            format!("{:.2}", serial / total),
        ]);

        // determinism contract: parallel matrices equal the serial ones
        let g = accumulate_glcm(&roi, &distances, strategy, threads);
        anyhow::ensure!(g == glcm_ref, "GLCM diverged under {strategy:?}");
        let r = accumulate_glrlm(&roi, strategy, threads);
        anyhow::ensure!(r == glrlm_ref, "GLRLM diverged under {strategy:?}");
        let d = accumulate_gldm(&roi, gldm_alpha, strategy, threads);
        anyhow::ensure!(d == gldm_ref, "GLDM diverged under {strategy:?}");
        let m = accumulate_ngtdm(&roi, strategy, threads);
        anyhow::ensure!(m == ngtdm_ref, "NGTDM diverged under {strategy:?}");
    }
    anyhow::ensure!(accumulate_glszm(&roi) == glszm_ref, "GLSZM diverged across runs");
    print!("{}", t.to_text());
    println!("glszm (serial flood fill): {:.1} ms", glszm_wall * 1e3);

    let fg = glcm_features(&glcm_ref).expect("dense GLCM");
    let fr = glrlm_features(&glrlm_ref).expect("dense GLRLM");
    let fz = glszm_features(&glszm_ref).expect("dense GLSZM");
    let fd = gldm_features(&gldm_ref).expect("dense GLDM");
    let fm = ngtdm_features(&ngtdm_ref).expect("dense NGTDM");
    println!(
        "\nGLCM contrast {:.4}, joint entropy {:.4}; GLRLM RP {:.4}, SRE {:.4}",
        fg.contrast, fg.joint_entropy, fr.run_percentage, fr.short_run_emphasis
    );
    println!(
        "GLSZM ZP {:.4}, ZE {:.4}; GLDM SDE {:.4}, DE {:.4}; NGTDM coarseness {:.6}, \
         busyness {:.4}",
        fz.zone_percentage,
        fz.zone_entropy,
        fd.small_dependence_emphasis,
        fd.dependence_entropy,
        fm.coarseness,
        fm.busyness
    );
    println!("parallel == serial verified bit-for-bit for all 5 strategies × 5 classes");

    if threads >= 2 {
        // quick mode runs on contended shared CI runners where a wall-clock
        // comparison can invert spuriously — report there, assert locally
        if best_parallel < serial {
            println!(
                "best parallel beats serial: {:.1} ms vs {:.1} ms ({:.2}x)",
                best_parallel * 1e3,
                serial * 1e3,
                serial / best_parallel
            );
        } else if common::quick() {
            println!(
                "WARNING: parallel ({:.1} ms) did not beat serial ({:.1} ms) on this \
                 contended quick-mode run",
                best_parallel * 1e3,
                serial * 1e3
            );
        } else {
            anyhow::bail!(
                "expected parallel accumulation ({:.1} ms) to beat serial ({:.1} ms) \
                 with {threads} threads",
                best_parallel * 1e3,
                serial * 1e3
            );
        }
    } else {
        println!("single-core machine: speedup assertion skipped");
    }
    Ok(())
}
