//! Texture-matrix accumulation: serial vs parallel on a ≥ 64³ synthetic
//! ROI, across all five matrix classes (GLCM, GLRLM, GLSZM, GLDM, NGTDM).
//! The per-voxel matrix loops are the workload PRs 2 and 5 open for
//! acceleration; this bench measures the two hot-path rewrites of this
//! tree — the single-pass probe-table GLCM vs its bounds-checked
//! reference, and the level-parallel indexed GLSZM vs the serial flood
//! fill — plus how the chunked per-thread partial matrices scale, and
//! verifies every determinism contract (parallel == serial bit-for-bit).
//! Results land in `BENCH_bench_texture.json` for `radpipe bench-check`.
//!
//! Run: `cargo bench --offline --bench bench_texture`
//! Quick mode: `RADPIPE_BENCH_QUICK=1` (CI smoke budget).

mod common;

use radpipe::features::texture::{
    accumulate_glcm, accumulate_glcm_reference, accumulate_gldm, accumulate_glrlm,
    accumulate_glszm, accumulate_glszm_indexed, accumulate_ngtdm, discretize, glcm_features,
    gldm_features, glrlm_features, glszm_features, ngtdm_features, Discretization,
};
use radpipe::geometry::Vec3;
use radpipe::parallel::Strategy;
use radpipe::report::Table;
use radpipe::testkit::Pcg32;
use radpipe::volume::{Dims, VoxelGrid};

/// Spherical ROI of edge `n` with a banded + noisy intensity pattern —
/// enough gray-level structure that the matrices are dense.
fn synthetic_case(n: usize) -> (VoxelGrid<f32>, VoxelGrid<u8>) {
    let dims = Dims::new(n, n, n);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut rng = Pcg32::new(7);
    let c = n as f64 / 2.0;
    let r = n as f64 * 0.45;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let v = ((x / 3 + y / 2 + z) % 24) as f64 * 10.0 + rng.normal() * 6.0;
                img.set(x, y, z, v as f32);
                let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    mask.set(x, y, z, 1);
                }
            }
        }
    }
    (img, mask)
}

fn main() -> anyhow::Result<()> {
    let quick = common::quick()?;
    let n = if quick { 64 } else { 96 };
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    // best-of-3 even in quick mode: the serial-vs-parallel assertion below
    // would be flaky on one-sample timings from a contended CI runner, and
    // the quick volume keeps three iterations well under a second
    let iters = 3;
    let distances = [1usize, 2];
    let gldm_alpha = 0.0;
    let mut report = common::report("bench_texture")?;

    let (img, mask) = synthetic_case(n);
    let roi = discretize(&img, &mask, Discretization::BinCount(16))?
        .expect("non-empty synthetic ROI");
    common::banner(&format!(
        "TEXTURE ACCUMULATION — {n}³ volume, {} ROI voxels, Ng={}, {} angles × {} \
         distances, {threads} threads, 5 matrix classes",
        roi.n_voxels,
        roi.ng,
        radpipe::features::texture::ANGLES_13.len(),
        distances.len(),
    ));

    // serial references (also the determinism baselines)
    let glcm_ref = accumulate_glcm_reference(&roi, &distances, Strategy::EqualSplit, 1);
    let glrlm_ref = accumulate_glrlm(&roi, Strategy::EqualSplit, 1);
    let glszm_ref = accumulate_glszm(&roi);
    let gldm_ref = accumulate_gldm(&roi, gldm_alpha, Strategy::EqualSplit, 1);
    let ngtdm_ref = accumulate_ngtdm(&roi, Strategy::EqualSplit, 1);

    // ---- win 1: single-pass probe-table GLCM vs bounds-checked reference
    let m_glcm_ref = common::measure(iters, || {
        let m = accumulate_glcm_reference(&roi, &distances, Strategy::EqualSplit, 1);
        std::hint::black_box(m);
    });
    let m_glcm_new = common::measure(iters, || {
        std::hint::black_box(accumulate_glcm(&roi, &distances, Strategy::EqualSplit, 1));
    });
    anyhow::ensure!(
        accumulate_glcm(&roi, &distances, Strategy::EqualSplit, 1) == glcm_ref,
        "single-pass GLCM diverged from the reference"
    );
    let glcm_win = m_glcm_ref.best / m_glcm_new.best;
    report.section("glcm/reference/serial", m_glcm_ref);
    report.section("glcm/single-pass/serial", m_glcm_new).bit_exact(true).speedup(glcm_win);
    println!(
        "glcm single-pass: {:.1} ms vs reference {:.1} ms ({glcm_win:.2}x)",
        m_glcm_new.best * 1e3,
        m_glcm_ref.best * 1e3
    );
    if quick {
        if glcm_win < 1.2 {
            println!(
                "WARNING: single-pass GLCM win {glcm_win:.2}x < 1.2x on this contended quick run"
            );
        }
    } else {
        anyhow::ensure!(
            glcm_win >= 1.2,
            "expected single-pass GLCM >= 1.2x the reference at {n}^3, got {glcm_win:.2}x"
        );
    }

    let m_glrlm = common::measure(iters, || {
        std::hint::black_box(accumulate_glrlm(&roi, Strategy::EqualSplit, 1));
    });
    let m_gldm = common::measure(iters, || {
        std::hint::black_box(accumulate_gldm(&roi, gldm_alpha, Strategy::EqualSplit, 1));
    });
    let m_ngtdm = common::measure(iters, || {
        std::hint::black_box(accumulate_ngtdm(&roi, Strategy::EqualSplit, 1));
    });
    report.section("glrlm/serial", m_glrlm);
    report.section("gldm/serial", m_gldm);
    report.section("ngtdm/serial", m_ngtdm);
    let serial = m_glcm_new.best + m_glrlm.best + m_gldm.best + m_ngtdm.best;

    // ---- win 2: level-parallel indexed GLSZM vs the serial flood fill
    let m_glszm_ref = common::measure(iters, || {
        std::hint::black_box(accumulate_glszm(&roi));
    });
    let m_glszm_idx = common::measure(iters, || {
        std::hint::black_box(accumulate_glszm_indexed(&roi, 1));
    });
    let m_glszm_par = common::measure(iters, || {
        std::hint::black_box(accumulate_glszm_indexed(&roi, threads));
    });
    anyhow::ensure!(accumulate_glszm_indexed(&roi, 1) == glszm_ref, "indexed GLSZM diverged");
    anyhow::ensure!(
        accumulate_glszm_indexed(&roi, threads) == glszm_ref,
        "parallel indexed GLSZM diverged"
    );
    let glszm_win = m_glszm_ref.best / m_glszm_par.best;
    report.section("glszm/reference/serial", m_glszm_ref);
    report.section("glszm/indexed/serial", m_glszm_idx).bit_exact(true);
    report.section("glszm/indexed/parallel", m_glszm_par).bit_exact(true).speedup(glszm_win);
    println!(
        "glszm level-parallel: {:.1} ms vs serial flood fill {:.1} ms ({glszm_win:.2}x)",
        m_glszm_par.best * 1e3,
        m_glszm_ref.best * 1e3
    );
    if threads >= 2 {
        if quick {
            if glszm_win < 1.1 {
                println!(
                    "WARNING: level-parallel GLSZM win {glszm_win:.2}x < 1.1x on this quick run"
                );
            }
        } else {
            anyhow::ensure!(
                glszm_win >= 1.1,
                "expected level-parallel GLSZM >= 1.1x serial, got {glszm_win:.2}x"
            );
        }
    }

    let mut t = Table::new(vec![
        "strategy",
        "threads",
        "glcm[ms]",
        "glrlm[ms]",
        "gldm[ms]",
        "ngtdm[ms]",
        "total[ms]",
        "speedup-vs-serial",
    ]);
    t.row(vec![
        "serial-reference".to_string(),
        "1".to_string(),
        format!("{:.1}", m_glcm_new.best * 1e3),
        format!("{:.1}", m_glrlm.best * 1e3),
        format!("{:.1}", m_gldm.best * 1e3),
        format!("{:.1}", m_ngtdm.best * 1e3),
        format!("{:.1}", serial * 1e3),
        "1.00".to_string(),
    ]);

    let mut best_parallel = f64::INFINITY;
    for strategy in Strategy::ALL {
        let p_glcm = common::measure(iters, || {
            std::hint::black_box(accumulate_glcm(&roi, &distances, strategy, threads));
        });
        let p_glrlm = common::measure(iters, || {
            std::hint::black_box(accumulate_glrlm(&roi, strategy, threads));
        });
        let p_gldm = common::measure(iters, || {
            std::hint::black_box(accumulate_gldm(&roi, gldm_alpha, strategy, threads));
        });
        let p_ngtdm = common::measure(iters, || {
            std::hint::black_box(accumulate_ngtdm(&roi, strategy, threads));
        });
        let total = p_glcm.best + p_glrlm.best + p_gldm.best + p_ngtdm.best;
        best_parallel = best_parallel.min(total);
        t.row(vec![
            strategy.label().to_string(),
            threads.to_string(),
            format!("{:.1}", p_glcm.best * 1e3),
            format!("{:.1}", p_glrlm.best * 1e3),
            format!("{:.1}", p_gldm.best * 1e3),
            format!("{:.1}", p_ngtdm.best * 1e3),
            format!("{:.1}", total * 1e3),
            format!("{:.2}", serial / total),
        ]);

        // determinism contract: parallel matrices equal the serial ones
        let g = accumulate_glcm(&roi, &distances, strategy, threads);
        anyhow::ensure!(g == glcm_ref, "GLCM diverged under {strategy:?}");
        let r = accumulate_glrlm(&roi, strategy, threads);
        anyhow::ensure!(r == glrlm_ref, "GLRLM diverged under {strategy:?}");
        let d = accumulate_gldm(&roi, gldm_alpha, strategy, threads);
        anyhow::ensure!(d == gldm_ref, "GLDM diverged under {strategy:?}");
        let m = accumulate_ngtdm(&roi, strategy, threads);
        anyhow::ensure!(m == ngtdm_ref, "NGTDM diverged under {strategy:?}");
        let sec = format!("texture/parallel/{}", strategy.label());
        report.section(&sec, common::Measurement::single(total)).bit_exact(true);
    }
    print!("{}", t.to_text());

    let fg = glcm_features(&glcm_ref).expect("dense GLCM");
    let fr = glrlm_features(&glrlm_ref).expect("dense GLRLM");
    let fz = glszm_features(&glszm_ref).expect("dense GLSZM");
    let fd = gldm_features(&gldm_ref).expect("dense GLDM");
    let fm = ngtdm_features(&ngtdm_ref).expect("dense NGTDM");
    println!(
        "\nGLCM contrast {:.4}, joint entropy {:.4}; GLRLM RP {:.4}, SRE {:.4}",
        fg.contrast, fg.joint_entropy, fr.run_percentage, fr.short_run_emphasis
    );
    println!(
        "GLSZM ZP {:.4}, ZE {:.4}; GLDM SDE {:.4}, DE {:.4}; NGTDM coarseness {:.6}, \
         busyness {:.4}",
        fz.zone_percentage,
        fz.zone_entropy,
        fd.small_dependence_emphasis,
        fd.dependence_entropy,
        fm.coarseness,
        fm.busyness
    );
    println!("parallel == serial verified bit-for-bit for all 5 strategies × 5 classes");

    if threads >= 2 {
        // quick mode runs on contended shared CI runners where a wall-clock
        // comparison can invert spuriously — report there, assert locally
        if best_parallel < serial {
            println!(
                "best parallel beats serial: {:.1} ms vs {:.1} ms ({:.2}x)",
                best_parallel * 1e3,
                serial * 1e3,
                serial / best_parallel
            );
        } else if quick {
            println!(
                "WARNING: parallel ({:.1} ms) did not beat serial ({:.1} ms) on this \
                 contended quick-mode run",
                best_parallel * 1e3,
                serial * 1e3
            );
        } else {
            anyhow::bail!(
                "expected parallel accumulation ({:.1} ms) to beat serial ({:.1} ms) \
                 with {threads} threads",
                best_parallel * 1e3,
                serial * 1e3
            );
        }
    } else {
        println!("single-core machine: speedup assertion skipped");
    }
    common::finish(&report)?;
    Ok(())
}
