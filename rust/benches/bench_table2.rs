//! Table 2 regeneration: per-case breakdown (file read, M.C., Diam,
//! D.tran) for the CPU baseline and the accelerated PJRT path, plus the
//! paper-GPU projections, over the 20-case synthetic KiTS19 stand-in.
//!
//! Run: `cargo bench --offline --bench bench_table2`
//! Scale via RADPIPE_BENCH_SCALE (default 0.05; paper scale = 1.0).

mod common;

use radpipe::experiments::{run_table2, table2, Table2Options};
use radpipe::synth::paper_cases;

fn main() -> anyhow::Result<()> {
    let manifest = common::bench_dataset()?;
    let artifact_dir = common::artifact_dir();
    let mut report = common::report("bench_table2")?;

    common::banner(&format!(
        "TABLE 2 — per-case breakdown (scale {}, 20 cases)",
        common::bench_scale()?
    ));
    let opts = Table2Options {
        artifact_dir: artifact_dir.clone().unwrap_or_else(|| "artifacts".into()),
        cpu_only: artifact_dir.is_none(),
    };
    let t0 = std::time::Instant::now();
    let out = run_table2(&manifest, &opts)?;
    let rows = &out.rows;
    report.section("table2/total", common::Measurement::single(t0.elapsed().as_secs_f64()));
    print!("{}", table2::to_table(rows).to_text());
    for (stage, total) in table2::stage_totals(&out.metrics) {
        println!("  {stage}: {:.1} ms total", total.as_secs_f64() * 1e3);
    }

    // headline claims
    let share_min = rows.iter().map(|r| r.diam_share).fold(f64::INFINITY, f64::min);
    let share_max = rows.iter().map(|r| r.diam_share).fold(0.0, f64::max);
    println!(
        "\ndiameter share of post-read CPU time: {:.1}%..{:.1}%  (paper: 95.7%..99.9%)",
        share_min * 100.0,
        share_max * 100.0
    );

    // paper-vs-projection comparison on the shared case ids
    common::banner("projection vs paper (RTX 4070 diameter column, ms)");
    let paper = paper_cases();
    let scale = common::bench_scale()?;
    let mut t = radpipe::report::Table::new(vec![
        "case", "paper Diam[ms]", "proj 4070[ms]", "note",
    ]);
    for r in rows {
        if let Some(p) = paper.iter().find(|p| p.case_id == r.case_id) {
            // projections are at the *scaled* vertex count; paper column is
            // full scale — note the expected ~scale² factor.
            t.row(vec![
                r.case_id.clone(),
                format!("{:.1}", p.t_diam_gpu_ms),
                format!("{:.2}", r.diam_4070_ms),
                format!("x{:.4} scale^2 expected", scale * scale),
            ]);
        }
    }
    print!("{}", t.to_text());
    common::finish(&report)?;
    Ok(())
}
