//! The paper's §3 optimisation study (Fig. 1): run all five diameter-kernel
//! strategies over the dataset, verify they agree bit-for-bit, and price
//! each on the three paper GPUs with the calibrated device model.
//!
//! Run: `cargo run --release --offline --example optimization_study [-- --scale 0.02]`

use radpipe::experiments::{fig1, run_fig1};
use radpipe::synth::{generate_dataset, GenOptions};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = radpipe::cli::Args::parse(&args)?;
    let scale = parsed.opt_parse::<f64>("scale")?.unwrap_or(0.02);

    let root = std::env::temp_dir().join(format!("radpipe_optstudy_{scale}"));
    eprintln!("generating dataset (scale {scale})…");
    let manifest = generate_dataset(&root, &GenOptions { scale, seed: 7 })?;

    eprintln!("running 5 strategies × 20 cases (each verified against brute force)…");
    let rows = run_fig1(&manifest, 0)?;
    print!("{}", fig1::to_table(&rows).to_text());

    println!("\nwinning strategy per device (paper: T4→block reduction,");
    println!("RTX 4070→local accumulators, H100→memory-careful/tiled):");
    for (dev, strat) in fig1::winners(&rows) {
        println!("  {dev}: {}", strat.label());
    }
    Ok(())
}
