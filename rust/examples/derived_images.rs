//! Derived-image walkthrough: build a synthetic case, run the imgproc
//! filter bank, and extract filter-qualified features from every derived
//! image (the PyRadiomics `imageType` workflow).
//!
//! Run: `cargo run --release --offline --example derived_images`

use radpipe::config::PipelineConfig;
use radpipe::dispatch::FeatureExtractor;
use radpipe::geometry::Vec3;
use radpipe::imgproc::{derive_images, ImageTypes, ImgprocOptions};
use radpipe::volume::{Dims, VoxelGrid};

fn main() -> anyhow::Result<()> {
    // a banded 24³ image — enough structure for LoG and wavelet responses
    let dims = Dims::new(24, 24, 24);
    let mut image = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..24 {
        for y in 0..24 {
            for x in 0..24 {
                image.set(x, y, z, ((x / 3 + y / 2 + z) % 13) as f32 * 9.0);
                let (dx, dy, dz) = (x as f64 - 12.0, y as f64 - 12.0, z as f64 - 12.0);
                if dx * dx + dy * dy + dz * dz <= 81.0 {
                    mask.set(x, y, z, 1);
                }
            }
        }
    }

    // the filter bank on its own
    let opts = ImgprocOptions {
        image_types: ImageTypes::parse("all")?,
        log_sigmas: vec![1.0, 2.0],
        ..Default::default()
    };
    let derived = derive_images(&image, &opts)?;
    println!("{} derived images:", derived.len());
    for d in &derived {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in d.image.data() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        println!("  {:<20} range [{lo:8.2}, {hi:8.2}]", d.name);
    }

    // the streaming visitor: identical images, but at most ~2 resident
    // volumes — this is what the extractor itself uses
    let stats = radpipe::imgproc::for_each_derived_image(&image, &opts, |d| {
        // a real consumer extracts features here, before the volume drops
        let _ = d.image.data().len();
        Ok(())
    })?;
    println!(
        "\nstreaming visitor: {} images, peak resident {:.2} MiB \
         (materialised bank above holds all {} volumes at once)",
        stats.images,
        stats.peak_resident_bytes as f64 / (1024.0 * 1024.0),
        derived.len()
    );

    // end-to-end: features per derived image through the extractor
    let cfg = PipelineConfig {
        backend: radpipe::config::Backend::Cpu,
        feature_classes: radpipe::config::FeatureClasses::parse("all")?,
        image_types: ImageTypes::parse("all")?,
        log_sigmas: vec![1.0, 2.0],
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg)?;
    let out = ex.execute_case(&mask, Some(&image))?;
    println!("\nfilter-qualified features (one line per derived image):");
    for d in &out.derived {
        let named = d.named();
        let mean = named.iter().find(|(n, _)| n.ends_with("Mean") || n == "Mean");
        if let Some((name, value)) = mean {
            println!("  {name:<40} = {value:.4}");
        }
    }
    println!(
        "\npreprocess {:.1} ms, texture {:.1} ms over {} derived images",
        out.timing.preprocess.as_secs_f64() * 1e3,
        out.timing.texture.as_secs_f64() * 1e3,
        out.derived.len()
    );
    Ok(())
}
