//! End-to-end validation driver (the repo's required e2e example): generate
//! the 20-case KiTS19-like dataset, run the *full streaming pipeline* twice
//! (CPU baseline, then accelerated with transparent dispatch), verify the
//! outputs agree feature-by-feature, and print the paper's headline
//! metrics: the Table 2 breakdown, the diameter-share claim and the
//! computation speedups.
//!
//! Run: `cargo run --release --offline --example cluster_pipeline [-- --scale 0.03]`
//! The run is recorded in EXPERIMENTS.md.

use std::path::PathBuf;

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::pipeline::run_pipeline;
use radpipe::report::Table;
use radpipe::synth::{generate_dataset, GenOptions};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = radpipe::cli::Args::parse(&args)?;
    let scale = parsed.opt_parse::<f64>("scale")?.unwrap_or(0.03);
    let artifacts = PathBuf::from(parsed.opt("artifacts").unwrap_or("artifacts"));

    let root = std::env::temp_dir().join(format!("radpipe_e2e_{scale}"));
    eprintln!("[1/4] generating dataset (scale {scale}) in {}", root.display());
    let manifest = generate_dataset(&root, &GenOptions { scale, seed: 7 })?;
    let total_verts: usize = manifest.cases.iter().map(|c| c.target_vertices).sum();
    eprintln!("      20 cases, {total_verts} total mesh vertices");

    eprintln!("[2/4] CPU baseline pipeline (single-thread PyRadiomics port)");
    let cpu_cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        read_workers: 2,
        feature_workers: 1,
        ..Default::default()
    };
    let cpu_ex = FeatureExtractor::new(&cpu_cfg)?;
    let cpu_report = run_pipeline(&manifest, &cpu_cfg, &cpu_ex)?;
    anyhow::ensure!(cpu_report.failures.is_empty(), "CPU failures: {:?}", cpu_report.failures);

    eprintln!("[3/4] accelerated pipeline (AOT artifacts via PJRT, auto dispatch)");
    let acc_cfg = PipelineConfig {
        backend: Backend::Auto,
        artifact_dir: artifacts,
        read_workers: 2,
        feature_workers: 2,
        ..Default::default()
    };
    let acc_ex = FeatureExtractor::new(&acc_cfg)?;
    eprintln!("      accelerated = {}", acc_ex.accelerated());
    let acc_report = run_pipeline(&manifest, &acc_cfg, &acc_ex)?;
    anyhow::ensure!(acc_report.failures.is_empty(), "accel failures: {:?}", acc_report.failures);

    eprintln!("[4/4] verifying identical output quality (paper §4)");
    let mut worst: f64 = 0.0;
    for (a, b) in cpu_report.results.iter().zip(&acc_report.results) {
        assert_eq!(a.case_id, b.case_id);
        for ((name, va), (_, vb)) in a.features.named().iter().zip(b.features.named()) {
            if va.is_nan() && vb.is_nan() {
                continue;
            }
            let rel = (va - vb).abs() / vb.abs().max(1e-9);
            anyhow::ensure!(rel < 1e-3, "{}: {name} {va} vs {vb}", a.case_id);
            worst = worst.max(rel);
        }
    }
    eprintln!("      max relative feature deviation: {worst:.2e}");

    // ---- the Table-2-style report
    let mut t = Table::new(vec![
        "case", "verts", "read[ms]", "MC[ms]", "Diam[ms]", "D.tran[ms]", "Diam.a[ms]",
        "Comp", "Overall", "path",
    ]);
    let mut sum_cpu = 0.0;
    let mut sum_acc = 0.0;
    for (c, a) in cpu_report.results.iter().zip(&acc_report.results) {
        let read = c.timing.read.as_secs_f64() * 1e3;
        let mc = (c.timing.preprocess + c.timing.marching).as_secs_f64() * 1e3;
        let diam = c.timing.diameters.as_secs_f64() * 1e3;
        let tran = a.timing.transfer.as_secs_f64() * 1e3;
        let diam_a = a.timing.diameters.as_secs_f64() * 1e3;
        let comp_cpu = mc + diam;
        let comp_acc = a.timing.compute_total().as_secs_f64() * 1e3;
        sum_cpu += comp_cpu;
        sum_acc += comp_acc;
        t.row(vec![
            c.case_id.clone(),
            c.features.vertex_count.to_string(),
            format!("{read:.1}"),
            format!("{mc:.1}"),
            format!("{diam:.1}"),
            format!("{tran:.2}"),
            format!("{diam_a:.1}"),
            format!("{:.2}", comp_cpu / comp_acc.max(1e-9)),
            format!("{:.2}", (read + comp_cpu) / (read + comp_acc).max(1e-9)),
            format!("{:?}", a.path),
        ]);
    }
    print!("{}", t.to_text());
    println!(
        "\ntotals: CPU compute {:.1} ms, accelerated compute {:.1} ms, ratio {:.2}x",
        sum_cpu,
        sum_acc,
        sum_cpu / sum_acc.max(1e-9)
    );
    println!(
        "pipeline wall: cpu {:.2}s, accelerated {:.2}s",
        cpu_report.wall.as_secs_f64(),
        acc_report.wall.as_secs_f64()
    );
    println!("\n--- cpu metrics ---\n{}", cpu_report.metrics_text);
    println!("--- accelerated metrics ---\n{}", acc_report.metrics_text);
    println!("NOTE: on this 1-core testbed the PJRT path measures the architecture, not");
    println!("GPU silicon; paper-scale device speedups are reproduced by `radpipe fig2`");
    println!("via the calibrated device model (DESIGN.md §Substitutions).");
    Ok(())
}
