//! Quickstart: the PyRadiomics-style 4-liner (paper §2):
//!
//! ```python
//! ext = featureextractor.RadiomicsFeatureExtractor()
//! res = ext.execute('scan.nii.gz', 'mask.nii.gz')
//! print(res['MeshVolume'], res['SurfaceArea'])
//! ```
//!
//! Run: `cargo run --release --offline --example quickstart`

use radpipe::config::PipelineConfig;
use radpipe::dispatch::FeatureExtractor;
use radpipe::geometry::Vec3;
use radpipe::io::write_nifti;
use radpipe::volume::{Dims, VoxelGrid};

fn main() -> anyhow::Result<()> {
    // Make a small mask file to stand in for 'mask.nii.gz'.
    let dir = std::env::temp_dir().join("radpipe_quickstart");
    std::fs::create_dir_all(&dir)?;
    let mask_path = dir.join("mask.nii.gz");
    let mut mask = VoxelGrid::zeros(Dims::new(32, 32, 24), Vec3::new(0.9, 0.9, 2.5));
    for z in 0..24 {
        for y in 0..32 {
            for x in 0..32 {
                let (dx, dy, dz) = (x as f64 - 16.0, y as f64 - 16.0, (z as f64 - 12.0) * 2.0);
                if dx * dx + dy * dy + dz * dz <= 81.0 {
                    mask.set(x, y, z, 1);
                }
            }
        }
    }
    write_nifti(&mask_path, &mask)?;

    // --- the PyRadiomics-equivalent 4 lines -----------------------------
    let ext = FeatureExtractor::new(&PipelineConfig::default())?; // auto-detect + fallback
    let res = ext.execute(&mask_path)?;
    println!("MeshVolume  = {:.2} mm^3", res.features.mesh_volume);
    println!("SurfaceArea = {:.2} mm^2", res.features.surface_area);
    // --------------------------------------------------------------------

    println!("\nall features:");
    for (name, value) in res.features.named() {
        println!("  {name:>24} = {value:.4}");
    }
    println!(
        "\npath taken: {:?} (Accelerated = artifacts + PJRT; CpuFallback = pure rust)",
        res.path
    );
    println!(
        "timing: read {:.1} ms, mesh {:.1} ms, diameters {:.1} ms",
        res.timing.read.as_secs_f64() * 1e3,
        res.timing.marching.as_secs_f64() * 1e3,
        res.timing.diameters.as_secs_f64() * 1e3
    );
    Ok(())
}
