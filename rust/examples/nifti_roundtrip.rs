//! Domain example: ingest a directory of `.nii.gz` masks (the KiTS19
//! format), extract features for each and write a CSV — the "batch
//! radiomics for an AI cohort" workflow that motivates the paper.
//!
//! Run: `cargo run --release --offline --example nifti_roundtrip`

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::io::write_nifti;
use radpipe::report::Table;
use radpipe::synth::{generate_case, paper_cases, GenOptions};

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join("radpipe_nifti_cohort");
    std::fs::create_dir_all(&dir)?;

    // Build a small .nii.gz cohort from the synthetic generator (5 cases).
    eprintln!("writing 5 .nii.gz masks to {}", dir.display());
    let opts = GenOptions { scale: 0.01, seed: 11 };
    let mut paths = Vec::new();
    for case in paper_cases().iter().take(5) {
        let (mask, _) = generate_case(case, &opts);
        let path = dir.join(format!("{}.nii.gz", case.case_id));
        write_nifti(&path, &mask)?;
        paths.push((case.case_id, path));
    }

    // Extract features for the cohort (auto backend, CPU fallback OK).
    let cfg = PipelineConfig { backend: Backend::Auto, ..Default::default() };
    let ex = FeatureExtractor::new(&cfg)?;
    eprintln!("accelerated backend: {}", ex.accelerated());

    let mut table = Table::new(vec![
        "case", "MeshVolume", "SurfaceArea", "Sphericity", "Max3DDiameter", "Elongation",
    ]);
    for (case_id, path) in &paths {
        let res = ex.execute(path)?;
        table.row(vec![
            case_id.to_string(),
            format!("{:.1}", res.features.mesh_volume),
            format!("{:.1}", res.features.surface_area),
            format!("{:.3}", res.features.sphericity),
            format!("{:.2}", res.features.maximum_3d_diameter),
            format!("{:.3}", res.features.elongation),
        ]);
    }
    print!("{}", table.to_text());

    let csv_path = dir.join("features.csv");
    std::fs::write(&csv_path, table.to_csv())?;
    println!("\nwrote {}", csv_path.display());
    Ok(())
}
