//! Cross-module integration tests: synthetic data → IO → pipeline →
//! features, exercised through the public API only.

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::geometry::Vec3;
use radpipe::io::{
    read_nifti, read_rvol, scan_dataset, write_nifti, write_rvol, CaseEntry, DatasetManifest,
};
use radpipe::mc::mesh_roi;
use radpipe::pipeline::run_pipeline;
use radpipe::synth::{generate_case, generate_dataset, paper_cases, GenOptions};
use radpipe::volume::{crop_to_roi, Dims, MaskStats, VoxelGrid};

fn tdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("radpipe_integration_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn synthetic_case_features_are_physically_plausible() {
    let case = &paper_cases()[4]; // 00002-1
    let (mask, nverts) = generate_case(case, &GenOptions { scale: 0.01, seed: 7 });
    let stats = MaskStats::compute(&mask);
    assert!(stats.count > 0);

    let cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let out = ex.execute_mask(&mask).unwrap();
    let f = &out.features;

    assert_eq!(f.vertex_count, nverts);
    // mesh volume within 25% of voxel volume (MT bevel + lobulation)
    assert!((f.mesh_volume - f.voxel_volume).abs() / f.voxel_volume < 0.25);
    // isoperimetric inequality: sphericity in (0, 1]
    assert!(f.sphericity > 0.0 && f.sphericity <= 1.0);
    // diameter bounded by the physical AABB diagonal of the mask
    let diag = Vec3::new(
        mask.dims.x as f64 * mask.spacing.x,
        mask.dims.y as f64 * mask.spacing.y,
        mask.dims.z as f64 * mask.spacing.z,
    )
    .norm();
    assert!(f.maximum_3d_diameter <= diag);
    // planar diameters never exceed the 3D diameter
    assert!(f.maximum_2d_diameter_slice <= f.maximum_3d_diameter + 1e-9);
    assert!(f.maximum_2d_diameter_column <= f.maximum_3d_diameter + 1e-9);
    assert!(f.maximum_2d_diameter_row <= f.maximum_3d_diameter + 1e-9);
    // axis ordering
    assert!(f.major_axis_length >= f.minor_axis_length);
    assert!(f.minor_axis_length >= f.least_axis_length);
}

#[test]
fn rvol_and_nifti_agree_through_the_extractor() {
    let dir = tdir("formats");
    let case = &paper_cases()[9];
    let (mask, _) = generate_case(case, &GenOptions { scale: 0.005, seed: 3 });
    let p_rvol = dir.join("m.rvol.gz");
    let p_nii = dir.join("m.nii.gz");
    write_rvol(&p_rvol, &mask).unwrap();
    write_nifti(&p_nii, &mask).unwrap();

    // float32 spacing in the NIfTI header loses f64 precision; compare the
    // voxel payloads exactly and features approximately.
    let a = read_rvol::<u8>(&p_rvol).unwrap();
    let b = read_nifti(&p_nii).unwrap();
    assert_eq!(a.data(), b.data());

    let cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let fa = ex.execute(&p_rvol).unwrap().features;
    let fb = ex.execute(&p_nii).unwrap().features;
    assert!((fa.mesh_volume - fb.mesh_volume).abs() / fa.mesh_volume < 1e-5);
    assert_eq!(fa.voxel_count, fb.voxel_count);
}

#[test]
fn crop_does_not_change_features() {
    let case = &paper_cases()[19];
    let (mask, _) = generate_case(case, &GenOptions { scale: 0.01, seed: 9 });
    let (cropped, _) = crop_to_roi(&mask);

    // meshing the full mask and the cropped mask yields identical stats
    let full = mesh_roi(&mask);
    let crop = mesh_roi(&cropped);
    assert_eq!(full.vertices.len(), crop.vertices.len());
    assert!((full.stats.volume - crop.stats.volume).abs() < 1e-9);
    assert!((full.stats.area - crop.stats.area).abs() < 1e-9);
}

#[test]
fn dataset_roundtrip_and_pipeline() {
    let dir = tdir("dataset");
    let m = generate_dataset(&dir, &GenOptions { scale: 0.002, seed: 1 }).unwrap();
    let re = scan_dataset(&dir).unwrap();
    assert_eq!(m.cases.len(), re.cases.len());

    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        read_workers: 2,
        feature_workers: 2,
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let report = run_pipeline(&re, &cfg, &ex).unwrap();
    assert!(report.failures.is_empty());
    assert_eq!(report.results.len(), 20);
    // vertex counts recorded in the manifest match the pipeline's
    for (r, e) in report.results.iter().zip(&re.cases) {
        assert_eq!(r.features.vertex_count, e.target_vertices, "{}", r.case_id);
    }
}

#[test]
fn diameter_share_claim_holds_on_larger_cases() {
    // §3: diameter dominates post-read time (95.7–99.9 % at paper scale;
    // on scaled-down cases the share shrinks but must still dominate).
    let case = &paper_cases()[2]; // the largest case
    let (mask, _) = generate_case(case, &GenOptions { scale: 0.04, seed: 7 });
    let cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let out = ex.execute_mask(&mask).unwrap();
    let mc = out.timing.marching.as_secs_f64();
    let diam = out.timing.diameters.as_secs_f64();
    assert!(
        diam / (diam + mc) > 0.5,
        "diameter share {:.1}% (mc {mc:.4}s diam {diam:.4}s)",
        100.0 * diam / (diam + mc)
    );
}

#[test]
fn empty_and_single_voxel_masks_do_not_break_the_pipeline() {
    let cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    let ex = FeatureExtractor::new(&cfg).unwrap();

    let empty = VoxelGrid::zeros(Dims::new(5, 5, 5), Vec3::splat(1.0));
    let out = ex.execute_mask(&empty).unwrap();
    assert_eq!(out.features.voxel_count, 0);

    let mut single = VoxelGrid::zeros(Dims::new(5, 5, 5), Vec3::splat(1.0));
    single.set(2, 2, 2, 1);
    let out = ex.execute_mask(&single).unwrap();
    assert_eq!(out.features.voxel_count, 1);
    assert!((out.features.mesh_volume - 0.5).abs() < 1e-9); // MT octahedron
    assert!(out.features.maximum_3d_diameter > 0.0);
}

#[test]
fn first_order_features_over_synthetic_image() {
    let case = &paper_cases()[0];
    let (mask, _) = generate_case(case, &GenOptions { scale: 0.005, seed: 2 });
    let image = radpipe::synth::synthesize_image(&mask, 42);
    let f = radpipe::features::compute_first_order(&image, &mask, 25.0).unwrap();
    // ROI is background(+grad −80..−30) + 120 contrast + σ=12 noise
    assert!(f.mean > 0.0 && f.mean < 90.0, "mean {}", f.mean);
    assert!(f.variance > 50.0, "variance {}", f.variance);
    assert!(f.minimum < f.percentile10 && f.percentile10 < f.median);
    assert!(f.median < f.percentile90 && f.percentile90 <= f.maximum);
    assert!(f.entropy > 0.5, "entropy {}", f.entropy);
    // deterministic across calls
    let image2 = radpipe::synth::synthesize_image(&mask, 42);
    let f2 = radpipe::features::compute_first_order(&image2, &mask, 25.0).unwrap();
    assert_eq!(f, f2);
}

#[test]
fn paired_images_drive_the_pipeline_not_the_stand_in() {
    // `gen-data` now pairs every mask with a real image volume; a full
    // pipeline run with an intensity class must read those images (zero
    // failures, no opt-in needed) and produce different features than the
    // synthetic stand-in would have.
    let dir = tdir("paired_images");
    let m = generate_dataset(&dir, &GenOptions { scale: 0.002, seed: 5 }).unwrap();
    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        read_workers: 2,
        feature_workers: 2,
        feature_classes: radpipe::config::FeatureClasses::parse("firstorder").unwrap(),
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let real = run_pipeline(&m, &cfg, &ex).unwrap();
    assert!(real.failures.is_empty(), "{:?}", real.failures);
    assert_eq!(real.results.len(), 20);
    assert!(real.results.iter().all(|r| r.first_order.is_some()));

    // same dataset with the `image=` pairings dropped + the explicit
    // stand-in opt-in: every case must come out with different intensities
    let mut stripped = DatasetManifest { root: m.root.clone(), cases: m.cases.clone() };
    for e in &mut stripped.cases {
        e.image = None;
    }
    let standin_cfg = PipelineConfig { synthetic_image: true, ..cfg };
    let standin_ex = FeatureExtractor::new(&standin_cfg).unwrap();
    let standin = run_pipeline(&stripped, &standin_cfg, &standin_ex).unwrap();
    assert!(standin.failures.is_empty(), "{:?}", standin.failures);
    for (r, s) in real.results.iter().zip(&standin.results) {
        assert_eq!(r.case_id, s.case_id);
        let (rf, sf) = (r.first_order.as_ref().unwrap(), s.first_order.as_ref().unwrap());
        assert_ne!(
            rf.mean.to_bits(),
            sf.mean.to_bits(),
            "{}: real image indistinguishable from the stand-in",
            r.case_id
        );
    }
}

#[test]
fn image_on_a_different_grid_is_auto_resampled_through_the_pipeline() {
    // A manifest may pair a mask with an image acquired on a different
    // grid (here: 1 mm isotropic vs the mask's 0.8×0.8×2.0 mm). The read
    // stage loads both as-is and the extractor trilinear-resamples the
    // image onto the mask grid; for a linear intensity field that
    // interpolation is exact, so the run must match the native-grid run.
    let dir = tdir("resample_grid");
    let (n, nz) = (20usize, 12usize);
    let spacing = Vec3::new(0.8, 0.8, 2.0);
    let mut mask = VoxelGrid::zeros(Dims::new(n, n, nz), spacing);
    let (c, cz, r) = (n as f64 / 2.0, nz as f64 / 2.0, 6.0f64);
    for z in 0..nz {
        for y in 0..n {
            for x in 0..n {
                let (dx, dy, dz) =
                    (x as f64 - c, y as f64 - c, (z as f64 - cz) * spacing.z / spacing.x);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    mask.set(x, y, z, 1);
                }
            }
        }
    }
    write_rvol(&dir.join("case.rvol.gz"), &mask).unwrap();

    // one continuous linear field, sampled on both grids (physical
    // coordinates are index × spacing, origin shared at voxel 0)
    let field = |xm: f64, ym: f64, zm: f64| (100.0 + 3.0 * xm + 2.0 * ym + 1.5 * zm) as f32;
    let mut native = VoxelGrid::zeros(mask.dims, spacing);
    for z in 0..nz {
        for y in 0..n {
            for x in 0..n {
                native.set(
                    x,
                    y,
                    z,
                    field(x as f64 * spacing.x, y as f64 * spacing.y, z as f64 * spacing.z),
                );
            }
        }
    }
    write_rvol(&dir.join("native.img.rvol.gz"), &native).unwrap();
    // 1 mm grid big enough to cover the mask's physical extent
    let idims = Dims::new(
        ((n - 1) as f64 * spacing.x).ceil() as usize + 2,
        ((n - 1) as f64 * spacing.y).ceil() as usize + 2,
        ((nz - 1) as f64 * spacing.z).ceil() as usize + 2,
    );
    let mut iso = VoxelGrid::zeros(idims, Vec3::splat(1.0));
    for z in 0..idims.z {
        for y in 0..idims.y {
            for x in 0..idims.x {
                iso.set(x, y, z, field(x as f64, y as f64, z as f64));
            }
        }
    }
    write_rvol(&dir.join("iso.img.rvol.gz"), &iso).unwrap();

    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        feature_classes: radpipe::config::FeatureClasses::parse("firstorder").unwrap(),
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let run = |image: &str| {
        let manifest = DatasetManifest {
            root: dir.clone(),
            cases: vec![CaseEntry {
                case_id: "case".into(),
                mask: "case.rvol.gz".into(),
                image: Some(image.into()),
                dims: Some(mask.dims),
                target_vertices: 0,
                labels: Vec::new(),
            }],
        };
        let report = run_pipeline(&manifest, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{image}: {:?}", report.failures);
        report.results[0].first_order.clone().unwrap()
    };
    let want = run("native.img.rvol.gz");
    let got = run("iso.img.rvol.gz");
    assert!(
        (got.mean - want.mean).abs() <= 1e-3 * want.mean.abs(),
        "resampled mean {} vs native {}",
        got.mean,
        want.mean
    );
    assert!(
        (got.variance - want.variance).abs() <= 1e-2 * want.variance.abs().max(1.0),
        "resampled variance {} vs native {}",
        got.variance,
        want.variance
    );
}
