//! Integration locks for label-map extraction and slab-streamed IO.
//!
//! Two contracts the out-of-core, label-aware path must never drift from:
//!
//! * a label-map run is bit-identical to N separate binary-mask runs —
//!   shape + first-order + all five texture classes, for every parallel
//!   strategy × thread count;
//! * a slab-streamed read (`slab_io = true`) yields bit-identical features
//!   to the whole-grid read, in every supported container format.
//!
//! Both rest on exact arithmetic: the fixtures use integer-valued
//! intensities (exact in f32) and the crop-nesting algebra unit-tested in
//! `volume::label`, so every assertion below is `==`, never a tolerance.

use radpipe::config::{Backend, FeatureClasses, LabelSelection, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::geometry::Vec3;
use radpipe::io::{write_nifti, write_nifti_image, write_rvol, CaseEntry, DatasetManifest};
use radpipe::parallel::Strategy;
use radpipe::pipeline::run_pipeline;
use radpipe::synth::{generate_multilabel_dataset, GenOptions};
use radpipe::volume::{Dims, LabelMask, VoxelGrid};

/// Thread counts for the determinism sweeps: 1/2/4/8 by default; the CI
/// thread-matrix leg pins the sweep via `RADPIPE_TEST_THREADS` (same
/// contract as tests/conformance.rs).
fn sweep_threads() -> Vec<usize> {
    if let Ok(v) = std::env::var("RADPIPE_TEST_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return vec![n];
            }
        }
    }
    vec![1, 2, 4, 8]
}

/// Three ROIs (labels 1, 3, 7) in an anisotropic 18×16×14 grid: two
/// blocks plus a thin bar, with label 3 touching the far x face so the
/// crop margin clamp path is exercised.
fn label_fixture() -> LabelMask {
    let mut g = VoxelGrid::zeros(Dims::new(18, 16, 14), Vec3::new(0.8, 0.8, 2.0));
    for z in 2..6 {
        for y in 3..8 {
            for x in 2..7 {
                g.set(x, y, z, 1);
            }
        }
    }
    for z in 7..12 {
        for y in 9..14 {
            for x in 11..18 {
                g.set(x, y, z, 3);
            }
        }
    }
    for x in 8..11 {
        g.set(x, 6, 6, 7);
    }
    LabelMask::from_grid(g)
}

/// Deterministic integer-valued intensities — exact in f32, so write →
/// read → extract round-trips are bit-preserving.
fn fixture_image(dims: Dims, spacing: Vec3) -> VoxelGrid<f32> {
    let mut img = VoxelGrid::zeros(dims, spacing);
    for z in 0..dims.z {
        for y in 0..dims.y {
            for x in 0..dims.x {
                img.set(x, y, z, ((7 * x + 3 * y + 11 * z) % 61) as f32 - 14.0);
            }
        }
    }
    img
}

#[test]
fn label_map_matches_binary_runs_for_every_strategy_and_thread_count() {
    // the tentpole conformance lock: one shared-pass label-map extraction
    // == N independent binary-mask extractions, bit for bit, with shape +
    // first-order + all five texture classes enabled
    let lm = label_fixture();
    let img = fixture_image(lm.grid.dims, lm.grid.spacing);
    for strategy in Strategy::ALL {
        for &threads in &sweep_threads() {
            let cfg = PipelineConfig {
                backend: Backend::Cpu,
                cpu_threads: threads,
                strategy,
                feature_classes: FeatureClasses::parse("all").unwrap(),
                ..Default::default()
            };
            let ex = FeatureExtractor::new(&cfg).unwrap();
            let out = ex.execute_label_map("case", &lm, Some(&img), &lm.labels).unwrap();
            assert_eq!(out.len(), 3, "{strategy:?} x{threads}");
            for (label, res) in out {
                let tag = format!("{strategy:?} x{threads} label {label}");
                let got = res.unwrap_or_else(|e| panic!("{tag}: {e:#}"));
                let want = ex.execute_case(&lm.binary(label), Some(&img)).unwrap();
                assert_eq!(got.features, want.features, "{tag}: shape");
                assert_eq!(got.first_order, want.first_order, "{tag}: first-order");
                assert_eq!(got.texture, want.texture, "{tag}: texture");
                assert_eq!(got.derived, want.derived, "{tag}: derived images");
            }
        }
    }
}

#[test]
fn synthetic_label_map_matches_binary_runs_on_every_derived_image() {
    // with the synthetic stand-in, the per-label image is synthesised on
    // the label's own crop, so even LoG/wavelet features are bit-identical
    // to the standalone binary run
    let lm = label_fixture();
    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 2,
        feature_classes: FeatureClasses::parse("all").unwrap(),
        image_types: radpipe::imgproc::ImageTypes::parse("all").unwrap(),
        log_sigmas: vec![1.0, 2.0],
        synthetic_image: true,
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let out = ex.execute_label_map("case", &lm, None, &lm.labels).unwrap();
    assert_eq!(out.len(), 3);
    for (label, res) in out {
        let got = res.unwrap();
        let want = ex.execute_mask(&lm.binary(label)).unwrap();
        assert_eq!(got.derived.len(), 11, "original + 2 LoG + 8 wavelet");
        assert_eq!(got.features, want.features, "label {label}: shape");
        assert_eq!(got.derived, want.derived, "label {label}: derived images");
    }
}

#[test]
fn slab_read_is_bit_identical_to_whole_read_in_every_container() {
    let lm = label_fixture();
    let img = fixture_image(lm.grid.dims, lm.grid.spacing);
    let base = std::env::temp_dir().join("radpipe_labelmap_slab_formats");
    let _ = std::fs::remove_dir_all(&base);

    for (mask_name, img_name) in [
        ("m.nii", "i.nii"),
        ("m.nii.gz", "i.nii.gz"),
        ("m.rvol", "i.rvol"),
        ("m.rvol.gz", "i.rvol.gz"),
    ] {
        let root = base.join(mask_name.replace('.', "_"));
        std::fs::create_dir_all(&root).unwrap();
        if mask_name.starts_with("m.nii") {
            // NIfTI masks carry the label ids in uint8 (ids here are ≤ 7)
            write_nifti(&root.join(mask_name), &lm.grid.map(|v| v as u8)).unwrap();
            write_nifti_image(&root.join(img_name), &img).unwrap();
        } else {
            write_rvol(&root.join(mask_name), &lm.grid).unwrap();
            write_rvol(&root.join(img_name), &img).unwrap();
        }
        let manifest = DatasetManifest {
            root: root.clone(),
            cases: vec![CaseEntry {
                case_id: format!("case-{mask_name}"),
                mask: mask_name.into(),
                image: Some(img_name.into()),
                dims: Some(lm.grid.dims),
                target_vertices: 0,
                labels: Vec::new(),
            }],
        };
        let cfg = |slab: bool| PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 1,
            feature_classes: FeatureClasses::parse("all").unwrap(),
            labels: LabelSelection::All,
            slab_io: slab,
            ..Default::default()
        };
        let whole_cfg = cfg(false);
        let whole =
            run_pipeline(&manifest, &whole_cfg, &FeatureExtractor::new(&whole_cfg).unwrap())
                .unwrap();
        let slab_cfg = cfg(true);
        slab_cfg.validate().unwrap();
        let slab =
            run_pipeline(&manifest, &slab_cfg, &FeatureExtractor::new(&slab_cfg).unwrap())
                .unwrap();
        assert!(whole.failures.is_empty(), "{mask_name}: {:?}", whole.failures);
        assert!(slab.failures.is_empty(), "{mask_name}: {:?}", slab.failures);
        assert_eq!(whole.results.len(), 3, "{mask_name}: one row per label");
        assert_eq!(slab.results.len(), whole.results.len(), "{mask_name}");
        for (a, b) in whole.results.iter().zip(&slab.results) {
            assert_eq!(a.case_id, b.case_id, "{mask_name}");
            assert_eq!(a.label, b.label, "{mask_name}");
            let tag = format!("{mask_name} label {:?}", a.label);
            assert_eq!(a.features, b.features, "{tag}: shape");
            assert_eq!(a.first_order, b.first_order, "{tag}: first-order");
            assert_eq!(a.texture, b.texture, "{tag}: texture");
            assert_eq!(a.derived, b.derived, "{tag}: derived images");
        }
        // the slab run tracked its bounded in-flight footprint
        assert!(
            slab.metrics.counter("mem.peak_pipeline_bytes").unwrap_or(0) > 0,
            "{mask_name}: peak gauge missing"
        );
    }
}

#[test]
fn multilabel_fixture_shares_one_pass_and_isolates_the_empty_label() {
    let root = std::env::temp_dir().join("radpipe_labelmap_fixture_run");
    let _ = std::fs::remove_dir_all(&root);
    let m = generate_multilabel_dataset(&root, &GenOptions { scale: 0.003, seed: 5 }).unwrap();
    assert_eq!(m.cases.len(), 3);
    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 2,
        feature_classes: FeatureClasses::parse("all").unwrap(),
        labels: LabelSelection::All,
        memory_budget: 1 << 20,
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let report = run_pipeline(&m, &cfg, &ex).unwrap();

    // 3 cases × labels 1..3 extract; the declared-but-empty label 4 of the
    // first case is the run's only failure — isolated, not fatal
    assert_eq!(report.results.len(), 9, "one row per populated (case, label)");
    assert_eq!(report.failures.len(), 1);
    let (case, err) = &report.failures[0];
    assert_eq!(case, &m.cases[0].case_id);
    assert!(err.contains("label 4") && err.contains("no voxels"), "{err}");

    // failure accounting stays exact: per-label errors land on their own
    // counter, the whole-case counter stays untouched, and the counters
    // sum to the failure list
    assert_eq!(report.metrics.counter("errors.label"), Some(1));
    assert_eq!(report.metrics.counter("errors.extract").unwrap_or(0), 0);
    let err_sum: u64 = report
        .metrics
        .counters
        .iter()
        .filter(|(k, _)| k.starts_with("errors."))
        .map(|(_, &v)| v)
        .sum();
    assert_eq!(err_sum, report.failures.len() as u64);

    // the N-label extraction shares ONE pass per case: preprocess counts
    // cases, mesh counts labels, and each mask file is read exactly once
    assert_eq!(report.metrics.timer("stage.preprocess").map(|t| t.count), Some(3));
    assert_eq!(report.metrics.timer("stage.mesh").map(|t| t.count), Some(9));
    assert_eq!(report.metrics.timer("stage.read").map(|t| t.count), Some(3));

    // the memory budget rode along and reported the peak it governed
    assert!(report.metrics.counter("mem.peak_pipeline_bytes").unwrap_or(0) > 0);
}
