//! Trace session semantics under concurrency.
//!
//! These tests install the process-global tracer, so they cannot live in
//! the lib test binary: concurrently-scheduled lib tests would emit spans
//! into an installed sink and race the `enabled()` flag. This binary is
//! its own process and every test serializes on a file-local mutex.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use radpipe::config::{Backend, FeatureClasses, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::imgproc::ImageTypes;
use radpipe::io::DatasetManifest;
use radpipe::pipeline::run_pipeline;
use radpipe::runtime::{BatchConfig, Batcher, CpuLoopbackBackend, EnginePool};
use radpipe::synth::{generate_dataset, GenOptions};
use radpipe::trace::{self, chrome};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn tiny_dataset(tag: &str) -> DatasetManifest {
    let root = std::env::temp_dir().join(format!("radpipe_trace_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    generate_dataset(&root, &GenOptions { scale: 0.003, seed: 5 }).unwrap()
}

#[test]
fn multithreaded_extraction_emits_a_well_formed_trace() {
    let _s = serial();
    let m = tiny_dataset("well_formed");
    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        read_workers: 3,
        feature_workers: 4,
        queue_capacity: 2,
        cpu_threads: 2,
        feature_classes: FeatureClasses::parse("all").unwrap(),
        // the residency tracker only meters filtered volumes (the borrowed
        // `original` is never held), so LoG must be on for the
        // mem.resident_bytes counter track to carry samples
        image_types: ImageTypes::parse("original,log").unwrap(),
        ..Default::default()
    };
    let ex = FeatureExtractor::new(&cfg).unwrap();

    let sink = trace::TraceSink::new();
    let session = trace::install(sink.clone());
    let report = run_pipeline(&m, &cfg, &ex).unwrap();
    drop(session);
    assert!(report.failures.is_empty(), "{:?}", report.failures);

    let parsed = chrome::parse(&sink.to_chrome_json()).unwrap();

    // pid/tid/timestamp sanity on every event of the concurrent run
    let pid = std::process::id() as u64;
    for ev in parsed.spans().chain(parsed.counters()) {
        assert_eq!(ev.pid, pid, "{}", ev.name);
        assert!(ev.tid >= 1, "{}", ev.name);
        assert!(ev.ts >= 0.0 && ev.dur >= 0.0, "{}", ev.name);
    }

    // spans are appended when their guard drops, so per-thread end times
    // are non-decreasing (up to ~2 µs of µs-truncation jitter)
    let mut last_end: HashMap<u64, f64> = HashMap::new();
    for ev in parsed.spans() {
        let prev = last_end.entry(ev.tid).or_insert(0.0);
        assert!(
            ev.end_ts() + 10.0 >= *prev,
            "tid {} span '{}' ends at {} µs, after one ending at {} µs",
            ev.tid,
            ev.name,
            ev.end_ts(),
            prev
        );
        *prev = prev.max(ev.end_ts());
    }

    // every manifest case is attributed on at least one span
    let cases = parsed.span_cases();
    for e in &m.cases {
        assert!(cases.contains(&e.case_id), "case {} missing from trace", e.case_id);
    }

    // the full-stage span inventory of an all-classes CPU run
    let names = parsed.span_names();
    for want in [
        "case",
        "stage.read",
        "stage.read_image",
        "stage.preprocess",
        "stage.mesh",
        "stage.diameters",
        "stage.derived",
        "stage.texture",
    ] {
        assert!(names.contains(want), "{want} missing from {names:?}");
    }

    // pipeline worker threads carry their names in the trace metadata
    let tnames: Vec<&str> = parsed.thread_names().values().map(String::as_str).collect();
    assert!(tnames.iter().any(|n| n.starts_with("read-")), "{tnames:?}");
    assert!(tnames.iter().any(|n| n.starts_with("extract-")), "{tnames:?}");

    // derived-image residency shows up as a counter track with values
    assert!(
        parsed.counter_tracks().contains("mem.resident_bytes"),
        "{:?}",
        parsed.counter_tracks()
    );
    for ev in parsed.counters() {
        assert!(ev.arg_num("value").is_some(), "counter {} has no value", ev.name);
    }
}

#[test]
fn tracing_off_emits_nothing_and_preserves_results() {
    let _s = serial();
    let m = tiny_dataset("off");
    let cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        feature_workers: 2,
        feature_classes: FeatureClasses::parse("all").unwrap(),
        ..Default::default()
    };

    // no session installed: span guards are inert, uninstalled sinks stay
    // empty, and the whole pipeline runs with the tracer disabled
    assert!(!trace::enabled());
    let idle = trace::TraceSink::new();
    {
        let _sp = trace::span("never-recorded");
    }
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let off = run_pipeline(&m, &cfg, &ex).unwrap();
    assert!(idle.is_empty(), "disabled tracer must record nothing");

    // the same extraction traced: bit-identical features, same metrics
    let sink = trace::TraceSink::new();
    let session = trace::install(sink.clone());
    let ex2 = FeatureExtractor::new(&cfg).unwrap();
    let on = run_pipeline(&m, &cfg, &ex2).unwrap();
    drop(session);
    assert!(!trace::enabled(), "session drop disables the tracer");
    assert!(sink.span_count() > 0, "enabled tracer must record the run");

    assert_eq!(off.results.len(), on.results.len());
    for (a, b) in off.results.iter().zip(&on.results) {
        assert_eq!(a.case_id, b.case_id);
        assert_eq!(a.features.mesh_volume, b.features.mesh_volume);
        assert_eq!(a.features.maximum_3d_diameter, b.features.maximum_3d_diameter);
        assert_eq!(a.texture, b.texture, "{}", a.case_id);
        assert_eq!(a.first_order, b.first_order, "{}", a.case_id);
        assert_eq!(a.derived, b.derived, "{}", a.case_id);
    }
    for stage in ["stage.read", "stage.preprocess", "stage.mesh", "stage.diameters"] {
        assert_eq!(
            off.metrics.timer(stage).map(|t| t.count),
            on.metrics.timer(stage).map(|t| t.count),
            "{stage}"
        );
    }
}

#[test]
fn batcher_flushes_are_traced_with_occupancy_args() {
    let _s = serial();
    let sink = trace::TraceSink::new();
    let session = trace::install(sink.clone());

    let b = Batcher::new(
        Arc::new(CpuLoopbackBackend::new(Duration::ZERO)),
        BatchConfig { batch_size: 1, linger: Duration::from_millis(1) },
    );
    let verts: Vec<f32> = (0..30).map(|i| (i % 7) as f32).collect();
    b.diameters(verts).unwrap();
    drop(b);
    drop(session);

    let parsed = chrome::parse(&sink.to_chrome_json()).unwrap();
    let flush =
        parsed.spans().find(|e| e.name == "batch.flush").expect("batch.flush span in trace");
    assert_eq!(flush.arg_num("items"), Some(1.0));
    assert_eq!(flush.arg_num("bucket"), Some(512.0));
    assert_eq!(flush.arg_str("trigger"), Some("size"));
}

#[test]
fn engine_threads_trace_requests_even_when_init_fails() {
    let _s = serial();
    let dir = std::env::temp_dir().join("radpipe_trace_engine");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("d512.hlo.txt"), "HloModule fake").unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "name=diameter bucket=512 file=d512.hlo.txt inputs=f32[512,3] outputs=1\n",
    )
    .unwrap();

    let sink = trace::TraceSink::new();
    let session = trace::install(sink.clone());
    let pool = EnginePool::start(&dir, 1).unwrap();
    // the vendored PJRT stub fails client construction: the request still
    // round-trips through the engine thread and is traced with its outcome
    let err = pool.diameters(vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap_err();
    assert!(format!("{err:#}").contains("PJRT"), "{err:#}");
    drop(pool); // joins the engine thread, flushing its spans into the sink
    drop(session);

    let parsed = chrome::parse(&sink.to_chrome_json()).unwrap();
    let req = parsed
        .spans()
        .find(|e| e.name == "engine.request" && e.arg_str("kind") == Some("diameters"))
        .expect("engine.request span in trace");
    assert_eq!(req.arg_str("outcome"), Some("init_failed"));
    let tnames: Vec<&str> = parsed.thread_names().values().map(String::as_str).collect();
    assert!(tnames.contains(&"pjrt-engine"), "{tnames:?}");
}
