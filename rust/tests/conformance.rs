//! Golden-value conformance suite: synthetic masks with closed-form
//! geometry, checked end-to-end through the public extractor API on the
//! CPU path, and through the batch scheduler on the batched path
//! (batched == unbatched bit-for-bit).
//!
//! Golden constants were generated with the cross-language oracle
//! (`python/compile/kernels/ref.py`: `mt_stats_ref` / `mt_vertices_ref` /
//! `diameters_ref`) on bit-identical masks; closed-form values and their
//! documented tolerances bound the discretisation error:
//!
//! * volumes: the marching-tetrahedra isosurface bevels edges, so mesh
//!   volume sits slightly *below* the analytic solid volume (−3 % spheres,
//!   −1 % boxes) and voxelised curved solids sit slightly above;
//! * areas: faceting over-counts curved surfaces (up to +25 % for spheres
//!   at this resolution) and under-counts box edges (−5 %);
//! * box diameters are exact: the extreme mesh vertices sit on the face
//!   planes at ±half a voxel outside the filled region, so every diameter
//!   family equals its closed form exactly.

use std::sync::Arc;
use std::time::Duration;

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::features::brute_force_diameters;
use radpipe::geometry::Vec3;
use radpipe::mc::mesh_roi;
use radpipe::runtime::{BatchConfig, Batcher, CpuLoopbackBackend};
use radpipe::volume::{crop_to_roi, Dims, VoxelGrid};

fn cpu_extractor() -> FeatureExtractor {
    let cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    FeatureExtractor::new(&cfg).unwrap()
}

fn rel_close(got: f64, want: f64, rel: f64) -> bool {
    (got - want).abs() <= rel * want.abs().max(1e-12)
}

// ---------------------------------------------------------------- shapes

fn sphere_mask(n: usize, r: f64, spacing: Vec3) -> VoxelGrid<u8> {
    let mut m = VoxelGrid::zeros(Dims::new(n, n, n), spacing);
    let c = n as f64 / 2.0;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    m.set(x, y, z, 1);
                }
            }
        }
    }
    m
}

/// Solid box over inclusive voxel-index ranges.
fn box_mask(
    dims: Dims,
    xr: (usize, usize),
    yr: (usize, usize),
    zr: (usize, usize),
    spacing: Vec3,
) -> VoxelGrid<u8> {
    let mut m = VoxelGrid::zeros(dims, spacing);
    for z in zr.0..=zr.1 {
        for y in yr.0..=yr.1 {
            for x in xr.0..=xr.1 {
                m.set(x, y, z, 1);
            }
        }
    }
    m
}

/// Axis-aligned cylinder: radius r around the centre voxel, z in [z0, z1].
fn cylinder_mask(n: usize, nz: usize, r: f64, z0: usize, z1: usize) -> VoxelGrid<u8> {
    let mut m = VoxelGrid::zeros(Dims::new(n, n, nz), Vec3::splat(1.0));
    // centre voxel index (10, 10) for n = 21 — matches the oracle run that
    // produced the golden constants
    let (cx, cy) = ((n / 2) as f64, (n / 2) as f64);
    for z in z0..=z1 {
        for y in 0..n {
            for x in 0..n {
                let (dx, dy) = (x as f64 - cx, y as f64 - cy);
                if dx * dx + dy * dy <= r * r {
                    m.set(x, y, z, 1);
                }
            }
        }
    }
    m
}

// ------------------------------------------------------- CPU-path goldens

#[test]
fn sphere_conformance_cpu_path() {
    use std::f64::consts::PI;
    let (r, n) = (8.0f64, 24);
    let f = cpu_extractor().execute_mask(&sphere_mask(n, r, Vec3::splat(1.0))).unwrap().features;

    // closed form with documented tolerance
    let v_analytic = 4.0 / 3.0 * PI * r * r * r;
    let a_analytic = 4.0 * PI * r * r;
    assert!(rel_close(f.mesh_volume, v_analytic, 0.05), "V {} vs {v_analytic}", f.mesh_volume);
    assert!(
        f.surface_area >= a_analytic && f.surface_area <= 1.3 * a_analytic,
        "A {} vs {a_analytic}",
        f.surface_area
    );
    assert!((f.maximum_3d_diameter - 2.0 * r).abs() < 2.0);

    // oracle locks (mt_stats_ref / diameters_ref on the identical mask)
    assert!(rel_close(f.mesh_volume, 2099.0, 1e-3), "V {}", f.mesh_volume);
    assert!(rel_close(f.surface_area, 1004.2422, 1e-3), "A {}", f.surface_area);
    let d_sq = [299.0, 290.0, 290.0, 290.0];
    assert!(rel_close(f.maximum_3d_diameter.powi(2), d_sq[0], 1e-6));
    assert!(rel_close(f.maximum_2d_diameter_slice.powi(2), d_sq[1], 1e-6));
    assert!(rel_close(f.maximum_2d_diameter_column.powi(2), d_sq[2], 1e-6));
    assert!(rel_close(f.maximum_2d_diameter_row.powi(2), d_sq[3], 1e-6));
}

#[test]
fn box_conformance_cpu_path_isotropic() {
    // 12 × 10 × 8 voxels in a 20³ grid, spacing 1 → extents (12, 10, 8) mm
    let mask = box_mask(Dims::new(20, 20, 20), (4, 15), (5, 14), (6, 13), Vec3::splat(1.0));
    let f = cpu_extractor().execute_mask(&mask).unwrap().features;

    // voxel volume is exact by construction
    assert_eq!(f.voxel_count, 12 * 10 * 8);
    assert!((f.voxel_volume - 960.0).abs() < 1e-9);

    // closed forms: V slightly below L³ (edge bevel), A slightly below 2ΣLL
    let (v_cf, a_cf) = (960.0, 592.0);
    assert!(f.mesh_volume <= v_cf && f.mesh_volume >= 0.98 * v_cf, "V {}", f.mesh_volume);
    assert!(f.surface_area <= a_cf && f.surface_area >= 0.95 * a_cf, "A {}", f.surface_area);
    // oracle locks
    assert!(rel_close(f.mesh_volume, 952.75, 1e-3));
    assert!(rel_close(f.surface_area, 573.8051, 1e-3));

    // diameters are exactly the closed forms (see module docs)
    assert!((f.maximum_3d_diameter.powi(2) - (144.0 + 100.0 + 64.0)).abs() < 1e-6);
    assert!((f.maximum_2d_diameter_slice.powi(2) - (144.0 + 100.0)).abs() < 1e-6);
    assert!((f.maximum_2d_diameter_column.powi(2) - (100.0 + 64.0)).abs() < 1e-6);
    assert!((f.maximum_2d_diameter_row.powi(2) - (144.0 + 64.0)).abs() < 1e-6);
}

#[test]
fn box_conformance_cpu_path_anisotropic() {
    // same voxel box, spacing (0.5, 0.5, 2.0) → extents (6, 5, 16) mm
    let mask = box_mask(
        Dims::new(20, 20, 20),
        (4, 15),
        (5, 14),
        (6, 13),
        Vec3::new(0.5, 0.5, 2.0),
    );
    let f = cpu_extractor().execute_mask(&mask).unwrap().features;

    assert!((f.voxel_volume - 480.0).abs() < 1e-9);
    let (v_cf, a_cf) = (480.0, 412.0);
    assert!(f.mesh_volume <= v_cf && f.mesh_volume >= 0.98 * v_cf);
    assert!(f.surface_area <= a_cf && f.surface_area >= 0.95 * a_cf);
    assert!(rel_close(f.mesh_volume, 476.375, 1e-3));
    assert!(rel_close(f.surface_area, 401.8779, 1e-3));

    // exact closed-form diameters in physical mm
    assert!((f.maximum_3d_diameter.powi(2) - (36.0 + 25.0 + 256.0)).abs() < 1e-6);
    assert!((f.maximum_2d_diameter_slice.powi(2) - (36.0 + 25.0)).abs() < 1e-6);
    assert!((f.maximum_2d_diameter_column.powi(2) - (25.0 + 256.0)).abs() < 1e-6);
    assert!((f.maximum_2d_diameter_row.powi(2) - (36.0 + 256.0)).abs() < 1e-6);
}

#[test]
fn cylinder_conformance_cpu_path() {
    use std::f64::consts::PI;
    // r = 6.5, height 10 (z in 3..=12), 21×21×16 grid, spacing 1
    let (r, h) = (6.5f64, 10.0f64);
    let mask = cylinder_mask(21, 16, r, 3, 12);
    let f = cpu_extractor().execute_mask(&mask).unwrap().features;

    // closed forms: the voxelised disc overshoots πr² slightly, flat caps
    // are exact → V within +4 %/−1 %, A within +12 %/−2 %
    let v_cf = PI * r * r * h;
    let a_cf = 2.0 * PI * r * r + 2.0 * PI * r * h;
    assert!(
        f.mesh_volume >= 0.99 * v_cf && f.mesh_volume <= 1.04 * v_cf,
        "V {} vs {v_cf}",
        f.mesh_volume
    );
    assert!(
        f.surface_area >= 0.98 * a_cf && f.surface_area <= 1.12 * a_cf,
        "A {} vs {a_cf}",
        f.surface_area
    );
    // oracle locks
    assert!(rel_close(f.mesh_volume, 1361.75, 1e-3));
    assert!(rel_close(f.surface_area, 738.6114, 1e-3));
    assert!(rel_close(f.maximum_3d_diameter.powi(2), 302.0, 1e-6));
    assert!(rel_close(f.maximum_2d_diameter_slice.powi(2), 202.0, 1e-6));
    assert!(rel_close(f.maximum_2d_diameter_column.powi(2), 269.0, 1e-6));
    assert!(rel_close(f.maximum_2d_diameter_row.powi(2), 269.0, 1e-6));
}

#[test]
fn single_voxel_conformance() {
    // one voxel: MT volume exactly 1/2, oracle area, diameters [3, 2, 2, 2]
    let mut mask = VoxelGrid::zeros(Dims::new(5, 5, 5), Vec3::splat(1.0));
    mask.set(2, 2, 2, 1);
    let f = cpu_extractor().execute_mask(&mask).unwrap().features;
    assert!((f.mesh_volume - 0.5).abs() < 1e-9);
    assert!(rel_close(f.surface_area, 3.6213202, 1e-6));
    assert!((f.maximum_3d_diameter.powi(2) - 3.0).abs() < 1e-9);
    assert!((f.maximum_2d_diameter_slice.powi(2) - 2.0).abs() < 1e-9);
    assert!((f.maximum_2d_diameter_column.powi(2) - 2.0).abs() < 1e-9);
    assert!((f.maximum_2d_diameter_row.powi(2) - 2.0).abs() < 1e-9);
}

// ------------------------------------------------------ batched path

/// All conformance meshes as f32 vertex buffers (the engine input layout).
fn conformance_vertex_sets() -> Vec<Vec<f32>> {
    let masks = vec![
        sphere_mask(24, 8.0, Vec3::splat(1.0)),
        box_mask(Dims::new(20, 20, 20), (4, 15), (5, 14), (6, 13), Vec3::splat(1.0)),
        box_mask(Dims::new(20, 20, 20), (4, 15), (5, 14), (6, 13), Vec3::new(0.5, 0.5, 2.0)),
        cylinder_mask(21, 16, 6.5, 3, 12),
    ];
    masks
        .iter()
        .map(|m| {
            let (cropped, _) = crop_to_roi(m);
            mesh_roi(&cropped).vertices_f32()
        })
        .collect()
}

fn batcher(batch_size: usize) -> Batcher {
    Batcher::new(
        Arc::new(CpuLoopbackBackend::new(Duration::ZERO)),
        BatchConfig { batch_size, linger: Duration::from_millis(1) },
    )
}

#[test]
fn batched_path_is_bit_identical_to_unbatched() {
    let sets = conformance_vertex_sets();

    // unbatched (per-case dispatch) through the same scheduler/backend
    let direct = batcher(1);
    let unbatched: Vec<[f64; 4]> = sets
        .iter()
        .map(|v| direct.diameters(v.clone()).unwrap().0.as_array())
        .collect();

    // batched: concurrent submission so pad-bucket groups actually form
    let grouped = batcher(4);
    let batched: Vec<[f64; 4]> = std::thread::scope(|scope| {
        let handles: Vec<_> = sets
            .iter()
            .map(|v| {
                let grouped = &grouped;
                let v = v.clone();
                scope.spawn(move || grouped.diameters(v).unwrap().0.as_array())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert_eq!(unbatched, batched, "batched and unbatched paths must agree bit-for-bit");

    // and both equal the reference oracle on the identical f32 input
    for (v, got) in sets.iter().zip(&unbatched) {
        let pts: Vec<Vec3> =
            v.chunks_exact(3).map(|c| Vec3::from([c[0], c[1], c[2]])).collect();
        assert_eq!(*got, brute_force_diameters(&pts).as_array());
    }
    assert_eq!(grouped.stats().flushed_items, sets.len() as u64);
}

#[test]
fn batched_path_hits_the_golden_diameters() {
    // mesh coordinates of every conformance shape are dyadic rationals, so
    // the f32 engine layout is exact and the batched path must reproduce
    // the golden squared diameters exactly
    let golden: Vec<[f64; 4]> = vec![
        [299.0, 290.0, 290.0, 290.0],              // sphere
        [308.0, 244.0, 164.0, 208.0],              // box, spacing 1
        [317.0, 61.0, 281.0, 292.0],               // box, spacing (.5, .5, 2)
        [302.0, 202.0, 269.0, 269.0],              // cylinder
    ];
    let grouped = batcher(4);
    let sets = conformance_vertex_sets();
    let got: Vec<[f64; 4]> = std::thread::scope(|scope| {
        let handles: Vec<_> = sets
            .into_iter()
            .map(|v| {
                let grouped = &grouped;
                scope.spawn(move || grouped.diameters(v).unwrap().0.as_array())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (g, want) in got.iter().zip(&golden) {
        for (a, b) in g.iter().zip(want) {
            assert!((a - b).abs() < 1e-9, "{g:?} vs {want:?}");
        }
    }
}

// ------------------------------------------ intensity-class oracle locks

/// Deterministic integer-valued image `(3x + 5y + 7z) mod 97` — exact in
/// f32, so the Rust and numpy oracles see bit-identical inputs.
fn deterministic_image(dims: Dims) -> VoxelGrid<f32> {
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..dims.z {
        for y in 0..dims.y {
            for x in 0..dims.x {
                img.set(x, y, z, ((3 * x + 5 * y + 7 * z) % 97) as f32);
            }
        }
    }
    img
}

#[test]
fn first_order_conformance_oracle_lock() {
    // 24³ sphere r=8 (the shape-locked mask: 2109 voxels) with the
    // deterministic image; goldens from
    // `python/compile/kernels/ref.py::firstorder_ref` on identical values.
    let mask = sphere_mask(24, 8.0, Vec3::splat(1.0));
    let img = deterministic_image(mask.dims);
    let f = radpipe::features::compute_first_order(&img, &mask, 25.0).unwrap();

    // exact values (integer arithmetic below 2^53 — no rounding)
    assert_eq!(f.minimum, 0.0);
    assert_eq!(f.maximum, 96.0);
    assert_eq!(f.range, 96.0);
    assert_eq!(f.energy, 6_461_520.0);
    assert_eq!(f.total_energy, 6_461_520.0); // unit voxel volume
    assert_eq!(f.percentile10, 10.0);
    assert_eq!(f.percentile90, 87.0);
    assert_eq!(f.median, 47.0);
    assert_eq!(f.interquartile_range, 47.0);

    // oracle locks (float summation order may differ at the last ulp)
    assert!(rel_close(f.mean, 47.90706495969654, 1e-9), "{}", f.mean);
    assert!(rel_close(f.variance, 768.6969107311999, 1e-9), "{}", f.variance);
    assert!(rel_close(f.entropy, 1.9959525045510498, 1e-9), "{}", f.entropy);
    assert!(rel_close(f.uniformity, 0.2514138755061118, 1e-9), "{}", f.uniformity);
    assert!(
        rel_close(f.mean_absolute_deviation, 23.94760111612698, 1e-9),
        "{}",
        f.mean_absolute_deviation
    );
    assert!(
        rel_close(f.robust_mean_absolute_deviation, 19.31748657248087, 1e-9),
        "{}",
        f.robust_mean_absolute_deviation
    );
    assert!(
        rel_close(f.root_mean_squared, 55.35145692557499, 1e-9),
        "{}",
        f.root_mean_squared
    );
    assert!(rel_close(f.skewness, 0.029408845567998654, 1e-9), "{}", f.skewness);
    assert!(rel_close(f.kurtosis, 1.8226732170613502, 1e-9), "{}", f.kurtosis);
}

#[test]
fn texture_conformance_oracle_lock() {
    // 4³ pattern `level = ((x + 2y + 3z) mod 5) + 1` (image values 0..4,
    // bin width 1 → levels are the values + 1); goldens from
    // `ref.py::glcm_features_ref` / `glrlm_features_ref`.
    use radpipe::features::texture::{
        accumulate_glcm, accumulate_glcm_reference, compute_texture, discretize, Discretization,
        TextureOptions,
    };
    use radpipe::parallel::Strategy;

    let dims = Dims::new(4, 4, 4);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..4 {
        for y in 0..4 {
            for x in 0..4 {
                img.set(x, y, z, ((x + 2 * y + 3 * z) % 5) as f32);
                mask.set(x, y, z, 1);
            }
        }
    }

    let compute = |threads: usize, strategy: Strategy| {
        let opts = TextureOptions {
            discretization: Discretization::BinWidth(1.0),
            distances: vec![1],
            strategy,
            threads,
            ..Default::default() // all five matrix classes enabled
        };
        compute_texture(&img, &mask, &opts).unwrap().unwrap()
    };
    let t = compute(1, Strategy::EqualSplit);
    assert_eq!(t.ng, 5);

    let g = t.glcm.as_ref().unwrap();
    assert!(rel_close(g.autocorrelation, 8.798967236467236, 1e-9));
    assert!(rel_close(g.contrast, 4.098468660968662, 1e-9));
    assert!(rel_close(g.correlation, -0.031005532369152693, 1e-9));
    assert!(rel_close(g.joint_energy, 0.11610552192149413, 1e-9));
    assert!(rel_close(g.joint_entropy, 3.1639537500081025, 1e-9));
    assert!(rel_close(g.idm, 0.4071759259259259, 1e-9));
    assert!(rel_close(g.idn, 0.7748432765793876, 1e-9));
    assert!(rel_close(g.cluster_shade, 0.07290863483997902, 1e-9));
    assert!(rel_close(g.cluster_prominence, 34.33419886329936, 1e-9));

    let r = t.glrlm.as_ref().unwrap();
    assert!(rel_close(r.short_run_emphasis, 0.9219301719301719, 1e-9));
    assert!(rel_close(r.long_run_emphasis, 1.6124146124146124, 1e-9));
    assert!(rel_close(r.gray_level_non_uniformity, 11.847137659637658, 1e-9));
    assert!(rel_close(r.run_length_non_uniformity, 55.77517077517078, 1e-9));
    assert!(rel_close(r.run_percentage, 0.9242788461538461, 1e-9));
    assert!(rel_close(r.low_gray_level_run_emphasis, 0.2942865199505824, 1e-9));
    assert!(rel_close(r.high_gray_level_run_emphasis, 10.809929091179091, 1e-9));
    assert!(rel_close(r.short_run_low_gray_level_emphasis, 0.2698205872424623, 1e-9));
    assert!(rel_close(r.short_run_high_gray_level_emphasis, 9.971714846714848, 1e-9));
    assert!(rel_close(r.long_run_low_gray_level_emphasis, 0.48490786932193175, 1e-9));
    assert!(rel_close(r.long_run_high_gray_level_emphasis, 17.256394787644787, 1e-9));

    // determinism: every strategy / thread count reproduces the goldens
    // bit-for-bit (the 4³ fixture is below the parallel chunk size, so this
    // exercises the serial shortcut path consistency)
    for strategy in Strategy::ALL {
        for threads in [1usize, 2, 4, 8] {
            assert_eq!(compute(threads, strategy), t, "{strategy:?} x{threads}");
        }
    }

    // ... and a 14³ volume (2744 voxels, above both chunk sizes) exercises
    // the genuinely parallel accumulation paths
    let dims = Dims::new(14, 14, 14);
    let mut big_img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut big_mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..14 {
        for y in 0..14 {
            for x in 0..14 {
                big_img.set(x, y, z, ((x + 2 * y + 3 * z) % 5) as f32);
                big_mask.set(x, y, z, 1);
            }
        }
    }
    let compute_big = |threads: usize, strategy: Strategy| {
        let opts = TextureOptions {
            discretization: Discretization::BinWidth(1.0),
            distances: vec![1, 2],
            strategy,
            threads,
            ..Default::default() // all five matrix classes enabled
        };
        compute_texture(&big_img, &big_mask, &opts).unwrap().unwrap()
    };
    let want = compute_big(1, Strategy::EqualSplit);
    for strategy in Strategy::ALL {
        for threads in [2usize, 4, 8] {
            assert_eq!(compute_big(threads, strategy), want, "{strategy:?} x{threads}");
        }
    }

    // the single-pass GLCM keeps the exact increment set of the
    // bounds-checked reference: lock the raw count matrices on both
    // fixtures for every strategy × thread count
    let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
    let big = discretize(&big_img, &big_mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
    let want_small = accumulate_glcm_reference(&roi, &[1], Strategy::EqualSplit, 1);
    let want_big = accumulate_glcm_reference(&big, &[1, 2], Strategy::EqualSplit, 1);
    for strategy in Strategy::ALL {
        for threads in [1usize, 2, 4, 8] {
            assert_eq!(
                accumulate_glcm(&roi, &[1], strategy, threads),
                want_small,
                "glcm single-pass {strategy:?} x{threads}"
            );
            assert_eq!(
                accumulate_glcm(&big, &[1, 2], strategy, threads),
                want_big,
                "glcm single-pass big {strategy:?} x{threads}"
            );
        }
    }
}

#[test]
fn written_then_read_images_hit_the_oracle_locks() {
    // The tentpole contract: an image volume written to disk in every
    // supported container, read back through `io::read_image`, and fed to
    // `execute_case` reproduces the ref.py oracle locks — proving the file
    // path carries *actual* intensities, not the synthetic stand-in.
    // `deterministic_image` is integer-valued below 97, exact in f32, so
    // write-then-read is bit-preserving and the goldens apply unchanged.
    use radpipe::io::{read_image, read_mask, write_nifti, write_nifti_image, write_rvol};

    let dir = std::env::temp_dir().join("radpipe_conf_img_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let mask = sphere_mask(24, 8.0, Vec3::splat(1.0));
    let img = deterministic_image(mask.dims);
    write_nifti(&dir.join("mask.nii.gz"), &mask).unwrap();
    let mask_back = read_mask(&dir.join("mask.nii.gz")).unwrap();
    assert_eq!(mask_back, mask);

    let fo_cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        feature_classes: radpipe::config::FeatureClasses::parse("firstorder").unwrap(),
        ..Default::default() // bin_width 25 — the oracle-lock discretization
    };
    let ex = FeatureExtractor::new(&fo_cfg).unwrap();

    for name in ["img.nii", "img.nii.gz", "img.rvol", "img.rvol.gz"] {
        let path = dir.join(name);
        if name.starts_with("img.nii") {
            write_nifti_image(&path, &img).unwrap();
        } else {
            write_rvol(&path, &img).unwrap();
        }
        let back = read_image(&path).unwrap();
        assert_eq!(back.dims, img.dims, "{name}");
        assert_eq!(back.data(), img.data(), "{name}: roundtrip not bit-exact");

        let f = ex
            .execute_case(&mask_back, Some(&back))
            .unwrap()
            .first_order
            .expect("firstorder enabled");
        assert_eq!(f.minimum, 0.0, "{name}");
        assert_eq!(f.maximum, 96.0, "{name}");
        assert_eq!(f.energy, 6_461_520.0, "{name}");
        assert!(rel_close(f.mean, 47.90706495969654, 1e-9), "{name}: {}", f.mean);
        assert!(rel_close(f.variance, 768.6969107311999, 1e-9), "{name}: {}", f.variance);
        assert!(rel_close(f.entropy, 1.9959525045510498, 1e-9), "{name}: {}", f.entropy);
        assert!(
            rel_close(f.uniformity, 0.2514138755061118, 1e-9),
            "{name}: {}",
            f.uniformity
        );
    }

    // ... and the synthetic stand-in would NOT have hit those goldens (the
    // silent substitution this PR removes was not a harmless default)
    let standin_cfg = PipelineConfig { synthetic_image: true, ..fo_cfg };
    let s = FeatureExtractor::new(&standin_cfg)
        .unwrap()
        .execute_mask(&mask)
        .unwrap()
        .first_order
        .unwrap();
    assert!(
        !rel_close(s.mean, 47.90706495969654, 1e-6),
        "stand-in mean {} indistinguishable from the real image",
        s.mean
    );

    // GLCM through the same written-then-read path: the 4³ texture fixture
    // at bin width 1 reproduces the `ref.py::glcm_features_ref` goldens.
    let dims = Dims::new(4, 4, 4);
    let mut timg = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut tmask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..4 {
        for y in 0..4 {
            for x in 0..4 {
                timg.set(x, y, z, ((x + 2 * y + 3 * z) % 5) as f32);
                tmask.set(x, y, z, 1);
            }
        }
    }
    write_nifti_image(&dir.join("timg.nii.gz"), &timg).unwrap();
    let timg_back = read_image(&dir.join("timg.nii.gz")).unwrap();
    assert_eq!(timg_back.data(), timg.data());

    let glcm_cfg = PipelineConfig {
        backend: Backend::Cpu,
        cpu_threads: 1,
        feature_classes: radpipe::config::FeatureClasses::parse("glcm").unwrap(),
        bin_width: 1.0,
        ..Default::default()
    };
    let t = FeatureExtractor::new(&glcm_cfg)
        .unwrap()
        .execute_case(&tmask, Some(&timg_back))
        .unwrap()
        .texture
        .expect("glcm enabled");
    let g = t.glcm.as_ref().unwrap();
    assert!(rel_close(g.autocorrelation, 8.798967236467236, 1e-9));
    assert!(rel_close(g.contrast, 4.098468660968662, 1e-9));
    assert!(rel_close(g.correlation, -0.031005532369152693, 1e-9));
    assert!(rel_close(g.joint_energy, 0.11610552192149413, 1e-9));
    assert!(rel_close(g.joint_entropy, 3.1639537500081025, 1e-9));
    assert!(rel_close(g.idm, 0.4071759259259259, 1e-9));
    assert!(rel_close(g.idn, 0.7748432765793876, 1e-9));
    assert!(rel_close(g.cluster_shade, 0.07290863483997902, 1e-9));
    assert!(rel_close(g.cluster_prominence, 34.33419886329936, 1e-9));
}

#[test]
fn region_texture_conformance_oracle_lock() {
    // Same 4³ fixture as the GLCM/GLRLM lock: `level = ((x + 2y + 3z) mod
    // 5) + 1`. Matrix counts are locked *exactly*; derived features at
    // 1e-9 against `ref.py::glszm_features_ref` / `gldm_features_ref` /
    // `ngtdm_features_ref` on the identical integer volume.
    use radpipe::features::texture::{
        accumulate_gldm, accumulate_glszm, accumulate_glszm_indexed, accumulate_ngtdm, discretize,
        gldm_features, glszm_features, ngtdm_features, Discretization,
    };
    use radpipe::parallel::Strategy;

    let dims = Dims::new(4, 4, 4);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..4 {
        for y in 0..4 {
            for x in 0..4 {
                img.set(x, y, z, ((x + 2 * y + 3 * z) % 5) as f32);
                mask.set(x, y, z, 1);
            }
        }
    }
    let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
    assert_eq!(roi.ng, 5);

    // --- GLSZM: exact zone inventory (level, size, count), then features
    let m = accumulate_glszm(&roi);
    assert_eq!(
        m.entries,
        vec![
            (1, 6, 1),
            (1, 7, 1),
            (2, 1, 1),
            (2, 4, 1),
            (2, 8, 1),
            (3, 1, 1),
            (3, 4, 1),
            (3, 8, 1),
            (4, 6, 1),
            (4, 7, 1),
            (5, 2, 2),
            (5, 8, 1),
        ],
        "oracle zone inventory (ref.py::glszm_ref)"
    );
    assert_eq!(m.n_zones, 13);
    assert_eq!(m.n_voxels, 64);
    let f = glszm_features(&m).unwrap();
    assert!(rel_close(f.small_area_emphasis, 0.21294206785278208, 1e-9));
    assert!(rel_close(f.large_area_emphasis, 31.076923076923077, 1e-9));
    assert!(rel_close(f.gray_level_non_uniformity, 2.6923076923076925, 1e-9));
    assert!(rel_close(f.gray_level_non_uniformity_normalized, 0.20710059171597633, 1e-9));
    assert!(rel_close(f.size_zone_non_uniformity, 2.230769230769231, 1e-9));
    assert!(rel_close(f.size_zone_non_uniformity_normalized, 0.17159763313609466, 1e-9));
    assert!(rel_close(f.zone_percentage, 0.203125, 1e-9));
    assert!(rel_close(f.gray_level_variance, 1.9171597633136093, 1e-9));
    assert!(rel_close(f.zone_variance, 6.840236686390534, 1e-9));
    assert!(rel_close(f.zone_entropy, 3.546593564294939, 1e-9));
    assert!(rel_close(f.low_gray_level_zone_emphasis, 0.25602564102564107, 1e-9));
    assert!(rel_close(f.high_gray_level_zone_emphasis, 11.384615384615385, 1e-9));

    // --- GLDM, alpha = 0: exact dependence-column sums, then features
    let m0 = accumulate_gldm(&roi, 0.0, Strategy::EqualSplit, 1);
    let col = |m: &radpipe::features::texture::GldmMatrix, d: usize| -> u64 {
        (0..m.ng)
            .map(|i| m.counts[i * radpipe::features::texture::MAX_DEPENDENCE + d])
            .sum()
    };
    assert_eq!(
        (0..5).map(|d| col(&m0, d)).collect::<Vec<u64>>(),
        vec![2, 22, 24, 8, 8],
        "oracle dependence columns (ref.py::gldm_ref, alpha 0)"
    );
    assert_eq!(m0.counts.iter().sum::<u64>(), 64, "every ROI voxel contributes");
    let f0 = gldm_features(&m0).unwrap();
    assert!(rel_close(f0.small_dependence_emphasis, 0.17166666666666666, 1e-9));
    assert!(rel_close(f0.large_dependence_emphasis, 9.90625, 1e-9));
    assert!(rel_close(f0.gray_level_non_uniformity, 12.8125, 1e-9));
    assert!(rel_close(f0.dependence_non_uniformity, 18.625, 1e-9));
    assert!(rel_close(f0.dependence_non_uniformity_normalized, 0.291015625, 1e-9));
    assert!(rel_close(f0.gray_level_variance, 1.9677734375, 1e-9));
    assert!(rel_close(f0.dependence_variance, 1.0927734375, 1e-9));
    assert!(rel_close(f0.dependence_entropy, 4.144247562960807, 1e-9));
    assert!(rel_close(f0.low_gray_level_emphasis, 0.2966710069444444, 1e-9));
    assert!(rel_close(f0.high_gray_level_emphasis, 10.78125, 1e-9));

    // --- GLDM, alpha = 1: the dependence widens, gray-level marginals
    // stay put (alpha only affects the neighbour comparison)
    let m1 = accumulate_gldm(&roi, 1.0, Strategy::EqualSplit, 1);
    let f1 = gldm_features(&m1).unwrap();
    assert!(rel_close(f1.small_dependence_emphasis, 0.023820066516873725, 1e-9));
    assert!(rel_close(f1.large_dependence_emphasis, 80.46875, 1e-9));
    assert!(rel_close(f1.dependence_non_uniformity, 14.09375, 1e-9));
    assert!(rel_close(f1.dependence_non_uniformity_normalized, 0.22021484375, 1e-9));
    assert!(rel_close(f1.dependence_variance, 11.8896484375, 1e-9));
    assert!(rel_close(f1.dependence_entropy, 4.382813189275507, 1e-9));
    // alpha only regroups voxels across dependence columns, so the
    // gray-level marginals agree (to summation-order ulps)
    assert!(rel_close(f1.gray_level_non_uniformity, f0.gray_level_non_uniformity, 1e-12));
    assert!(rel_close(f1.gray_level_variance, f0.gray_level_variance, 1e-12));
    assert!(rel_close(f1.low_gray_level_emphasis, f0.low_gray_level_emphasis, 1e-12));
    assert!(rel_close(f1.high_gray_level_emphasis, f0.high_gray_level_emphasis, 1e-12));

    // --- NGTDM: exact level populations, then features
    let mn = accumulate_ngtdm(&roi, Strategy::EqualSplit, 1);
    assert_eq!(mn.counts, vec![13, 13, 13, 13, 12], "oracle n_i (ref.py::ngtdm_ref)");
    assert_eq!(mn.n_valid(), 64);
    let fn_ = ngtdm_features(&mn).unwrap();
    assert!(rel_close(fn_.coarseness, 0.061083666812548926, 1e-9));
    assert!(rel_close(fn_.contrast, 0.25405425675685755, 1e-9));
    assert!(rel_close(fn_.busyness, 2.1827984515484515, 1e-9));
    assert!(rel_close(fn_.complexity, 11.472858134512546, 1e-9));
    assert!(rel_close(fn_.strength, 0.48031083803785524, 1e-9));

    // determinism: every strategy / thread count reproduces the locked
    // matrices and features bit-for-bit
    for strategy in Strategy::ALL {
        for threads in [1usize, 2, 4, 8] {
            assert_eq!(accumulate_glszm(&roi), m, "glszm {strategy:?} x{threads}");
            assert_eq!(accumulate_glszm_indexed(&roi, threads), m, "glszm-indexed x{threads}");
            assert_eq!(
                accumulate_gldm(&roi, 1.0, strategy, threads),
                m1,
                "gldm {strategy:?} x{threads}"
            );
            assert_eq!(
                accumulate_ngtdm(&roi, strategy, threads),
                mn,
                "ngtdm {strategy:?} x{threads}"
            );
        }
    }
}

#[test]
fn region_texture_closed_form_fixtures() {
    // Hand-computed closed forms on tiny fixtures (no oracle involved).
    // NB under 26-connectivity the 2×2×2 checkerboard is NOT all
    // singleton zones — face diagonals connect equal levels, giving one
    // size-4 zone per level; the alternating 4×1×1 line is the true
    // all-singletons fixture.
    use radpipe::features::texture::{compute_texture, Discretization, TextureOptions};
    use radpipe::parallel::Strategy;

    let close = |a: f64, b: f64| (a - b).abs() < 1e-12;
    let opts = TextureOptions {
        discretization: Discretization::BinWidth(1.0),
        strategy: Strategy::EqualSplit,
        threads: 1,
        ..Default::default()
    };

    // 2×2×2 checkerboard
    let dims = Dims::new(2, 2, 2);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..2 {
        for y in 0..2 {
            for x in 0..2 {
                img.set(x, y, z, ((x + y + z) % 2) as f32);
                mask.set(x, y, z, 1);
            }
        }
    }
    let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
    let z = t.glszm.unwrap();
    assert!(close(z.small_area_emphasis, 1.0 / 16.0), "one size-4 zone per level");
    assert!(close(z.large_area_emphasis, 16.0));
    assert!(close(z.zone_percentage, 0.25));
    assert!(close(z.zone_entropy, 1.0));
    let d = t.gldm.unwrap();
    assert!(close(d.small_dependence_emphasis, 1.0 / 16.0), "every dependence is 4");
    assert!(close(d.dependence_variance, 0.0));
    let n = t.ngtdm.unwrap();
    assert!(close(n.coarseness, 7.0 / 16.0), "s_i = 16/7 per level");
    assert!(close(n.contrast, 1.0 / 7.0));
    assert!(close(n.busyness, 16.0 / 7.0));
    assert!(close(n.complexity, 4.0 / 7.0));
    assert!(close(n.strength, 7.0 / 16.0));

    // alternating 4×1×1 line: all zones size 1
    let dims = Dims::new(4, 1, 1);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for x in 0..4 {
        img.set(x, 0, 0, (x % 2) as f32);
        mask.set(x, 0, 0, 1);
    }
    let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
    let z = t.glszm.unwrap();
    assert!(close(z.small_area_emphasis, 1.0));
    assert!(close(z.large_area_emphasis, 1.0));
    assert!(close(z.zone_percentage, 1.0));

    // constant 6³ ROI: single zone; NGTDM coarseness edge case (flat
    // neighbourhood sum → the PyRadiomics 1e6 cap)
    let dims = Dims::new(6, 6, 6);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for zz in 0..6 {
        for y in 0..6 {
            for x in 0..6 {
                img.set(x, y, zz, 7.0);
                mask.set(x, y, zz, 1);
            }
        }
    }
    let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
    let z = t.glszm.unwrap();
    assert!(close(z.zone_percentage, 1.0 / 216.0), "single zone of 216 voxels");
    assert!(close(z.zone_entropy, 0.0));
    let n = t.ngtdm.unwrap();
    assert_eq!(n.coarseness, 1e6);
    assert_eq!(n.contrast, 0.0);
    assert_eq!(n.busyness, 0.0);
    assert_eq!(n.complexity, 0.0);
    assert_eq!(n.strength, 0.0);
    assert!(t.named().iter().all(|(_, v)| v.is_finite()));
}

#[test]
fn degenerate_rois_are_defined_for_all_five_texture_classes() {
    // single-voxel, all-one-gray-level and NaN-intensity ROIs must yield
    // defined values (or a located error for NaN) — no panics, no NaN
    // leaks — with every texture class enabled
    use radpipe::features::texture::{compute_texture, TextureOptions};

    let opts = TextureOptions::default(); // all five classes on

    // single voxel
    let dims = Dims::new(3, 3, 3);
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    img.set(1, 1, 1, 5.0);
    mask.set(1, 1, 1, 1);
    let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
    assert!(t.glcm.is_none(), "no co-occurring pairs");
    assert!(t.ngtdm.is_none(), "no valid 26-neighbourhood");
    assert!(t.glrlm.is_some() && t.glszm.is_some() && t.gldm.is_some());
    assert!(t.named().iter().all(|(_, v)| v.is_finite()), "{:?}", t.named());

    // all one gray level
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..3 {
        for y in 0..3 {
            for x in 0..3 {
                img.set(x, y, z, 42.0);
                mask.set(x, y, z, 1);
            }
        }
    }
    let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
    assert_eq!(t.ng, 1);
    assert_eq!(t.named().len(), 47, "all five classes defined on a flat ROI");
    assert!(t.named().iter().all(|(_, v)| v.is_finite()), "{:?}", t.named());

    // NaN inside the ROI: located error, not a panic or NaN leak
    let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..3 {
        for y in 0..3 {
            for x in 0..3 {
                img.set(x, y, z, 1.0);
            }
        }
    }
    img.set(2, 0, 1, f32::NAN);
    let err = compute_texture(&img, &mask, &opts).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("non-finite") && msg.contains("(2, 0, 1)"), "{msg}");
}

// ------------------------------------- derived-image (imgproc) oracle locks

/// Gaussian blob `exp(-r² / 2s²)` (s in mm) sampled on a grid, f32 like
/// the oracle run in `ref.py`.
fn gaussian_blob(
    dims: Dims,
    spacing: Vec3,
    centre: (usize, usize, usize),
    s: f64,
) -> VoxelGrid<f32> {
    let mut g = VoxelGrid::zeros(dims, spacing);
    let c = (
        centre.0 as f64 * spacing.x,
        centre.1 as f64 * spacing.y,
        centre.2 as f64 * spacing.z,
    );
    for z in 0..dims.z {
        for y in 0..dims.y {
            for x in 0..dims.x {
                let p = g.world(x, y, z);
                let r2 = (p.x - c.0).powi(2) + (p.y - c.1).powi(2) + (p.z - c.2).powi(2);
                g.set(x, y, z, (-r2 / (2.0 * s * s)).exp() as f32);
            }
        }
    }
    g
}

#[test]
fn log_filter_conformance_gaussian_blob() {
    use radpipe::imgproc::{gaussian_smooth, log_filter};
    use radpipe::parallel::Strategy;

    // 33³ blob, s = 4 mm, sigma = 2 mm. Closed forms: G_σ ∗ blob is a blob
    // of scale t² = s² + σ² and amplitude A = (s²/t²)^{3/2}; the
    // scale-normalised LoG at the centre is σ²·∇²(G∗f)(0) = -3σ²A/t².
    let (s, sigma) = (4.0f64, 2.0f64);
    let blob = gaussian_blob(Dims::new(33, 33, 33), Vec3::splat(1.0), (16, 16, 16), s);
    let t2 = s * s + sigma * sigma;
    let amplitude = (s * s / t2).powf(1.5);
    let closed = -3.0 * sigma * sigma * amplitude / t2;

    let sm = gaussian_smooth(&blob, sigma, Strategy::EqualSplit, 1).unwrap();
    let got = sm.get(16, 16, 16) as f64;
    assert!(rel_close(got, amplitude, 2e-3), "smooth centre {got} vs {amplitude}");
    // oracle lock (ref.py::gaussian_smooth_ref on the identical volume)
    assert!(rel_close(got, 0.7155762911, 1e-4), "smooth centre {got}");

    let log = log_filter(&blob, sigma, Strategy::EqualSplit, 1).unwrap();
    let centre = log.get(16, 16, 16) as f64;
    assert!(rel_close(centre, closed, 2e-2), "LoG centre {centre} vs closed {closed}");
    // oracle locks (ref.py::log_filter_ref on the identical volume)
    assert!(rel_close(centre, -0.4300333858, 1e-4), "{centre}");
    assert!(rel_close(log.get(16, 16, 12) as f64, -0.2113275975, 1e-4));
    assert!(rel_close(log.get(10, 16, 16) as f64, -0.0698708147, 1e-4));
    assert!(rel_close(log.get(16, 20, 16) as f64, -0.2113276124, 1e-4));

    // anisotropic spacing: mm-denominated sigma reproduces the same
    // physical response on a (1, 1, 2) mm grid
    let blob2 = gaussian_blob(Dims::new(33, 33, 17), Vec3::new(1.0, 1.0, 2.0), (16, 16, 8), s);
    let log2 = log_filter(&blob2, sigma, Strategy::EqualSplit, 1).unwrap();
    let centre2 = log2.get(16, 16, 8) as f64;
    assert!(rel_close(centre2, closed, 2e-2), "aniso LoG centre {centre2}");
    assert!(rel_close(centre2, -0.4298683107, 1e-4), "{centre2}");
}

#[test]
fn wavelet_conformance_subband_energies() {
    use radpipe::imgproc::{haar_decompose, haar_reconstruct, SUB_BANDS};
    use radpipe::parallel::Strategy;

    // fixed 8³ pattern (3x + 5y + 7z) mod 17 — dyadic arithmetic, so the
    // oracle (ref.py::wavelet_ref) and the Rust bands agree exactly
    let dims = Dims::new(8, 8, 8);
    let mut v = VoxelGrid::zeros(dims, Vec3::splat(1.0));
    for z in 0..8 {
        for y in 0..8 {
            for x in 0..8 {
                v.set(x, y, z, ((3 * x + 5 * y + 7 * z) % 17) as f32);
            }
        }
    }
    let bands = haar_decompose(&v, 1, Strategy::EqualSplit, 1).unwrap();
    let golden = [
        ("LLL", 33345.25),
        ("HLL", 1021.8125),
        ("LHL", 1341.1875),
        ("HHL", 1228.25),
        ("LLH", 2464.125),
        ("HLH", 1210.1875),
        ("LHH", 2908.0625),
        ("HHH", 1264.375),
    ];
    for ((band, name), (gname, genergy)) in bands.iter().zip(SUB_BANDS).zip(golden) {
        assert_eq!(name, gname);
        let energy: f64 = band.data().iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert!(rel_close(energy, genergy, 1e-12), "{name}: {energy} vs {genergy}");
    }
    // oracle value probes + exact reconstruction
    assert_eq!(bands[0].get(4, 4, 4), 8.0);
    assert_eq!(bands[7].get(2, 3, 1), -2.125);
    assert_eq!(haar_reconstruct(&bands), v, "Σ bands reconstructs exactly");
}

/// Thread counts for the determinism sweeps: 1/2/4/8 by default. The CI
/// thread-matrix leg sets `RADPIPE_TEST_THREADS` to pin the sweep to
/// exactly that worker count (the serial reference is computed at 1
/// thread regardless), so each leg exercises a distinct configuration
/// instead of repeating the default list.
fn sweep_threads() -> Vec<usize> {
    if let Ok(v) = std::env::var("RADPIPE_TEST_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return vec![n];
            }
        }
    }
    vec![1, 2, 4, 8]
}

#[test]
fn derived_image_determinism_sweep() {
    use radpipe::imgproc::{derive_images, ImageTypes, ImgprocOptions};
    use radpipe::parallel::Strategy;

    // 14³ banded volume: large enough that every pass genuinely splits
    let dims = Dims::new(14, 14, 14);
    let mut img = VoxelGrid::zeros(dims, Vec3::new(0.9, 1.1, 1.4));
    for z in 0..14 {
        for y in 0..14 {
            for x in 0..14 {
                img.set(x, y, z, ((5 * x + 3 * y + 11 * z) % 23) as f32);
            }
        }
    }
    let base = ImgprocOptions {
        image_types: ImageTypes::parse("all").unwrap(),
        log_sigmas: vec![1.0, 2.5],
        wavelet_levels: 2,
        strategy: Strategy::EqualSplit,
        threads: 1,
    };
    let want = derive_images(&img, &base).unwrap();
    assert_eq!(want.len(), 19, "original + 2 LoG + 16 wavelet");
    for strategy in Strategy::ALL {
        for &threads in &sweep_threads() {
            let opts = ImgprocOptions { strategy, threads, ..base.clone() };
            let got = derive_images(&img, &opts).unwrap();
            assert_eq!(got, want, "{strategy:?} threads={threads}");
        }
    }
}

#[test]
fn derived_feature_determinism_sweep() {
    use radpipe::parallel::Strategy;

    // end-to-end: every derived image's first-order + texture features are
    // bit-identical for every strategy × thread count
    let mask = sphere_mask(14, 5.0, Vec3::new(0.8, 0.8, 2.0));
    let extract = |threads: usize, strategy: Strategy| {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: threads,
            strategy,
            feature_classes: radpipe::config::FeatureClasses::parse("all").unwrap(),
            image_types: radpipe::imgproc::ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            // this sweep drives a bare mask; the stand-in needs the opt-in
            synthetic_image: true,
            ..Default::default()
        };
        FeatureExtractor::new(&cfg).unwrap().execute_mask(&mask).unwrap()
    };
    let want = extract(1, Strategy::EqualSplit);
    assert_eq!(want.derived.len(), 11);
    assert!(want.derived.iter().all(|d| d.first_order.is_some() && d.texture.is_some()));
    // the sweep covers all five texture classes — including the
    // region-based GLSZM/GLDM/NGTDM — on original + LoG + wavelet images
    for d in &want.derived {
        let t = d.texture.as_ref().unwrap();
        assert!(
            t.glcm.is_some()
                && t.glrlm.is_some()
                && t.glszm.is_some()
                && t.gldm.is_some()
                && t.ngtdm.is_some(),
            "{}: every texture class must be computed",
            d.image
        );
    }
    for strategy in Strategy::ALL {
        for &threads in &sweep_threads() {
            let got = extract(threads, strategy);
            assert_eq!(got.derived, want.derived, "{strategy:?} threads={threads}");
            assert_eq!(
                got.features.named(),
                want.features.named(),
                "{strategy:?} threads={threads}: shape must not drift either"
            );
        }
    }
}

#[test]
fn streaming_visitor_determinism_matches_materialised() {
    use radpipe::imgproc::{
        derive_images, for_each_derived_image, DerivedImage, ImageTypes, ImgprocOptions,
    };
    use radpipe::parallel::Strategy;

    // 14³ banded volume, every image type, 2 wavelet levels: the streaming
    // visitor must emit the exact collect-based list (names and bits) for
    // every strategy × thread count, while holding ≤ 3 crop-sized volumes
    let dims = Dims::new(14, 14, 14);
    let mut img = VoxelGrid::zeros(dims, Vec3::new(0.9, 1.1, 1.4));
    for z in 0..14 {
        for y in 0..14 {
            for x in 0..14 {
                img.set(x, y, z, ((5 * x + 3 * y + 11 * z) % 23) as f32);
            }
        }
    }
    let base = ImgprocOptions {
        image_types: ImageTypes::parse("all").unwrap(),
        log_sigmas: vec![1.0, 2.5],
        wavelet_levels: 2,
        strategy: Strategy::EqualSplit,
        threads: 1,
    };
    let want = derive_images(&img, &base).unwrap();
    assert_eq!(want.len(), 19, "original + 2 LoG + 16 wavelet");
    let vol_bytes = (dims.len() * std::mem::size_of::<f32>()) as u64;
    for strategy in Strategy::ALL {
        for &threads in &sweep_threads() {
            let opts = ImgprocOptions { strategy, threads, ..base.clone() };
            let mut got: Vec<DerivedImage> = Vec::new();
            let stats = for_each_derived_image(&img, &opts, |d| {
                got.push(DerivedImage { name: d.name, image: d.image.clone() });
                Ok(())
            })
            .unwrap();
            assert_eq!(got, want, "{strategy:?} threads={threads}");
            assert_eq!(stats.images, want.len());
            assert!(
                stats.peak_resident_bytes <= 3 * vol_bytes,
                "{strategy:?} threads={threads}: streaming held {} bytes (> 3 volumes)",
                stats.peak_resident_bytes
            );
        }
    }
}

#[test]
fn streaming_feature_determinism_matches_materialised_flow() {
    use radpipe::features::texture::Discretization;
    use radpipe::features::{compute_first_order_with, compute_texture};
    use radpipe::imgproc::derive_images;
    use radpipe::parallel::Strategy;
    use radpipe::volume::crop_box;

    // end-to-end: the streamed extractor's per-image feature set must be
    // bit-identical to recomputing it from the materialised bank, for
    // every strategy × thread count
    let mask = sphere_mask(14, 5.0, Vec3::splat(1.0));
    let img = deterministic_image(mask.dims);
    for strategy in Strategy::ALL {
        for &threads in &sweep_threads() {
            let cfg = PipelineConfig {
                backend: Backend::Cpu,
                cpu_threads: threads,
                strategy,
                feature_classes: radpipe::config::FeatureClasses::parse("all").unwrap(),
                image_types: radpipe::imgproc::ImageTypes::parse("all").unwrap(),
                log_sigmas: vec![1.0, 2.0],
                wavelet_levels: 2,
                ..Default::default()
            };
            let ex = FeatureExtractor::new(&cfg).unwrap();
            let out = ex.execute_case(&mask, Some(&img)).unwrap();
            assert_eq!(out.derived.len(), 19, "original + 2 LoG + 16 wavelet");

            let (cropped_mask, offset) = crop_to_roi(&mask);
            let cropped_img = crop_box(&img, offset, cropped_mask.dims);
            let bank = derive_images(&cropped_img, &ex.imgproc_options()).unwrap();
            assert_eq!(bank.len(), out.derived.len());
            for (got, d) in out.derived.iter().zip(&bank) {
                assert_eq!(got.image, d.name, "{strategy:?} threads={threads}");
                let fo = compute_first_order_with(
                    &d.image,
                    &cropped_mask,
                    Discretization::BinWidth(25.0),
                );
                assert_eq!(got.first_order, fo, "{strategy:?} x{threads} {}", d.name);
                let tex =
                    compute_texture(&d.image, &cropped_mask, &ex.texture_options()).unwrap();
                assert_eq!(got.texture, tex, "{strategy:?} x{threads} {}", d.name);
            }
        }
    }
}

#[test]
fn log_only_derived_feature_determinism_sweep() {
    use radpipe::parallel::Strategy;

    // no `original` derived image: the legacy first_order/texture mirrors
    // must stay empty (not alias a LoG image) and the LoG-only feature
    // set must be bit-identical across every strategy × thread count
    let mask = sphere_mask(14, 5.0, Vec3::new(0.8, 0.8, 2.0));
    let extract = |threads: usize, strategy: Strategy| {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: threads,
            strategy,
            feature_classes: radpipe::config::FeatureClasses::parse("all").unwrap(),
            image_types: radpipe::imgproc::ImageTypes::parse("log").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            // this sweep drives a bare mask; the stand-in needs the opt-in
            synthetic_image: true,
            ..Default::default()
        };
        FeatureExtractor::new(&cfg).unwrap().execute_mask(&mask).unwrap()
    };
    let want = extract(1, Strategy::EqualSplit);
    assert!(want.first_order.is_none(), "no original entry to mirror");
    assert!(want.texture.is_none());
    assert_eq!(want.derived.len(), 2);
    assert!(want.derived.iter().all(|d| d.image.starts_with("log-sigma")));
    for strategy in Strategy::ALL {
        for &threads in &sweep_threads() {
            let got = extract(threads, strategy);
            assert!(got.first_order.is_none() && got.texture.is_none());
            assert_eq!(got.derived, want.derived, "{strategy:?} threads={threads}");
        }
    }
}

// ------------------------------------- engine-backed batching (artifacts)

#[test]
fn engine_batched_matches_unbatched_when_artifacts_exist() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let unbatched_cfg = PipelineConfig {
        backend: Backend::Accelerated,
        artifact_dir: dir.clone(),
        ..Default::default()
    };
    let batched_cfg = PipelineConfig {
        backend: Backend::Accelerated,
        artifact_dir: dir,
        engine_count: 2,
        batch_size: 4,
        batch_linger_ms: 1,
        ..Default::default()
    };
    let unbatched = FeatureExtractor::new(&unbatched_cfg).unwrap();
    let batched = FeatureExtractor::new(&batched_cfg).unwrap();
    let mask = sphere_mask(20, 6.0, Vec3::new(0.8, 0.8, 2.5));
    let a = unbatched.execute_mask(&mask).unwrap().features;
    let b = batched.execute_mask(&mask).unwrap().features;
    for ((name, va), (_, vb)) in a.named().iter().zip(b.named()) {
        if va.is_nan() && vb.is_nan() {
            continue;
        }
        assert_eq!(*va, vb, "{name}: batched {vb} vs unbatched {va}");
    }
}
