//! Cohort batch-mode integration tests: cache replay identity, kill-mid-run
//! resume, and per-case failure isolation — through the public API only.

use std::path::PathBuf;

use radpipe::cohort::{run_batch, BatchOptions, BatchOutcome};
use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::FeatureExtractor;
use radpipe::synth::{generate_dataset, GenOptions};

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("radpipe_cohort_{tag}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Generate the 20-case paper dataset (tiny scale) and derive a cohort
/// CSV manifest from it.
fn fixture(tag: &str) -> (PathBuf, PathBuf, usize) {
    let dir = tdir(tag);
    let m = generate_dataset(&dir, &GenOptions { scale: 0.002, seed: 3 }).unwrap();
    let mut csv = String::from("case_id,mask\n");
    for e in &m.cases {
        csv.push_str(&format!("{},{}\n", e.case_id, e.mask.display()));
    }
    let manifest = dir.join("cohort.csv");
    std::fs::write(&manifest, csv).unwrap();
    (dir, manifest, m.cases.len())
}

fn cfg() -> PipelineConfig {
    PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() }
}

fn opts(manifest: &PathBuf) -> BatchOptions {
    BatchOptions {
        manifest: manifest.clone(),
        cache_dir: None,
        cache_max_bytes: 0,
        journal: None,
        resume: false,
    }
}

fn errors_total(outcome: &BatchOutcome) -> u64 {
    outcome
        .metrics
        .counters
        .iter()
        .filter(|(k, _)| k.starts_with("errors."))
        .map(|(_, v)| *v)
        .sum()
}

#[test]
fn warm_cache_run_is_bit_identical_with_zero_extractions() {
    let (dir, manifest, total) = fixture("warm");
    let cfg = cfg();
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let mut o = opts(&manifest);
    o.cache_dir = Some(dir.join("cache"));

    let cold = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(cold.total, total);
    assert_eq!(cold.executed, total, "cold cache executes everything");
    assert_eq!(cold.from_cache, 0);
    assert_eq!(cold.failed, 0);
    assert_eq!(cold.metrics.counter("cache.miss"), Some(total as u64));

    let warm = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(warm.executed, 0, "warm cache extracts nothing");
    assert_eq!(warm.from_cache, total);
    assert_eq!(
        warm.metrics.counter("cache.hit"),
        Some(warm.succeeded as u64),
        "every success came from the cache"
    );
    assert_eq!(
        cold.to_csv(),
        warm.to_csv(),
        "cache replay must reproduce the report byte-for-byte"
    );
    // warm runs skip the pipeline entirely: no stage timers, only cache ones
    assert!(warm.metrics.timer("stage.mesh").is_none());
    assert!(warm.metrics.timer("stage.cache").is_some());
}

#[test]
fn resume_after_a_kill_reexecutes_only_unfinished_cases() {
    let (dir, manifest, total) = fixture("resume");
    let cfg = cfg();
    let ex = FeatureExtractor::new(&cfg).unwrap();

    // the reference run, journaled to completion
    let full_journal = dir.join("full.journal");
    let mut o = opts(&manifest);
    o.journal = Some(full_journal.clone());
    let reference = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(reference.failed, 0);
    let reference_csv = reference.to_csv();
    let journal_text = std::fs::read_to_string(&full_journal).unwrap();
    let lines: Vec<&str> = journal_text.lines().collect();
    assert_eq!(lines.len(), total, "one journal line per case");

    // simulate a kill after N cases: keep N intact lines plus half of the
    // next one (the torn tail a SIGKILL mid-write leaves behind)
    for n in [0usize, 7, total - 1] {
        let partial = dir.join(format!("killed_at_{n}.journal"));
        let mut text: String =
            lines[..n].iter().map(|l| format!("{l}\n")).collect();
        let torn = lines[n];
        text.push_str(&torn[..torn.len() / 2]);
        std::fs::write(&partial, text).unwrap();

        let mut o = opts(&manifest);
        o.journal = Some(partial);
        o.resume = true;
        let resumed = run_batch(&cfg, &ex, &o).unwrap();
        assert_eq!(resumed.from_journal, n, "kill after {n}");
        assert_eq!(
            resumed.executed,
            total - n,
            "only unfinished cases re-execute (kill after {n})"
        );
        assert_eq!(resumed.failed, 0);
        assert_eq!(
            resumed.to_csv(),
            reference_csv,
            "resumed report must match the uninterrupted run (kill after {n})"
        );
    }
}

#[test]
fn a_poisoned_case_is_isolated_and_counted() {
    let (dir, manifest, total) = fixture("poison");
    std::fs::write(dir.join("garbage.rvol.gz"), b"definitely not a volume").unwrap();
    let mut text = std::fs::read_to_string(&manifest).unwrap();
    text.push_str("poisoned,garbage.rvol.gz\n");
    std::fs::write(&manifest, text).unwrap();

    let cfg = cfg();
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let mut o = opts(&manifest);
    o.cache_dir = Some(dir.join("cache"));

    let cold = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(cold.total, total + 1);
    assert_eq!(cold.succeeded, total, "healthy cases are unaffected");
    assert_eq!(cold.failed, 1);
    let failed_rows: Vec<_> =
        cold.rows.iter().filter(|r| r.status == "failed").collect();
    assert_eq!(failed_rows.len(), 1);
    assert_eq!(failed_rows[0].case_id, "poisoned");
    assert!(!failed_rows[0].error.is_empty(), "the error column carries the cause");
    assert_eq!(
        errors_total(&cold),
        1,
        "error counters must account for every failure: {:?}",
        cold.metrics.counters
    );

    // failures are never cached: a warm re-run retries exactly the failed
    // case and replays everything else
    let warm = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(warm.from_cache, total);
    assert_eq!(warm.executed, 1, "only the poisoned case re-executes");
    assert_eq!(warm.failed, 1);
    assert_eq!(
        warm.metrics.counter("cache.hit"),
        Some(warm.succeeded as u64),
        "CI gate: hits == successes on a warm run"
    );
    assert_eq!(cold.to_csv(), warm.to_csv());
}

#[test]
fn journal_and_cache_compose_across_a_resume() {
    // kill-then-resume with the cache on: replayed-from-journal cases must
    // not double-count as cache hits, and the resumed run still stores the
    // features it computes
    let (dir, manifest, total) = fixture("compose");
    let cfg = cfg();
    let ex = FeatureExtractor::new(&cfg).unwrap();
    let journal = dir.join("run.journal");
    let mut o = opts(&manifest);
    o.cache_dir = Some(dir.join("cache"));
    o.journal = Some(journal.clone());

    let first = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(first.executed, total);
    let reference_csv = first.to_csv();

    // keep only the first 5 journal entries, as if the run died there
    let text = std::fs::read_to_string(&journal).unwrap();
    let head: String = text.lines().take(5).map(|l| format!("{l}\n")).collect();
    std::fs::write(&journal, head).unwrap();
    // and wipe the cache entries so the resumed run actually executes
    let _ = std::fs::remove_dir_all(dir.join("cache"));

    let mut o2 = o.clone();
    o2.resume = true;
    let resumed = run_batch(&cfg, &ex, &o2).unwrap();
    assert_eq!(resumed.from_journal, 5);
    assert_eq!(resumed.from_cache, 0, "cache was wiped");
    assert_eq!(resumed.executed, total - 5);
    assert_eq!(resumed.to_csv(), reference_csv);

    // the resumed run refilled the cache for the cases it executed
    let warm = run_batch(&cfg, &ex, &o).unwrap();
    assert_eq!(warm.from_cache, total - 5);
    assert_eq!(warm.executed, 5, "journal-replayed cases were not re-cached");
}
