//! Property-based tests (via the in-repo testkit) on the coordinator's
//! core invariants: routing/bucketing, batching/padding, diameter-strategy
//! equivalence, mesh invariants and channel state.

use radpipe::features::{brute_force_diameters, Diameters};
use radpipe::geometry::{Aabb, Vec3};
use radpipe::imgproc::{haar_decompose, haar_reconstruct, resample_image, resample_mask};
use radpipe::mc::{mesh_roi, planar_diameters_grouped};
use radpipe::parallel::{compute_diameters, Strategy};
use radpipe::pipeline::bounded;
use radpipe::runtime::{bucket_for, pad_triangles, pad_vertices};
use radpipe::testkit::{forall, int_range, Gen, Pcg32};
use radpipe::volume::{crop_to_roi, Dims, VoxelGrid};

/// Random vertex cloud with quantised planes (mesh-like).
fn cloud_gen() -> Gen<Vec<Vec3>> {
    Gen::new(|rng: &mut Pcg32, size: usize| {
        let n = 1 + (rng.next_u32() as usize) % (size * 24 + 8);
        (0..n)
            .map(|_| {
                Vec3::new(
                    (rng.below(200) as f64) * 0.5,
                    (rng.below(200) as f64) * 0.5,
                    (rng.below(32) as f64) * 1.5,
                )
            })
            .collect()
    })
}

/// Random small mask volume.
fn mask_gen() -> Gen<VoxelGrid<u8>> {
    Gen::new(|rng: &mut Pcg32, size: usize| {
        let d = 4 + (rng.next_u32() as usize) % (size / 4 + 4).min(12);
        let mut m = VoxelGrid::zeros(
            Dims::new(d, d, d),
            Vec3::new(rng.range_f64(0.5, 2.0), rng.range_f64(0.5, 2.0), rng.range_f64(0.5, 3.0)),
        );
        let fill = rng.range_f64(0.05, 0.5);
        for z in 1..d - 1 {
            for y in 1..d - 1 {
                for x in 1..d - 1 {
                    if rng.next_f64() < fill {
                        m.set(x, y, z, 1);
                    }
                }
            }
        }
        m
    })
}

#[test]
fn prop_all_strategies_equal_brute_force() {
    forall("strategies-equal-brute", &cloud_gen(), 40, |v| {
        let want = brute_force_diameters(v);
        Strategy::ALL.into_iter().all(|s| {
            let (got, _) = compute_diameters(s, v, 3);
            got.as_array() == want.as_array()
        })
    });
}

/// Degenerate point-cloud families: 0/1/2 points, collinear runs, coplanar
/// sheets, and all-identical clusters — the shapes where decomposition
/// edge-cases (empty blocks, single-row tiles, zero pair counts) bite.
fn degenerate_cloud_gen() -> Gen<Vec<Vec3>> {
    Gen::new(|rng: &mut Pcg32, size: usize| {
        let family = rng.below(6);
        let n = 1 + (rng.next_u32() as usize) % (size * 8 + 4);
        let q = |rng: &mut Pcg32| (rng.below(64) as f64) * 0.25;
        match family {
            0 => Vec::new(),
            1 => vec![Vec3::new(q(rng), q(rng), q(rng))],
            2 => vec![Vec3::new(q(rng), q(rng), q(rng)), Vec3::new(q(rng), q(rng), q(rng))],
            3 => {
                // collinear: p + t·d with quantised t (exact arithmetic)
                let p = Vec3::new(q(rng), q(rng), q(rng));
                let d = Vec3::new(q(rng) - 8.0, q(rng) - 8.0, q(rng) - 8.0);
                (0..n).map(|i| p + d * (i as f64)).collect()
            }
            4 => {
                // coplanar: constant z sheet
                let z = q(rng);
                (0..n).map(|_| Vec3::new(q(rng), q(rng), z)).collect()
            }
            _ => {
                // all-identical cluster
                let p = Vec3::new(q(rng), q(rng), q(rng));
                vec![p; n]
            }
        }
    })
}

#[test]
fn prop_strategies_equal_brute_force_on_degenerate_inputs() {
    forall("strategies-degenerate", &degenerate_cloud_gen(), 120, |v| {
        let want = brute_force_diameters(v);
        Strategy::ALL.into_iter().all(|s| {
            [1usize, 2, 5].into_iter().all(|threads| {
                let (got, _) = compute_diameters(s, v, threads);
                got.as_array() == want.as_array()
            })
        })
    });
}

#[test]
fn strategies_equal_brute_force_on_tiny_fixed_inputs() {
    // the explicit 0-, 1- and 2-point cases, plus exact collinear and
    // coplanar micro-fixtures (no RNG so failures are trivially replayable)
    let fixtures: Vec<Vec<Vec3>> = vec![
        vec![],
        vec![Vec3::new(1.0, 2.0, 3.0)],
        vec![Vec3::new(0.0, 0.0, 0.0), Vec3::new(1.0, 1.0, 1.0)],
        vec![Vec3::new(0.0, 0.0, 0.0), Vec3::new(0.0, 0.0, 0.0)],
        (0..5).map(|i| Vec3::new(i as f64, 2.0 * i as f64, -(i as f64))).collect(),
        (0..7).map(|i| Vec3::new(i as f64, (i * i) as f64, 4.0)).collect(),
    ];
    for v in &fixtures {
        let want = brute_force_diameters(v);
        for s in Strategy::ALL {
            for threads in [1usize, 3] {
                let (got, _) = compute_diameters(s, v, threads);
                assert_eq!(
                    got.as_array(),
                    want.as_array(),
                    "{s:?} threads={threads} n={}",
                    v.len()
                );
            }
        }
    }
}

#[test]
fn prop_diameter_bounded_by_aabb_diagonal() {
    forall("diameter-le-diagonal", &cloud_gen(), 40, |v| {
        let d = brute_force_diameters(v);
        let diag = Aabb::from_points(v.iter().copied()).diagonal();
        d.d3d_sq.sqrt() <= diag + 1e-9
    });
}

#[test]
fn prop_planar_diameters_bounded_by_3d() {
    forall("planar-le-3d", &cloud_gen(), 40, |v| {
        let d = brute_force_diameters(v);
        [d.dxy_sq, d.dyz_sq, d.dxz_sq].into_iter().all(|p| p <= d.d3d_sq + 1e-9)
    });
}

#[test]
fn prop_grouped_planars_match_brute_force() {
    forall("grouped-planar-equiv", &cloud_gen(), 30, |v| {
        let brute = brute_force_diameters(v);
        let grouped = planar_diameters_grouped(v);
        (grouped[0] - brute.dxy_sq).abs() < 1e-9
            && (grouped[1] - brute.dyz_sq).abs() < 1e-9
            && (grouped[2] - brute.dxz_sq).abs() < 1e-9
    });
}

#[test]
fn prop_vertex_padding_preserves_diameters() {
    forall("padding-invariant", &cloud_gen(), 30, |v| {
        let base = brute_force_diameters(v);
        let f32s: Vec<f32> = v.iter().flat_map(|p| p.to_f32()).collect();
        let bucket = (v.len() + 17).next_power_of_two();
        let padded = pad_vertices(&f32s, bucket).unwrap();
        let back: Vec<Vec3> = padded
            .chunks_exact(3)
            .map(|c| Vec3::from([c[0], c[1], c[2]]))
            .collect();
        let after = brute_force_diameters(&back);
        // f32 roundtrip: exact because inputs are f32-representable halves
        base.as_array()
            .iter()
            .zip(after.as_array())
            .all(|(a, b)| (a - b).abs() < 1e-6 * a.abs().max(1.0))
    });
}

#[test]
fn prop_bucket_routing_is_minimal_and_fits() {
    let buckets = [512usize, 1024, 2048, 4096, 8192];
    forall("bucket-minimal", &int_range(1, 8192), 200, |&n| {
        let b = bucket_for(n as usize, &buckets).unwrap();
        let fits = n as usize <= b;
        let minimal = buckets.iter().all(|&x| x >= b || x < n as usize);
        fits && minimal
    });
}

#[test]
fn prop_triangle_padding_never_changes_soup_stats() {
    forall("tri-padding", &int_range(0, 60), 30, |&t| {
        let mut rng = Pcg32::new(t as u64);
        let tris: Vec<f32> = (0..t * 9).map(|_| rng.range_f64(-5.0, 5.0) as f32).collect();
        let padded = pad_triangles(&tris, (t as usize + 13).next_power_of_two()).unwrap();
        // volume/area contributions of padding rows must be exactly zero
        padded[tris.len()..].iter().all(|&v| v == 0.0)
    });
}

#[test]
fn prop_mesh_watertight_and_consistent() {
    forall("mesh-watertight", &mask_gen(), 25, |mask| {
        let mesh = mesh_roi(mask);
        if mesh.triangles.is_empty() {
            return mask.count_nonzero() == 0 || mesh.stats.volume == 0.0;
        }
        // (a) vertices unique
        let mut seen = std::collections::HashSet::new();
        for v in &mesh.vertices {
            if !seen.insert((v.x.to_bits(), v.y.to_bits(), v.z.to_bits())) {
                return false;
            }
        }
        // (b) signed volume is translation invariant (closed surface)
        let shift = Vec3::new(11.0, -7.0, 5.0);
        let mut s0 = 0.0;
        let mut s1 = 0.0;
        for i in 0..mesh.triangles.len() {
            let t = mesh.triangle(i);
            s0 += t.signed_volume();
            let t2 = radpipe::geometry::Triangle::new(t.a + shift, t.b + shift, t.c + shift);
            s1 += t2.signed_volume();
        }
        if (s0 - s1).abs() > 1e-6 * s0.abs().max(1.0) {
            return false;
        }
        // (c) volume ≤ voxel volume of the mask (bevelled isosurface)
        let voxvol = mask.count_nonzero() as f64 * mask.voxel_volume();
        mesh.stats.volume <= voxvol + 1e-9
    });
}

#[test]
fn prop_crop_preserves_mesh_stats() {
    forall("crop-preserves-stats", &mask_gen(), 25, |mask| {
        let full = mesh_roi(mask);
        let (cropped, _) = crop_to_roi(mask);
        let crop = mesh_roi(&cropped);
        full.vertices.len() == crop.vertices.len()
            && (full.stats.volume - crop.stats.volume).abs() < 1e-9
            && (full.stats.area - crop.stats.area).abs() < 1e-9
    });
}

#[test]
fn prop_diameters_merge_commutative_idempotent() {
    let dgen = Gen::new(|rng: &mut Pcg32, _| Diameters {
        d3d_sq: rng.range_f64(-1.0, 100.0),
        dxy_sq: rng.range_f64(-1.0, 100.0),
        dyz_sq: rng.range_f64(-1.0, 100.0),
        dxz_sq: rng.range_f64(-1.0, 100.0),
    });
    let pair = Gen::new(move |rng: &mut Pcg32, s| (dgen.sample(rng, s), dgen.sample(rng, s)));
    forall("merge-algebra", &pair, 50, |(a, b)| {
        a.merge(b).as_array() == b.merge(a).as_array()
            && a.merge(a).as_array() == a.as_array()
    });
}

/// Random trilinear polynomial field `Σ c_abc · x^a y^b z^c` (a,b,c ≤ 1)
/// with small integer coefficients, sampled on a grid with dyadic spacing
/// — every arithmetic step is exact in f32/f64, so trilinear resampling
/// must reproduce the field exactly at the resampled positions.
fn trilinear_field_gen() -> Gen<(VoxelGrid<f32>, [f64; 8], Vec3)> {
    Gen::new(|rng: &mut Pcg32, _| {
        let dy = [0.25, 0.5, 1.0, 2.0];
        let spacing = Vec3::new(
            dy[rng.below(4) as usize],
            dy[rng.below(4) as usize],
            dy[rng.below(4) as usize],
        );
        let new_spacing = Vec3::new(
            dy[rng.below(4) as usize],
            dy[rng.below(4) as usize],
            dy[rng.below(4) as usize],
        );
        let d = 3 + (rng.below(6) as usize);
        let c: [f64; 8] = std::array::from_fn(|_| (rng.below(9) as f64) - 4.0);
        let mut g = VoxelGrid::zeros(Dims::new(d, d, d), spacing);
        for z in 0..d {
            for y in 0..d {
                for x in 0..d {
                    let p = g.world(x, y, z);
                    g.set(x, y, z, eval_trilinear(&c, p) as f32);
                }
            }
        }
        (g, c, new_spacing)
    })
}

fn eval_trilinear(c: &[f64; 8], p: Vec3) -> f64 {
    c[0] + c[1] * p.x
        + c[2] * p.y
        + c[3] * p.z
        + c[4] * p.x * p.y
        + c[5] * p.x * p.z
        + c[6] * p.y * p.z
        + c[7] * p.x * p.y * p.z
}

#[test]
fn prop_trilinear_resample_reproduces_trilinear_fields() {
    forall("trilinear-exact", &trilinear_field_gen(), 60, |(g, c, new_spacing)| {
        let out = resample_image(g, *new_spacing, Strategy::EqualSplit, 2).unwrap();
        for z in 0..out.dims.z {
            for y in 0..out.dims.y {
                for x in 0..out.dims.x {
                    let want = eval_trilinear(c, out.world(x, y, z));
                    let got = out.get(x, y, z) as f64;
                    if (got - want).abs() > 1e-9 * want.abs().max(1.0) {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prop_resample_at_source_spacing_is_identity() {
    forall("resample-identity", &trilinear_field_gen(), 40, |(g, _, _)| {
        let img = resample_image(g, g.spacing, Strategy::EqualSplit, 3).unwrap();
        if img != *g {
            return false;
        }
        // nearest-neighbour mask path: also the bit-exact identity
        let mask = g.map(|v| (v as i64 & 1) as u8);
        resample_mask(&mask, mask.spacing, Strategy::EqualSplit, 3).unwrap() == mask
    });
}

/// Random small integer volume (values exact in f32 and dyadic through
/// the Haar `/2` normalisation).
fn integer_volume_gen() -> Gen<VoxelGrid<f32>> {
    Gen::new(|rng: &mut Pcg32, size: usize| {
        let dx = 2 + (rng.next_u32() as usize) % (size / 4 + 6).min(9);
        let dy = 2 + (rng.next_u32() as usize) % 7;
        let dz = 1 + (rng.next_u32() as usize) % 7;
        let mut g = VoxelGrid::zeros(Dims::new(dx, dy, dz), Vec3::splat(1.0));
        for v in g.data_mut() {
            *v = rng.below(256) as f32;
        }
        g
    })
}

#[test]
fn prop_streaming_visitor_matches_materialised_on_random_dims() {
    use radpipe::imgproc::{
        derive_images, for_each_derived_image, DerivedImage, ImageTypes, ImgprocOptions,
    };

    // random dims/spacings/intensities: the streaming visitor must emit
    // exactly the collect-based bank (names and bits) while holding at
    // most ~2 crop-sized volumes (in-flight image + wavelet LLL seed)
    let vol_gen = Gen::new(|rng: &mut Pcg32, size: usize| {
        let dim = |rng: &mut Pcg32| 2 + (rng.next_u32() as usize) % (size / 3 + 5).min(9);
        let dims = Dims::new(dim(rng), dim(rng), dim(rng));
        let spacing = Vec3::new(
            rng.range_f64(0.5, 2.0),
            rng.range_f64(0.5, 2.0),
            rng.range_f64(0.5, 3.0),
        );
        let mut g = VoxelGrid::zeros(dims, spacing);
        for v in g.data_mut() {
            *v = rng.below(128) as f32;
        }
        g
    });
    forall("streaming-matches-materialised", &vol_gen, 25, |g| {
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0],
            wavelet_levels: 2,
            strategy: Strategy::LocalAccumulators,
            threads: 2,
        };
        let want = derive_images(g, &opts).unwrap();
        let mut got: Vec<DerivedImage> = Vec::new();
        let stats = for_each_derived_image(g, &opts, |d| {
            got.push(DerivedImage { name: d.name, image: d.image.clone() });
            Ok(())
        })
        .unwrap();
        let vol_bytes = (g.dims.len() * std::mem::size_of::<f32>()) as u64;
        got == want
            && stats.images == want.len()
            && stats.peak_resident_bytes <= 2 * vol_bytes
    });
}

#[test]
fn prop_haar_roundtrip_is_exact_on_integer_volumes() {
    forall("haar-roundtrip", &integer_volume_gen(), 60, |g| {
        for level in 1..=2 {
            let bands = haar_decompose(g, level, Strategy::LocalAccumulators, 2).unwrap();
            if haar_reconstruct(&bands) != *g {
                return false;
            }
        }
        true
    });
}

// ---------------------------------------- region-based texture classes

use radpipe::features::texture::{
    accumulate_gldm, accumulate_glszm, accumulate_ngtdm, compute_texture, discretize,
    DiscretizedRoi, Discretization, TextureOptions, MAX_DEPENDENCE, NEIGHBOURS_26,
};

/// Random small labelled case: an intensity volume (few integer values, so
/// `BinWidth(1)` discretizes losslessly) plus a holey mask, dims ≤ 8³.
fn texture_case_gen() -> Gen<(VoxelGrid<f32>, VoxelGrid<u8>)> {
    Gen::new(|rng: &mut Pcg32, size: usize| {
        let dim = |rng: &mut Pcg32| 2 + (rng.next_u32() as usize) % (size / 4 + 4).min(7);
        let dims = Dims::new(dim(rng), dim(rng), dim(rng));
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let levels = 2 + rng.below(4);
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    img.set(x, y, z, rng.below(levels) as f32);
                    if rng.below(5) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        (img, mask)
    })
}

fn discretized(img: &VoxelGrid<f32>, mask: &VoxelGrid<u8>) -> Option<DiscretizedRoi> {
    discretize(img, mask, Discretization::BinWidth(1.0)).unwrap()
}

/// Brute-force zone inventory via min-label fixpoint propagation — a
/// different algorithm from the implementation's flood fill (labels
/// converge to the per-component minimum flat index).
fn brute_zone_entries(roi: &DiscretizedRoi) -> Vec<(u32, u32, u64)> {
    let dims = roi.levels.dims;
    let data = roi.levels.data();
    let plane = dims.x * dims.y;
    let mut label: Vec<usize> = (0..data.len()).collect();
    loop {
        let mut changed = false;
        for idx in 0..data.len() {
            if data[idx] == 0 {
                continue;
            }
            let x = (idx % dims.x) as isize;
            let y = ((idx / dims.x) % dims.y) as isize;
            let z = (idx / plane) as isize;
            let mut m = label[idx];
            for &(dx, dy, dz) in &NEIGHBOURS_26 {
                let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                if qx < 0
                    || qy < 0
                    || qz < 0
                    || qx as usize >= dims.x
                    || qy as usize >= dims.y
                    || qz as usize >= dims.z
                {
                    continue;
                }
                let q = qz as usize * plane + qy as usize * dims.x + qx as usize;
                if data[q] == data[idx] {
                    m = m.min(label[q]);
                }
            }
            if m < label[idx] {
                label[idx] = m;
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }
    let mut sizes: std::collections::BTreeMap<(u32, usize), u32> = Default::default();
    for idx in 0..data.len() {
        if data[idx] != 0 {
            *sizes.entry((data[idx], label[idx])).or_insert(0) += 1;
        }
    }
    let mut zones: std::collections::BTreeMap<(u32, u32), u64> = Default::default();
    for ((lvl, _), size) in sizes {
        *zones.entry((lvl, size)).or_insert(0) += 1;
    }
    zones.into_iter().map(|((i, s), c)| (i, s, c)).collect()
}

#[test]
fn prop_glszm_zone_sizes_sum_to_roi_voxel_count() {
    forall("glszm-covers-roi", &texture_case_gen(), 40, |(img, mask)| {
        let Some(roi) = discretized(img, mask) else { return true };
        let m = accumulate_glszm(&roi);
        m.entries.iter().map(|&(_, s, c)| s as u64 * c).sum::<u64>() == roi.n_voxels as u64
    });
}

#[test]
fn prop_glszm_matches_brute_force_labelling() {
    forall("glszm-brute-equiv", &texture_case_gen(), 40, |(img, mask)| {
        let Some(roi) = discretized(img, mask) else { return true };
        accumulate_glszm(&roi).entries == brute_zone_entries(&roi)
    });
}

#[test]
fn prop_gldm_dependences_sum_to_roi_voxel_count() {
    forall("gldm-covers-roi", &texture_case_gen(), 40, |(img, mask)| {
        let Some(roi) = discretized(img, mask) else { return true };
        [0.0, 1.0, 3.0].into_iter().all(|alpha| {
            let m = accumulate_gldm(&roi, alpha, Strategy::LocalAccumulators, 2);
            m.counts.iter().sum::<u64>() == roi.n_voxels as u64
        })
    });
}

#[test]
fn prop_gldm_matches_brute_force() {
    forall("gldm-brute-equiv", &texture_case_gen(), 40, |(img, mask)| {
        let Some(roi) = discretized(img, mask) else { return true };
        let dims = roi.levels.dims;
        let data = roi.levels.data();
        let plane = dims.x * dims.y;
        for alpha in [0.0, 1.0] {
            let mut brute = vec![0u64; roi.ng * MAX_DEPENDENCE];
            for idx in 0..data.len() {
                if data[idx] == 0 {
                    continue;
                }
                let x = (idx % dims.x) as isize;
                let y = ((idx / dims.x) % dims.y) as isize;
                let z = (idx / plane) as isize;
                let mut dep = 1usize;
                for &(dx, dy, dz) in &NEIGHBOURS_26 {
                    let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                    if qx < 0
                        || qy < 0
                        || qz < 0
                        || qx as usize >= dims.x
                        || qy as usize >= dims.y
                        || qz as usize >= dims.z
                    {
                        continue;
                    }
                    let lj = data[qz as usize * plane + qy as usize * dims.x + qx as usize];
                    if lj != 0 && (data[idx] as f64 - lj as f64).abs() <= alpha {
                        dep += 1;
                    }
                }
                brute[(data[idx] as usize - 1) * MAX_DEPENDENCE + (dep - 1)] += 1;
            }
            let m = accumulate_gldm(&roi, alpha, Strategy::EqualSplit, 3);
            if m.counts != brute {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_ngtdm_matches_brute_force() {
    // the implementation accumulates exact integer numerators grouped by
    // (level, neighbour count); the brute force sums naive per-voxel f64
    // terms — they must agree to float tolerance, and the populations
    // exactly
    forall("ngtdm-brute-equiv", &texture_case_gen(), 40, |(img, mask)| {
        let Some(roi) = discretized(img, mask) else { return true };
        let dims = roi.levels.dims;
        let data = roi.levels.data();
        let plane = dims.x * dims.y;
        let mut s = vec![0.0f64; roi.ng];
        let mut n = vec![0u64; roi.ng];
        for idx in 0..data.len() {
            if data[idx] == 0 {
                continue;
            }
            let x = (idx % dims.x) as isize;
            let y = ((idx / dims.x) % dims.y) as isize;
            let z = (idx / plane) as isize;
            let mut sum = 0.0f64;
            let mut count = 0u64;
            for &(dx, dy, dz) in &NEIGHBOURS_26 {
                let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                if qx < 0
                    || qy < 0
                    || qz < 0
                    || qx as usize >= dims.x
                    || qy as usize >= dims.y
                    || qz as usize >= dims.z
                {
                    continue;
                }
                let lj = data[qz as usize * plane + qy as usize * dims.x + qx as usize];
                if lj != 0 {
                    sum += lj as f64;
                    count += 1;
                }
            }
            if count == 0 {
                continue;
            }
            s[data[idx] as usize - 1] += (data[idx] as f64 - sum / count as f64).abs();
            n[data[idx] as usize - 1] += 1;
        }
        let m = accumulate_ngtdm(&roi, Strategy::BlockReduction, 2);
        if m.counts != n {
            return false;
        }
        m.s()
            .iter()
            .zip(&s)
            .all(|(a, b)| (a - b).abs() <= 1e-9 * b.abs().max(1.0))
    });
}

#[test]
fn prop_texture_invariant_under_bin_aligned_intensity_shift() {
    // BinWidth discretization is edge-aligned, so shifting every intensity
    // by a multiple of the bin width re-centres the same levels — NGTDM
    // (and every other matrix class) must be bit-identical
    forall("ngtdm-shift-invariant", &texture_case_gen(), 30, |(img, mask)| {
        let w = 2.0f32;
        let opts = TextureOptions {
            discretization: Discretization::BinWidth(w as f64),
            ..Default::default()
        };
        let base = compute_texture(img, mask, &opts).unwrap();
        for k in [1.0f32, -3.0, 40.0] {
            let shifted = img.map(|v| v + k * w);
            let got = compute_texture(&shifted, mask, &opts).unwrap();
            if got != base {
                return false;
            }
        }
        true
    });
}

#[test]
fn prop_channel_delivers_exactly_once_under_permuted_sizes() {
    forall("channel-exactly-once", &int_range(1, 300), 15, |&n| {
        let n = n as usize;
        let (tx, rx) = bounded::<usize>(3);
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                tx.send(i).unwrap();
            }
        });
        let mut got = Vec::new();
        while let Ok(v) = rx.recv() {
            got.push(v);
        }
        producer.join().unwrap();
        got == (0..n).collect::<Vec<_>>()
    });
}
