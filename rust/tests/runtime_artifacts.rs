//! Integration tests over the real AOT artifact bundle: load HLO text via
//! PJRT, execute, and compare against the in-process CPU implementations.
//!
//! These tests are skipped (cleanly, with a message) when `make artifacts`
//! has not run — CI order is artifacts → cargo test.

use std::path::PathBuf;

use radpipe::config::{Backend, PipelineConfig};
use radpipe::dispatch::{FeatureExtractor, PathTaken};
use radpipe::features::brute_force_diameters;
use radpipe::geometry::Vec3;
use radpipe::mc::mesh_roi;
use radpipe::runtime::Engine;
use radpipe::volume::{Dims, VoxelGrid};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        None
    }
}

fn sphere_mask(n: usize, r: f64) -> VoxelGrid<u8> {
    let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::new(0.8, 0.8, 2.5));
    let c = n as f64 / 2.0;
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                if dx * dx + dy * dy + dz * dz <= r * r {
                    m.set(x, y, z, 1);
                }
            }
        }
    }
    m
}

#[test]
fn engine_diameters_match_cpu() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let mesh = mesh_roi(&sphere_mask(20, 6.0));
    let want = brute_force_diameters(&mesh.vertices);

    let (got, timing) = engine.handle().diameters(mesh.vertices_f32()).unwrap();
    assert!(timing.bucket >= mesh.vertices.len());
    for (g, w) in got.as_array().iter().zip(want.as_array()) {
        assert!(
            (g - w).abs() <= 1e-3 * w.abs().max(1.0),
            "diameter mismatch: {g} vs {w}"
        );
    }
    // second call hits the executable cache (no compile time)
    let (_, timing2) = engine.handle().diameters(mesh.vertices_f32()).unwrap();
    assert_eq!(timing2.compile, std::time::Duration::ZERO);
}

#[test]
fn engine_mesh_stats_match_cpu() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let mesh = mesh_roi(&sphere_mask(18, 5.0));
    let (got, _) = engine.handle().mesh_stats(mesh.triangle_soup_f32()).unwrap();
    assert!(
        (got[0] - mesh.stats.volume).abs() <= 1e-2 * mesh.stats.volume,
        "volume {} vs {}",
        got[0],
        mesh.stats.volume
    );
    assert!(
        (got[1] - mesh.stats.area).abs() <= 1e-2 * mesh.stats.area,
        "area {} vs {}",
        got[1],
        mesh.stats.area
    );
}

#[test]
fn engine_bucket_routing_padding_invariance() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    // A vertex set evaluated in its natural bucket must give identical
    // results to the same set force-padded into a larger bucket.
    let mesh = mesh_roi(&sphere_mask(14, 4.0));
    let verts = mesh.vertices_f32();
    let (d1, t1) = engine.handle().diameters(verts.clone()).unwrap();
    // re-pad into the next bucket by appending duplicates of vertex 0
    let mut padded = verts.clone();
    let dup = [verts[0], verts[1], verts[2]];
    while padded.len() / 3 <= t1.bucket {
        padded.extend_from_slice(&dup);
    }
    let (d2, t2) = engine.handle().diameters(padded).unwrap();
    assert!(t2.bucket > t1.bucket, "expected next bucket");
    for (a, b) in d1.as_array().iter().zip(d2.as_array()) {
        assert!((a - b).abs() <= 1e-3 * a.abs().max(1.0), "{a} vs {b}");
    }
}

#[test]
fn dispatcher_takes_accelerated_path_and_matches_cpu() {
    let Some(dir) = artifact_dir() else { return };
    let accel_cfg = PipelineConfig {
        backend: Backend::Accelerated,
        artifact_dir: dir,
        ..Default::default()
    };
    let accel = FeatureExtractor::new(&accel_cfg).unwrap();
    assert!(accel.accelerated());

    let cpu_cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    let cpu = FeatureExtractor::new(&cpu_cfg).unwrap();

    let mask = sphere_mask(22, 7.0);
    let a = accel.execute_mask(&mask).unwrap();
    let b = cpu.execute_mask(&mask).unwrap();
    assert_eq!(a.path, PathTaken::Accelerated);
    assert_eq!(b.path, PathTaken::CpuFallback);

    // the paper's "identical output quality" claim, feature by feature
    for ((name, va), (_, vb)) in a.features.named().iter().zip(b.features.named()) {
        if va.is_nan() && vb.is_nan() {
            continue;
        }
        assert!(
            (va - vb).abs() <= 1e-3 * vb.abs().max(1e-9),
            "{name}: accelerated {va} vs cpu {vb}"
        );
    }
}

#[test]
fn engine_warm_up_compiles_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let engine = Engine::start(&dir).unwrap();
    let compiled = engine.handle().warm_up().unwrap();
    assert!(compiled > 0, "expected fresh compilations");
    // warm again: everything cached
    assert_eq!(engine.handle().warm_up().unwrap(), 0);
}
