//! Pipeline metrics: thread-safe counters, timers and duration histograms,
//! aggregated into per-stage reports. The experiment harnesses read these to
//! produce the Table 2 breakdown columns.

pub mod snapshot;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Log-scale duration histogram (µs buckets, powers of two) + exact sum.
#[derive(Debug)]
pub struct Histogram {
    /// bucket i counts durations in [2^i, 2^(i+1)) µs; 40 buckets ≈ 12 days.
    buckets: [AtomicU64; 40],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(39);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> Duration {
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
        }
    }

    /// Approximate quantile from the log buckets: the upper edge of the
    /// bucket holding the target rank, clamped to the recorded maximum so
    /// a reported p99 can never exceed `max()` (the edge `2^(i+1)` µs
    /// overshoots whenever every sample in the top bucket is below it).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1 << (i + 1)).min(self.max());
            }
        }
        self.max()
    }

    /// Immutable point-in-time copy of the histogram state (bucket counts,
    /// count/sum/max), as used by the `radpipe.metrics/1` export. Take it
    /// when the histogram is quiescent: the atomics are loaded one by one,
    /// so a concurrent `record` can skew the derived fields against each
    /// other. `count` is derived from the bucket sum to keep the snapshot
    /// self-consistent under the parser's invariants.
    pub fn snapshot(&self) -> snapshot::TimerSnapshot {
        let buckets: Vec<(usize, u64)> = self
            .buckets
            .iter()
            .enumerate()
            .filter_map(|(i, b)| {
                let n = b.load(Ordering::Relaxed);
                (n > 0).then_some((i, n))
            })
            .collect();
        let count = buckets.iter().map(|&(_, n)| n).sum();
        snapshot::TimerSnapshot {
            count,
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Registry of named histograms + counters, shared across pipeline stages.
#[derive(Debug, Default)]
pub struct Metrics {
    timers: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
}

/// Lock a registry map, recovering from poisoning. A panicking worker
/// thread poisons any registry lock it held; the maps only hold `Arc`
/// handles and `BTreeMap` insertions are not left half-applied by the
/// panic sites here (panics originate in *timed user closures*, never
/// between map mutations), so the data is structurally sound — recover
/// the guard instead of escalating one bad case into a pipeline-wide
/// panic on every later metric call.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-create a histogram by name.
    pub fn timer(&self, name: &str) -> std::sync::Arc<Histogram> {
        let mut g = lock_recover(&self.timers);
        g.entry(name.to_string()).or_default().clone()
    }

    /// Fetch-or-create a counter by name.
    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        let mut g = lock_recover(&self.counters);
        g.entry(name.to_string()).or_default().clone()
    }

    /// Set a counter to an absolute value (gauge-style snapshot metrics,
    /// e.g. the batch-occupancy counters folded in at pipeline end).
    pub fn set_counter(&self, name: &str, value: u64) {
        self.counter(name).store(value, Ordering::Relaxed);
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = self.timer(name);
        let start = Instant::now();
        let out = f();
        t.record(start.elapsed());
        out
    }

    /// Render a sorted plain-text report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, h) in lock_recover(&self.timers).iter() {
            s.push_str(&format!(
                "{name}: n={} total={:.3}s mean={:.3}ms p99~{:.3}ms max={:.3}ms\n",
                h.count(),
                h.total().as_secs_f64(),
                h.mean().as_secs_f64() * 1e3,
                h.quantile(0.99).as_secs_f64() * 1e3,
                h.max().as_secs_f64() * 1e3,
            ));
        }
        for (name, c) in lock_recover(&self.counters).iter() {
            s.push_str(&format!("{name}: {}\n", c.load(Ordering::Relaxed)));
        }
        s
    }

    /// Machine-readable point-in-time copy of every timer and counter
    /// (the `radpipe.metrics/1` document body). Take it after the
    /// pipeline has quiesced — see [`Histogram::snapshot`].
    pub fn snapshot(&self) -> snapshot::MetricsSnapshot {
        let timers = lock_recover(&self.timers)
            .iter()
            .map(|(name, h)| (name.clone(), h.snapshot()))
            .collect();
        let counters = lock_recover(&self.counters)
            .iter()
            .map(|(name, c)| (name.clone(), c.load(Ordering::Relaxed)))
            .collect();
        snapshot::MetricsSnapshot { timers, counters }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let h = Histogram::default();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(1000));
        h.record(Duration::from_micros(100));
        assert_eq!(h.count(), 3);
        assert_eq!(h.total(), Duration::from_micros(1110));
        assert_eq!(h.mean(), Duration::from_micros(370));
        assert_eq!(h.max(), Duration::from_micros(1000));
    }

    #[test]
    fn quantiles_are_monotone() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_micros(256)); // upper edge of the 2^8 bucket
        assert!(p99 <= Duration::from_micros(2048));
    }

    #[test]
    fn registry_time_and_report() {
        let m = Metrics::new();
        let out = m.time("stage.read", || 42);
        assert_eq!(out, 42);
        m.counter("cases").fetch_add(3, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("stage.read: n=1"));
        assert!(r.contains("cases: 3"));
    }

    #[test]
    fn same_name_same_histogram() {
        let m = Metrics::new();
        m.time("x", || ());
        m.time("x", || ());
        assert_eq!(m.timer("x").count(), 2);
    }

    #[test]
    fn set_counter_is_absolute() {
        let m = Metrics::new();
        m.counter("batch.flushes").fetch_add(7, Ordering::Relaxed);
        m.set_counter("batch.flushes", 3);
        assert_eq!(m.counter("batch.flushes").load(Ordering::Relaxed), 3);
        assert!(m.report().contains("batch.flushes: 3"));
    }

    #[test]
    fn empty_histogram_quantile_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn quantile_never_exceeds_max() {
        // every sample lands in the [1024, 2048) µs bucket; the naive
        // upper-edge estimate would report 2048 µs for every quantile,
        // overshooting the true maximum of 1100 µs
        let h = Histogram::default();
        for us in [1024u64, 1050, 1100] {
            h.record(Duration::from_micros(us));
        }
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert!(
                h.quantile(q) <= h.max(),
                "q={q}: {:?} exceeds max {:?}",
                h.quantile(q),
                h.max()
            );
        }
        assert_eq!(h.quantile(0.5), Duration::from_micros(1100));
        assert_eq!(h.quantile(1.0), h.max());
    }

    #[test]
    fn quantile_of_one_is_the_top_bucket_clamped_to_max() {
        let h = Histogram::default();
        h.record(Duration::from_micros(3)); // bucket [2, 4)
        h.record(Duration::from_micros(700)); // bucket [512, 1024)
        assert_eq!(h.quantile(1.0), Duration::from_micros(700));
        // lower quantiles still report the covering bucket's upper edge
        assert_eq!(h.quantile(0.5), Duration::from_micros(4));
    }

    #[test]
    fn poisoned_registry_still_records_and_reports() {
        // a worker that panics while holding a registry lock must not
        // escalate into a panic on every later metric call — deliberately
        // poison both maps and keep using the registry
        let m = Metrics::new();
        m.time("survivor", || ());
        m.counter("cases").fetch_add(2, Ordering::Relaxed);

        // silence the two expected panics' default stderr reports
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r1 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.timers.lock().unwrap();
            panic!("poison the timer registry");
        }));
        let r2 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.counters.lock().unwrap();
            panic!("poison the counter registry");
        }));
        std::panic::set_hook(prev);
        assert!(r1.is_err() && r2.is_err(), "the poisoning closures must panic");
        assert!(m.timers.is_poisoned() && m.counters.is_poisoned());

        // recording through the poisoned registry works, old data intact
        m.time("survivor", || ());
        m.counter("cases").fetch_add(1, Ordering::Relaxed);
        m.set_counter("gauge", 7);
        let r = m.report();
        assert!(r.contains("survivor: n=2"), "{r}");
        assert!(r.contains("cases: 3"), "{r}");
        assert!(r.contains("gauge: 7"), "{r}");
    }
}
