//! Pipeline metrics: thread-safe counters, timers and duration histograms,
//! aggregated into per-stage reports. The experiment harnesses read these to
//! produce the Table 2 breakdown columns.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Log-scale duration histogram (µs buckets, powers of two) + exact sum.
#[derive(Debug)]
pub struct Histogram {
    /// bucket i counts durations in [2^i, 2^(i+1)) µs; 40 buckets ≈ 12 days.
    buckets: [AtomicU64; 40],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(39);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn total(&self) -> Duration {
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
        }
    }

    /// Approximate quantile from the log buckets (upper bucket edge).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(1 << (i + 1));
            }
        }
        self.max()
    }
}

/// Registry of named histograms + counters, shared across pipeline stages.
#[derive(Debug, Default)]
pub struct Metrics {
    timers: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
    counters: Mutex<BTreeMap<String, std::sync::Arc<AtomicU64>>>,
}

/// Lock a registry map, recovering from poisoning. A panicking worker
/// thread poisons any registry lock it held; the maps only hold `Arc`
/// handles and `BTreeMap` insertions are not left half-applied by the
/// panic sites here (panics originate in *timed user closures*, never
/// between map mutations), so the data is structurally sound — recover
/// the guard instead of escalating one bad case into a pipeline-wide
/// panic on every later metric call.
fn lock_recover<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-create a histogram by name.
    pub fn timer(&self, name: &str) -> std::sync::Arc<Histogram> {
        let mut g = lock_recover(&self.timers);
        g.entry(name.to_string()).or_default().clone()
    }

    /// Fetch-or-create a counter by name.
    pub fn counter(&self, name: &str) -> std::sync::Arc<AtomicU64> {
        let mut g = lock_recover(&self.counters);
        g.entry(name.to_string()).or_default().clone()
    }

    /// Set a counter to an absolute value (gauge-style snapshot metrics,
    /// e.g. the batch-occupancy counters folded in at pipeline end).
    pub fn set_counter(&self, name: &str, value: u64) {
        self.counter(name).store(value, Ordering::Relaxed);
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = self.timer(name);
        let start = Instant::now();
        let out = f();
        t.record(start.elapsed());
        out
    }

    /// Render a sorted plain-text report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, h) in lock_recover(&self.timers).iter() {
            s.push_str(&format!(
                "{name}: n={} total={:.3}s mean={:.3}ms p99~{:.3}ms max={:.3}ms\n",
                h.count(),
                h.total().as_secs_f64(),
                h.mean().as_secs_f64() * 1e3,
                h.quantile(0.99).as_secs_f64() * 1e3,
                h.max().as_secs_f64() * 1e3,
            ));
        }
        for (name, c) in lock_recover(&self.counters).iter() {
            s.push_str(&format!("{name}: {}\n", c.load(Ordering::Relaxed)));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let h = Histogram::default();
        h.record(Duration::from_micros(10));
        h.record(Duration::from_micros(1000));
        h.record(Duration::from_micros(100));
        assert_eq!(h.count(), 3);
        assert_eq!(h.total(), Duration::from_micros(1110));
        assert_eq!(h.mean(), Duration::from_micros(370));
        assert_eq!(h.max(), Duration::from_micros(1000));
    }

    #[test]
    fn quantiles_are_monotone() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        assert!(p50 >= Duration::from_micros(256)); // upper edge of the 2^8 bucket
        assert!(p99 <= Duration::from_micros(2048));
    }

    #[test]
    fn registry_time_and_report() {
        let m = Metrics::new();
        let out = m.time("stage.read", || 42);
        assert_eq!(out, 42);
        m.counter("cases").fetch_add(3, Ordering::Relaxed);
        let r = m.report();
        assert!(r.contains("stage.read: n=1"));
        assert!(r.contains("cases: 3"));
    }

    #[test]
    fn same_name_same_histogram() {
        let m = Metrics::new();
        m.time("x", || ());
        m.time("x", || ());
        assert_eq!(m.timer("x").count(), 2);
    }

    #[test]
    fn set_counter_is_absolute() {
        let m = Metrics::new();
        m.counter("batch.flushes").fetch_add(7, Ordering::Relaxed);
        m.set_counter("batch.flushes", 3);
        assert_eq!(m.counter("batch.flushes").load(Ordering::Relaxed), 3);
        assert!(m.report().contains("batch.flushes: 3"));
    }

    #[test]
    fn empty_histogram_quantile_zero() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn poisoned_registry_still_records_and_reports() {
        // a worker that panics while holding a registry lock must not
        // escalate into a panic on every later metric call — deliberately
        // poison both maps and keep using the registry
        let m = Metrics::new();
        m.time("survivor", || ());
        m.counter("cases").fetch_add(2, Ordering::Relaxed);

        // silence the two expected panics' default stderr reports
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let r1 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.timers.lock().unwrap();
            panic!("poison the timer registry");
        }));
        let r2 = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.counters.lock().unwrap();
            panic!("poison the counter registry");
        }));
        std::panic::set_hook(prev);
        assert!(r1.is_err() && r2.is_err(), "the poisoning closures must panic");
        assert!(m.timers.is_poisoned() && m.counters.is_poisoned());

        // recording through the poisoned registry works, old data intact
        m.time("survivor", || ());
        m.counter("cases").fetch_add(1, Ordering::Relaxed);
        m.set_counter("gauge", 7);
        let r = m.report();
        assert!(r.contains("survivor: n=2"), "{r}");
        assert!(r.contains("cases: 3"), "{r}");
        assert!(r.contains("gauge: 7"), "{r}");
    }
}
