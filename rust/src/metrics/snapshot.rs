//! Machine-readable metrics export: the `radpipe.metrics/1` document.
//!
//! A [`MetricsSnapshot`] is a point-in-time copy of a [`super::Metrics`]
//! registry — every timer with its full log-histogram state and every
//! counter — serialized as schema-versioned JSON and read back by a
//! validating parser, mirroring the `radpipe.bench/1` report pattern
//! (`bench::report`). Consumers (the JSON run report, `--metrics-out`,
//! `experiments::table2`, the CI observability gate) work from this
//! document instead of scraping the plain-text `Metrics::report` blob.
//!
//! Document layout (stable key order, diffable):
//!
//! ```json
//! {
//!   "schema": "radpipe.metrics/1",
//!   "timers": {
//!     "stage.read": {
//!       "count": 20, "sum_us": 1834, "max_us": 402,
//!       "buckets": [[6, 12], [7, 7], [8, 1]]
//!     }
//!   },
//!   "counters": { "cases.total": 20, "errors.read": 0 }
//! }
//! ```
//!
//! `buckets` is sparse: `[i, n]` says `n` samples fell in the log bucket
//! `[2^i, 2^(i+1))` µs, indices strictly increasing, zero buckets omitted.
//! The parser enforces that shape plus the cross-field invariants
//! (Σ bucket counts == `count`, `max_us ≤ sum_us`, empty timers are
//! all-zero), so a document that round-trips is internally consistent.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::report::JsonValue;

/// Schema tag stamped on (and required from) every document.
pub const SCHEMA: &str = "radpipe.metrics/1";

/// Number of log buckets in [`super::Histogram`] — valid indices are
/// `0..BUCKETS`.
pub const BUCKETS: usize = 40;

/// Point-in-time copy of one timer's log-histogram state.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TimerSnapshot {
    pub count: u64,
    pub sum_us: u64,
    pub max_us: u64,
    /// Sparse buckets `(index, samples)`: index `i` covers
    /// `[2^i, 2^(i+1))` µs; strictly increasing, counts ≥ 1.
    pub buckets: Vec<(usize, u64)>,
}

impl TimerSnapshot {
    pub fn total(&self) -> Duration {
        Duration::from_micros(self.sum_us)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us)
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_us / self.count)
        }
    }

    /// Approximate quantile, identical to [`super::Histogram::quantile`]
    /// (upper bucket edge, clamped to the recorded maximum).
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (self.count as f64 * q).ceil() as u64;
        let mut seen = 0;
        for &(i, n) in &self.buckets {
            seen += n;
            if seen >= target {
                return Duration::from_micros(1 << (i + 1)).min(self.max());
            }
        }
        self.max()
    }

    fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("count", self.count as f64);
        o.set("sum_us", self.sum_us as f64);
        o.set("max_us", self.max_us as f64);
        let buckets: Vec<JsonValue> = self
            .buckets
            .iter()
            .map(|&(i, n)| JsonValue::Arr(vec![JsonValue::Num(i as f64), JsonValue::Num(n as f64)]))
            .collect();
        o.set("buckets", JsonValue::Arr(buckets));
        o
    }
}

/// Point-in-time copy of a whole [`super::Metrics`] registry.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub timers: BTreeMap<String, TimerSnapshot>,
    pub counters: BTreeMap<String, u64>,
}

impl MetricsSnapshot {
    pub fn timer(&self, name: &str) -> Option<&TimerSnapshot> {
        self.timers.get(name)
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.get(name).copied()
    }

    /// Build the `radpipe.metrics/1` JSON document.
    pub fn to_json(&self) -> JsonValue {
        let mut timers = JsonValue::obj();
        for (name, t) in &self.timers {
            timers.set(name, t.to_json());
        }
        let mut counters = JsonValue::obj();
        for (name, v) in &self.counters {
            counters.set(name, *v as f64);
        }
        let mut doc = JsonValue::obj();
        doc.set("schema", SCHEMA).set("timers", timers).set("counters", counters);
        doc
    }

    pub fn to_json_text(&self) -> String {
        self.to_json().to_string()
    }

    /// Write the document to a file.
    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json_text())
            .with_context(|| format!("writing metrics snapshot to {}", path.display()))
    }

    /// Parse and validate a `radpipe.metrics/1` document.
    pub fn from_json_text(text: &str) -> Result<MetricsSnapshot> {
        let doc = JsonValue::parse(text).context("parsing metrics snapshot")?;
        let Some(schema) = doc.get("schema").and_then(JsonValue::as_str) else {
            bail!("metrics snapshot has no \"schema\" tag");
        };
        if schema != SCHEMA {
            bail!("schema mismatch: document says {schema:?}, reader expects {SCHEMA:?}");
        }

        let Some(JsonValue::Obj(timers_json)) = doc.get("timers") else {
            bail!("metrics snapshot has no \"timers\" object");
        };
        let mut timers = BTreeMap::new();
        for (name, t) in timers_json {
            timers.insert(name.clone(), parse_timer(name, t)?);
        }

        let Some(JsonValue::Obj(counters_json)) = doc.get("counters") else {
            bail!("metrics snapshot has no \"counters\" object");
        };
        let mut counters = BTreeMap::new();
        for (name, v) in counters_json {
            counters.insert(name.clone(), uint(Some(v), &format!("counter {name:?}"))?);
        }

        Ok(MetricsSnapshot { timers, counters })
    }

    /// Read and validate a snapshot file.
    pub fn read(path: &Path) -> Result<MetricsSnapshot> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading metrics snapshot {}", path.display()))?;
        Self::from_json_text(&text)
            .with_context(|| format!("validating metrics snapshot {}", path.display()))
    }
}

/// Require a non-negative integral JSON number (exact in an f64).
fn uint(v: Option<&JsonValue>, what: &str) -> Result<u64> {
    let Some(n) = v.and_then(JsonValue::as_f64) else {
        bail!("{what}: missing numeric value");
    };
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
        bail!("{what}: not a non-negative integer (got {n})");
    }
    Ok(n as u64)
}

fn parse_timer(name: &str, t: &JsonValue) -> Result<TimerSnapshot> {
    let JsonValue::Obj(fields) = t else {
        bail!("timer {name:?} is not an object");
    };
    for key in fields.keys() {
        if !matches!(key.as_str(), "count" | "sum_us" | "max_us" | "buckets") {
            bail!("timer {name:?} has unknown field {key:?}");
        }
    }
    let count = uint(t.get("count"), &format!("timer {name:?} count"))?;
    let sum_us = uint(t.get("sum_us"), &format!("timer {name:?} sum_us"))?;
    let max_us = uint(t.get("max_us"), &format!("timer {name:?} max_us"))?;

    let Some(buckets_json) = t.get("buckets").and_then(JsonValue::as_arr) else {
        bail!("timer {name:?} has no \"buckets\" array");
    };
    let mut buckets = Vec::with_capacity(buckets_json.len());
    let mut prev: Option<usize> = None;
    let mut total: u64 = 0;
    for (k, pair) in buckets_json.iter().enumerate() {
        let Some(pair) = pair.as_arr() else {
            bail!("timer {name:?} bucket #{k} is not a [index, count] pair");
        };
        if pair.len() != 2 {
            bail!("timer {name:?} bucket #{k} has {} elements, expected 2", pair.len());
        }
        let idx = uint(pair.first(), &format!("timer {name:?} bucket #{k} index"))? as usize;
        let n = uint(pair.get(1), &format!("timer {name:?} bucket #{k} count"))?;
        if idx >= BUCKETS {
            bail!("timer {name:?} bucket #{k}: index {idx} out of range (< {BUCKETS})");
        }
        if let Some(p) = prev {
            if idx <= p {
                bail!("timer {name:?} bucket #{k}: index {idx} not strictly increasing after {p}");
            }
        }
        if n == 0 {
            bail!("timer {name:?} bucket #{k}: zero-count bucket must be omitted");
        }
        prev = Some(idx);
        total += n;
        buckets.push((idx, n));
    }

    if total != count {
        bail!("timer {name:?}: bucket counts sum to {total} but count says {count}");
    }
    if count == 0 && (sum_us != 0 || max_us != 0) {
        bail!("timer {name:?}: empty timer with non-zero sum/max");
    }
    if max_us > sum_us {
        bail!("timer {name:?}: max_us {max_us} exceeds sum_us {sum_us}");
    }
    Ok(TimerSnapshot { count, sum_us, max_us, buckets })
}

#[cfg(test)]
mod tests {
    use super::super::Metrics;
    use super::*;

    fn sample_metrics() -> Metrics {
        let m = Metrics::new();
        for us in [3u64, 9, 150, 700, 700, 4000] {
            m.timer("stage.read").record(Duration::from_micros(us));
        }
        m.timer("stage.mesh").record(Duration::from_micros(42));
        let _ = m.timer("stage.empty"); // registered but never recorded
        m.counter("cases.total").fetch_add(6, std::sync::atomic::Ordering::Relaxed);
        m.set_counter("errors.read", 0);
        m
    }

    #[test]
    fn snapshot_round_trips_through_the_validating_parser() {
        let snap = sample_metrics().snapshot();
        let text = snap.to_json_text();
        let parsed = MetricsSnapshot::from_json_text(&text).unwrap();
        assert_eq!(parsed, snap);
        // stable serialization
        assert_eq!(parsed.to_json_text(), text);
        // schema tag is on the wire
        assert!(text.contains("\"schema\":\"radpipe.metrics/1\""));
    }

    #[test]
    fn snapshot_matches_live_histogram_stats() {
        let m = sample_metrics();
        let h = m.timer("stage.read");
        let snap = m.snapshot();
        let t = snap.timer("stage.read").unwrap();
        assert_eq!(t.count, h.count());
        assert_eq!(t.total(), h.total());
        assert_eq!(t.max(), h.max());
        assert_eq!(t.mean(), h.mean());
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(t.quantile(q), h.quantile(q), "q={q}");
        }
        assert_eq!(snap.counter("cases.total"), Some(6));
        assert_eq!(snap.counter("errors.read"), Some(0));
        assert_eq!(snap.counter("missing"), None);
    }

    #[test]
    fn empty_timer_snapshots_as_all_zero() {
        let snap = sample_metrics().snapshot();
        let t = snap.timer("stage.empty").unwrap();
        assert_eq!(t, &TimerSnapshot::default());
        assert_eq!(t.quantile(0.99), Duration::ZERO);
    }

    #[test]
    fn empty_registry_round_trips() {
        let snap = Metrics::new().snapshot();
        let parsed = MetricsSnapshot::from_json_text(&snap.to_json_text()).unwrap();
        assert!(parsed.timers.is_empty() && parsed.counters.is_empty());
    }

    #[test]
    fn parser_rejects_broken_documents() {
        let ok_timer = r#""t":{"buckets":[[3,1]],"count":1,"max_us":9,"sum_us":9}"#;
        let doc = |schema: &str, timer: &str| {
            format!(r#"{{"schema":"{schema}","timers":{{{timer}}},"counters":{{"c":1}}}}"#)
        };
        // the template itself parses
        assert!(MetricsSnapshot::from_json_text(&doc("radpipe.metrics/1", ok_timer)).is_ok());

        for (text, why) in [
            (doc("radpipe.metrics/2", ok_timer), "schema mismatch"),
            (r#"{"timers":{},"counters":{}}"#.to_string(), "missing schema"),
            (r#"{"schema":"radpipe.metrics/1","counters":{}}"#.to_string(), "missing timers"),
            (r#"{"schema":"radpipe.metrics/1","timers":{}}"#.to_string(), "missing counters"),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[[40,1]],"count":1,"max_us":1,"sum_us":1}"#,
                ),
                "bucket index out of range",
            ),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[[3,1],[3,1]],"count":2,"max_us":1,"sum_us":2}"#,
                ),
                "non-increasing bucket index",
            ),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[[3,0]],"count":0,"max_us":0,"sum_us":0}"#,
                ),
                "zero-count bucket",
            ),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[[3,2]],"count":1,"max_us":9,"sum_us":9}"#,
                ),
                "bucket sum != count",
            ),
            (
                doc("radpipe.metrics/1", r#""t":{"buckets":[],"count":0,"max_us":3,"sum_us":0}"#),
                "empty timer with max",
            ),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[[3,1]],"count":1,"max_us":9,"sum_us":5}"#,
                ),
                "max exceeds sum",
            ),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[],"count":0,"max_us":0,"sum_us":0,"x":1}"#,
                ),
                "unknown timer field",
            ),
            (
                doc(
                    "radpipe.metrics/1",
                    r#""t":{"buckets":[[3,1.5]],"count":1,"max_us":1,"sum_us":1}"#,
                ),
                "fractional bucket count",
            ),
            (
                doc("radpipe.metrics/1", r#""t":{"count":1,"max_us":1,"sum_us":1}"#),
                "missing buckets",
            ),
            (
                r#"{"schema":"radpipe.metrics/1","timers":{},"counters":{"c":-1}}"#.to_string(),
                "negative counter",
            ),
            (
                r#"{"schema":"radpipe.metrics/1","timers":{},"counters":{"c":"x"}}"#.to_string(),
                "non-numeric counter",
            ),
        ] {
            let err = MetricsSnapshot::from_json_text(&text);
            assert!(err.is_err(), "{why}: {text}");
        }
    }
}
