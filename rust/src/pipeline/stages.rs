//! The staged pipeline proper.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::channel::bounded;
use crate::config::{LabelSelection, PipelineConfig};
use crate::dispatch::{CaseTiming, DerivedImageFeatures, FeatureExtractor, PathTaken};
use crate::features::{FirstOrderFeatures, ShapeFeatures, TextureFeatures};
use crate::imgproc::{BudgetGuard, MemoryBudget, PipelineHold};
use crate::io::slab::{read_image_crop, read_label_crop, read_volume_header, scan_mask_slab};
use crate::io::DatasetManifest;
use crate::metrics::Metrics;
use crate::volume::{LabelMask, VoxelGrid};

/// Fully-processed case (or, on a label-map run, one label of a case).
/// `first_order`/`texture` are populated when the corresponding feature
/// classes are enabled in the config; `derived` holds the
/// per-derived-image feature sets (original / LoG / wavelet) when
/// intensity classes are enabled.
#[derive(Debug, Clone)]
pub struct CaseResult {
    pub case_id: String,
    /// The label this row belongs to on a label-map run (`labels`
    /// selector set); `None` on the legacy binary-mask path.
    pub label: Option<u16>,
    pub features: ShapeFeatures,
    pub first_order: Option<FirstOrderFeatures>,
    pub texture: Option<TextureFeatures>,
    pub derived: Vec<DerivedImageFeatures>,
    pub timing: CaseTiming,
    pub path: PathTaken,
}

/// Pipeline outcome: ordered case results + failures + the metrics dump
/// (human-readable text and the machine-readable `radpipe.metrics/1`
/// snapshot, taken from the same registry after the run quiesced).
#[derive(Debug)]
pub struct PipelineReport {
    pub results: Vec<CaseResult>,
    pub failures: Vec<(String, String)>,
    pub metrics_text: String,
    pub metrics: crate::metrics::snapshot::MetricsSnapshot,
    pub wall: std::time::Duration,
}

/// Every computed (name, value) pair of one case row, in stable order:
/// shape, then every derived image (original keeps the historical plain
/// names; LoG / wavelet images carry filter-qualified names, e.g.
/// `log-sigma-2-0-mm_firstorder_Mean`). Both the report writers and the
/// cohort feature cache serialise exactly this list.
pub fn case_named_features(r: &CaseResult) -> Vec<(String, f64)> {
    let mut out: Vec<(String, f64)> =
        r.features.named().into_iter().map(|(n, v)| (n.to_string(), v)).collect();
    for d in &r.derived {
        out.extend(d.named());
    }
    out
}

/// Everything the pipeline produced for ONE manifest entry: its feature
/// rows (one on the binary-mask path, one per label on a label-map run)
/// plus its failures (whole-case or per-label). Exactly one outcome is
/// emitted per case, which is what lets a cohort journal record case
/// completion atomically.
#[derive(Debug, Clone)]
pub struct CaseOutcome {
    pub case_id: String,
    /// Successful rows, label-ascending on a label-map run.
    pub rows: Vec<CaseResult>,
    pub failures: Vec<(String, String)>,
}

impl CaseOutcome {
    /// A case counts as succeeded only when nothing in it failed (a
    /// label-map case with one bad label is *not* cacheable as complete).
    pub fn is_success(&self) -> bool {
        self.failures.is_empty() && !self.rows.is_empty()
    }
}

/// A case as the scanner hands it to the read pool.
struct CaseJob {
    case_id: String,
    mask_path: PathBuf,
    image_path: Option<PathBuf>,
    declared_dims: Option<crate::volume::Dims>,
    declared_labels: Vec<u16>,
}

/// What the read stage loaded for the extract stage.
enum MaskPayload {
    /// Legacy binary-mask case.
    Binary(VoxelGrid<u8>),
    /// Label-map case (`labels` selector set): the integer mask plus the
    /// resolved label selection to extract.
    Labels { mask: LabelMask, selected: Vec<u16> },
}

struct ReadItem {
    case_id: String,
    payload: MaskPayload,
    image: Option<VoxelGrid<f32>>,
    read: Duration,
    read_image: Duration,
    /// Feeds the `mem.peak_pipeline_bytes` gauge while the case is in
    /// flight (read → extracted).
    _hold: PipelineHold,
    /// Admission ticket from the pipeline memory budget; dropping it
    /// (when this item is fully extracted) lets the read pool admit the
    /// next case.
    _budget: Option<BudgetGuard>,
}

/// Everything `load_case` produced; the read worker wraps it into a
/// [`ReadItem`] with timings.
struct LoadedCase {
    payload: MaskPayload,
    image: Option<VoxelGrid<f32>>,
    read_image: Duration,
    hold: PipelineHold,
    budget: Option<BudgetGuard>,
}

/// Resolve the `labels` selector against what the mask actually contains
/// (`observed`) and what the manifest promises (`declared`). `All` is the
/// union of both, so a declared-but-empty label is *selected* and then
/// fails per-label downstream instead of silently vanishing.
fn resolve_labels(sel: &LabelSelection, observed: &[u16], declared: &[u16]) -> Vec<u16> {
    match sel {
        LabelSelection::Unset => Vec::new(),
        LabelSelection::List(ids) => ids.clone(),
        LabelSelection::All => {
            let mut all: Vec<u16> = observed.to_vec();
            all.extend_from_slice(declared);
            all.sort_unstable();
            all.dedup();
            all
        }
    }
}

/// Read one case's volumes, respecting the `labels` selector, the
/// `slab_io` knob and the pipeline memory budget. Errors carry the
/// per-stage error-counter name (`errors.read` / `errors.read_image`).
///
/// With `slab_io` the mask file is scanned in z-slabs first — a cheap
/// streaming pass that finds the union ROI bounding box and the label
/// inventory without materialising the grid — and only the crop is then
/// read, for both the mask and the image. The budget is therefore sized
/// on the *crop*, not the file. Whole-grid reads size the budget on the
/// manifest's declared dims.
fn load_case(
    job: &CaseJob,
    labels_cfg: &LabelSelection,
    slab_io: bool,
    needs_image: bool,
    budget: &Arc<MemoryBudget>,
) -> Result<LoadedCase, (&'static str, String)> {
    let want_image = needs_image && job.image_path.is_some();
    let read_err = |e: anyhow::Error| ("errors.read", format!("read: {e:#}"));
    let dims_err = |want: crate::volume::Dims, got: crate::volume::Dims| {
        (
            "errors.read",
            format!(
                "read: mask dims {got} do not match the manifest's dims={want} \
                 (stale or corrupt cases.txt?)"
            ),
        )
    };

    if slab_io {
        let scan = scan_mask_slab(&job.mask_path).map_err(read_err)?;
        if let Some(want) = job.declared_dims {
            if scan.file_dims != want {
                return Err(dims_err(want, scan.file_dims));
            }
        }
        let (off, dims) = scan.crop_box();
        let crop_vox = (dims.x * dims.y * dims.z) as u64;
        let bytes = crop_vox * 2 + if want_image { crop_vox * 4 } else { 0 };
        let budget_guard = budget.acquire(bytes);
        let hold = PipelineHold::new(bytes);
        let grid = read_label_crop(&job.mask_path, off, dims).map_err(read_err)?;
        let mask = LabelMask::from_grid(grid);
        let payload = if labels_cfg.is_set() {
            let selected = resolve_labels(labels_cfg, &mask.labels, &job.declared_labels);
            if selected.is_empty() {
                return Err((
                    "errors.read",
                    "read: --labels all selected nothing: the mask contains no labels \
                     and the manifest declares none (labels= in cases.txt)"
                        .to_string(),
                ));
            }
            MaskPayload::Labels { mask, selected }
        } else {
            if mask.labels.len() > 1 {
                return Err((
                    "errors.read",
                    format!(
                        "read: mask '{}' is a label map with {} distinct labels ({}): \
                         select the ROIs to extract with --labels <ids|all> (config \
                         key `labels`) instead of silently merging them into one",
                        job.mask_path.display(),
                        mask.labels.len(),
                        crate::io::format_labels(&mask.labels)
                    ),
                ));
            }
            MaskPayload::Binary(mask.collapsed())
        };
        let mut image = None;
        let mut read_image = Duration::ZERO;
        // `if let` rather than unwrap: a case with no image simply reads
        // none (the extract stage then reports the missing-image remedy),
        // instead of gambling the whole worker on the guard staying in
        // sync with this branch
        if let Some(ipath) = job.image_path.as_ref().filter(|_| needs_image) {
            let t0 = Instant::now();
            let sp = crate::trace::span("stage.read_image");
            let loaded = read_volume_header(ipath)
                .and_then(|(idims, ispacing)| {
                    if idims != scan.file_dims || ispacing != scan.spacing {
                        anyhow::bail!(
                            "slab_io needs the image on the mask grid, but image dims \
                             {idims} / spacing {ispacing:?} differ from mask dims {} / \
                             spacing {:?}; disable slab_io to auto-resample",
                            scan.file_dims,
                            scan.spacing
                        );
                    }
                    read_image_crop(ipath, off, dims)
                })
                .map_err(|e| {
                    ("errors.read_image", format!("read image {}: {e:#}", ipath.display()))
                });
            drop(sp);
            read_image = t0.elapsed();
            image = Some(loaded?);
        }
        return Ok(LoadedCase { payload, image, read_image, hold, budget: Some(budget_guard) });
    }

    // whole-grid read: budget on the declared dims (2 bytes/voxel for a
    // label mask, 1 for binary, +4 for the f32 image when one is read);
    // cohort entries declare no dims, so size from the file header — a
    // cheap header-only read, no payload
    let d = match job.declared_dims {
        Some(d) => d,
        None => read_volume_header(&job.mask_path).map_err(read_err)?.0,
    };
    let file_vox = (d.x * d.y * d.z) as u64;
    let mask_elem = if labels_cfg.is_set() { 2 } else { 1 };
    let bytes = file_vox * mask_elem + if want_image { file_vox * 4 } else { 0 };
    let budget_guard = budget.acquire(bytes);
    let hold = PipelineHold::new(bytes);
    let payload = if labels_cfg.is_set() {
        let mask = crate::io::read_label_mask(&job.mask_path).map_err(read_err)?;
        if let Some(want) = job.declared_dims {
            if mask.grid.dims != want {
                return Err(dims_err(want, mask.grid.dims));
            }
        }
        let selected = resolve_labels(labels_cfg, &mask.labels, &job.declared_labels);
        if selected.is_empty() {
            return Err((
                "errors.read",
                "read: --labels all selected nothing: the mask contains no labels \
                 and the manifest declares none (labels= in cases.txt)"
                    .to_string(),
            ));
        }
        MaskPayload::Labels { mask, selected }
    } else {
        let mask = crate::io::read_mask(&job.mask_path).map_err(read_err)?;
        if let Some(want) = job.declared_dims {
            if mask.dims != want {
                return Err(dims_err(want, mask.dims));
            }
        }
        MaskPayload::Binary(mask)
    };
    let mut image = None;
    let mut read_image = Duration::ZERO;
    if let Some(ipath) = job.image_path.as_ref().filter(|_| needs_image) {
        let t0 = Instant::now();
        let sp = crate::trace::span("stage.read_image");
        let loaded = crate::io::read_image(ipath).map_err(|e| {
            ("errors.read_image", format!("read image {}: {e:#}", ipath.display()))
        });
        drop(sp);
        read_image = t0.elapsed();
        image = Some(loaded?);
    }
    Ok(LoadedCase { payload, image, read_image, hold, budget: Some(budget_guard) })
}

/// Run the full streaming pipeline over a dataset.
///
/// Stage topology (bounded channels of `cfg.queue_capacity` between each):
/// scanner (inline) → read pool → extract pool (preprocess + mesh +
/// dispatch) → sink (inline). The extractor is shared: on the accelerated
/// path its batch scheduler groups concurrent diameter requests by
/// pad-bucket and shards fused batches across the engine pool
/// (`cfg.engine_count`, `cfg.batch_size`, `cfg.batch_linger_ms`); with the
/// defaults this degenerates to the paper's one-accelerator serialisation.
pub fn run_pipeline(
    manifest: &DatasetManifest,
    cfg: &PipelineConfig,
    extractor: &FeatureExtractor,
) -> Result<PipelineReport> {
    run_pipeline_with(manifest, cfg, extractor, &mut |_| {})
}

/// [`run_pipeline`] plus a completion callback: `on_case` runs on the
/// sink thread, once per manifest entry, as soon as that case's outcome
/// arrives (NOT in manifest order — cases complete as workers finish
/// them). The cohort batch front-end uses it to journal and cache each
/// case the moment it is done, so a killed run loses at most the cases
/// that were still in flight.
pub fn run_pipeline_with(
    manifest: &DatasetManifest,
    cfg: &PipelineConfig,
    extractor: &FeatureExtractor,
    on_case: &mut dyn FnMut(&CaseOutcome),
) -> Result<PipelineReport> {
    let start = Instant::now();
    let metrics = Arc::new(Metrics::new());
    // scope the memory gauges to this run (process-wide high-water marks;
    // concurrent runs in one process share the meters)
    crate::imgproc::reset_peak_derived_bytes();
    crate::imgproc::reset_peak_pipeline_bytes();
    // pipeline-wide read-admission budget (0 = unlimited)
    let budget = MemoryBudget::new(cfg.memory_budget);

    let (case_tx, case_rx) = bounded::<CaseJob>(cfg.queue_capacity);
    let (read_tx, read_rx) = bounded::<ReadItem>(cfg.queue_capacity);
    let (out_tx, out_rx) = bounded::<CaseOutcome>(cfg.queue_capacity);

    let n_cases = manifest.cases.len();
    // the image is loaded only when an enabled class will read it —
    // shape-only runs must not pay image IO
    let needs_image = cfg.feature_classes.needs_image();

    std::thread::scope(|scope| {
        // scanner: feed case paths
        {
            let case_tx = case_tx;
            let manifest = manifest.clone();
            spawn_named(scope, "scan".to_string(), move || {
                for e in &manifest.cases {
                    let job = CaseJob {
                        case_id: e.case_id.clone(),
                        mask_path: manifest.mask_path(e),
                        image_path: manifest.image_path(e),
                        declared_dims: e.dims,
                        declared_labels: e.labels.clone(),
                    };
                    if case_tx.send(job).is_err() {
                        break;
                    }
                }
            });
        }

        // read pool
        for i in 0..cfg.read_workers.max(1) {
            let case_rx = case_rx.clone();
            let read_tx = read_tx.clone();
            let out_tx = out_tx.clone();
            let metrics = metrics.clone();
            let budget = budget.clone();
            let labels_cfg = cfg.labels.clone();
            let slab_io = cfg.slab_io;
            spawn_named(scope, format!("read-{i}"), move || {
                while let Ok(job) = case_rx.recv() {
                    let _case = crate::trace::case_scope(&job.case_id);
                    let t0 = Instant::now();
                    let sp = crate::trace::span("stage.read");
                    let loaded = load_case(&job, &labels_cfg, slab_io, needs_image, &budget);
                    drop(sp);
                    let total = t0.elapsed();
                    let loaded = match loaded {
                        Ok(l) => l,
                        Err((counter, msg)) => {
                            metrics.timer("stage.read").record(total);
                            metrics
                                .counter(counter)
                                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            let outcome = CaseOutcome {
                                case_id: job.case_id.clone(),
                                rows: Vec::new(),
                                failures: vec![(job.case_id, msg)],
                            };
                            if out_tx.send(outcome).is_err() {
                                break;
                            }
                            continue;
                        }
                    };
                    // mask-read time is the case total minus the image leg
                    let read = total.saturating_sub(loaded.read_image);
                    metrics.timer("stage.read").record(read);
                    if loaded.image.is_some() {
                        metrics.timer("stage.read_image").record(loaded.read_image);
                    }
                    let item = ReadItem {
                        case_id: job.case_id,
                        payload: loaded.payload,
                        image: loaded.image,
                        read,
                        read_image: loaded.read_image,
                        _hold: loaded.hold,
                        _budget: loaded.budget,
                    };
                    if read_tx.send(item).is_err() {
                        break;
                    }
                }
            });
        }
        drop(case_rx);
        drop(read_tx);

        // extract pool (preprocess + mesh + dispatch + derive)
        for i in 0..cfg.feature_workers.max(1) {
            let read_rx = read_rx.clone();
            let out_tx = out_tx.clone();
            let metrics = metrics.clone();
            spawn_named(scope, format!("extract-{i}"), move || {
                let bump = |name: &str| {
                    metrics.counter(name).fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                };
                let record = |ex: &crate::dispatch::Extraction| {
                    metrics.timer("stage.mesh").record(ex.timing.marching);
                    metrics.timer("stage.diameters").record(ex.timing.diameters);
                    metrics.timer("stage.transfer").record(ex.timing.transfer);
                    // timing.texture covers the whole intensity phase; only
                    // attribute it to the texture stage when texture
                    // matrices actually ran on any derived image
                    // (ex.texture alone mirrors just the `original` image,
                    // which may be disabled)
                    if ex.derived.iter().any(|d| d.texture.is_some()) {
                        metrics.timer("stage.texture").record(ex.timing.texture);
                    }
                    bump(match ex.path {
                        PathTaken::Accelerated => "path.accelerated",
                        PathTaken::CpuFallback => "path.cpu",
                    });
                };
                while let Ok(item) = read_rx.recv() {
                    let _case = crate::trace::case_scope(&item.case_id);
                    let mut outcome = CaseOutcome {
                        case_id: item.case_id.clone(),
                        rows: Vec::new(),
                        failures: Vec::new(),
                    };
                    match &item.payload {
                        MaskPayload::Binary(mask) => {
                            let sp = crate::trace::span("case");
                            let res = extractor.execute_case(mask, item.image.as_ref());
                            drop(sp);
                            match res {
                                Ok(mut ex) => {
                                    ex.timing.read = item.read;
                                    ex.timing.read_image = item.read_image;
                                    metrics
                                        .timer("stage.preprocess")
                                        .record(ex.timing.preprocess);
                                    record(&ex);
                                    outcome.rows.push(CaseResult {
                                        case_id: item.case_id.clone(),
                                        label: None,
                                        features: ex.features,
                                        first_order: ex.first_order,
                                        texture: ex.texture,
                                        derived: ex.derived,
                                        timing: ex.timing,
                                        path: ex.path,
                                    });
                                }
                                Err(e) => {
                                    // every per-case failure lands in
                                    // exactly one named counter; this is the
                                    // bucket for failures inside the extract
                                    // stage itself
                                    bump("errors.extract");
                                    outcome
                                        .failures
                                        .push((item.case_id.clone(), format!("extract: {e:#}")));
                                }
                            }
                        }
                        MaskPayload::Labels { mask, selected } => {
                            let sp = crate::trace::span("case");
                            let res = extractor.execute_label_map(
                                &item.case_id,
                                mask,
                                item.image.as_ref(),
                                selected,
                            );
                            drop(sp);
                            match res {
                                Err(e) => {
                                    // whole-case failure (shared prep):
                                    // one errors.extract bump, one failure
                                    bump("errors.extract");
                                    outcome
                                        .failures
                                        .push((item.case_id.clone(), format!("extract: {e:#}")));
                                }
                                Ok(per_label) => {
                                    // `stage.preprocess` counts once per
                                    // *case* (the pass is shared), while
                                    // mesh/diameters/texture count once per
                                    // label
                                    let mut case_preprocess = Duration::ZERO;
                                    let mut attached_read = false;
                                    for (label, r) in per_label {
                                        match r {
                                            Ok(mut ex) => {
                                                if !attached_read {
                                                    ex.timing.read = item.read;
                                                    ex.timing.read_image = item.read_image;
                                                    attached_read = true;
                                                }
                                                case_preprocess += ex.timing.preprocess;
                                                record(&ex);
                                                outcome.rows.push(CaseResult {
                                                    case_id: item.case_id.clone(),
                                                    label: Some(label),
                                                    features: ex.features,
                                                    first_order: ex.first_order,
                                                    texture: ex.texture,
                                                    derived: ex.derived,
                                                    timing: ex.timing,
                                                    path: ex.path,
                                                });
                                            }
                                            Err(e) => {
                                                // per-label isolation: this
                                                // label failed, the case's
                                                // other labels still flow;
                                                // separate counter so
                                                // errors.extract stays
                                                // per-case
                                                bump("errors.label");
                                                outcome.failures.push((
                                                    item.case_id.clone(),
                                                    format!("label {label}: {e:#}"),
                                                ));
                                            }
                                        }
                                    }
                                    if !outcome.rows.is_empty() {
                                        metrics
                                            .timer("stage.preprocess")
                                            .record(case_preprocess);
                                    }
                                }
                            }
                        }
                    }
                    if out_tx.send(outcome).is_err() {
                        break;
                    }
                }
            });
        }
        drop(read_rx);
        drop(out_tx);

        // sink (inline in the scope so `results` lives on this stack);
        // the callback fires before the outcome is folded into the
        // report, in completion order
        let mut results = Vec::with_capacity(n_cases);
        let mut failures = Vec::new();
        while let Ok(outcome) = out_rx.recv() {
            on_case(&outcome);
            results.extend(outcome.rows);
            failures.extend(outcome.failures);
        }
        // stable order: manifest order, then ascending label within a case
        let order: std::collections::HashMap<&str, usize> = manifest
            .cases
            .iter()
            .enumerate()
            .map(|(i, e)| (e.case_id.as_str(), i))
            .collect();
        results.sort_by_key(|r| {
            (
                order.get(r.case_id.as_str()).copied().unwrap_or(usize::MAX),
                r.label.unwrap_or(0),
            )
        });

        // Batch-occupancy counters from the accelerated dispatcher, when it
        // is live (cumulative over the extractor's lifetime).
        if let Some(bs) = extractor.batch_stats() {
            metrics.set_counter("batch.submitted", bs.submitted);
            metrics.set_counter("batch.flushes", bs.flushes);
            metrics.set_counter("batch.flushed_items", bs.flushed_items);
            metrics.set_counter("batch.full_flushes", bs.full_flushes);
            metrics.set_counter("batch.linger_flushes", bs.linger_flushes);
            metrics.set_counter("batch.max_occupancy", bs.max_occupancy);
            // mean group occupancy ×100 (integer metric registry)
            if bs.flushes > 0 {
                metrics
                    .set_counter("batch.occupancy_x100", bs.flushed_items * 100 / bs.flushes);
            }
        }

        // Peak derived-image residency: with the streaming extractor this
        // stays at ~2 crop-sized volumes × feature_workers regardless of
        // image_types / wavelet_levels (the point of the visitor); only
        // meaningful when intensity classes actually derive images.
        if cfg.feature_classes.needs_image() {
            metrics.set_counter(
                "mem.peak_derived_bytes",
                crate::imgproc::peak_derived_bytes(),
            );
        }

        // Peak in-flight case residency (mask + image bytes held between
        // read admission and extraction): the gauge the `memory_budget`
        // knob bounds, and the slab-vs-whole bench leg's measurement.
        metrics.set_counter(
            "mem.peak_pipeline_bytes",
            crate::imgproc::peak_pipeline_bytes(),
        );

        Ok(PipelineReport {
            results,
            failures,
            metrics_text: metrics.report(),
            metrics: metrics.snapshot(),
            wall: start.elapsed(),
        })
    })
}

/// Spawn a scoped worker with a stable thread name. The name shows up in
/// trace thread metadata (and debugger thread lists); spawn failure is a
/// resource-exhaustion condition the pipeline cannot limp past.
fn spawn_named<'scope, 'env, F>(
    scope: &'scope std::thread::Scope<'scope, 'env>,
    name: String,
    f: F,
) -> std::thread::ScopedJoinHandle<'scope, ()>
where
    F: FnOnce() + Send + 'scope,
{
    std::thread::Builder::new()
        .name(name)
        .spawn_scoped(scope, f)
        .expect("spawn pipeline worker thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Backend;
    use crate::synth::{generate_dataset, GenOptions};

    fn tiny_dataset(tag: &str) -> DatasetManifest {
        let root = std::env::temp_dir().join(format!("radpipe_pipeline_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        generate_dataset(&root, &GenOptions { scale: 0.003, seed: 5 }).unwrap()
    }

    fn cpu_cfg() -> PipelineConfig {
        PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() }
    }

    #[test]
    fn processes_all_cases_in_manifest_order() {
        let m = tiny_dataset("order");
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        assert_eq!(report.results.len(), 20);
        let ids: Vec<_> = report.results.iter().map(|r| r.case_id.as_str()).collect();
        let want: Vec<_> = m.cases.iter().map(|e| e.case_id.as_str()).collect();
        assert_eq!(ids, want);
        assert!(report.metrics_text.contains("stage.read"));
        assert!(report.metrics_text.contains("stage.preprocess"));
    }

    #[test]
    fn multiworker_matches_single_worker() {
        let m = tiny_dataset("workers");
        let cfg1 = cpu_cfg();
        let ex1 = FeatureExtractor::new(&cfg1).unwrap();
        let r1 = run_pipeline(&m, &cfg1, &ex1).unwrap();

        let cfg4 = PipelineConfig {
            read_workers: 3,
            feature_workers: 4,
            queue_capacity: 2,
            ..cpu_cfg()
        };
        let ex4 = FeatureExtractor::new(&cfg4).unwrap();
        let r4 = run_pipeline(&m, &cfg4, &ex4).unwrap();

        assert_eq!(r1.results.len(), r4.results.len());
        for (a, b) in r1.results.iter().zip(&r4.results) {
            assert_eq!(a.case_id, b.case_id);
            assert_eq!(a.features.mesh_volume, b.features.mesh_volume);
            assert_eq!(a.features.maximum_3d_diameter, b.features.maximum_3d_diameter);
        }
    }

    #[test]
    fn missing_file_reported_not_fatal() {
        let mut m = tiny_dataset("missing");
        m.cases[3].mask = PathBuf::from("does-not-exist.rvol.gz");
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].0, m.cases[3].case_id);
        assert!(report.failures[0].1.contains("read"));
    }

    #[test]
    fn corrupt_file_reported_not_fatal() {
        let m = tiny_dataset("corrupt");
        std::fs::write(m.mask_path(&m.cases[0]), b"garbage").unwrap();
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
    }

    #[test]
    fn tiny_queue_capacity_still_completes() {
        let m = tiny_dataset("queue");
        let cfg = PipelineConfig { queue_capacity: 1, feature_workers: 2, ..cpu_cfg() };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 20);
    }

    #[test]
    fn texture_classes_flow_through_the_pipeline_deterministically() {
        let m = tiny_dataset("texture");
        let classes = crate::config::FeatureClasses::parse("all").unwrap();
        let cfg1 = PipelineConfig { feature_classes: classes, ..cpu_cfg() };
        let ex1 = FeatureExtractor::new(&cfg1).unwrap();
        let r1 = run_pipeline(&m, &cfg1, &ex1).unwrap();
        assert!(r1.failures.is_empty(), "{:?}", r1.failures);
        assert!(r1.results.iter().all(|r| r.texture.is_some() && r.first_order.is_some()));
        assert!(r1.metrics_text.contains("stage.texture"));

        // multi-worker, multi-thread accumulation: identical values
        let cfg4 = PipelineConfig {
            feature_workers: 3,
            cpu_threads: 4,
            feature_classes: classes,
            ..cpu_cfg()
        };
        let ex4 = FeatureExtractor::new(&cfg4).unwrap();
        let r4 = run_pipeline(&m, &cfg4, &ex4).unwrap();
        for (a, b) in r1.results.iter().zip(&r4.results) {
            assert_eq!(a.case_id, b.case_id);
            assert_eq!(a.texture, b.texture, "{}", a.case_id);
            assert_eq!(a.first_order, b.first_order, "{}", a.case_id);
        }
    }

    #[test]
    fn default_config_reports_no_texture_metrics() {
        let m = tiny_dataset("notexture");
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.results.iter().all(|r| r.texture.is_none()));
        assert!(!report.metrics_text.contains("stage.texture"));
        // shape-only runs derive no images: no memory gauge either
        assert!(!report.metrics_text.contains("mem.peak_derived_bytes"));
    }

    #[test]
    fn derived_runs_report_the_peak_memory_gauge() {
        let m = tiny_dataset("membytes");
        let cfg = PipelineConfig {
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            image_types: crate::imgproc::ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0],
            ..cpu_cfg()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        // presence only: the value is a process-wide high-water mark and
        // concurrently-running tests share the meter
        assert!(
            report.metrics_text.contains("mem.peak_derived_bytes"),
            "{}",
            report.metrics_text
        );
    }

    #[test]
    fn firstorder_only_runs_report_no_texture_metric() {
        let m = tiny_dataset("fo_only");
        let cfg = PipelineConfig {
            feature_classes: crate::config::FeatureClasses::parse("firstorder").unwrap(),
            ..cpu_cfg()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        assert!(report.results.iter().all(|r| r.first_order.is_some()));
        assert!(report.results.iter().all(|r| r.texture.is_none()));
        // first-order time must not be misattributed to a texture stage
        assert!(!report.metrics_text.contains("stage.texture"));
    }

    #[test]
    fn derived_image_features_flow_through_the_pipeline() {
        let m = tiny_dataset("derived");
        let cfg = PipelineConfig {
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            image_types: crate::imgproc::ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            ..cpu_cfg()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        for r in &report.results {
            assert_eq!(r.derived.len(), 11, "{}", r.case_id);
            assert!(r.derived.iter().all(|d| d.first_order.is_some()), "{}", r.case_id);
        }
        // multi-worker run reproduces every derived feature bit-for-bit
        let cfg4 = PipelineConfig { feature_workers: 3, cpu_threads: 4, ..cfg.clone() };
        let ex4 = FeatureExtractor::new(&cfg4).unwrap();
        let r4 = run_pipeline(&m, &cfg4, &ex4).unwrap();
        for (a, b) in report.results.iter().zip(&r4.results) {
            assert_eq!(a.derived, b.derived, "{}", a.case_id);
        }
    }

    #[test]
    fn texture_metric_is_recorded_without_the_original_image_type() {
        // image_types = "log" only: the legacy ex.texture mirror is None,
        // but texture matrices still run on the LoG images and their time
        // must land in stage.texture
        let m = tiny_dataset("logonly");
        let cfg = PipelineConfig {
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            image_types: crate::imgproc::ImageTypes::parse("log").unwrap(),
            log_sigmas: vec![1.0],
            ..cpu_cfg()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        for r in &report.results {
            assert!(r.texture.is_none(), "no 'original' entry to mirror");
            assert_eq!(r.derived.len(), 1);
            assert!(r.derived[0].texture.is_some());
        }
        assert!(report.metrics_text.contains("stage.texture"));
    }

    #[test]
    fn batching_config_matches_unbatched_results() {
        // Auto backend with no artifacts → CPU fallback; the batching knobs
        // must plumb through without changing a single feature value.
        let m = tiny_dataset("batchcfg");
        let base_cfg = cpu_cfg();
        let base = FeatureExtractor::new(&base_cfg).unwrap();
        let r1 = run_pipeline(&m, &base_cfg, &base).unwrap();

        let cfg = PipelineConfig {
            backend: Backend::Auto,
            artifact_dir: PathBuf::from("/nonexistent/artifacts"),
            cpu_threads: 1,
            engine_count: 3,
            batch_size: 8,
            batch_linger_ms: 1,
            feature_workers: 3,
            ..PipelineConfig::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let r2 = run_pipeline(&m, &cfg, &ex).unwrap();

        assert_eq!(r1.results.len(), r2.results.len());
        for (a, b) in r1.results.iter().zip(&r2.results) {
            assert_eq!(a.case_id, b.case_id);
            assert_eq!(a.features.mesh_volume, b.features.mesh_volume);
            assert_eq!(a.features.maximum_3d_diameter, b.features.maximum_3d_diameter);
        }
        // CPU fallback → no batch counters in the report
        assert!(!r2.metrics_text.contains("batch.flushes"));
    }

    fn firstorder_cfg() -> PipelineConfig {
        PipelineConfig {
            feature_classes: crate::config::FeatureClasses::parse("firstorder").unwrap(),
            ..cpu_cfg()
        }
    }

    #[test]
    fn real_images_feed_intensity_features_not_the_stand_in() {
        let m = tiny_dataset("realimg");
        let cfg = firstorder_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let real = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(real.failures.is_empty(), "{:?}", real.failures);
        assert!(real.metrics_text.contains("stage.read_image"), "{}", real.metrics_text);

        // same manifest with the images stripped, synthetic stand-in opted
        // in: every case must produce *different* first-order values —
        // proof the image files are actually read
        let mut bare = m.clone();
        for e in &mut bare.cases {
            e.image = None;
        }
        let cfg_synth = PipelineConfig { synthetic_image: true, ..firstorder_cfg() };
        let ex_synth = FeatureExtractor::new(&cfg_synth).unwrap();
        let synth = run_pipeline(&bare, &cfg_synth, &ex_synth).unwrap();
        assert!(synth.failures.is_empty(), "{:?}", synth.failures);
        assert!(!synth.metrics_text.contains("stage.read_image"));
        assert_eq!(real.results.len(), synth.results.len());
        for (a, b) in real.results.iter().zip(&synth.results) {
            assert_eq!(a.case_id, b.case_id);
            assert_ne!(a.first_order, b.first_order, "{}", a.case_id);
        }
    }

    #[test]
    fn missing_image_without_optin_fails_only_that_case() {
        let mut m = tiny_dataset("nooptin");
        m.cases[4].image = None;
        let cfg = firstorder_cfg();
        assert!(!cfg.synthetic_image);
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].0, m.cases[4].case_id);
        assert!(report.failures[0].1.contains("image="), "{}", report.failures[0].1);
        assert!(
            report.failures[0].1.contains("--synthetic-image"),
            "{}",
            report.failures[0].1
        );
    }

    #[test]
    fn missing_image_is_isolated_on_the_slab_path_too() {
        // regression: the slab read arm used to unwrap image_path behind a
        // want_image guard; a mask-only case on an intensity run must fail
        // as *that case*, with the remedy, never panic a read worker
        let mut m = tiny_dataset("slabnoimg");
        m.cases[4].image = None;
        let cfg = PipelineConfig { slab_io: true, ..firstorder_cfg() };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].0, m.cases[4].case_id);
        assert!(
            report.failures[0].1.contains("--synthetic-image"),
            "{}",
            report.failures[0].1
        );
    }

    #[test]
    fn undeclared_dims_still_flow_through_both_read_paths() {
        // cohort manifests carry no dims declaration: None must skip the
        // mismatch check and still size the whole-grid budget correctly
        let mut m = tiny_dataset("nodims");
        for e in &mut m.cases {
            e.dims = None;
        }
        let cfg = PipelineConfig { memory_budget: 1 << 20, ..cpu_cfg() };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let whole = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(whole.failures.is_empty(), "{:?}", whole.failures);
        assert_eq!(whole.results.len(), 20);
        let slab_cfg = PipelineConfig { slab_io: true, ..cpu_cfg() };
        let ex2 = FeatureExtractor::new(&slab_cfg).unwrap();
        let slab = run_pipeline(&m, &slab_cfg, &ex2).unwrap();
        assert!(slab.failures.is_empty(), "{:?}", slab.failures);
        for (a, b) in whole.results.iter().zip(&slab.results) {
            assert_eq!(a.features, b.features, "{}", a.case_id);
        }
    }

    #[test]
    fn per_case_callback_sees_every_outcome_exactly_once() {
        let mut m = tiny_dataset("callback");
        m.cases[3].mask = PathBuf::from("does-not-exist.rvol.gz");
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let mut seen: Vec<(String, bool)> = Vec::new();
        let report = run_pipeline_with(&m, &cfg, &ex, &mut |o| {
            seen.push((o.case_id.clone(), o.is_success()));
        })
        .unwrap();
        assert_eq!(seen.len(), 20, "one callback per manifest entry");
        assert_eq!(seen.iter().filter(|(_, ok)| !ok).count(), 1);
        let failed = seen.iter().find(|(_, ok)| !ok).unwrap();
        assert_eq!(failed.0, m.cases[3].case_id);
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
    }

    #[test]
    fn extract_failures_land_in_the_errors_extract_counter() {
        // an intensity run with one image stripped (and no synthetic
        // stand-in opt-in) fails inside the extract stage — exactly one
        // bump of the extract-stage error counter, zero read-stage ones
        let mut m = tiny_dataset("exterr");
        m.cases[6].image = None;
        let cfg = firstorder_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].0, m.cases[6].case_id);
        assert!(report.failures[0].1.starts_with("extract:"), "{}", report.failures[0].1);
        assert_eq!(report.metrics.counter("errors.extract"), Some(1));
        assert_eq!(report.metrics.counter("errors.read"), None);
        assert_eq!(report.metrics.counter("errors.read_image"), None);
        assert!(report.metrics_text.contains("errors.extract"), "{}", report.metrics_text);
        // the taxonomy is total: failures and error counters agree
        let errors: u64 = report
            .metrics
            .counters
            .iter()
            .filter(|(k, _)| k.starts_with("errors."))
            .map(|(_, v)| v)
            .sum();
        assert_eq!(errors, report.failures.len() as u64);
    }

    #[test]
    fn metrics_snapshot_rides_along_with_the_report() {
        let m = tiny_dataset("snapshot");
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        let snap = &report.metrics;
        assert_eq!(snap.timer("stage.read").map(|t| t.count), Some(20));
        assert_eq!(snap.timer("stage.mesh").map(|t| t.count), Some(20));
        assert_eq!(snap.counter("path.cpu"), Some(20));
        // the embedded snapshot round-trips through the validating parser
        let text = snap.to_json_text();
        let back = crate::metrics::snapshot::MetricsSnapshot::from_json_text(&text).unwrap();
        assert_eq!(&back, snap);
    }

    #[test]
    fn unreadable_image_is_a_case_failure_not_fatal() {
        let mut m = tiny_dataset("badimg");
        m.cases[2].image = Some(PathBuf::from("no-such-image.rvol.gz"));
        std::fs::write(m.image_path(&m.cases[7]).unwrap(), b"garbage").unwrap();
        let cfg = firstorder_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 18);
        assert_eq!(report.failures.len(), 2);
        for (case, msg) in &report.failures {
            assert!(msg.contains("read image"), "{case}: {msg}");
        }
        assert!(report.metrics_text.contains("errors.read_image"));
    }

    #[test]
    fn dims_mismatch_is_a_case_failure() {
        let mut m = tiny_dataset("dims");
        m.cases[1].dims = Some(crate::volume::Dims::new(1, 2, 3));
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert_eq!(report.results.len(), 19);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].0, m.cases[1].case_id);
        assert!(report.failures[0].1.contains("dims=1x2x3"), "{}", report.failures[0].1);
    }

    fn multilabel_dataset(tag: &str) -> DatasetManifest {
        let root = std::env::temp_dir().join(format!("radpipe_pipeline_ml_{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        crate::synth::generate_multilabel_dataset(&root, &GenOptions { scale: 0.003, seed: 5 })
            .unwrap()
    }

    #[test]
    fn label_map_run_shares_one_pass_and_isolates_the_empty_label() {
        let m = multilabel_dataset("all");
        let cfg = PipelineConfig {
            labels: LabelSelection::All,
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            ..cpu_cfg()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        // 3 cases × labels {1,2,3}; the declared-but-empty label 4 on the
        // first case fails per-label, not per-case
        assert_eq!(report.results.len(), 9, "{:?}", report.failures);
        assert_eq!(report.failures.len(), 1);
        assert_eq!(report.failures[0].0, m.cases[0].case_id);
        assert!(report.failures[0].1.contains("label 4"), "{}", report.failures[0].1);
        assert!(report.failures[0].1.contains("no voxels"), "{}", report.failures[0].1);
        assert_eq!(report.metrics.counter("errors.label"), Some(1));
        assert_eq!(report.metrics.counter("errors.extract"), None);
        // the error taxonomy stays total with the per-label counter
        let errors: u64 = report
            .metrics
            .counters
            .iter()
            .filter(|(k, _)| k.starts_with("errors."))
            .map(|(_, v)| v)
            .sum();
        assert_eq!(errors, report.failures.len() as u64);
        // ONE shared pass per case: preprocess counts cases, mesh counts labels
        assert_eq!(report.metrics.timer("stage.preprocess").map(|t| t.count), Some(3));
        assert_eq!(report.metrics.timer("stage.mesh").map(|t| t.count), Some(9));
        assert_eq!(report.metrics.timer("stage.read").map(|t| t.count), Some(3));
        // rows are (case, label)-ordered and label-tagged
        let got: Vec<(String, Option<u16>)> = report
            .results
            .iter()
            .map(|r| (r.case_id.clone(), r.label))
            .collect();
        let want: Vec<(String, Option<u16>)> = m
            .cases
            .iter()
            .flat_map(|e| (1u16..=3).map(move |l| (e.case_id.clone(), Some(l))))
            .collect();
        assert_eq!(got, want);
        assert!(report.results.iter().all(|r| r.texture.is_some()));
        assert!(report.metrics_text.contains("mem.peak_pipeline_bytes"));
    }

    #[test]
    fn multi_label_mask_without_a_selector_fails_with_the_remedy() {
        let m = multilabel_dataset("nosel");
        let cfg = cpu_cfg();
        assert!(!cfg.labels.is_set());
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.results.is_empty());
        assert_eq!(report.failures.len(), 3);
        for (case, msg) in &report.failures {
            assert!(msg.contains("label map"), "{case}: {msg}");
            assert!(msg.contains("--labels"), "{case}: {msg}");
            assert!(msg.contains("1,2,3"), "names the labels found — {case}: {msg}");
        }
        assert_eq!(report.metrics.counter("errors.read"), Some(3));
    }

    #[test]
    fn explicit_label_list_extracts_only_those_labels() {
        let m = multilabel_dataset("list");
        let cfg = PipelineConfig { labels: LabelSelection::List(vec![2]), ..cpu_cfg() };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        assert_eq!(report.results.len(), 3);
        assert!(report.results.iter().all(|r| r.label == Some(2)));
    }

    #[test]
    fn slab_read_run_is_bit_identical_to_whole_read() {
        let m = multilabel_dataset("slab");
        let whole_cfg = PipelineConfig {
            labels: LabelSelection::All,
            feature_classes: crate::config::FeatureClasses::parse("shape,firstorder").unwrap(),
            ..cpu_cfg()
        };
        let ex = FeatureExtractor::new(&whole_cfg).unwrap();
        let whole = run_pipeline(&m, &whole_cfg, &ex).unwrap();
        let slab_cfg = PipelineConfig { slab_io: true, ..whole_cfg.clone() };
        slab_cfg.validate().unwrap();
        let ex2 = FeatureExtractor::new(&slab_cfg).unwrap();
        let slab = run_pipeline(&m, &slab_cfg, &ex2).unwrap();
        assert_eq!(whole.results.len(), slab.results.len());
        for (a, b) in whole.results.iter().zip(&slab.results) {
            assert_eq!((a.case_id.as_str(), a.label), (b.case_id.as_str(), b.label));
            assert_eq!(a.features, b.features, "{} label {:?}", a.case_id, a.label);
            assert_eq!(a.first_order, b.first_order, "{} label {:?}", a.case_id, a.label);
            assert_eq!(a.derived, b.derived, "{} label {:?}", a.case_id, a.label);
        }
        assert_eq!(whole.failures.len(), slab.failures.len());
    }

    #[test]
    fn slab_io_also_serves_legacy_binary_masks() {
        let m = tiny_dataset("slabbin");
        let whole_cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&whole_cfg).unwrap();
        let whole = run_pipeline(&m, &whole_cfg, &ex).unwrap();
        let slab_cfg = PipelineConfig { slab_io: true, ..cpu_cfg() };
        let ex2 = FeatureExtractor::new(&slab_cfg).unwrap();
        let slab = run_pipeline(&m, &slab_cfg, &ex2).unwrap();
        assert!(slab.failures.is_empty(), "{:?}", slab.failures);
        assert_eq!(whole.results.len(), slab.results.len());
        for (a, b) in whole.results.iter().zip(&slab.results) {
            assert_eq!(a.case_id, b.case_id);
            assert_eq!(a.features, b.features, "{}", a.case_id);
        }
    }

    #[test]
    fn memory_budget_throttles_but_completes_identically() {
        let m = tiny_dataset("budget");
        let free_cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&free_cfg).unwrap();
        let free = run_pipeline(&m, &free_cfg, &ex).unwrap();
        // a budget far below one case still admits cases one at a time
        let tight_cfg = PipelineConfig {
            memory_budget: 1024,
            read_workers: 3,
            feature_workers: 2,
            ..cpu_cfg()
        };
        let ex2 = FeatureExtractor::new(&tight_cfg).unwrap();
        let tight = run_pipeline(&m, &tight_cfg, &ex2).unwrap();
        assert!(tight.failures.is_empty(), "{:?}", tight.failures);
        assert_eq!(free.results.len(), tight.results.len());
        for (a, b) in free.results.iter().zip(&tight.results) {
            assert_eq!(a.case_id, b.case_id);
            assert_eq!(a.features, b.features, "{}", a.case_id);
        }
        let peak = tight.metrics.counter("mem.peak_pipeline_bytes").unwrap();
        assert!(peak > 0, "gauge must reflect in-flight case bytes");
    }

    #[test]
    fn shape_only_runs_never_read_the_image_files() {
        let m = tiny_dataset("skipimg");
        // corrupt every image: a shape-only run must not even open them
        for e in &m.cases {
            std::fs::write(m.image_path(e).unwrap(), b"garbage").unwrap();
        }
        let cfg = cpu_cfg();
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let report = run_pipeline(&m, &cfg, &ex).unwrap();
        assert!(report.failures.is_empty(), "{:?}", report.failures);
        assert_eq!(report.results.len(), 20);
        assert!(!report.metrics_text.contains("stage.read_image"));
    }
}
