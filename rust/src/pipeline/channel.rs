//! Bounded MPMC channel (Mutex + Condvar). `std::sync::mpsc` is MPSC-only
//! and its `sync_channel` cannot be shared by multiple consumers; pipeline
//! stages need N producers *and* M consumers, so this is a small purpose-
//! built channel with close semantics and queue-depth introspection (the
//! backpressure signal the Table-2 harness plots).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Shared<T> {
    q: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

/// Sending half; clone for multiple producers.
pub struct Sender<T>(Arc<Shared<T>>);

/// Receiving half; clone for multiple consumers.
pub struct Receiver<T>(Arc<Shared<T>>);

/// Error: all receivers dropped.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error: channel empty and all senders dropped.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Create a bounded channel of the given capacity (≥ 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1, "capacity must be >= 1");
    let shared = Arc::new(Shared {
        q: Mutex::new(Inner { items: VecDeque::new(), senders: 1, receivers: 1 }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
    });
    (Sender(shared.clone()), Receiver(shared))
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().senders += 1;
        Sender(self.0.clone())
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut g = self.0.q.lock().unwrap();
        g.senders -= 1;
        if g.senders == 0 {
            drop(g);
            self.0.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.0.q.lock().unwrap().receivers += 1;
        Receiver(self.0.clone())
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut g = self.0.q.lock().unwrap();
        g.receivers -= 1;
        if g.receivers == 0 {
            drop(g);
            self.0.not_full.notify_all();
        }
    }
}

impl<T> Sender<T> {
    /// Blocking send with backpressure; fails when all receivers are gone.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut g = self.0.q.lock().unwrap();
        loop {
            if g.receivers == 0 {
                return Err(SendError(value));
            }
            if g.items.len() < self.0.capacity {
                g.items.push_back(value);
                drop(g);
                self.0.not_empty.notify_one();
                return Ok(());
            }
            g = self.0.not_full.wait(g).unwrap();
        }
    }

    /// Current queue depth (sampling hook for backpressure metrics).
    pub fn depth(&self) -> usize {
        self.0.q.lock().unwrap().items.len()
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Err(RecvError)` once empty and senders are gone.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut g = self.0.q.lock().unwrap();
        loop {
            if let Some(v) = g.items.pop_front() {
                drop(g);
                self.0.not_full.notify_one();
                return Ok(v);
            }
            if g.senders == 0 {
                return Err(RecvError);
            }
            g = self.0.not_empty.wait(g).unwrap();
        }
    }

    /// Drain into an iterator (consumes until closed).
    pub fn iter(&self) -> impl Iterator<Item = T> + '_ {
        std::iter::from_fn(move || self.recv().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn fifo_single_thread() {
        let (tx, rx) = bounded(4);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
    }

    #[test]
    fn recv_fails_after_senders_drop() {
        let (tx, rx) = bounded::<i32>(2);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn send_fails_after_receivers_drop() {
        let (tx, rx) = bounded::<i32>(2);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError(1)));
    }

    #[test]
    fn backpressure_blocks_until_recv() {
        let (tx, rx) = bounded::<i32>(1);
        tx.send(1).unwrap();
        let t = std::thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until a recv happens
            tx.depth()
        });
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(rx.recv(), Ok(1));
        t.join().unwrap();
        assert_eq!(rx.recv(), Ok(2));
    }

    #[test]
    fn mpmc_all_items_delivered_exactly_once() {
        let (tx, rx) = bounded::<usize>(8);
        let n_items = 1000;
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let tx = tx.clone();
                std::thread::spawn(move || {
                    for i in 0..n_items / 4 {
                        tx.send(p * (n_items / 4) + i).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Ok(v) = rx.recv() {
                        got.push(v);
                    }
                    got
                })
            })
            .collect();
        drop(rx);
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<usize> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        all.sort_unstable();
        assert_eq!(all, (0..n_items).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = bounded::<i32>(0);
    }
}
