//! The streaming coordinator: a staged, backpressured pipeline
//!
//! ```text
//! scanner → [read workers] → [preprocess+mesh workers] → [feature workers] → sink
//! ```
//!
//! built on an in-repo bounded MPMC channel (no tokio offline; the thread
//! runtime is part of the deliverable). Every stage records per-case phase
//! timings into [`crate::metrics::Metrics`]; the sink aggregates
//! [`CaseResult`]s for the experiment harnesses.

mod channel;
mod stages;

pub use channel::{bounded, Receiver, RecvError, SendError, Sender};
pub use stages::{
    case_named_features, run_pipeline, run_pipeline_with, CaseOutcome, CaseResult,
    PipelineReport,
};
