//! The complete PyRadiomics *Shape (3D)* feature class.
//!
//! Feature definitions follow the PyRadiomics documentation exactly; all are
//! computed in physical (mm) space. The expensive inputs (mesh volume,
//! surface area, diameters) come either from the CPU path
//! ([`crate::mc::mesh_roi`] + [`crate::parallel`]) or from the PJRT
//! artifacts ([`crate::dispatch`]); the cheap closed-form features are
//! derived here.

mod shape;
mod diameters;
mod firstorder;

pub use diameters::{brute_force_diameters, Diameters};
pub use firstorder::{compute_first_order, FirstOrderFeatures};
pub use shape::{compute_shape_features, ShapeFeatures};
