//! The PyRadiomics feature classes: *Shape (3D)*, *first-order* statistics
//! and the *texture* matrices (GLCM, GLRLM, GLSZM, GLDM, NGTDM).
//!
//! Feature definitions follow the PyRadiomics documentation; shape is
//! computed in physical (mm) space. The expensive shape inputs (mesh
//! volume, surface area, diameters) come either from the CPU path
//! ([`crate::mc::mesh_roi`] + [`crate::parallel`]) or from the PJRT
//! artifacts ([`crate::dispatch`]); the cheap closed-form features are
//! derived here. The texture matrices are accumulated in parallel with
//! deterministic results — see [`texture`].

mod shape;
mod diameters;
mod firstorder;
pub mod texture;

pub use diameters::{brute_force_diameters, Diameters};
pub use firstorder::{compute_first_order, compute_first_order_with, FirstOrderFeatures};
pub use shape::{compute_shape_features, ShapeFeatures};
pub use texture::{compute_texture, TextureFeatures, TextureOptions};
