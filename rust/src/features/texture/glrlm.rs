//! Gray Level Run Length Matrix (3D, 13 directions) and its derived
//! features — PyRadiomics `radiomics.glrlm` semantics: runs of equal gray
//! level along each direction (out-of-ROI voxels break runs), one matrix
//! per direction, features computed per matrix and averaged.

use std::ops::Range;

use super::discretize::DiscretizedRoi;
use super::glcm::ANGLES_13;
use crate::parallel::{fold_chunks, Strategy};

/// Line starts per work unit for the parallel accumulation (each item is a
/// whole line walk, so units are coarser than the GLCM's voxel chunks).
const CHUNK: usize = 128;

/// Run-length count matrices: one `ng × max_len` block per direction.
#[derive(Debug, Clone, PartialEq)]
pub struct GlrlmMatrices {
    /// `counts[d * ng * max_len + (i-1) * max_len + (l-1)]` = number of
    /// runs of gray level `i` and length `l` along direction `d`.
    pub counts: Vec<u64>,
    pub ng: usize,
    /// Longest representable run (the largest grid extent).
    pub max_len: usize,
    /// Direction count (13).
    pub n_directions: usize,
    /// ROI voxel count (`Np`, the RunPercentage denominator).
    pub n_voxels: usize,
}

impl GlrlmMatrices {
    /// Counts of one direction as an `ng × max_len` row-major slice.
    pub fn matrix(&self, d: usize) -> &[u64] {
        let s = self.ng * self.max_len;
        &self.counts[d * s..(d + 1) * s]
    }
}

/// The derived GLRLM feature vector (mean over the 13 directions).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GlrlmFeatures {
    pub short_run_emphasis: f64,
    pub long_run_emphasis: f64,
    pub gray_level_non_uniformity: f64,
    pub run_length_non_uniformity: f64,
    pub run_percentage: f64,
    pub low_gray_level_run_emphasis: f64,
    pub high_gray_level_run_emphasis: f64,
    pub short_run_low_gray_level_emphasis: f64,
    pub short_run_high_gray_level_emphasis: f64,
    pub long_run_low_gray_level_emphasis: f64,
    pub long_run_high_gray_level_emphasis: f64,
}

impl GlrlmFeatures {
    /// Ordered (name, value) view, mirroring the other feature classes.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("Glrlm_ShortRunEmphasis", self.short_run_emphasis),
            ("Glrlm_LongRunEmphasis", self.long_run_emphasis),
            ("Glrlm_GrayLevelNonUniformity", self.gray_level_non_uniformity),
            ("Glrlm_RunLengthNonUniformity", self.run_length_non_uniformity),
            ("Glrlm_RunPercentage", self.run_percentage),
            ("Glrlm_LowGrayLevelRunEmphasis", self.low_gray_level_run_emphasis),
            ("Glrlm_HighGrayLevelRunEmphasis", self.high_gray_level_run_emphasis),
            ("Glrlm_ShortRunLowGrayLevelEmphasis", self.short_run_low_gray_level_emphasis),
            ("Glrlm_ShortRunHighGrayLevelEmphasis", self.short_run_high_gray_level_emphasis),
            ("Glrlm_LongRunLowGrayLevelEmphasis", self.long_run_low_gray_level_emphasis),
            ("Glrlm_LongRunHighGrayLevelEmphasis", self.long_run_high_gray_level_emphasis),
        ]
    }
}

/// Accumulate the 13-direction run-length matrices of `roi`.
///
/// Every line (maximal lattice walk along a direction) is an independent
/// work item: [`fold_chunks`] distributes line starts across threads and
/// each worker tallies that line's runs into its partial matrix. Counts
/// are integers, so the merged result is bit-for-bit identical for every
/// strategy / thread count.
pub fn accumulate_glrlm(
    roi: &DiscretizedRoi,
    strategy: Strategy,
    threads: usize,
) -> GlrlmMatrices {
    let ng = roi.ng;
    let dims = roi.levels.dims;
    let max_len = dims.x.max(dims.y).max(dims.z).max(1);
    let msize = ng * max_len;

    // Line starts: voxels whose predecessor along the direction falls
    // outside the grid. Enumerated once, serially (O(13·N) index tests).
    let mut starts: Vec<(u32, u32, u32, u32)> = Vec::new(); // (dir, x, y, z)
    for (di, &(dx, dy, dz)) in ANGLES_13.iter().enumerate() {
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    let px = x as isize - dx;
                    let py = y as isize - dy;
                    let pz = z as isize - dz;
                    let inside = px >= 0
                        && py >= 0
                        && pz >= 0
                        && (px as usize) < dims.x
                        && (py as usize) < dims.y
                        && (pz as usize) < dims.z;
                    if !inside {
                        starts.push((di as u32, x as u32, y as u32, z as u32));
                    }
                }
            }
        }
    }

    let fold = |counts: &mut Vec<u64>, range: Range<usize>| {
        for &(di, sx, sy, sz) in &starts[range] {
            let (dx, dy, dz) = ANGLES_13[di as usize];
            let base = di as usize * msize;
            let (mut x, mut y, mut z) = (sx as isize, sy as isize, sz as isize);
            let mut run_level = 0usize;
            let mut run_len = 0usize;
            loop {
                let inside = x >= 0
                    && y >= 0
                    && z >= 0
                    && (x as usize) < dims.x
                    && (y as usize) < dims.y
                    && (z as usize) < dims.z;
                let level = if inside {
                    roi.levels.get(x as usize, y as usize, z as usize) as usize
                } else {
                    0
                };
                if level == run_level && level != 0 {
                    run_len += 1;
                } else {
                    if run_level != 0 {
                        counts[base + (run_level - 1) * max_len + (run_len - 1)] += 1;
                    }
                    run_level = level;
                    run_len = 1;
                }
                if !inside {
                    break;
                }
                x += dx;
                y += dy;
                z += dz;
            }
        }
    };

    let counts = fold_chunks(
        strategy,
        starts.len(),
        CHUNK,
        threads,
        || vec![0u64; ANGLES_13.len() * msize],
        fold,
        |acc: &mut Vec<u64>, part| {
            for (a, b) in acc.iter_mut().zip(part) {
                *a += b;
            }
        },
    );
    GlrlmMatrices {
        counts,
        ng,
        max_len,
        n_directions: ANGLES_13.len(),
        n_voxels: roi.n_voxels,
    }
}

/// Per-direction features, averaged over directions with at least one run.
///
/// Returns `None` when the ROI is empty (no runs in any direction).
pub fn glrlm_features(mats: &GlrlmMatrices) -> Option<GlrlmFeatures> {
    let (ng, max_len) = (mats.ng, mats.max_len);
    let mut sums = [0.0f64; 11];
    let mut n_valid = 0usize;

    for d in 0..mats.n_directions {
        let counts = mats.matrix(d);
        let nr: u64 = counts.iter().sum();
        if nr == 0 {
            continue;
        }
        n_valid += 1;
        let nr = nr as f64;

        let mut sre = 0.0;
        let mut lre = 0.0;
        let mut lglre = 0.0;
        let mut hglre = 0.0;
        let mut srlgle = 0.0;
        let mut srhgle = 0.0;
        let mut lrlgle = 0.0;
        let mut lrhgle = 0.0;
        let mut gln = 0.0;
        for i in 0..ng {
            let gi_sq = ((i + 1) * (i + 1)) as f64;
            let mut row = 0.0f64;
            for l in 0..max_len {
                let c = counts[i * max_len + l];
                if c == 0 {
                    continue;
                }
                let r = c as f64;
                let l_sq = ((l + 1) * (l + 1)) as f64;
                row += r;
                sre += r / l_sq;
                lre += r * l_sq;
                lglre += r / gi_sq;
                hglre += r * gi_sq;
                srlgle += r / (gi_sq * l_sq);
                srhgle += r * gi_sq / l_sq;
                lrlgle += r * l_sq / gi_sq;
                lrhgle += r * gi_sq * l_sq;
            }
            gln += row * row;
        }
        let mut rln = 0.0;
        for l in 0..max_len {
            let mut col = 0.0f64;
            for i in 0..ng {
                col += counts[i * max_len + l] as f64;
            }
            rln += col * col;
        }

        for (s, v) in sums.iter_mut().zip([
            sre / nr,
            lre / nr,
            gln / nr,
            rln / nr,
            nr / mats.n_voxels as f64,
            lglre / nr,
            hglre / nr,
            srlgle / nr,
            srhgle / nr,
            lrlgle / nr,
            lrhgle / nr,
        ]) {
            *s += v;
        }
    }

    if n_valid == 0 {
        return None;
    }
    let n = n_valid as f64;
    Some(GlrlmFeatures {
        short_run_emphasis: sums[0] / n,
        long_run_emphasis: sums[1] / n,
        gray_level_non_uniformity: sums[2] / n,
        run_length_non_uniformity: sums[3] / n,
        run_percentage: sums[4] / n,
        low_gray_level_run_emphasis: sums[5] / n,
        high_gray_level_run_emphasis: sums[6] / n,
        short_run_low_gray_level_emphasis: sums[7] / n,
        short_run_high_gray_level_emphasis: sums[8] / n,
        long_run_low_gray_level_emphasis: sums[9] / n,
        long_run_high_gray_level_emphasis: sums[10] / n,
    })
}

#[cfg(test)]
mod tests {
    use super::super::discretize::{discretize, Discretization};
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::{Dims, VoxelGrid};

    /// 4×1×1 line with levels [1, 1, 2, 2] — hand-computable run matrices:
    /// direction (1,0,0) has two runs of length 2; the other 12 directions
    /// see four isolated runs of length 1.
    fn line_roi() -> DiscretizedRoi {
        let dims = Dims::new(4, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..4 {
            img.set(x, 0, 0, if x < 2 { 0.0 } else { 1.0 });
            mask.set(x, 0, 0, 1);
        }
        discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap()
    }

    #[test]
    fn line_matrices_match_closed_form() {
        let roi = line_roi();
        let mats = accumulate_glrlm(&roi, Strategy::EqualSplit, 1);
        assert_eq!(mats.ng, 2);
        assert_eq!(mats.max_len, 4);
        // direction 0 = (1,0,0): R[1][2] = 1, R[2][2] = 1
        let m0 = mats.matrix(0);
        assert_eq!(m0[1], 1); // level 1, length 2
        assert_eq!(m0[4 + 1], 1); // level 2, length 2
        assert_eq!(m0.iter().sum::<u64>(), 2);
        // every other direction: 2 runs of length 1 per level
        for d in 1..13 {
            let m = mats.matrix(d);
            assert_eq!(m[0], 2, "dir {d}");
            assert_eq!(m[4], 2, "dir {d}");
            assert_eq!(m.iter().sum::<u64>(), 4, "dir {d}");
        }
    }

    #[test]
    fn line_features_match_closed_form() {
        // hand-computed per-direction values averaged over 13 directions
        // (see matrices above): e.g. SRE = (0.25 + 12·1)/13.
        let roi = line_roi();
        let f = glrlm_features(&accumulate_glrlm(&roi, Strategy::EqualSplit, 1)).unwrap();
        let close = |a: f64, b: f64| (a - b).abs() < 1e-9;
        assert!(close(f.short_run_emphasis, 12.25 / 13.0), "{}", f.short_run_emphasis);
        assert!(close(f.long_run_emphasis, 16.0 / 13.0), "{}", f.long_run_emphasis);
        assert!(close(f.gray_level_non_uniformity, 25.0 / 13.0));
        assert!(close(f.run_length_non_uniformity, 50.0 / 13.0));
        assert!(close(f.run_percentage, 12.5 / 13.0));
        assert!(close(f.low_gray_level_run_emphasis, 0.625));
        assert!(close(f.high_gray_level_run_emphasis, 2.5));
        assert!(close(f.short_run_low_gray_level_emphasis, 7.65625 / 13.0));
        assert!(close(f.short_run_high_gray_level_emphasis, 30.625 / 13.0));
        assert!(close(f.long_run_low_gray_level_emphasis, 10.0 / 13.0));
        assert!(close(f.long_run_high_gray_level_emphasis, 40.0 / 13.0));
    }

    #[test]
    fn masked_out_voxels_break_runs() {
        // levels [1, 1, _, 1] — the hole splits the x-run into 2 + 1
        let dims = Dims::new(4, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..4 {
            img.set(x, 0, 0, 5.0);
            mask.set(x, 0, 0, u8::from(x != 2));
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let mats = accumulate_glrlm(&roi, Strategy::EqualSplit, 1);
        let m0 = mats.matrix(0);
        assert_eq!(m0[0], 1); // run of length 1
        assert_eq!(m0[1], 1); // run of length 2
        assert_eq!(m0.iter().sum::<u64>(), 2);
    }

    #[test]
    fn every_roi_voxel_is_covered_by_runs_in_every_direction() {
        // Σ_l l·R[i][l] summed over i must equal Np for each direction
        let dims = Dims::new(6, 5, 4);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(3);
        for z in 0..4 {
            for y in 0..5 {
                for x in 0..6 {
                    img.set(x, y, z, rng.below(4) as f32);
                    if rng.below(5) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let mats = accumulate_glrlm(&roi, Strategy::EqualSplit, 1);
        for d in 0..13 {
            let m = mats.matrix(d);
            let covered: u64 = (0..mats.ng)
                .flat_map(|i| (0..mats.max_len).map(move |l| (i, l)))
                .map(|(i, l)| m[i * mats.max_len + l] * (l as u64 + 1))
                .sum();
            assert_eq!(covered, roi.n_voxels as u64, "dir {d}");
        }
    }

    #[test]
    fn accumulation_is_deterministic_across_strategies_and_threads() {
        let dims = Dims::new(8, 7, 6);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(23);
        for z in 0..6 {
            for y in 0..7 {
                for x in 0..8 {
                    img.set(x, y, z, rng.below(5) as f32);
                    if rng.below(8) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let want = accumulate_glrlm(&roi, Strategy::EqualSplit, 1);
        for strategy in Strategy::ALL {
            for threads in [1usize, 2, 4] {
                let got = accumulate_glrlm(&roi, strategy, threads);
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }

    #[test]
    fn empty_roi_has_no_features() {
        let dims = Dims::new(3, 3, 3);
        let img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        assert!(discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().is_none());
    }
}
