//! The texture feature subsystem: gray-level discretization feeding 3D
//! GLCM (13 angles, symmetric, distance-configurable) and GLRLM matrices
//! with their standard derived features.
//!
//! Texture is the per-voxel hot loop the related GPU radiomics ports
//! (cuRadiomics, Nyxus) accelerate next after shape; here the matrices are
//! accumulated **in parallel** — per-thread partial count matrices over
//! voxel/line chunks via [`crate::parallel::fold_chunks`], merged at the
//! end. Counts are integers, so results are bit-for-bit deterministic
//! regardless of strategy or thread count (tested).

mod discretize;
mod glcm;
mod glrlm;

pub use discretize::{discretize, DiscretizedRoi, Discretization, MAX_GRAY_LEVELS};
pub use glcm::{accumulate_glcm, glcm_features, GlcmFeatures, GlcmMatrices, ANGLES_13};
pub use glrlm::{accumulate_glrlm, glrlm_features, GlrlmFeatures, GlrlmMatrices};

use anyhow::Result;

use crate::parallel::Strategy;
use crate::volume::VoxelGrid;

/// Knobs for the texture computation (config/CLI plumb these through).
#[derive(Debug, Clone, PartialEq)]
pub struct TextureOptions {
    /// Gray-level binning of the ROI intensities.
    pub discretization: Discretization,
    /// GLCM neighbour distances in voxels (PyRadiomics default `[1]`).
    pub distances: Vec<usize>,
    /// Work decomposition for the parallel accumulation.
    pub strategy: Strategy,
    /// Worker threads (`0` = all cores, `1` = serial).
    pub threads: usize,
    /// Compute the GLCM class.
    pub glcm: bool,
    /// Compute the GLRLM class.
    pub glrlm: bool,
}

impl Default for TextureOptions {
    fn default() -> Self {
        TextureOptions {
            discretization: Discretization::BinWidth(25.0),
            distances: vec![1],
            strategy: Strategy::LocalAccumulators,
            threads: 0,
            glcm: true,
            glrlm: true,
        }
    }
}

/// The combined texture feature vector of one case.
#[derive(Debug, Clone, PartialEq)]
pub struct TextureFeatures {
    /// Gray levels after discretization (`Ng`).
    pub ng: usize,
    /// GLCM features (`None` when disabled or no co-occurring pairs).
    pub glcm: Option<GlcmFeatures>,
    /// GLRLM features (`None` when disabled).
    pub glrlm: Option<GlrlmFeatures>,
}

impl TextureFeatures {
    /// Ordered (name, value) view over every computed texture feature,
    /// mirroring [`super::ShapeFeatures::named`].
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        let mut out = Vec::new();
        if let Some(g) = &self.glcm {
            out.extend(g.named());
        }
        if let Some(g) = &self.glrlm {
            out.extend(g.named());
        }
        out
    }
}

/// Compute the enabled texture classes of `image` over `mask != 0`.
///
/// Returns `Ok(None)` for an empty ROI (consistent with
/// [`super::compute_first_order`]); errors only on invalid discretization
/// settings. The result is identical for any `opts.threads` value.
pub fn compute_texture(
    image: &VoxelGrid<f32>,
    mask: &VoxelGrid<u8>,
    opts: &TextureOptions,
) -> Result<Option<TextureFeatures>> {
    let Some(roi) = discretize(image, mask, opts.discretization)? else {
        return Ok(None);
    };
    let glcm = if opts.glcm {
        let distances = if opts.distances.is_empty() { vec![1] } else { opts.distances.clone() };
        glcm_features(&accumulate_glcm(&roi, &distances, opts.strategy, opts.threads))
    } else {
        None
    };
    let glrlm = if opts.glrlm {
        glrlm_features(&accumulate_glrlm(&roi, opts.strategy, opts.threads))
    } else {
        None
    };
    Ok(Some(TextureFeatures { ng: roi.ng, glcm, glrlm }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn patterned(n: usize) -> (VoxelGrid<f32>, VoxelGrid<u8>) {
        let dims = Dims::new(n, n, n);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    img.set(x, y, z, ((3 * x + 5 * y + 7 * z) % 60) as f32);
                    let c = n as f64 / 2.0;
                    let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                    if dx * dx + dy * dy + dz * dz <= (n as f64 / 2.5).powi(2) {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        (img, mask)
    }

    #[test]
    fn full_texture_vector_has_20_features() {
        let (img, mask) = patterned(12);
        let t = compute_texture(&img, &mask, &TextureOptions::default()).unwrap().unwrap();
        assert_eq!(t.named().len(), 9 + 11);
        assert!(t.ng >= 2);
        assert!(t.named().iter().all(|(_, v)| v.is_finite()));
    }

    #[test]
    fn classes_can_be_disabled_independently() {
        let (img, mask) = patterned(8);
        let opts = TextureOptions { glcm: false, ..Default::default() };
        let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
        assert!(t.glcm.is_none());
        assert!(t.glrlm.is_some());
        let opts = TextureOptions { glrlm: false, ..Default::default() };
        let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
        assert!(t.glcm.is_some());
        assert!(t.glrlm.is_none());
    }

    #[test]
    fn thread_count_does_not_change_a_single_bit() {
        let (img, mask) = patterned(14);
        let serial = TextureOptions { threads: 1, ..Default::default() };
        let want = compute_texture(&img, &mask, &serial).unwrap().unwrap();
        for strategy in Strategy::ALL {
            for threads in [2usize, 3, 8] {
                let opts = TextureOptions { threads, strategy, ..Default::default() };
                let got = compute_texture(&img, &mask, &opts).unwrap().unwrap();
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }

    #[test]
    fn empty_roi_is_none() {
        let dims = Dims::new(4, 4, 4);
        let img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        assert!(compute_texture(&img, &mask, &TextureOptions::default()).unwrap().is_none());
    }

    #[test]
    fn constant_roi_is_well_defined() {
        // one gray level: correlation defined as 1, contrast 0, SRE → long runs
        let dims = Dims::new(6, 6, 6);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..6 {
            for y in 0..6 {
                for x in 0..6 {
                    img.set(x, y, z, 42.0);
                    mask.set(x, y, z, 1);
                }
            }
        }
        let t = compute_texture(&img, &mask, &TextureOptions::default()).unwrap().unwrap();
        assert_eq!(t.ng, 1);
        let g = t.glcm.unwrap();
        assert_eq!(g.contrast, 0.0);
        assert_eq!(g.correlation, 1.0);
        assert_eq!(g.joint_energy, 1.0);
        let r = t.glrlm.unwrap();
        assert!(r.long_run_emphasis > 1.0);
        assert!(r.run_percentage < 1.0);
    }
}
