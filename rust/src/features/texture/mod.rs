//! The texture feature subsystem: gray-level discretization feeding 3D
//! GLCM (13 angles, symmetric, distance-configurable), GLRLM and the
//! region-based matrix classes GLSZM, GLDM and NGTDM with their standard
//! derived features.
//!
//! Texture is the per-voxel hot loop the related GPU radiomics ports
//! (cuRadiomics, Nyxus) accelerate next after shape; here the matrices are
//! accumulated **in parallel** — per-thread partial integer count matrices
//! over voxel/line chunks via [`crate::parallel::fold_chunks`], merged at
//! the end. Counts (and the NGTDM's rational numerators) are integers, so
//! results are bit-for-bit deterministic regardless of strategy or thread
//! count (tested). GLSZM zone labelling buckets seed voxels per gray
//! level and flood-fills whole levels on worker threads — connected
//! components are traversal-independent, so it honours the same
//! determinism contract with only a key-sum merge (the serial fixed-order
//! fill stays on as the conformance reference).

mod discretize;
mod glcm;
mod gldm;
mod glrlm;
mod glszm;
mod ngtdm;

pub use discretize::{discretize, DiscretizedRoi, Discretization, MAX_GRAY_LEVELS};
pub use glcm::{
    accumulate_glcm, accumulate_glcm_reference, glcm_features, GlcmFeatures, GlcmMatrices,
    ANGLES_13,
};
pub use gldm::{accumulate_gldm, gldm_features, GldmFeatures, GldmMatrix, MAX_DEPENDENCE};
pub use glrlm::{accumulate_glrlm, glrlm_features, GlrlmFeatures, GlrlmMatrices};
pub use glszm::{
    accumulate_glszm, accumulate_glszm_indexed, glszm_features, GlszmFeatures, GlszmMatrix,
    NEIGHBOURS_26,
};
pub use ngtdm::{accumulate_ngtdm, ngtdm_features, NgtdmFeatures, NgtdmMatrix};

use anyhow::Result;

use crate::parallel::Strategy;
use crate::volume::VoxelGrid;

/// Knobs for the texture computation (config/CLI plumb these through).
#[derive(Debug, Clone, PartialEq)]
pub struct TextureOptions {
    /// Gray-level binning of the ROI intensities.
    pub discretization: Discretization,
    /// GLCM neighbour distances in voxels (PyRadiomics default `[1]`).
    pub distances: Vec<usize>,
    /// GLDM dependence threshold: a 26-neighbour is *dependent* when its
    /// gray level differs by at most this much (PyRadiomics default `0`).
    pub gldm_alpha: f64,
    /// Work decomposition for the parallel accumulation.
    pub strategy: Strategy,
    /// Worker threads (`0` = all cores, `1` = serial).
    pub threads: usize,
    /// Compute the GLCM class.
    pub glcm: bool,
    /// Compute the GLRLM class.
    pub glrlm: bool,
    /// Compute the GLSZM class.
    pub glszm: bool,
    /// Compute the GLDM class.
    pub gldm: bool,
    /// Compute the NGTDM class.
    pub ngtdm: bool,
}

impl Default for TextureOptions {
    fn default() -> Self {
        TextureOptions {
            discretization: Discretization::BinWidth(25.0),
            distances: vec![1],
            gldm_alpha: 0.0,
            strategy: Strategy::LocalAccumulators,
            threads: 0,
            glcm: true,
            glrlm: true,
            glszm: true,
            gldm: true,
            ngtdm: true,
        }
    }
}

/// The combined texture feature vector of one case.
#[derive(Debug, Clone, PartialEq)]
pub struct TextureFeatures {
    /// Gray levels after discretization (`Ng`).
    pub ng: usize,
    /// GLCM features (`None` when disabled or no co-occurring pairs).
    pub glcm: Option<GlcmFeatures>,
    /// GLRLM features (`None` when disabled).
    pub glrlm: Option<GlrlmFeatures>,
    /// GLSZM features (`None` when disabled).
    pub glszm: Option<GlszmFeatures>,
    /// GLDM features (`None` when disabled).
    pub gldm: Option<GldmFeatures>,
    /// NGTDM features (`None` when disabled or no voxel has a valid
    /// 26-neighbourhood, e.g. a single-voxel ROI).
    pub ngtdm: Option<NgtdmFeatures>,
}

impl TextureFeatures {
    /// Ordered (name, value) view over every computed texture feature,
    /// mirroring [`super::ShapeFeatures::named`].
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        let mut out = Vec::new();
        if let Some(g) = &self.glcm {
            out.extend(g.named());
        }
        if let Some(g) = &self.glrlm {
            out.extend(g.named());
        }
        if let Some(g) = &self.glszm {
            out.extend(g.named());
        }
        if let Some(g) = &self.gldm {
            out.extend(g.named());
        }
        if let Some(g) = &self.ngtdm {
            out.extend(g.named());
        }
        out
    }
}

/// Compute the enabled texture classes of `image` over `mask != 0`.
///
/// Returns `Ok(None)` for an empty ROI (consistent with
/// [`super::compute_first_order`]); errors only on invalid discretization
/// settings. The result is identical for any `opts.threads` value.
pub fn compute_texture(
    image: &VoxelGrid<f32>,
    mask: &VoxelGrid<u8>,
    opts: &TextureOptions,
) -> Result<Option<TextureFeatures>> {
    let Some(roi) = discretize(image, mask, opts.discretization)? else {
        return Ok(None);
    };
    let glcm = if opts.glcm {
        let distances = if opts.distances.is_empty() { vec![1] } else { opts.distances.clone() };
        glcm_features(&accumulate_glcm(&roi, &distances, opts.strategy, opts.threads))
    } else {
        None
    };
    let glrlm = if opts.glrlm {
        glrlm_features(&accumulate_glrlm(&roi, opts.strategy, opts.threads))
    } else {
        None
    };
    let glszm = if opts.glszm {
        glszm_features(&accumulate_glszm_indexed(&roi, opts.threads))
    } else {
        None
    };
    let gldm = if opts.gldm {
        gldm_features(&accumulate_gldm(&roi, opts.gldm_alpha, opts.strategy, opts.threads))
    } else {
        None
    };
    let ngtdm = if opts.ngtdm {
        ngtdm_features(&accumulate_ngtdm(&roi, opts.strategy, opts.threads))
    } else {
        None
    };
    Ok(Some(TextureFeatures { ng: roi.ng, glcm, glrlm, glszm, gldm, ngtdm }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn patterned(n: usize) -> (VoxelGrid<f32>, VoxelGrid<u8>) {
        let dims = Dims::new(n, n, n);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    img.set(x, y, z, ((3 * x + 5 * y + 7 * z) % 60) as f32);
                    let c = n as f64 / 2.0;
                    let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                    if dx * dx + dy * dy + dz * dz <= (n as f64 / 2.5).powi(2) {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        (img, mask)
    }

    #[test]
    fn full_texture_vector_has_47_features() {
        let (img, mask) = patterned(12);
        let t = compute_texture(&img, &mask, &TextureOptions::default()).unwrap().unwrap();
        assert_eq!(t.named().len(), 9 + 11 + 12 + 10 + 5);
        assert!(t.ng >= 2);
        assert!(t.named().iter().all(|(_, v)| v.is_finite()));
    }

    #[test]
    fn classes_can_be_disabled_independently() {
        let (img, mask) = patterned(8);
        let all = compute_texture(&img, &mask, &TextureOptions::default()).unwrap().unwrap();
        assert!(
            all.glcm.is_some()
                && all.glrlm.is_some()
                && all.glszm.is_some()
                && all.gldm.is_some()
                && all.ngtdm.is_some()
        );
        for off in 0..5 {
            let opts = TextureOptions {
                glcm: off != 0,
                glrlm: off != 1,
                glszm: off != 2,
                gldm: off != 3,
                ngtdm: off != 4,
                ..Default::default()
            };
            let t = compute_texture(&img, &mask, &opts).unwrap().unwrap();
            assert_eq!(t.glcm.is_none(), off == 0);
            assert_eq!(t.glrlm.is_none(), off == 1);
            assert_eq!(t.glszm.is_none(), off == 2);
            assert_eq!(t.gldm.is_none(), off == 3);
            assert_eq!(t.ngtdm.is_none(), off == 4);
        }
    }

    #[test]
    fn thread_count_does_not_change_a_single_bit() {
        let (img, mask) = patterned(14);
        let serial = TextureOptions { threads: 1, ..Default::default() };
        let want = compute_texture(&img, &mask, &serial).unwrap().unwrap();
        for strategy in Strategy::ALL {
            for threads in [2usize, 3, 8] {
                let opts = TextureOptions { threads, strategy, ..Default::default() };
                let got = compute_texture(&img, &mask, &opts).unwrap().unwrap();
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }

    #[test]
    fn empty_roi_is_none() {
        let dims = Dims::new(4, 4, 4);
        let img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        assert!(compute_texture(&img, &mask, &TextureOptions::default()).unwrap().is_none());
    }

    #[test]
    fn constant_roi_is_well_defined() {
        // one gray level: correlation defined as 1, contrast 0, SRE → long
        // runs; one zone; dependence 27 in the interior; NGTDM coarseness
        // hits the 1e6 cap — no NaN leaks anywhere
        let dims = Dims::new(6, 6, 6);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..6 {
            for y in 0..6 {
                for x in 0..6 {
                    img.set(x, y, z, 42.0);
                    mask.set(x, y, z, 1);
                }
            }
        }
        let t = compute_texture(&img, &mask, &TextureOptions::default()).unwrap().unwrap();
        assert_eq!(t.ng, 1);
        let g = t.glcm.unwrap();
        assert_eq!(g.contrast, 0.0);
        assert_eq!(g.correlation, 1.0);
        assert_eq!(g.joint_energy, 1.0);
        let r = t.glrlm.unwrap();
        assert!(r.long_run_emphasis > 1.0);
        assert!(r.run_percentage < 1.0);
        let z = t.glszm.unwrap();
        assert_eq!(z.zone_percentage, 1.0 / 216.0);
        assert_eq!(z.gray_level_variance, 0.0);
        let d = t.gldm.unwrap();
        assert!(d.large_dependence_emphasis > 1.0);
        assert_eq!(d.gray_level_variance, 0.0);
        let n = t.ngtdm.unwrap();
        assert_eq!(n.coarseness, 1e6);
        assert_eq!(n.contrast, 0.0);
        assert!(t.named().iter().all(|(_, v)| v.is_finite()));
    }

    #[test]
    fn single_voxel_roi_is_defined_for_every_class() {
        // GLCM has no pairs (None) and NGTDM no valid neighbourhood
        // (None); GLRLM/GLSZM/GLDM yield defined singleton statistics
        let dims = Dims::new(3, 3, 3);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        img.set(1, 1, 1, 5.0);
        mask.set(1, 1, 1, 1);
        let t = compute_texture(&img, &mask, &TextureOptions::default()).unwrap().unwrap();
        assert!(t.glcm.is_none(), "no co-occurring pairs");
        assert!(t.ngtdm.is_none(), "no valid neighbourhood");
        assert!(t.glrlm.is_some());
        let z = t.glszm.unwrap();
        assert_eq!(z.zone_percentage, 1.0);
        let d = t.gldm.unwrap();
        assert_eq!(d.small_dependence_emphasis, 1.0);
        assert!(t.named().iter().all(|(_, v)| v.is_finite()));
    }

    #[test]
    fn nan_intensity_inside_roi_is_a_located_error() {
        let dims = Dims::new(3, 3, 3);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..3 {
            for y in 0..3 {
                for x in 0..3 {
                    img.set(x, y, z, 1.0);
                    mask.set(x, y, z, 1);
                }
            }
        }
        img.set(1, 2, 0, f32::NAN);
        let err = compute_texture(&img, &mask, &TextureOptions::default()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("non-finite") && msg.contains("(1, 2, 0)"), "{msg}");
    }
}
