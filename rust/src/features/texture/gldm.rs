//! Gray Level Dependence Matrix (3D, 26-neighbourhood) and its derived
//! features — PyRadiomics `radiomics.gldm` semantics: the *dependence* of
//! a ROI voxel of level `i` is `1 +` the number of its 26-neighbours
//! inside the ROI whose level differs from `i` by at most `gldm_alpha`
//! (the voxel always counts itself, so dependences run `1..=27`).
//! `P(i, d)` counts voxels, and every ROI voxel contributes exactly one
//! entry — the matrix sums to `Np`.

use std::ops::Range;

use super::discretize::DiscretizedRoi;
use super::glszm::NEIGHBOURS_26;
use crate::parallel::{fold_chunks, Strategy};

/// Largest possible dependence: the centre voxel plus its 26 neighbours.
pub const MAX_DEPENDENCE: usize = 27;

/// Voxels per work unit for the parallel accumulation (each unit probes
/// 26 neighbours per voxel, comparable to the GLCM's 13 × distances).
const CHUNK: usize = 512;

/// The dependence count matrix: a dense `ng × 27` block.
#[derive(Debug, Clone, PartialEq)]
pub struct GldmMatrix {
    /// `counts[(i-1) * MAX_DEPENDENCE + (d-1)]` = voxels of gray level
    /// `i` with dependence `d`.
    pub counts: Vec<u64>,
    /// Number of gray levels (`Ng`).
    pub ng: usize,
    /// ROI voxel count (`Np` — also the matrix total, every voxel has
    /// exactly one dependence).
    pub n_voxels: usize,
}

/// The derived GLDM feature vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GldmFeatures {
    pub small_dependence_emphasis: f64,
    pub large_dependence_emphasis: f64,
    pub gray_level_non_uniformity: f64,
    pub dependence_non_uniformity: f64,
    pub dependence_non_uniformity_normalized: f64,
    pub gray_level_variance: f64,
    pub dependence_variance: f64,
    pub dependence_entropy: f64,
    pub low_gray_level_emphasis: f64,
    pub high_gray_level_emphasis: f64,
}

impl GldmFeatures {
    /// Ordered (name, value) view, mirroring the other feature classes.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("Gldm_SmallDependenceEmphasis", self.small_dependence_emphasis),
            ("Gldm_LargeDependenceEmphasis", self.large_dependence_emphasis),
            ("Gldm_GrayLevelNonUniformity", self.gray_level_non_uniformity),
            ("Gldm_DependenceNonUniformity", self.dependence_non_uniformity),
            (
                "Gldm_DependenceNonUniformityNormalized",
                self.dependence_non_uniformity_normalized,
            ),
            ("Gldm_GrayLevelVariance", self.gray_level_variance),
            ("Gldm_DependenceVariance", self.dependence_variance),
            ("Gldm_DependenceEntropy", self.dependence_entropy),
            ("Gldm_LowGrayLevelEmphasis", self.low_gray_level_emphasis),
            ("Gldm_HighGrayLevelEmphasis", self.high_gray_level_emphasis),
        ]
    }
}

/// Accumulate the dependence matrix of `roi` with threshold `alpha`.
///
/// Work is decomposed over flat voxel indices by [`fold_chunks`]; each
/// worker tallies its voxels' dependences into a per-thread partial
/// integer matrix, merged at the end — bit-for-bit identical for every
/// strategy / thread count.
pub fn accumulate_gldm(
    roi: &DiscretizedRoi,
    alpha: f64,
    strategy: Strategy,
    threads: usize,
) -> GldmMatrix {
    let ng = roi.ng;
    let dims = roi.levels.dims;
    let data = roi.levels.data();
    let plane = dims.x * dims.y;

    let fold = |counts: &mut Vec<u64>, range: Range<usize>| {
        for idx in range {
            let li = data[idx];
            if li == 0 {
                continue;
            }
            let x = (idx % dims.x) as isize;
            let y = ((idx / dims.x) % dims.y) as isize;
            let z = (idx / plane) as isize;
            let mut dep = 1usize;
            for &(dx, dy, dz) in &NEIGHBOURS_26 {
                let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                if qx < 0
                    || qy < 0
                    || qz < 0
                    || qx as usize >= dims.x
                    || qy as usize >= dims.y
                    || qz as usize >= dims.z
                {
                    continue;
                }
                let lj = data[qz as usize * plane + qy as usize * dims.x + qx as usize];
                if lj != 0 && (li as i64 - lj as i64).unsigned_abs() as f64 <= alpha {
                    dep += 1;
                }
            }
            counts[(li as usize - 1) * MAX_DEPENDENCE + (dep - 1)] += 1;
        }
    };

    let counts = fold_chunks(
        strategy,
        dims.len(),
        CHUNK,
        threads,
        || vec![0u64; ng * MAX_DEPENDENCE],
        fold,
        |acc: &mut Vec<u64>, part| {
            for (a, b) in acc.iter_mut().zip(part) {
                *a += b;
            }
        },
    );
    GldmMatrix { counts, ng, n_voxels: roi.n_voxels }
}

/// The 10 derived GLDM features, or `None` for an empty matrix (no ROI).
pub fn gldm_features(m: &GldmMatrix) -> Option<GldmFeatures> {
    let total: u64 = m.counts.iter().sum();
    if total == 0 {
        return None;
    }
    let nz = total as f64;

    let mut sde = 0.0;
    let mut lde = 0.0;
    let mut lgle = 0.0;
    let mut hgle = 0.0;
    let mut mu_i = 0.0;
    let mut mu_d = 0.0;
    let mut entropy = 0.0;
    let mut gln = 0.0;
    for i in 0..m.ng {
        let gi = (i + 1) as f64;
        let gi_sq = gi * gi;
        let mut row = 0.0f64;
        for d in 0..MAX_DEPENDENCE {
            let c = m.counts[i * MAX_DEPENDENCE + d];
            if c == 0 {
                continue;
            }
            let cf = c as f64;
            let dj = (d + 1) as f64;
            row += cf;
            sde += cf / (dj * dj);
            lde += cf * dj * dj;
            lgle += cf / gi_sq;
            hgle += cf * gi_sq;
            mu_i += cf * gi;
            mu_d += cf * dj;
            let p = cf / nz;
            entropy -= p * p.log2();
        }
        gln += row * row;
    }
    mu_i /= nz;
    mu_d /= nz;
    let mut glv = 0.0;
    let mut dv = 0.0;
    let mut dn = 0.0;
    for d in 0..MAX_DEPENDENCE {
        let dj = (d + 1) as f64;
        let mut col = 0.0f64;
        for i in 0..m.ng {
            let c = m.counts[i * MAX_DEPENDENCE + d];
            if c == 0 {
                continue;
            }
            let cf = c as f64;
            col += cf;
            let gi = (i + 1) as f64;
            glv += cf * (gi - mu_i) * (gi - mu_i);
            dv += cf * (dj - mu_d) * (dj - mu_d);
        }
        dn += col * col;
    }

    Some(GldmFeatures {
        small_dependence_emphasis: sde / nz,
        large_dependence_emphasis: lde / nz,
        gray_level_non_uniformity: gln / nz,
        dependence_non_uniformity: dn / nz,
        dependence_non_uniformity_normalized: dn / (nz * nz),
        gray_level_variance: glv / nz,
        dependence_variance: dv / nz,
        dependence_entropy: entropy,
        low_gray_level_emphasis: lgle / nz,
        high_gray_level_emphasis: hgle / nz,
    })
}

#[cfg(test)]
mod tests {
    use super::super::discretize::{discretize, Discretization};
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::{Dims, VoxelGrid};

    /// 2×2×2 checkerboard: every voxel has 3 equal-level neighbours out of
    /// 7, so every dependence is 4 at `alpha = 0` (and 8 at `alpha >= 1`).
    fn checkerboard() -> DiscretizedRoi {
        let dims = Dims::new(2, 2, 2);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..2 {
                    img.set(x, y, z, ((x + y + z) % 2) as f32);
                    mask.set(x, y, z, 1);
                }
            }
        }
        discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap()
    }

    #[test]
    fn checkerboard_matrix_matches_closed_form() {
        let m = accumulate_gldm(&checkerboard(), 0.0, Strategy::EqualSplit, 1);
        assert_eq!(m.counts[3], 4, "level 1, dependence 4");
        assert_eq!(m.counts[MAX_DEPENDENCE + 3], 4, "level 2, dependence 4");
        assert_eq!(m.counts.iter().sum::<u64>(), 8);
        let f = gldm_features(&m).unwrap();
        let close = |a: f64, b: f64| (a - b).abs() < 1e-12;
        assert!(close(f.small_dependence_emphasis, 1.0 / 16.0));
        assert!(close(f.large_dependence_emphasis, 16.0));
        assert!(close(f.gray_level_non_uniformity, 4.0));
        assert!(close(f.dependence_non_uniformity, 8.0));
        assert!(close(f.dependence_non_uniformity_normalized, 1.0));
        assert!(close(f.gray_level_variance, 0.25));
        assert!(close(f.dependence_variance, 0.0));
        assert!(close(f.dependence_entropy, 1.0));
        assert!(close(f.low_gray_level_emphasis, 0.625));
        assert!(close(f.high_gray_level_emphasis, 2.5));
    }

    #[test]
    fn alpha_widens_the_dependence() {
        // alpha = 1: the level-1/level-2 split no longer matters — every
        // voxel depends on all 7 neighbours (dependence 8)
        let m = accumulate_gldm(&checkerboard(), 1.0, Strategy::EqualSplit, 1);
        assert_eq!(m.counts[7], 4);
        assert_eq!(m.counts[MAX_DEPENDENCE + 7], 4);
        assert_eq!(m.counts.iter().sum::<u64>(), 8);
        let f = gldm_features(&m).unwrap();
        assert!((f.large_dependence_emphasis - 64.0).abs() < 1e-12);
        assert!((f.dependence_variance - 0.0).abs() < 1e-12);
    }

    #[test]
    fn dependences_sum_to_roi_voxel_count() {
        let dims = Dims::new(7, 6, 5);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(29);
        for z in 0..5 {
            for y in 0..6 {
                for x in 0..7 {
                    img.set(x, y, z, rng.below(4) as f32);
                    if rng.below(4) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        for alpha in [0.0, 1.0, 2.5] {
            let m = accumulate_gldm(&roi, alpha, Strategy::EqualSplit, 1);
            assert_eq!(m.counts.iter().sum::<u64>(), roi.n_voxels as u64, "alpha {alpha}");
        }
    }

    #[test]
    fn accumulation_is_deterministic_across_strategies_and_threads() {
        let dims = Dims::new(9, 8, 7);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(31);
        for z in 0..7 {
            for y in 0..8 {
                for x in 0..9 {
                    img.set(x, y, z, rng.below(5) as f32);
                    if rng.below(8) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let want = accumulate_gldm(&roi, 1.0, Strategy::EqualSplit, 1);
        for strategy in Strategy::ALL {
            for threads in [1usize, 2, 4] {
                let got = accumulate_gldm(&roi, 1.0, strategy, threads);
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }

    #[test]
    fn single_voxel_roi_has_dependence_one() {
        let dims = Dims::new(3, 3, 3);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        img.set(1, 1, 1, 5.0);
        mask.set(1, 1, 1, 1);
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let m = accumulate_gldm(&roi, 0.0, Strategy::EqualSplit, 1);
        assert_eq!(m.counts[0], 1, "dependence 1 (the voxel itself)");
        let f = gldm_features(&m).unwrap();
        assert_eq!(f.small_dependence_emphasis, 1.0);
        assert_eq!(f.large_dependence_emphasis, 1.0);
        assert_eq!(f.dependence_entropy, 0.0);
        assert!(f.named().iter().all(|(_, v)| v.is_finite()));
    }
}
