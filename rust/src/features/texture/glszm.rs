//! Gray Level Size Zone Matrix (3D, 26-connected) and its derived
//! features — PyRadiomics `radiomics.glszm` semantics: a *zone* is a
//! maximal 26-connected component of equal gray level inside the ROI;
//! `P(i, s)` counts zones of level `i` and size `s` voxels.
//!
//! Zone labelling is a flood fill. [`accumulate_glszm`] is the serial
//! fixed-order reference; [`accumulate_glszm_indexed`] buckets seed
//! indices per gray level in one scan and flood-fills whole levels on
//! worker threads (zones of different levels never touch, so the split
//! needs no cross-worker synchronisation). The zone partition of a
//! volume is a traversal-order-independent fact (connected components
//! are unique), so both produce the same matrix — all integer counts —
//! bit-for-bit for every `parallel::Strategy` × thread count.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::discretize::DiscretizedRoi;

/// The 26 neighbour offsets of the Chebyshev-distance-1 shell, in fixed
/// (z, y, x)-major order — shared by the zone growth here and the GLDM /
/// NGTDM neighbourhood walks.
pub const NEIGHBOURS_26: [(isize, isize, isize); 26] = [
    (-1, -1, -1),
    (0, -1, -1),
    (1, -1, -1),
    (-1, 0, -1),
    (0, 0, -1),
    (1, 0, -1),
    (-1, 1, -1),
    (0, 1, -1),
    (1, 1, -1),
    (-1, -1, 0),
    (0, -1, 0),
    (1, -1, 0),
    (-1, 0, 0),
    (1, 0, 0),
    (-1, 1, 0),
    (0, 1, 0),
    (1, 1, 0),
    (-1, -1, 1),
    (0, -1, 1),
    (1, -1, 1),
    (-1, 0, 1),
    (0, 0, 1),
    (1, 0, 1),
    (-1, 1, 1),
    (0, 1, 1),
    (1, 1, 1),
];

/// The size-zone count matrix in sparse form (zone sizes are unbounded —
/// up to the ROI voxel count — so a dense `ng × max_size` block could be
/// gigabytes on large ROIs).
#[derive(Debug, Clone, PartialEq)]
pub struct GlszmMatrix {
    /// `(level, size, count)` entries sorted by `(level, size)` — the
    /// fixed iteration order every derived feature sums in.
    pub entries: Vec<(u32, u32, u64)>,
    /// Number of gray levels (`Ng`).
    pub ng: usize,
    /// Total zone count (`Nz`, the normalising denominator).
    pub n_zones: u64,
    /// ROI voxel count (`Np`, the ZonePercentage denominator).
    pub n_voxels: usize,
    /// Largest zone size observed.
    pub max_zone_size: u32,
}

/// The derived GLSZM feature vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GlszmFeatures {
    pub small_area_emphasis: f64,
    pub large_area_emphasis: f64,
    pub gray_level_non_uniformity: f64,
    pub gray_level_non_uniformity_normalized: f64,
    pub size_zone_non_uniformity: f64,
    pub size_zone_non_uniformity_normalized: f64,
    pub zone_percentage: f64,
    pub gray_level_variance: f64,
    pub zone_variance: f64,
    pub zone_entropy: f64,
    pub low_gray_level_zone_emphasis: f64,
    pub high_gray_level_zone_emphasis: f64,
}

impl GlszmFeatures {
    /// Ordered (name, value) view, mirroring the other feature classes.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("Glszm_SmallAreaEmphasis", self.small_area_emphasis),
            ("Glszm_LargeAreaEmphasis", self.large_area_emphasis),
            ("Glszm_GrayLevelNonUniformity", self.gray_level_non_uniformity),
            (
                "Glszm_GrayLevelNonUniformityNormalized",
                self.gray_level_non_uniformity_normalized,
            ),
            ("Glszm_SizeZoneNonUniformity", self.size_zone_non_uniformity),
            (
                "Glszm_SizeZoneNonUniformityNormalized",
                self.size_zone_non_uniformity_normalized,
            ),
            ("Glszm_ZonePercentage", self.zone_percentage),
            ("Glszm_GrayLevelVariance", self.gray_level_variance),
            ("Glszm_ZoneVariance", self.zone_variance),
            ("Glszm_ZoneEntropy", self.zone_entropy),
            ("Glszm_LowGrayLevelZoneEmphasis", self.low_gray_level_zone_emphasis),
            ("Glszm_HighGrayLevelZoneEmphasis", self.high_gray_level_zone_emphasis),
        ]
    }
}

/// Label the 26-connected equal-level zones of `roi` and tally them into
/// the sparse size-zone matrix.
///
/// The flood fill visits seed voxels in flat scan order and grows each
/// zone with an explicit stack; since connected components are unique
/// whatever the traversal, the result is deterministic (and independent
/// of any strategy/thread configuration by construction). Serial — kept
/// as the conformance reference for [`accumulate_glszm_indexed`], which
/// the extraction pipeline uses.
pub fn accumulate_glszm(roi: &DiscretizedRoi) -> GlszmMatrix {
    let dims = roi.levels.dims;
    let data = roi.levels.data();
    let (nx, ny) = (dims.x, dims.y);
    let plane = nx * ny;
    let mut visited = vec![false; data.len()];
    let mut stack: Vec<usize> = Vec::new();
    let mut zones: BTreeMap<(u32, u32), u64> = BTreeMap::new();
    let mut max_zone_size = 0u32;

    for seed in 0..data.len() {
        let level = data[seed];
        if level == 0 || visited[seed] {
            continue;
        }
        visited[seed] = true;
        stack.push(seed);
        let mut size = 0u32;
        while let Some(idx) = stack.pop() {
            size += 1;
            let x = (idx % nx) as isize;
            let y = ((idx / nx) % ny) as isize;
            let z = (idx / plane) as isize;
            for &(dx, dy, dz) in &NEIGHBOURS_26 {
                let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                if qx < 0
                    || qy < 0
                    || qz < 0
                    || qx as usize >= dims.x
                    || qy as usize >= dims.y
                    || qz as usize >= dims.z
                {
                    continue;
                }
                let q = qz as usize * plane + qy as usize * nx + qx as usize;
                if !visited[q] && data[q] == level {
                    visited[q] = true;
                    stack.push(q);
                }
            }
        }
        max_zone_size = max_zone_size.max(size);
        *zones.entry((level, size)).or_insert(0) += 1;
    }

    let entries: Vec<(u32, u32, u64)> =
        zones.into_iter().map(|((i, s), c)| (i, s, c)).collect();
    let n_zones = entries.iter().map(|&(_, _, c)| c).sum();
    GlszmMatrix { entries, ng: roi.ng, n_zones, n_voxels: roi.n_voxels, max_zone_size }
}

/// Per-worker scratch for the level-parallel labelling: a stamped
/// visited map (reset in O(1) by switching stamp values between levels),
/// the flood-fill stack and this worker's partial tallies.
struct LevelScratch {
    stamp: Vec<u32>,
    stack: Vec<usize>,
    zones: BTreeMap<(u32, u32), u64>,
    max_zone_size: u32,
}

impl LevelScratch {
    fn new(n: usize) -> LevelScratch {
        LevelScratch {
            stamp: vec![0; n],
            stack: Vec::new(),
            zones: BTreeMap::new(),
            max_zone_size: 0,
        }
    }

    /// Flood-fill every zone of one gray `level` from its seed list.
    ///
    /// The level value doubles as the visited stamp: a scratch never sees
    /// the same level twice, so the previous level's marks become
    /// invisible without clearing the map.
    fn flood_level(&mut self, roi: &DiscretizedRoi, level: u32, seeds: &[usize]) {
        let dims = roi.levels.dims;
        let data = roi.levels.data();
        let (nx, ny) = (dims.x, dims.y);
        let plane = nx * ny;
        for &seed in seeds {
            if self.stamp[seed] == level {
                continue;
            }
            self.stamp[seed] = level;
            self.stack.push(seed);
            let mut size = 0u32;
            while let Some(idx) = self.stack.pop() {
                size += 1;
                let x = (idx % nx) as isize;
                let y = ((idx / nx) % ny) as isize;
                let z = (idx / plane) as isize;
                for &(dx, dy, dz) in &NEIGHBOURS_26 {
                    let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                    if qx < 0
                        || qy < 0
                        || qz < 0
                        || qx as usize >= dims.x
                        || qy as usize >= dims.y
                        || qz as usize >= dims.z
                    {
                        continue;
                    }
                    let q = qz as usize * plane + qy as usize * nx + qx as usize;
                    if self.stamp[q] != level && data[q] == level {
                        self.stamp[q] = level;
                        self.stack.push(q);
                    }
                }
            }
            self.max_zone_size = self.max_zone_size.max(size);
            *self.zones.entry((level, size)).or_insert(0) += 1;
        }
    }
}

/// Label the same zones as [`accumulate_glszm`], parallelised across
/// gray levels.
///
/// One serial O(N) scan buckets the flat index of every ROI voxel by its
/// gray level, preserving scan order; worker threads then pull whole
/// levels from an atomic queue and flood-fill them independently — zones
/// of different levels never touch, so workers share nothing but the
/// read-only volume. Per-worker tallies merge by key-sum into the same
/// sorted entries the serial fill emits; connected components are
/// unique, so the result is bit-for-bit identical to the reference for
/// every thread count (`0` = all cores) — locked by the conformance
/// suite.
pub fn accumulate_glszm_indexed(roi: &DiscretizedRoi, threads: usize) -> GlszmMatrix {
    let data = roi.levels.data();
    let ng = roi.ng;
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); ng];
    for (idx, &level) in data.iter().enumerate() {
        if level > 0 {
            buckets[level as usize - 1].push(idx);
        }
    }

    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    let workers = threads.min(ng.max(1));
    let (zones, max_zone_size) = if workers <= 1 {
        let mut scratch = LevelScratch::new(data.len());
        for (li, seeds) in buckets.iter().enumerate() {
            scratch.flood_level(roi, li as u32 + 1, seeds);
        }
        (scratch.zones, scratch.max_zone_size)
    } else {
        let next = AtomicUsize::new(0);
        let parts = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                handles.push(scope.spawn(|| {
                    let mut scratch = LevelScratch::new(data.len());
                    loop {
                        let li = next.fetch_add(1, Ordering::Relaxed);
                        if li >= ng {
                            break;
                        }
                        scratch.flood_level(roi, li as u32 + 1, &buckets[li]);
                    }
                    scratch
                }));
            }
            let mut parts = Vec::with_capacity(workers);
            for h in handles {
                parts.push(h.join().expect("glszm level worker"));
            }
            parts
        });
        let mut zones: BTreeMap<(u32, u32), u64> = BTreeMap::new();
        let mut max_zone_size = 0u32;
        for part in parts {
            max_zone_size = max_zone_size.max(part.max_zone_size);
            for (key, count) in part.zones {
                *zones.entry(key).or_insert(0) += count;
            }
        }
        (zones, max_zone_size)
    };

    let entries: Vec<(u32, u32, u64)> =
        zones.into_iter().map(|((i, s), c)| (i, s, c)).collect();
    let n_zones = entries.iter().map(|&(_, _, c)| c).sum();
    GlszmMatrix { entries, ng, n_zones, n_voxels: roi.n_voxels, max_zone_size }
}

/// The 12 derived GLSZM features, or `None` for an empty matrix (no ROI).
pub fn glszm_features(m: &GlszmMatrix) -> Option<GlszmFeatures> {
    if m.n_zones == 0 {
        return None;
    }
    let nz = m.n_zones as f64;

    let mut row = vec![0.0f64; m.ng];
    let mut col: BTreeMap<u32, f64> = BTreeMap::new();
    let mut sae = 0.0;
    let mut lae = 0.0;
    let mut lglze = 0.0;
    let mut hglze = 0.0;
    let mut mu_i = 0.0;
    let mut mu_s = 0.0;
    let mut entropy = 0.0;
    for &(i, s, c) in &m.entries {
        let cf = c as f64;
        let (gi, sz) = (i as f64, s as f64);
        row[i as usize - 1] += cf;
        *col.entry(s).or_insert(0.0) += cf;
        sae += cf / (sz * sz);
        lae += cf * sz * sz;
        lglze += cf / (gi * gi);
        hglze += cf * gi * gi;
        mu_i += cf * gi;
        mu_s += cf * sz;
        let p = cf / nz;
        entropy -= p * p.log2();
    }
    mu_i /= nz;
    mu_s /= nz;
    let mut glv = 0.0;
    let mut zv = 0.0;
    for &(i, s, c) in &m.entries {
        let cf = c as f64;
        glv += cf * (i as f64 - mu_i) * (i as f64 - mu_i);
        zv += cf * (s as f64 - mu_s) * (s as f64 - mu_s);
    }
    let gln: f64 = row.iter().map(|&r| r * r).sum();
    let szn: f64 = col.values().map(|&v| v * v).sum();

    Some(GlszmFeatures {
        small_area_emphasis: sae / nz,
        large_area_emphasis: lae / nz,
        gray_level_non_uniformity: gln / nz,
        gray_level_non_uniformity_normalized: gln / (nz * nz),
        size_zone_non_uniformity: szn / nz,
        size_zone_non_uniformity_normalized: szn / (nz * nz),
        zone_percentage: nz / m.n_voxels as f64,
        gray_level_variance: glv / nz,
        zone_variance: zv / nz,
        zone_entropy: entropy,
        low_gray_level_zone_emphasis: lglze / nz,
        high_gray_level_zone_emphasis: hglze / nz,
    })
}

#[cfg(test)]
mod tests {
    use super::super::discretize::{discretize, Discretization};
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::{Dims, VoxelGrid};

    /// 2×2×2 checkerboard `level = 1 + (x+y+z) mod 2`: under
    /// 26-connectivity the face diagonals connect equal levels, so each
    /// level forms ONE zone of size 4 (not four singletons).
    fn checkerboard() -> DiscretizedRoi {
        let dims = Dims::new(2, 2, 2);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..2 {
                    img.set(x, y, z, ((x + y + z) % 2) as f32);
                    mask.set(x, y, z, 1);
                }
            }
        }
        discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap()
    }

    #[test]
    fn checkerboard_zones_match_closed_form() {
        let m = accumulate_glszm(&checkerboard());
        assert_eq!(m.entries, vec![(1, 4, 1), (2, 4, 1)]);
        assert_eq!(m.n_zones, 2);
        assert_eq!(m.max_zone_size, 4);
        let f = glszm_features(&m).unwrap();
        let close = |a: f64, b: f64| (a - b).abs() < 1e-12;
        assert!(close(f.small_area_emphasis, 1.0 / 16.0));
        assert!(close(f.large_area_emphasis, 16.0));
        assert!(close(f.gray_level_non_uniformity, 1.0));
        assert!(close(f.gray_level_non_uniformity_normalized, 0.5));
        assert!(close(f.size_zone_non_uniformity, 2.0));
        assert!(close(f.size_zone_non_uniformity_normalized, 1.0));
        assert!(close(f.zone_percentage, 0.25));
        assert!(close(f.gray_level_variance, 0.25));
        assert!(close(f.zone_variance, 0.0));
        assert!(close(f.zone_entropy, 1.0));
        assert!(close(f.low_gray_level_zone_emphasis, 0.625));
        assert!(close(f.high_gray_level_zone_emphasis, 2.5));
    }

    #[test]
    fn alternating_line_is_all_singleton_zones() {
        // levels [1, 2, 1, 2]: no equal-level contact → 4 zones of size 1
        let dims = Dims::new(4, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..4 {
            img.set(x, 0, 0, (x % 2) as f32);
            mask.set(x, 0, 0, 1);
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let m = accumulate_glszm(&roi);
        assert_eq!(m.entries, vec![(1, 1, 2), (2, 1, 2)]);
        let f = glszm_features(&m).unwrap();
        assert_eq!(f.zone_percentage, 1.0);
        assert_eq!(f.small_area_emphasis, 1.0);
        assert_eq!(f.large_area_emphasis, 1.0);
    }

    #[test]
    fn constant_roi_is_one_zone() {
        let dims = Dims::new(6, 6, 6);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..6 {
            for y in 0..6 {
                for x in 0..6 {
                    img.set(x, y, z, 42.0);
                    mask.set(x, y, z, 1);
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(25.0)).unwrap().unwrap();
        let m = accumulate_glszm(&roi);
        assert_eq!(m.entries, vec![(1, 216, 1)]);
        let f = glszm_features(&m).unwrap();
        assert_eq!(f.zone_percentage, 1.0 / 216.0);
        assert_eq!(f.zone_entropy, 0.0);
        assert_eq!(f.gray_level_variance, 0.0);
        assert_eq!(f.zone_variance, 0.0);
    }

    #[test]
    fn zone_sizes_cover_every_roi_voxel() {
        let dims = Dims::new(7, 6, 5);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(17);
        for z in 0..5 {
            for y in 0..6 {
                for x in 0..7 {
                    img.set(x, y, z, rng.below(3) as f32);
                    if rng.below(4) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let m = accumulate_glszm(&roi);
        let covered: u64 = m.entries.iter().map(|&(_, s, c)| s as u64 * c).sum();
        assert_eq!(covered, roi.n_voxels as u64);
        assert!(m.max_zone_size as usize <= roi.n_voxels);
    }

    #[test]
    fn repeated_runs_are_identical() {
        let roi = checkerboard();
        let a = accumulate_glszm(&roi);
        for _ in 0..3 {
            assert_eq!(accumulate_glszm(&roi), a);
        }
    }

    #[test]
    fn indexed_labelling_matches_the_serial_reference() {
        // random levels and holes across shapes with singleton, spanning
        // and boundary-hugging zones; every thread count must reproduce
        // the serial matrix bit-for-bit
        let mut rng = crate::testkit::Pcg32::new(29);
        for (nx, ny, nz) in [(1, 1, 1), (4, 1, 1), (7, 6, 5), (12, 10, 3)] {
            let dims = Dims::new(nx, ny, nz);
            let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
            let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
            for z in 0..nz {
                for y in 0..ny {
                    for x in 0..nx {
                        img.set(x, y, z, rng.below(4) as f32);
                        if rng.below(5) > 0 {
                            mask.set(x, y, z, 1);
                        }
                    }
                }
            }
            let roi = match discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap() {
                Some(roi) => roi,
                None => continue,
            };
            let want = accumulate_glszm(&roi);
            for threads in [0usize, 1, 2, 4, 8] {
                let got = accumulate_glszm_indexed(&roi, threads);
                assert_eq!(got, want, "{dims:?} threads={threads}");
            }
        }
    }

    #[test]
    fn indexed_labelling_clamps_workers_to_the_level_count() {
        // checkerboard has 2 levels: 8 requested threads spawn only 2
        // workers, and the merge still reproduces the serial matrix
        let roi = checkerboard();
        assert_eq!(accumulate_glszm_indexed(&roi, 8), accumulate_glszm(&roi));
    }
}
