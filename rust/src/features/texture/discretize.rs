//! Gray-level discretization — the shared front half of every texture
//! matrix (PyRadiomics `imageoperations.binImage` semantics).

use anyhow::{bail, Result};

use crate::volume::VoxelGrid;

/// Upper bound on the discretized gray-level count: a GLCM is `Ng²` cells
/// per angle, so a runaway bin width would silently allocate gigabytes.
pub const MAX_GRAY_LEVELS: usize = 512;

/// How to map ROI intensities onto gray levels `1..=Ng`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Discretization {
    /// Fixed bin width: `level = floor(x/w) - floor(min/w) + 1`
    /// (PyRadiomics `binWidth`, default 25). Bin edges are aligned to
    /// multiples of `w`, so levels are comparable across cases.
    BinWidth(f64),
    /// Fixed bin count: `level = min(floor((x-min)/((max-min)/n)) + 1, n)`
    /// (PyRadiomics `binCount`). A constant ROI maps to the single level 1.
    BinCount(usize),
}

/// A discretized ROI: per-voxel gray levels with `0 = outside the mask`.
#[derive(Debug, Clone, PartialEq)]
pub struct DiscretizedRoi {
    /// Gray level per voxel; `0` outside the ROI, `1..=ng` inside.
    pub levels: VoxelGrid<u32>,
    /// Number of gray levels (`Ng`).
    pub ng: usize,
    /// ROI voxel count (`Np`).
    pub n_voxels: usize,
}

/// Discretize `image` over `mask != 0`.
///
/// Returns `Ok(None)` for an empty ROI; errors when the requested binning
/// would produce more than [`MAX_GRAY_LEVELS`] levels.
pub fn discretize(
    image: &VoxelGrid<f32>,
    mask: &VoxelGrid<u8>,
    disc: Discretization,
) -> Result<Option<DiscretizedRoi>> {
    assert_eq!(image.dims, mask.dims, "image/mask dims mismatch");

    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    let mut n_voxels = 0usize;
    for (x, y, z) in mask.iter_roi() {
        let v = image.get(x, y, z) as f64;
        // NaN slips through min/max folding, and ±inf would overflow the
        // level arithmetic below — reject both with a located error
        if !v.is_finite() {
            bail!("non-finite intensity {v} at voxel ({x}, {y}, {z}) inside the ROI");
        }
        min = min.min(v);
        max = max.max(v);
        n_voxels += 1;
    }
    if n_voxels == 0 {
        return Ok(None);
    }

    let mut levels: VoxelGrid<u32> = VoxelGrid::zeros(mask.dims, mask.spacing);
    let ng = match disc {
        Discretization::BinWidth(w) => {
            if w <= 0.0 || !w.is_finite() {
                bail!("bin_width must be a positive finite number, got {w}");
            }
            let base = (min / w).floor();
            let ng = ((max / w).floor() - base) as usize + 1;
            if ng > MAX_GRAY_LEVELS {
                bail!(
                    "bin_width {w} over intensity range [{min}, {max}] yields {ng} gray \
                     levels (max {MAX_GRAY_LEVELS}); raise bin_width or use bin_count"
                );
            }
            for (x, y, z) in mask.iter_roi() {
                let v = image.get(x, y, z) as f64;
                let lvl = ((v / w).floor() - base) as u32 + 1;
                levels.set(x, y, z, lvl.min(ng as u32));
            }
            ng
        }
        Discretization::BinCount(n) => {
            if n == 0 {
                bail!("bin_count must be >= 1");
            }
            if n > MAX_GRAY_LEVELS {
                bail!("bin_count {n} exceeds the maximum of {MAX_GRAY_LEVELS}");
            }
            let range = max - min;
            if range <= 0.0 {
                // constant ROI: every voxel is level 1
                for (x, y, z) in mask.iter_roi() {
                    levels.set(x, y, z, 1);
                }
                1
            } else {
                let width = range / n as f64;
                for (x, y, z) in mask.iter_roi() {
                    let v = image.get(x, y, z) as f64;
                    let lvl = (((v - min) / width).floor() as u32 + 1).min(n as u32);
                    levels.set(x, y, z, lvl);
                }
                n
            }
        }
    };
    Ok(Some(DiscretizedRoi { levels, ng, n_voxels }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn line_image(vals: &[f32]) -> (VoxelGrid<f32>, VoxelGrid<u8>) {
        let dims = Dims::new(vals.len(), 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for (x, &v) in vals.iter().enumerate() {
            img.set(x, 0, 0, v);
            mask.set(x, 0, 0, 1);
        }
        (img, mask)
    }

    #[test]
    fn bin_width_levels_are_edge_aligned() {
        // width 25: values 0..24 → level 1, 25..49 → level 2, 60 → level 3
        let (img, mask) = line_image(&[0.0, 10.0, 24.9, 25.0, 49.0, 60.0]);
        let r = discretize(&img, &mask, Discretization::BinWidth(25.0)).unwrap().unwrap();
        assert_eq!(r.ng, 3);
        assert_eq!(r.n_voxels, 6);
        let got: Vec<u32> = (0..6).map(|x| r.levels.get(x, 0, 0)).collect();
        assert_eq!(got, vec![1, 1, 1, 2, 2, 3]);
    }

    #[test]
    fn bin_width_negative_min_keeps_level_one_based() {
        // min −30 → base floor(−30/25) = −2; levels start at 1
        let (img, mask) = line_image(&[-30.0, -1.0, 0.0, 30.0]);
        let r = discretize(&img, &mask, Discretization::BinWidth(25.0)).unwrap().unwrap();
        assert_eq!(r.ng, 4); // bins [−50,−25), [−25,0), [0,25), [25,50)
        let got: Vec<u32> = (0..4).map(|x| r.levels.get(x, 0, 0)).collect();
        assert_eq!(got, vec![1, 2, 3, 4]);
    }

    #[test]
    fn bin_count_spans_min_to_max() {
        let (img, mask) = line_image(&[0.0, 1.0, 2.0, 3.0, 4.0]);
        let r = discretize(&img, &mask, Discretization::BinCount(2)).unwrap().unwrap();
        assert_eq!(r.ng, 2);
        let got: Vec<u32> = (0..5).map(|x| r.levels.get(x, 0, 0)).collect();
        // width 2: [0,2) → 1, [2,4] → 2 (max clamps into the last bin)
        assert_eq!(got, vec![1, 1, 2, 2, 2]);
    }

    #[test]
    fn constant_roi_is_single_level() {
        let (img, mask) = line_image(&[7.0, 7.0, 7.0]);
        let r = discretize(&img, &mask, Discretization::BinCount(16)).unwrap().unwrap();
        assert_eq!(r.ng, 1);
        assert!((0..3).all(|x| r.levels.get(x, 0, 0) == 1));
    }

    #[test]
    fn empty_roi_is_none() {
        let dims = Dims::new(3, 1, 1);
        let img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        assert!(discretize(&img, &mask, Discretization::BinWidth(25.0)).unwrap().is_none());
    }

    #[test]
    fn runaway_level_count_is_an_error() {
        let (img, mask) = line_image(&[0.0, 1e6]);
        let err = discretize(&img, &mask, Discretization::BinWidth(0.5)).unwrap_err();
        assert!(err.to_string().contains("gray levels"), "{err}");
        assert!(discretize(&img, &mask, Discretization::BinWidth(0.0)).is_err());
        assert!(discretize(&img, &mask, Discretization::BinCount(0)).is_err());
    }

    #[test]
    fn non_finite_roi_intensities_are_clear_errors() {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let (img, mask) = line_image(&[1.0, bad, 3.0]);
            let err = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap_err();
            assert!(err.to_string().contains("non-finite"), "{err}");
        }
        // non-finite voxels *outside* the mask are ignored
        let (img, mut mask) = line_image(&[1.0, f32::NAN, 3.0]);
        mask.set(1, 0, 0, 0);
        assert!(discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().is_some());
    }

    #[test]
    fn outside_mask_is_level_zero() {
        let (img, mut mask) = line_image(&[1.0, 2.0, 3.0]);
        mask.set(1, 0, 0, 0);
        let r = discretize(&img, &mask, Discretization::BinCount(2)).unwrap().unwrap();
        assert_eq!(r.levels.get(1, 0, 0), 0);
        assert_eq!(r.n_voxels, 2);
    }
}
