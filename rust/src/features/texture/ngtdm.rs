//! Neighbouring Gray Tone Difference Matrix (3D, 26-neighbourhood) and
//! its five derived features (coarseness, contrast, busyness, complexity,
//! strength) — PyRadiomics `radiomics.ngtdm` semantics: for every ROI
//! voxel with at least one 26-neighbour inside the ROI, `s_i` accumulates
//! `|i − mean(neighbour levels)|` and `n_i` counts the voxel; voxels with
//! no valid neighbour are excluded entirely.
//!
//! Determinism: the per-voxel term `|i − sum/c|` is the rational
//! `|i·c − sum| / c` with an integer numerator, so the accumulation stores
//! **integer** numerators grouped by `(level, neighbour count)` — mergeable
//! in any order without rounding — and only converts to `f64` in a fixed
//! `(level, count)` order when the features are derived. Results are
//! bit-for-bit identical for every strategy / thread count.

use std::ops::Range;

use super::discretize::DiscretizedRoi;
use super::glszm::NEIGHBOURS_26;
use crate::parallel::{fold_chunks, Strategy};

/// Voxels per work unit for the parallel accumulation.
const CHUNK: usize = 512;

/// Highest possible valid-neighbour count (the full 26-shell).
const MAX_NEIGHBOURS: usize = 26;

/// The NGTDM ingredients in exact integer form.
#[derive(Debug, Clone, PartialEq)]
pub struct NgtdmMatrix {
    /// `numer[(i-1) * 26 + (c-1)]` = Σ `|i·c − Σ neighbour levels|` over
    /// ROI voxels of level `i` with exactly `c` valid neighbours.
    pub numer: Vec<u64>,
    /// `counts[i-1]` = `n_i`, the voxels of level `i` with ≥ 1 valid
    /// neighbour.
    pub counts: Vec<u64>,
    /// Number of gray levels (`Ng`).
    pub ng: usize,
    /// ROI voxel count (`Np`; `Σ counts` ≤ `Np` — isolated voxels drop).
    pub n_voxels: usize,
}

impl NgtdmMatrix {
    /// The gray-tone difference sums `s_i`, derived from the integer
    /// numerators in fixed `(level, count)` order — deterministic.
    pub fn s(&self) -> Vec<f64> {
        (0..self.ng)
            .map(|i| {
                (0..MAX_NEIGHBOURS)
                    .map(|c| self.numer[i * MAX_NEIGHBOURS + c] as f64 / (c + 1) as f64)
                    .sum()
            })
            .collect()
    }

    /// Total voxels with a valid neighbourhood (`Nvp`).
    pub fn n_valid(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// The derived NGTDM feature vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NgtdmFeatures {
    pub coarseness: f64,
    pub contrast: f64,
    pub busyness: f64,
    pub complexity: f64,
    pub strength: f64,
}

impl NgtdmFeatures {
    /// Ordered (name, value) view, mirroring the other feature classes.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("Ngtdm_Coarseness", self.coarseness),
            ("Ngtdm_Contrast", self.contrast),
            ("Ngtdm_Busyness", self.busyness),
            ("Ngtdm_Complexity", self.complexity),
            ("Ngtdm_Strength", self.strength),
        ]
    }
}

/// Accumulate the NGTDM ingredients of `roi`.
///
/// Work is decomposed over flat voxel indices by [`fold_chunks`]; every
/// per-thread partial is a pair of integer vectors merged by addition, so
/// the result is bit-for-bit identical for every strategy / thread count.
pub fn accumulate_ngtdm(
    roi: &DiscretizedRoi,
    strategy: Strategy,
    threads: usize,
) -> NgtdmMatrix {
    let ng = roi.ng;
    let dims = roi.levels.dims;
    let data = roi.levels.data();
    let plane = dims.x * dims.y;

    type Acc = (Vec<u64>, Vec<u64>); // (numer, counts)
    let fold = |acc: &mut Acc, range: Range<usize>| {
        for idx in range {
            let li = data[idx] as u64;
            if li == 0 {
                continue;
            }
            let x = (idx % dims.x) as isize;
            let y = ((idx / dims.x) % dims.y) as isize;
            let z = (idx / plane) as isize;
            let mut sum = 0u64;
            let mut count = 0u64;
            for &(dx, dy, dz) in &NEIGHBOURS_26 {
                let (qx, qy, qz) = (x + dx, y + dy, z + dz);
                if qx < 0
                    || qy < 0
                    || qz < 0
                    || qx as usize >= dims.x
                    || qy as usize >= dims.y
                    || qz as usize >= dims.z
                {
                    continue;
                }
                let lj = data[qz as usize * plane + qy as usize * dims.x + qx as usize];
                if lj != 0 {
                    sum += lj as u64;
                    count += 1;
                }
            }
            if count == 0 {
                continue; // isolated voxel: excluded from the matrix
            }
            let numer = (li * count).abs_diff(sum);
            acc.0[(li as usize - 1) * MAX_NEIGHBOURS + (count as usize - 1)] += numer;
            acc.1[li as usize - 1] += 1;
        }
    };

    let (numer, counts) = fold_chunks(
        strategy,
        dims.len(),
        CHUNK,
        threads,
        || (vec![0u64; ng * MAX_NEIGHBOURS], vec![0u64; ng]),
        fold,
        |acc: &mut Acc, part| {
            for (a, b) in acc.0.iter_mut().zip(part.0) {
                *a += b;
            }
            for (a, b) in acc.1.iter_mut().zip(part.1) {
                *a += b;
            }
        },
    );
    NgtdmMatrix { numer, counts, ng, n_voxels: roi.n_voxels }
}

/// The 5 derived NGTDM features, or `None` when no ROI voxel has a valid
/// neighbourhood (single-voxel or fully scattered ROIs).
///
/// Edge cases follow PyRadiomics: a flat neighbourhood sum (`Σ pᵢsᵢ = 0`,
/// e.g. a constant ROI) caps coarseness at `1e6`; contrast is `0` with a
/// single present gray level; busyness and strength are `0` when their
/// denominators vanish.
pub fn ngtdm_features(m: &NgtdmMatrix) -> Option<NgtdmFeatures> {
    let nvp = m.n_valid();
    if nvp == 0 {
        return None;
    }
    let nvp = nvp as f64;
    let s = m.s();
    let p: Vec<f64> = m.counts.iter().map(|&n| n as f64 / nvp).collect();
    let present: Vec<usize> = (0..m.ng).filter(|&i| m.counts[i] > 0).collect();
    let ngp = present.len();

    let ps: f64 = present.iter().map(|&i| p[i] * s[i]).sum();
    let s_total: f64 = s.iter().sum();

    let coarseness = if ps > 0.0 { 1.0 / ps } else { 1e6 };

    let contrast = if ngp > 1 {
        let mut pair = 0.0;
        for &i in &present {
            for &j in &present {
                let diff = i as f64 - j as f64;
                pair += p[i] * p[j] * diff * diff;
            }
        }
        pair / (ngp * (ngp - 1)) as f64 * s_total / nvp
    } else {
        0.0
    };

    let mut busy_denom = 0.0;
    let mut complexity = 0.0;
    let mut strength_num = 0.0;
    for &i in &present {
        for &j in &present {
            let gi = (i + 1) as f64;
            let gj = (j + 1) as f64;
            busy_denom += (gi * p[i] - gj * p[j]).abs();
            complexity += (gi - gj).abs() * (p[i] * s[i] + p[j] * s[j]) / (p[i] + p[j]);
            strength_num += (p[i] + p[j]) * (gi - gj) * (gi - gj);
        }
    }
    let busyness = if busy_denom > 0.0 { ps / busy_denom } else { 0.0 };
    let complexity = complexity / nvp;
    let strength = if s_total > 0.0 { strength_num / s_total } else { 0.0 };

    Some(NgtdmFeatures { coarseness, contrast, busyness, complexity, strength })
}

#[cfg(test)]
mod tests {
    use super::super::discretize::{discretize, Discretization};
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::{Dims, VoxelGrid};

    fn checkerboard() -> DiscretizedRoi {
        let dims = Dims::new(2, 2, 2);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..2 {
                    img.set(x, y, z, ((x + y + z) % 2) as f32);
                    mask.set(x, y, z, 1);
                }
            }
        }
        discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap()
    }

    #[test]
    fn checkerboard_matches_closed_form() {
        // level 1 voxels: 7 neighbours, mean (3·1 + 4·2)/7 → |1 − 11/7| =
        // 4/7 each; s₁ = s₂ = 16/7, n₁ = n₂ = 4 (hand-computed; see the
        // conformance suite for the oracle-locked variants)
        let m = accumulate_ngtdm(&checkerboard(), Strategy::EqualSplit, 1);
        assert_eq!(m.counts, vec![4, 4]);
        let s = m.s();
        let close = |a: f64, b: f64| (a - b).abs() < 1e-12;
        assert!(close(s[0], 16.0 / 7.0), "{}", s[0]);
        assert!(close(s[1], 16.0 / 7.0), "{}", s[1]);
        let f = ngtdm_features(&m).unwrap();
        assert!(close(f.coarseness, 7.0 / 16.0));
        assert!(close(f.contrast, 1.0 / 7.0));
        assert!(close(f.busyness, 16.0 / 7.0));
        assert!(close(f.complexity, 4.0 / 7.0));
        assert!(close(f.strength, 7.0 / 16.0));
    }

    #[test]
    fn constant_roi_hits_the_coarseness_cap() {
        let dims = Dims::new(6, 6, 6);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..6 {
            for y in 0..6 {
                for x in 0..6 {
                    img.set(x, y, z, 42.0);
                    mask.set(x, y, z, 1);
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(25.0)).unwrap().unwrap();
        let m = accumulate_ngtdm(&roi, Strategy::EqualSplit, 1);
        assert_eq!(m.n_valid(), 216);
        let f = ngtdm_features(&m).unwrap();
        assert_eq!(f.coarseness, 1e6, "flat ROI caps at PyRadiomics' 1e6");
        assert_eq!(f.contrast, 0.0);
        assert_eq!(f.busyness, 0.0);
        assert_eq!(f.complexity, 0.0);
        assert_eq!(f.strength, 0.0);
    }

    #[test]
    fn isolated_voxels_are_excluded() {
        // two ROI voxels at opposite corners of a 5³ grid: no voxel has a
        // valid neighbour → the matrix is empty and features are None
        let dims = Dims::new(5, 5, 5);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        img.set(0, 0, 0, 1.0);
        img.set(4, 4, 4, 2.0);
        mask.set(0, 0, 0, 1);
        mask.set(4, 4, 4, 1);
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let m = accumulate_ngtdm(&roi, Strategy::EqualSplit, 1);
        assert_eq!(m.n_valid(), 0);
        assert!(ngtdm_features(&m).is_none());
    }

    #[test]
    fn accumulation_is_deterministic_across_strategies_and_threads() {
        let dims = Dims::new(9, 8, 7);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(37);
        for z in 0..7 {
            for y in 0..8 {
                for x in 0..9 {
                    img.set(x, y, z, rng.below(6) as f32);
                    if rng.below(8) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let want = accumulate_ngtdm(&roi, Strategy::EqualSplit, 1);
        let want_f = ngtdm_features(&want).unwrap();
        for strategy in Strategy::ALL {
            for threads in [1usize, 2, 4] {
                let got = accumulate_ngtdm(&roi, strategy, threads);
                assert_eq!(got, want, "{strategy:?} threads={threads}");
                assert_eq!(
                    ngtdm_features(&got).unwrap(),
                    want_f,
                    "{strategy:?} threads={threads}"
                );
            }
        }
    }
}
