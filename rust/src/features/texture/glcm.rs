//! Gray Level Co-occurrence Matrix (3D, 13 angles, symmetric) and its
//! derived features — PyRadiomics `radiomics.glcm` semantics: one matrix
//! per (distance, angle), features computed per matrix, then averaged over
//! all non-empty matrices.

use std::ops::Range;

use super::discretize::DiscretizedRoi;
use crate::parallel::{fold_chunks, Strategy};

/// The 13 unique 3D directions (half of the 26-neighbourhood; the other
/// half is covered by matrix symmetry).
pub const ANGLES_13: [(isize, isize, isize); 13] = [
    (1, 0, 0),
    (0, 1, 0),
    (0, 0, 1),
    (1, 1, 0),
    (1, -1, 0),
    (1, 0, 1),
    (1, 0, -1),
    (0, 1, 1),
    (0, 1, -1),
    (1, 1, 1),
    (1, 1, -1),
    (1, -1, 1),
    (1, -1, -1),
];

/// Voxels per work unit for the parallel accumulation. Small enough that
/// even modest cropped ROIs split across threads (each unit still does
/// `13 × distances` neighbour probes per voxel).
const CHUNK: usize = 512;

/// Co-occurrence count matrices: one `ng × ng` block per (distance, angle).
#[derive(Debug, Clone, PartialEq)]
pub struct GlcmMatrices {
    /// `counts[m * ng * ng + (i-1) * ng + (j-1)]` for matrix `m`.
    pub counts: Vec<u64>,
    pub ng: usize,
    /// Number of matrices (`13 × distances.len()`).
    pub n_matrices: usize,
}

impl GlcmMatrices {
    /// Counts of one matrix as an `ng × ng` row-major slice.
    pub fn matrix(&self, m: usize) -> &[u64] {
        let s = self.ng * self.ng;
        &self.counts[m * s..(m + 1) * s]
    }
}

/// The derived GLCM feature vector (mean over non-empty matrices).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GlcmFeatures {
    pub autocorrelation: f64,
    pub contrast: f64,
    pub correlation: f64,
    pub joint_energy: f64,
    pub joint_entropy: f64,
    pub idm: f64,
    pub idn: f64,
    pub cluster_shade: f64,
    pub cluster_prominence: f64,
}

impl GlcmFeatures {
    /// Ordered (name, value) view, mirroring the other feature classes.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("Glcm_Autocorrelation", self.autocorrelation),
            ("Glcm_Contrast", self.contrast),
            ("Glcm_Correlation", self.correlation),
            ("Glcm_JointEnergy", self.joint_energy),
            ("Glcm_JointEntropy", self.joint_entropy),
            ("Glcm_Idm", self.idm),
            ("Glcm_Idn", self.idn),
            ("Glcm_ClusterShade", self.cluster_shade),
            ("Glcm_ClusterProminence", self.cluster_prominence),
        ]
    }
}

/// One precomputed `(distance, angle)` probe: the neighbour as a flat
/// index offset, its per-axis step (used only for boundary voxels) and
/// the base of the matrix block it feeds.
struct Probe {
    off: isize,
    dx: isize,
    dy: isize,
    dz: isize,
    base: usize,
}

/// Accumulate the symmetric GLCMs of `roi` for every `(distance, angle)`.
///
/// Each ordered voxel pair `(v, v + d·angle)` with both endpoints inside
/// the ROI increments `(level(v), level(v+δ))` **and** its transpose —
/// the symmetric matrix, built in one forward pass. All `13 × distances`
/// probes are precomputed as flat-index offsets and resolved in a single
/// walk over the volume; voxels at least the maximum distance away from
/// every face take an interior fast path with no per-probe bounds checks.
/// The increment set is identical to [`accumulate_glcm_reference`] and
/// counts are integers, so the result is bit-for-bit identical to the
/// reference for every strategy / thread count.
pub fn accumulate_glcm(
    roi: &DiscretizedRoi,
    distances: &[usize],
    strategy: Strategy,
    threads: usize,
) -> GlcmMatrices {
    let ng = roi.ng;
    let dims = roi.levels.dims;
    let n_matrices = distances.len() * ANGLES_13.len();
    let msize = ng * ng;
    let data = roi.levels.data();
    let (sx, sy, sz) = (dims.x as isize, dims.y as isize, dims.z as isize);

    let mut probes = Vec::with_capacity(n_matrices);
    let mut reach = 0isize;
    for (di, &d) in distances.iter().enumerate() {
        let d = d as isize;
        reach = reach.max(d);
        for (ai, &(ax, ay, az)) in ANGLES_13.iter().enumerate() {
            probes.push(Probe {
                off: ax * d + ay * d * sx + az * d * sx * sy,
                dx: ax * d,
                dy: ay * d,
                dz: az * d,
                base: (di * ANGLES_13.len() + ai) * msize,
            });
        }
    }

    let fold = |counts: &mut Vec<u64>, range: Range<usize>| {
        for idx in range {
            let li = data[idx] as usize;
            if li == 0 {
                continue;
            }
            let x = (idx % dims.x) as isize;
            let y = ((idx / dims.x) % dims.y) as isize;
            let z = (idx / (dims.x * dims.y)) as isize;
            let row = (li - 1) * ng;
            let interior = x >= reach
                && x < sx - reach
                && y >= reach
                && y < sy - reach
                && z >= reach
                && z < sz - reach;
            if interior {
                for p in &probes {
                    let lj = data[(idx as isize + p.off) as usize] as usize;
                    if lj == 0 {
                        continue;
                    }
                    counts[p.base + row + (lj - 1)] += 1;
                    counts[p.base + (lj - 1) * ng + (li - 1)] += 1;
                }
            } else {
                for p in &probes {
                    let (qx, qy, qz) = (x + p.dx, y + p.dy, z + p.dz);
                    if qx < 0 || qy < 0 || qz < 0 || qx >= sx || qy >= sy || qz >= sz {
                        continue;
                    }
                    let lj = data[(idx as isize + p.off) as usize] as usize;
                    if lj == 0 {
                        continue;
                    }
                    counts[p.base + row + (lj - 1)] += 1;
                    counts[p.base + (lj - 1) * ng + (li - 1)] += 1;
                }
            }
        }
    };

    let counts = fold_chunks(
        strategy,
        dims.len(),
        CHUNK,
        threads,
        || vec![0u64; n_matrices * msize],
        fold,
        |acc: &mut Vec<u64>, part| {
            for (a, b) in acc.iter_mut().zip(part) {
                *a += b;
            }
        },
    );
    GlcmMatrices { counts, ng, n_matrices }
}

/// The straightforward bounds-checked accumulation — kept as the
/// conformance reference for [`accumulate_glcm`] and as the slow leg of
/// the `bench_texture` speedup section.
pub fn accumulate_glcm_reference(
    roi: &DiscretizedRoi,
    distances: &[usize],
    strategy: Strategy,
    threads: usize,
) -> GlcmMatrices {
    let ng = roi.ng;
    let dims = roi.levels.dims;
    let n_matrices = distances.len() * ANGLES_13.len();
    let msize = ng * ng;
    let data = roi.levels.data();

    let fold = |counts: &mut Vec<u64>, range: Range<usize>| {
        for idx in range {
            let li = data[idx] as usize;
            if li == 0 {
                continue;
            }
            let x = (idx % dims.x) as isize;
            let y = ((idx / dims.x) % dims.y) as isize;
            let z = (idx / (dims.x * dims.y)) as isize;
            for (di, &d) in distances.iter().enumerate() {
                let d = d as isize;
                for (ai, &(dx, dy, dz)) in ANGLES_13.iter().enumerate() {
                    let (nx, ny, nz) = (x + dx * d, y + dy * d, z + dz * d);
                    if nx < 0
                        || ny < 0
                        || nz < 0
                        || nx as usize >= dims.x
                        || ny as usize >= dims.y
                        || nz as usize >= dims.z
                    {
                        continue;
                    }
                    let lj = roi.levels.get(nx as usize, ny as usize, nz as usize) as usize;
                    if lj == 0 {
                        continue;
                    }
                    let m = di * ANGLES_13.len() + ai;
                    counts[m * msize + (li - 1) * ng + (lj - 1)] += 1;
                    counts[m * msize + (lj - 1) * ng + (li - 1)] += 1;
                }
            }
        }
    };

    let counts = fold_chunks(
        strategy,
        dims.len(),
        CHUNK,
        threads,
        || vec![0u64; n_matrices * msize],
        fold,
        |acc: &mut Vec<u64>, part| {
            for (a, b) in acc.iter_mut().zip(part) {
                *a += b;
            }
        },
    );
    GlcmMatrices { counts, ng, n_matrices }
}

/// Per-matrix feature ingredients, averaged over non-empty matrices.
///
/// Returns `None` when every matrix is empty (e.g. a single-voxel ROI has
/// no co-occurring pairs).
pub fn glcm_features(mats: &GlcmMatrices) -> Option<GlcmFeatures> {
    let ng = mats.ng;
    let mut sums = [0.0f64; 9];
    let mut n_valid = 0usize;

    for m in 0..mats.n_matrices {
        let counts = mats.matrix(m);
        let total: u64 = counts.iter().sum();
        if total == 0 {
            continue;
        }
        n_valid += 1;
        let total = total as f64;

        // marginals (symmetric matrix → px == py, σx == σy)
        let px: Vec<f64> = (0..ng)
            .map(|i| (0..ng).map(|j| counts[i * ng + j] as f64 / total).sum())
            .collect();
        let mut mu = 0.0;
        for (i, &pxi) in px.iter().enumerate() {
            mu += (i + 1) as f64 * pxi;
        }
        let mut sigma_sq = 0.0;
        for (i, &pxi) in px.iter().enumerate() {
            sigma_sq += ((i + 1) as f64 - mu) * ((i + 1) as f64 - mu) * pxi;
        }

        let mut autocorr = 0.0;
        let mut contrast = 0.0;
        let mut energy = 0.0;
        let mut entropy = 0.0;
        let mut idm = 0.0;
        let mut idn = 0.0;
        let mut shade = 0.0;
        let mut prominence = 0.0;
        for i in 0..ng {
            let gi = (i + 1) as f64;
            for j in 0..ng {
                let c = counts[i * ng + j];
                if c == 0 {
                    continue;
                }
                let p = c as f64 / total;
                let gj = (j + 1) as f64;
                let diff = gi - gj;
                let dev = gi + gj - 2.0 * mu;
                autocorr += gi * gj * p;
                contrast += diff * diff * p;
                energy += p * p;
                entropy -= p * p.log2();
                idm += p / (1.0 + diff * diff);
                idn += p / (1.0 + diff.abs() / ng as f64);
                shade += dev * dev * dev * p;
                prominence += dev * dev * dev * dev * p;
            }
        }
        // PyRadiomics: correlation of a fully homogeneous matrix is 1
        let correlation = if sigma_sq > 1e-12 { (autocorr - mu * mu) / sigma_sq } else { 1.0 };

        for (s, v) in sums.iter_mut().zip([
            autocorr, contrast, correlation, energy, entropy, idm, idn, shade, prominence,
        ]) {
            *s += v;
        }
    }

    if n_valid == 0 {
        return None;
    }
    let n = n_valid as f64;
    Some(GlcmFeatures {
        autocorrelation: sums[0] / n,
        contrast: sums[1] / n,
        correlation: sums[2] / n,
        joint_energy: sums[3] / n,
        joint_entropy: sums[4] / n,
        idm: sums[5] / n,
        idn: sums[6] / n,
        cluster_shade: sums[7] / n,
        cluster_prominence: sums[8] / n,
    })
}

#[cfg(test)]
mod tests {
    use super::super::discretize::{discretize, Discretization};
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::{Dims, VoxelGrid};

    /// 2×2×2 checkerboard: level = 1 + (x+y+z) mod 2 — the closed-form
    /// GLCM fixture from the module docs. Angle classification: the 7
    /// odd-parity directions (3 axis + 4 body diagonals) pair distinct
    /// levels (p12 = p21 = ½); the 6 even-parity face diagonals pair equal
    /// levels (p11 = p22 = ½).
    fn checkerboard() -> DiscretizedRoi {
        let dims = Dims::new(2, 2, 2);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..2 {
            for y in 0..2 {
                for x in 0..2 {
                    img.set(x, y, z, ((x + y + z) % 2) as f32);
                    mask.set(x, y, z, 1);
                }
            }
        }
        discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap()
    }

    #[test]
    fn checkerboard_matrices_match_closed_form() {
        let roi = checkerboard();
        assert_eq!(roi.ng, 2);
        let mats = accumulate_glcm(&roi, &[1], Strategy::EqualSplit, 1);
        assert_eq!(mats.n_matrices, 13);
        for (a, &(dx, dy, dz)) in ANGLES_13.iter().enumerate() {
            let m = mats.matrix(a);
            let parity = (dx + dy + dz).rem_euclid(2);
            // pair count per angle: axis 4, face diagonal 2, body diagonal 1
            let pairs = match dx.abs() + dy.abs() + dz.abs() {
                1 => 4,
                2 => 2,
                _ => 1,
            } as u64;
            if parity == 1 {
                // distinct levels: symmetric off-diagonal counts only
                assert_eq!(m, &[0, pairs, pairs, 0][..], "angle {a}");
            } else {
                // equal levels: one pair each of (1,1) and (2,2), doubled
                assert_eq!(m, &[pairs, 0, 0, pairs][..], "angle {a}");
            }
        }
    }

    #[test]
    fn checkerboard_features_match_closed_form() {
        // 7 odd-parity angles: contrast 1, corr −1, Idm ½, Idn ⅔, CP 0
        // 6 even-parity angles: contrast 0, corr +1, Idm 1, Idn 1, CP 1
        let roi = checkerboard();
        let mats = accumulate_glcm(&roi, &[1], Strategy::EqualSplit, 1);
        let f = glcm_features(&mats).unwrap();
        let close = |a: f64, b: f64| (a - b).abs() < 1e-9;
        assert!(close(f.autocorrelation, 29.0 / 13.0), "{}", f.autocorrelation);
        assert!(close(f.contrast, 7.0 / 13.0), "{}", f.contrast);
        assert!(close(f.correlation, -1.0 / 13.0), "{}", f.correlation);
        assert!(close(f.joint_energy, 0.5), "{}", f.joint_energy);
        assert!(close(f.joint_entropy, 1.0), "{}", f.joint_entropy);
        assert!(close(f.idm, 9.5 / 13.0), "{}", f.idm);
        assert!(close(f.idn, 32.0 / 39.0), "{}", f.idn);
        assert!(close(f.cluster_shade, 0.0), "{}", f.cluster_shade);
        assert!(close(f.cluster_prominence, 6.0 / 13.0), "{}", f.cluster_prominence);
    }

    #[test]
    fn accumulation_is_deterministic_across_strategies_and_threads() {
        // pseudo-random levels over a 12×10×8 grid (960 voxels — above the
        // chunk size, so multi-thread runs really take the parallel path)
        // with holes in the mask
        let dims = Dims::new(12, 10, 8);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut rng = crate::testkit::Pcg32::new(11);
        for z in 0..8 {
            for y in 0..10 {
                for x in 0..12 {
                    img.set(x, y, z, rng.below(6) as f32);
                    if rng.below(10) > 0 {
                        mask.set(x, y, z, 1);
                    }
                }
            }
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let want = accumulate_glcm(&roi, &[1, 2], Strategy::EqualSplit, 1);
        for strategy in Strategy::ALL {
            for threads in [1usize, 2, 4] {
                let got = accumulate_glcm(&roi, &[1, 2], strategy, threads);
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }

    #[test]
    fn matrices_are_symmetric_with_equal_totals_per_angle() {
        let roi = checkerboard();
        let mats = accumulate_glcm(&roi, &[1], Strategy::LocalAccumulators, 2);
        for m in 0..mats.n_matrices {
            let c = mats.matrix(m);
            for i in 0..roi.ng {
                for j in 0..roi.ng {
                    assert_eq!(c[i * roi.ng + j], c[j * roi.ng + i]);
                }
            }
        }
    }

    #[test]
    fn single_voxel_roi_has_no_glcm() {
        let dims = Dims::new(3, 3, 3);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        img.set(1, 1, 1, 5.0);
        mask.set(1, 1, 1, 1);
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let mats = accumulate_glcm(&roi, &[1], Strategy::EqualSplit, 1);
        assert!(mats.counts.iter().all(|&c| c == 0));
        assert!(glcm_features(&mats).is_none());
    }

    #[test]
    fn distance_two_skips_adjacent_voxels() {
        // line of 3 voxels, levels 1,2,3: distance 2 pairs only (1,3)
        let dims = Dims::new(3, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..3 {
            img.set(x, 0, 0, x as f32);
            mask.set(x, 0, 0, 1);
        }
        let roi = discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap().unwrap();
        let mats = accumulate_glcm(&roi, &[2], Strategy::EqualSplit, 1);
        let m0 = mats.matrix(0); // angle (1,0,0); row-major (i-1)*ng+(j-1)
        assert_eq!(m0[2], 1); // (1,3)
        assert_eq!(m0[6], 1); // (3,1)
        assert_eq!(m0.iter().sum::<u64>(), 2);
    }

    #[test]
    fn single_pass_matches_the_reference_everywhere() {
        // random holes over deliberately lopsided dims so boundary voxels
        // dominate, plus a distance exceeding the shortest axis — every
        // bounds-check edge the interior fast path must not change
        let mut rng = crate::testkit::Pcg32::new(23);
        for (nx, ny, nz) in [(1, 1, 1), (5, 3, 2), (9, 4, 7), (16, 16, 3)] {
            let dims = Dims::new(nx, ny, nz);
            let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
            let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
            for z in 0..nz {
                for y in 0..ny {
                    for x in 0..nx {
                        img.set(x, y, z, rng.below(5) as f32);
                        if rng.below(5) > 0 {
                            mask.set(x, y, z, 1);
                        }
                    }
                }
            }
            let roi = match discretize(&img, &mask, Discretization::BinWidth(1.0)).unwrap() {
                Some(roi) => roi,
                None => continue,
            };
            for distances in [&[1usize][..], &[1, 2][..], &[3][..]] {
                let want = accumulate_glcm_reference(&roi, distances, Strategy::EqualSplit, 1);
                for strategy in Strategy::ALL {
                    for threads in [1usize, 2, 4, 8] {
                        let got = accumulate_glcm(&roi, distances, strategy, threads);
                        assert_eq!(got, want, "{dims:?} {distances:?} {strategy:?} t={threads}");
                    }
                }
            }
        }
    }
}
