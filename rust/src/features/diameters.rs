//! Maximum 3D + planar diameters — the single-threaded reference
//! implementation (the faithful "PyRadiomics CPU" baseline of every
//! benchmark; the optimised variants live in [`crate::parallel`]).

use crate::geometry::Vec3;

/// Squared maximum diameters, `-1.0` when a family has no valid pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Diameters {
    /// Maximum3DDiameter², any vertex pair.
    pub d3d_sq: f64,
    /// Maximum2DDiameterSlice² — pairs sharing z (XY plane).
    pub dxy_sq: f64,
    /// Maximum2DDiameterColumn² — pairs sharing x (YZ plane).
    pub dyz_sq: f64,
    /// Maximum2DDiameterRow² — pairs sharing y (XZ plane).
    pub dxz_sq: f64,
}

impl Diameters {
    pub const EMPTY: Diameters =
        Diameters { d3d_sq: -1.0, dxy_sq: -1.0, dyz_sq: -1.0, dxz_sq: -1.0 };

    /// As `[d3d², dxy², dyz², dxz²]` (the artifact output order).
    pub fn as_array(&self) -> [f64; 4] {
        [self.d3d_sq, self.dxy_sq, self.dyz_sq, self.dxz_sq]
    }

    pub fn from_array(a: [f64; 4]) -> Diameters {
        Diameters { d3d_sq: a[0], dxy_sq: a[1], dyz_sq: a[2], dxz_sq: a[3] }
    }

    /// Square root with `-1 → NaN` (PyRadiomics' degenerate-plane value).
    pub fn lengths(&self) -> [f64; 4] {
        self.as_array().map(|d| if d < 0.0 { f64::NAN } else { d.sqrt() })
    }

    /// Merge two partial results (max per family).
    pub fn merge(&self, o: &Diameters) -> Diameters {
        Diameters {
            d3d_sq: self.d3d_sq.max(o.d3d_sq),
            dxy_sq: self.dxy_sq.max(o.dxy_sq),
            dyz_sq: self.dyz_sq.max(o.dyz_sq),
            dxz_sq: self.dxz_sq.max(o.dxz_sq),
        }
    }
}

/// The PyRadiomics `cshape.calculate_diameter` port: brute force over all
/// vertex pairs, updating the 3D diameter always and each planar diameter
/// when the dropped coordinate matches exactly. O(m²) — this is the 95.7 to
/// 99.9 % hot spot of Table 2.
pub fn brute_force_diameters(vertices: &[Vec3]) -> Diameters {
    let mut d = Diameters::EMPTY;
    if vertices.is_empty() {
        return d;
    }
    // Self-pairs (i == j) are included, matching the GPU kernel's diagonal
    // tiles: they contribute distance 0, which only matters for the planar
    // families (a plane with a single vertex reports 0, not -1).
    for i in 0..vertices.len() {
        let vi = vertices[i];
        for j in i..vertices.len() {
            let vj = vertices[j];
            let dsq = vi.dist_sq(vj);
            if dsq > d.d3d_sq {
                d.d3d_sq = dsq;
            }
            if vi.z == vj.z && dsq > d.dxy_sq {
                d.dxy_sq = dsq;
            }
            if vi.x == vj.x && dsq > d.dyz_sq {
                d.dyz_sq = dsq;
            }
            if vi.y == vj.y && dsq > d.dxz_sq {
                d.dxz_sq = dsq;
            }
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input() {
        assert_eq!(brute_force_diameters(&[]), Diameters::EMPTY);
    }

    #[test]
    fn unit_square_in_plane() {
        let v = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 0.0, 0.0),
            Vec3::new(0.0, 1.0, 0.0),
            Vec3::new(1.0, 1.0, 0.0),
        ];
        let d = brute_force_diameters(&v);
        assert_eq!(d.d3d_sq, 2.0);
        assert_eq!(d.dxy_sq, 2.0); // all share z=0
        assert_eq!(d.dyz_sq, 1.0); // pairs sharing x
        assert_eq!(d.dxz_sq, 1.0); // pairs sharing y
    }

    #[test]
    fn distinct_z_gives_zero_planar() {
        let v = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(0.0, 0.0, 2.5),
        ];
        let d = brute_force_diameters(&v);
        assert_eq!(d.d3d_sq, 6.25);
        assert_eq!(d.dxy_sq, 0.0); // self-pairs only
        assert_eq!(d.dyz_sq, 6.25); // all share x
    }

    #[test]
    fn lengths_maps_negative_to_nan() {
        let l = Diameters::EMPTY.lengths();
        assert!(l.iter().all(|v| v.is_nan()));
        let d = Diameters { d3d_sq: 9.0, dxy_sq: 4.0, dyz_sq: -1.0, dxz_sq: 0.0 };
        let l = d.lengths();
        assert_eq!(l[0], 3.0);
        assert_eq!(l[1], 2.0);
        assert!(l[2].is_nan());
        assert_eq!(l[3], 0.0);
    }

    #[test]
    fn merge_takes_maxima() {
        let a = Diameters { d3d_sq: 4.0, dxy_sq: 1.0, dyz_sq: -1.0, dxz_sq: 2.0 };
        let b = Diameters { d3d_sq: 3.0, dxy_sq: 5.0, dyz_sq: 0.5, dxz_sq: -1.0 };
        let m = a.merge(&b);
        assert_eq!(m.as_array(), [4.0, 5.0, 0.5, 2.0]);
    }

    #[test]
    fn roundtrip_array() {
        let d = Diameters { d3d_sq: 1.0, dxy_sq: 2.0, dyz_sq: 3.0, dxz_sq: 4.0 };
        assert_eq!(Diameters::from_array(d.as_array()), d);
    }
}
