//! The full PyRadiomics 3D shape-feature vector.

use super::Diameters;
use crate::mc::MeshStats;
use crate::volume::{MaskStats, VoxelGrid};
use crate::geometry::sym3_eigenvalues;

/// All 17 PyRadiomics shape (3D) features, plus bookkeeping fields used by
/// the experiment harnesses (voxel/vertex counts).
///
/// Formula sources: PyRadiomics documentation, `radiomics.shape`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapeFeatures {
    pub mesh_volume: f64,
    pub voxel_volume: f64,
    pub surface_area: f64,
    pub surface_volume_ratio: f64,
    pub sphericity: f64,
    pub compactness1: f64,
    pub compactness2: f64,
    pub spherical_disproportion: f64,
    pub maximum_3d_diameter: f64,
    pub maximum_2d_diameter_slice: f64,
    pub maximum_2d_diameter_column: f64,
    pub maximum_2d_diameter_row: f64,
    pub major_axis_length: f64,
    pub minor_axis_length: f64,
    pub least_axis_length: f64,
    pub elongation: f64,
    pub flatness: f64,
    /// ROI voxel count (not a PyRadiomics feature; used by reports).
    pub voxel_count: usize,
    /// Mesh vertex count (the paper's "vertices in 3D space" column).
    pub vertex_count: usize,
}

impl ShapeFeatures {
    /// Ordered (name, value) view — used by the CSV/JSON reporters and the
    /// PyRadiomics-compatible result map.
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("MeshVolume", self.mesh_volume),
            ("VoxelVolume", self.voxel_volume),
            ("SurfaceArea", self.surface_area),
            ("SurfaceVolumeRatio", self.surface_volume_ratio),
            ("Sphericity", self.sphericity),
            ("Compactness1", self.compactness1),
            ("Compactness2", self.compactness2),
            ("SphericalDisproportion", self.spherical_disproportion),
            ("Maximum3DDiameter", self.maximum_3d_diameter),
            ("Maximum2DDiameterSlice", self.maximum_2d_diameter_slice),
            ("Maximum2DDiameterColumn", self.maximum_2d_diameter_column),
            ("Maximum2DDiameterRow", self.maximum_2d_diameter_row),
            ("MajorAxisLength", self.major_axis_length),
            ("MinorAxisLength", self.minor_axis_length),
            ("LeastAxisLength", self.least_axis_length),
            ("Elongation", self.elongation),
            ("Flatness", self.flatness),
        ]
    }
}

/// Derive the full feature vector from the three measured ingredients
/// (mask statistics, fused mesh stats, diameters).
///
/// This is pure closed-form math — the expensive parts were already done —
/// so it is shared verbatim by the CPU fallback and the accelerated path
/// (guaranteeing the paper's "identical output quality" property by
/// construction for everything except the measured inputs themselves).
pub fn compute_shape_features(
    mask: &VoxelGrid<u8>,
    mask_stats: &MaskStats,
    mesh: &MeshStats,
    diam: &Diameters,
    vertex_count: usize,
) -> ShapeFeatures {
    use std::f64::consts::PI;

    let v = mesh.volume;
    let a = mesh.area;
    let voxel_volume = mask_stats.count as f64 * mask.voxel_volume();

    // Sphericity family (PyRadiomics definitions). Degenerate meshes —
    // empty masks, or meshes collapsed to zero volume/area — would turn
    // every ratio into NaN/inf; they are *defined as zero* instead so that
    // downstream consumers (reports, CSV, aggregation) see a sentinel that
    // is unambiguous and sorts/serialises cleanly. A zero is unambiguous
    // here because every one of these ratios is strictly positive for any
    // non-degenerate mesh.
    let degenerate = v <= 0.0 || a <= 0.0;
    let sphericity = if degenerate { 0.0 } else { (36.0 * PI * v * v).cbrt() / a };
    let compactness1 = if degenerate { 0.0 } else { v / (PI.sqrt() * a.powf(1.5)) };
    let compactness2 = if degenerate { 0.0 } else { 36.0 * PI * v * v / (a * a * a) };
    let spherical_disproportion = if degenerate { 0.0 } else { 1.0 / sphericity };
    let surface_volume_ratio = if degenerate { 0.0 } else { a / v };

    // PCA axis lengths: 4·sqrt(λ) over the physical-coordinate covariance.
    let eig = sym3_eigenvalues(mask_stats.covariance);
    let lam_least = eig[0].max(0.0);
    let lam_minor = eig[1].max(0.0);
    let lam_major = eig[2].max(0.0);
    let major = 4.0 * lam_major.sqrt();
    let minor = 4.0 * lam_minor.sqrt();
    let least = 4.0 * lam_least.sqrt();
    let elongation = if lam_major > 0.0 { (lam_minor / lam_major).sqrt() } else { f64::NAN };
    let flatness = if lam_major > 0.0 { (lam_least / lam_major).sqrt() } else { f64::NAN };

    let dl = diam.lengths();
    ShapeFeatures {
        mesh_volume: v,
        voxel_volume,
        surface_area: a,
        surface_volume_ratio,
        sphericity,
        compactness1,
        compactness2,
        spherical_disproportion,
        maximum_3d_diameter: dl[0],
        maximum_2d_diameter_slice: dl[1],
        maximum_2d_diameter_column: dl[2],
        maximum_2d_diameter_row: dl[3],
        major_axis_length: major,
        minor_axis_length: minor,
        least_axis_length: least,
        elongation,
        flatness,
        voxel_count: mask_stats.count,
        vertex_count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::features::brute_force_diameters;
    use crate::geometry::Vec3;
    use crate::mc::mesh_roi;
    use crate::volume::Dims;

    fn sphere(n: usize, r: f64) -> VoxelGrid<u8> {
        let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
        let c = n as f64 / 2.0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                    if dx * dx + dy * dy + dz * dz <= r * r {
                        m.set(x, y, z, 1);
                    }
                }
            }
        }
        m
    }

    fn features_of(mask: &VoxelGrid<u8>) -> ShapeFeatures {
        let stats = MaskStats::compute(mask);
        let mesh = mesh_roi(mask);
        let diam = brute_force_diameters(&mesh.vertices);
        compute_shape_features(mask, &stats, &mesh.stats, &diam, mesh.vertices.len())
    }

    #[test]
    fn sphere_features_match_analytic() {
        let r = 8.0;
        let f = features_of(&sphere(24, r));
        // volumes within discretisation error
        let vol = 4.0 / 3.0 * std::f64::consts::PI * r * r * r;
        assert!((f.mesh_volume - vol).abs() / vol < 0.05);
        assert!((f.voxel_volume - vol).abs() / vol < 0.05);
        // sphere: sphericity near 1 (MT faceting reduces it)
        assert!(f.sphericity > 0.75 && f.sphericity <= 1.0, "{}", f.sphericity);
        assert!((f.spherical_disproportion - 1.0 / f.sphericity).abs() < 1e-12);
        // diameter ≈ 2r (+ surface offset)
        assert!((f.maximum_3d_diameter - 2.0 * r).abs() < 2.0);
        // near-isotropic axes
        assert!((f.elongation - 1.0).abs() < 0.1);
        assert!((f.flatness - 1.0).abs() < 0.1);
        assert!(f.major_axis_length >= f.minor_axis_length);
        assert!(f.minor_axis_length >= f.least_axis_length);
        assert!(f.vertex_count > 100);
        assert_eq!(f.voxel_count, 2109); // locked: |{p: |p-c|<=8}| in 24³
    }

    #[test]
    fn ellipsoid_axis_lengths() {
        // Half-axes (a, b, c) = (10, 6, 3) → axis lengths ≈ (4√(a²/5), …).
        let n = 28;
        let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
        let cc = n as f64 / 2.0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let dx = (x as f64 - cc) / 10.0;
                    let dy = (y as f64 - cc) / 6.0;
                    let dz = (z as f64 - cc) / 3.0;
                    if dx * dx + dy * dy + dz * dz <= 1.0 {
                        m.set(x, y, z, 1);
                    }
                }
            }
        }
        let f = features_of(&m);
        // Uniform solid ellipsoid: λ_major = a²/5 → major = 4a/√5 ≈ 17.9.
        let expect_major = 4.0 * 10.0 / 5.0f64.sqrt();
        let expect_minor = 4.0 * 6.0 / 5.0f64.sqrt();
        let expect_least = 4.0 * 3.0 / 5.0f64.sqrt();
        assert!((f.major_axis_length - expect_major).abs() / expect_major < 0.08);
        assert!((f.minor_axis_length - expect_minor).abs() / expect_minor < 0.08);
        assert!((f.least_axis_length - expect_least).abs() / expect_least < 0.12);
        assert!((f.elongation - 0.6).abs() < 0.05); // b/a
        assert!((f.flatness - 0.3).abs() < 0.05); // c/a
        // elongated: sphericity < sphere's
        assert!(f.sphericity < 0.95);
    }

    #[test]
    fn surface_volume_ratio_consistency() {
        let f = features_of(&sphere(20, 6.0));
        assert!((f.surface_volume_ratio - f.surface_area / f.mesh_volume).abs() < 1e-12);
        // compactness identities: C2 = sphericity³, SD = C2^(-1/3)
        assert!((f.compactness2 - f.sphericity.powi(3)).abs() < 1e-9);
    }

    #[test]
    fn named_exports_all_17() {
        let f = features_of(&sphere(16, 4.0));
        let named = f.named();
        assert_eq!(named.len(), 17);
        assert_eq!(named[0].0, "MeshVolume");
        assert!(named.iter().all(|(_, v)| !v.is_nan()));
    }

    #[test]
    fn empty_mask_yields_defined_zeros_not_nans() {
        let m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let stats = MaskStats::compute(&m);
        let mesh = mesh_roi(&m);
        let d = brute_force_diameters(&[]);
        let f = compute_shape_features(&m, &stats, &mesh.stats, &d, 0);
        assert_eq!(f.voxel_volume, 0.0);
        // degenerate-mesh ratio family: defined zeros (no NaN/inf)
        assert_eq!(f.sphericity, 0.0);
        assert_eq!(f.compactness1, 0.0);
        assert_eq!(f.compactness2, 0.0);
        assert_eq!(f.spherical_disproportion, 0.0);
        assert_eq!(f.surface_volume_ratio, 0.0);
        // diameters keep PyRadiomics' NaN for "no vertex pair"
        assert!(f.maximum_3d_diameter.is_nan());
    }

    #[test]
    fn zero_area_mesh_stats_yield_zeros_not_infinities() {
        // a fabricated degenerate mesh (zero area, nonzero volume and the
        // reverse) must never produce NaN or inf in the ratio family
        let m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let stats = MaskStats::compute(&m);
        let d = brute_force_diameters(&[]);
        for mesh in [
            MeshStats { volume: 3.0, area: 0.0 },
            MeshStats { volume: 0.0, area: 5.0 },
            MeshStats { volume: 0.0, area: 0.0 },
        ] {
            let f = compute_shape_features(&m, &stats, &mesh, &d, 0);
            for value in [
                f.sphericity,
                f.compactness1,
                f.compactness2,
                f.spherical_disproportion,
                f.surface_volume_ratio,
            ] {
                assert_eq!(value, 0.0, "mesh {mesh:?}");
            }
        }
    }

    #[test]
    fn non_degenerate_mesh_keeps_exact_ratio_identities() {
        // the guard must not perturb the regular path
        let f = features_of(&sphere(16, 5.0));
        assert!(f.sphericity > 0.0);
        assert!((f.surface_volume_ratio - f.surface_area / f.mesh_volume).abs() < 1e-12);
        assert!((f.spherical_disproportion - 1.0 / f.sphericity).abs() < 1e-12);
    }
}
