//! PyRadiomics *first-order* statistics — the feature class the prior-work
//! GPU ports (cuRadiomics, §1) accelerate. Included so the pipeline covers
//! the paper's comparison surface: intensity statistics over the ROI of an
//! image volume, computed in one sort + two passes.
//!
//! Definitions follow `radiomics.firstorder` (bin width 25 for the
//! histogram features, voxel volume `c` for Energy/TotalEnergy). The
//! Entropy/Uniformity histogram honours the same discretization settings
//! as the texture classes — see [`compute_first_order_with`].

use super::texture::Discretization;
use crate::volume::VoxelGrid;

/// The PyRadiomics first-order feature vector (18 features).
#[derive(Debug, Clone, PartialEq)]
pub struct FirstOrderFeatures {
    pub energy: f64,
    pub total_energy: f64,
    pub entropy: f64,
    pub minimum: f64,
    pub percentile10: f64,
    pub percentile90: f64,
    pub maximum: f64,
    pub mean: f64,
    pub median: f64,
    pub interquartile_range: f64,
    pub range: f64,
    pub mean_absolute_deviation: f64,
    pub robust_mean_absolute_deviation: f64,
    pub root_mean_squared: f64,
    pub skewness: f64,
    pub kurtosis: f64,
    pub variance: f64,
    pub uniformity: f64,
}

impl FirstOrderFeatures {
    /// Ordered (name, value) view, mirroring [`super::ShapeFeatures::named`].
    pub fn named(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("Energy", self.energy),
            ("TotalEnergy", self.total_energy),
            ("Entropy", self.entropy),
            ("Minimum", self.minimum),
            ("10Percentile", self.percentile10),
            ("90Percentile", self.percentile90),
            ("Maximum", self.maximum),
            ("Mean", self.mean),
            ("Median", self.median),
            ("InterquartileRange", self.interquartile_range),
            ("Range", self.range),
            ("MeanAbsoluteDeviation", self.mean_absolute_deviation),
            ("RobustMeanAbsoluteDeviation", self.robust_mean_absolute_deviation),
            ("RootMeanSquared", self.root_mean_squared),
            ("Skewness", self.skewness),
            ("Kurtosis", self.kurtosis),
            ("Variance", self.variance),
            ("Uniformity", self.uniformity),
        ]
    }
}

/// Linear-interpolated percentile of a sorted slice (numpy default).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Compute the first-order features of `image` restricted to `mask != 0`.
///
/// Returns `None` for an empty ROI (PyRadiomics raises; callers surface a
/// clean error). `bin_width` controls the Entropy/Uniformity histogram
/// (PyRadiomics default 25).
pub fn compute_first_order(
    image: &VoxelGrid<f32>,
    mask: &VoxelGrid<u8>,
    bin_width: f64,
) -> Option<FirstOrderFeatures> {
    compute_first_order_with(image, mask, Discretization::BinWidth(bin_width))
}

/// Histogram size ceiling: a pathological `bin_width` (say `1e-9` over a
/// wide intensity range) must degrade gracefully — excess values clamp
/// into the last bin — rather than attempt an unbounded allocation.
const MAX_HIST_BINS: usize = 1 << 20;

/// [`compute_first_order`] with the full discretization policy: the
/// Entropy/Uniformity histogram uses edge-aligned fixed-width bins
/// ([`Discretization::BinWidth`], PyRadiomics `binWidth`) or a fixed bin
/// count over the ROI range ([`Discretization::BinCount`], PyRadiomics
/// `binCount`) — matching whatever the texture classes use, so a single
/// `bin_count` config knob governs every discretized feature. The
/// histogram is capped at [`MAX_HIST_BINS`]. Non-finite intensities do
/// not panic; they propagate NaN into the order statistics.
pub fn compute_first_order_with(
    image: &VoxelGrid<f32>,
    mask: &VoxelGrid<u8>,
    disc: Discretization,
) -> Option<FirstOrderFeatures> {
    assert_eq!(image.dims, mask.dims, "image/mask dims mismatch");
    let mut vals: Vec<f64> = mask
        .iter_roi()
        .map(|(x, y, z)| image.get(x, y, z) as f64)
        .collect();
    if vals.is_empty() {
        return None;
    }
    // total order: NaN intensities sort to the ends instead of panicking
    // (real medical volumes do contain NaN voxels)
    vals.sort_by(|a, b| a.total_cmp(b));
    let n = vals.len() as f64;

    let minimum = vals[0];
    let maximum = *vals.last().unwrap();
    let sum: f64 = vals.iter().sum();
    let mean = sum / n;
    let energy: f64 = vals.iter().map(|v| v * v).sum();
    let variance = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    let std = variance.sqrt();

    let p10 = percentile(&vals, 10.0);
    let p25 = percentile(&vals, 25.0);
    let p50 = percentile(&vals, 50.0);
    let p75 = percentile(&vals, 75.0);
    let p90 = percentile(&vals, 90.0);

    let mad = vals.iter().map(|v| (v - mean).abs()).sum::<f64>() / n;
    // robust MAD: MAD over values within [p10, p90]
    let robust: Vec<f64> = vals.iter().copied().filter(|&v| v >= p10 && v <= p90).collect();
    let rmean = robust.iter().sum::<f64>() / robust.len().max(1) as f64;
    let rmad = if robust.is_empty() {
        0.0
    } else {
        robust.iter().map(|v| (v - rmean).abs()).sum::<f64>() / robust.len() as f64
    };

    let (skewness, kurtosis) = if std > 1e-12 {
        let m3 = vals.iter().map(|v| (v - mean).powi(3)).sum::<f64>() / n;
        let m4 = vals.iter().map(|v| (v - mean).powi(4)).sum::<f64>() / n;
        (m3 / std.powi(3), m4 / (variance * variance))
    } else {
        (0.0, 0.0) // degenerate constant ROI (PyRadiomics yields 0)
    };

    // discretised histogram for Entropy / Uniformity
    let (lo, bin_width, nbins) = match disc {
        Discretization::BinWidth(w) => {
            // same precondition style as the dims assert above: an invalid
            // width is a programmer error (config/CLI validate user input),
            // not something to silently rewrite
            assert!(w > 0.0 && w.is_finite(), "bin width must be positive, got {w}");
            let lo = (minimum / w).floor() * w;
            let raw = ((maximum - lo) / w).floor();
            let nbins = if raw.is_finite() && raw < (MAX_HIST_BINS - 1) as f64 {
                raw as usize + 1
            } else if raw.is_finite() {
                MAX_HIST_BINS
            } else {
                1 // NaN range (non-finite intensities): degenerate histogram
            };
            (lo, w, nbins.max(1))
        }
        Discretization::BinCount(n) => {
            let n = n.clamp(1, MAX_HIST_BINS);
            if maximum > minimum {
                (minimum, (maximum - minimum) / n as f64, n)
            } else {
                (minimum, 1.0, 1) // constant ROI: one bin
            }
        }
    };
    let mut hist = vec![0u64; nbins];
    for &v in &vals {
        let b = (((v - lo) / bin_width).floor() as usize).min(nbins - 1);
        hist[b] += 1;
    }
    let mut entropy = 0.0;
    let mut uniformity = 0.0;
    for &c in &hist {
        if c > 0 {
            let p = c as f64 / n;
            entropy -= p * p.log2();
            uniformity += p * p;
        }
    }

    Some(FirstOrderFeatures {
        energy,
        total_energy: energy * image.voxel_volume(),
        entropy,
        minimum,
        percentile10: p10,
        percentile90: p90,
        maximum,
        mean,
        median: p50,
        interquartile_range: p75 - p25,
        range: maximum - minimum,
        mean_absolute_deviation: mad,
        robust_mean_absolute_deviation: rmad,
        root_mean_squared: (energy / n).sqrt(),
        skewness,
        kurtosis,
        variance,
        uniformity,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    /// Image with ROI values exactly [1, 2, 3, 4, 5].
    fn fixture() -> (VoxelGrid<f32>, VoxelGrid<u8>) {
        let dims = Dims::new(5, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::new(2.0, 1.0, 1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::new(2.0, 1.0, 1.0));
        for x in 0..5 {
            img.set(x, 0, 0, (x + 1) as f32);
            mask.set(x, 0, 0, 1);
        }
        (img, mask)
    }

    #[test]
    fn known_values_1_to_5() {
        let (img, mask) = fixture();
        let f = compute_first_order(&img, &mask, 25.0).unwrap();
        assert_eq!(f.minimum, 1.0);
        assert_eq!(f.maximum, 5.0);
        assert_eq!(f.mean, 3.0);
        assert_eq!(f.median, 3.0);
        assert_eq!(f.range, 4.0);
        assert_eq!(f.energy, 55.0);
        assert_eq!(f.total_energy, 110.0); // voxel volume 2
        assert!((f.variance - 2.0).abs() < 1e-12);
        assert!((f.root_mean_squared - (11.0f64).sqrt()).abs() < 1e-12);
        assert!((f.mean_absolute_deviation - 1.2).abs() < 1e-12);
        assert_eq!(f.skewness, 0.0); // symmetric
        // all values land in one bin (width 25) → entropy 0, uniformity 1
        assert_eq!(f.entropy, 0.0);
        assert_eq!(f.uniformity, 1.0);
    }

    #[test]
    fn percentiles_numpy_semantics() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
        assert_eq!(percentile(&v, 25.0), 1.75);
    }

    #[test]
    fn entropy_of_two_equal_bins() {
        let dims = Dims::new(4, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..4 {
            img.set(x, 0, 0, if x < 2 { 0.0 } else { 30.0 }); // two bins at width 25
            mask.set(x, 0, 0, 1);
        }
        let f = compute_first_order(&img, &mask, 25.0).unwrap();
        assert!((f.entropy - 1.0).abs() < 1e-12);
        assert!((f.uniformity - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bin_count_histogram_matches_fixed_count_semantics() {
        // values [1..5], 2 bins over [1, 5]: [1,3) holds {1,2}, [3,5]
        // holds {3,4,5} (max clamps into the last bin)
        let (img, mask) = fixture();
        let f =
            compute_first_order_with(&img, &mask, Discretization::BinCount(2)).unwrap();
        let want_entropy = -(0.4f64 * 0.4f64.log2() + 0.6 * 0.6f64.log2());
        assert!((f.entropy - want_entropy).abs() < 1e-12, "{}", f.entropy);
        assert!((f.uniformity - 0.52).abs() < 1e-12, "{}", f.uniformity);
        // non-histogram features are unaffected by the discretization policy
        let g = compute_first_order(&img, &mask, 25.0).unwrap();
        assert_eq!(f.mean, g.mean);
        assert_eq!(f.variance, g.variance);
        assert_eq!(f.energy, g.energy);
    }

    #[test]
    fn pathological_bin_settings_do_not_blow_up() {
        // tiny width over a wide range must clamp the histogram, not OOM
        let dims = Dims::new(2, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        img.set(0, 0, 0, 0.0);
        img.set(1, 0, 0, 1e9);
        mask.set(0, 0, 0, 1);
        mask.set(1, 0, 0, 1);
        let f = compute_first_order(&img, &mask, 1e-9).unwrap();
        assert!(f.entropy.is_finite());
        let f = compute_first_order_with(&img, &mask, Discretization::BinCount(usize::MAX))
            .unwrap();
        assert!(f.entropy.is_finite());
    }

    #[test]
    fn nan_intensity_propagates_without_panicking() {
        let dims = Dims::new(3, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..3 {
            img.set(x, 0, 0, if x == 1 { f32::NAN } else { x as f32 });
            mask.set(x, 0, 0, 1);
        }
        let f = compute_first_order(&img, &mask, 25.0).unwrap();
        // NaN sorts to an end under total order and taints the statistics
        // honestly instead of crashing the extract worker
        assert!(f.maximum.is_nan() || f.minimum.is_nan());
        assert!(f.mean.is_nan());
    }

    #[test]
    fn empty_roi_is_none() {
        let dims = Dims::new(3, 3, 3);
        let img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        assert!(compute_first_order(&img, &mask, 25.0).is_none());
    }

    #[test]
    fn constant_roi_degenerate_moments() {
        let dims = Dims::new(3, 1, 1);
        let mut img = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        let mut mask = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for x in 0..3 {
            img.set(x, 0, 0, 7.5);
            mask.set(x, 0, 0, 1);
        }
        let f = compute_first_order(&img, &mask, 25.0).unwrap();
        assert_eq!(f.variance, 0.0);
        assert_eq!(f.skewness, 0.0);
        assert_eq!(f.kurtosis, 0.0);
        assert_eq!(f.interquartile_range, 0.0);
    }

    #[test]
    fn named_exports_18() {
        let (img, mask) = fixture();
        let f = compute_first_order(&img, &mask, 25.0).unwrap();
        assert_eq!(f.named().len(), 18);
    }

    #[test]
    fn mask_restricts_values() {
        let (img, mut mask) = fixture();
        mask.set(4, 0, 0, 0); // drop the value 5
        let f = compute_first_order(&img, &mask, 25.0).unwrap();
        assert_eq!(f.maximum, 4.0);
        assert_eq!(f.mean, 2.5);
    }
}
