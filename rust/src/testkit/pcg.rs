//! PCG32 (O'Neill 2014, `pcg32_random_r`): small, fast, statistically solid
//! and fully deterministic across platforms — used by the synthetic data
//! generator and the property tests.

/// PCG-XSH-RR 64/32 generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seeded generator with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seeded generator with an explicit stream id (different streams are
    /// independent even with equal seeds).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        (self.next_u32() as u64) << 32 | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for tests).
    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::with_stream(7, 1);
        let mut b = Pcg32::with_stream(7, 2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut rng = Pcg32::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Pcg32::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
