//! In-repo testing substrate: a deterministic PRNG and a miniature
//! property-testing framework (the offline mirror has no `proptest`/`rand`,
//! so these are part of the deliverable — see DESIGN.md).

mod pcg;
mod prop;

pub use pcg::Pcg32;
pub use prop::{f64_range, forall, int_range, vec_of, Gen};
