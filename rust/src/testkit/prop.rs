//! Miniature property-testing framework: generator combinators + a
//! `forall` runner with iteration-deepening shrink-lite (re-running the
//! predicate on "smaller" regenerations rather than structural shrinking —
//! enough to pin down minimal sizes in practice).

use super::Pcg32;

/// A value generator: size-aware, deterministic given the RNG.
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Pcg32, usize) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Pcg32, usize) -> T + 'static) -> Self {
        Gen { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Pcg32, size: usize) -> T {
        (self.f)(rng, size)
    }

    /// Map the generated value.
    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng, size| g(self.sample(rng, size)))
    }
}

/// Integers in `[lo, hi]`.
pub fn int_range(lo: i64, hi: i64) -> Gen<i64> {
    assert!(lo <= hi);
    Gen::new(move |rng, _| lo + (rng.next_u64() % (hi - lo + 1) as u64) as i64)
}

/// Floats in `[lo, hi)`.
pub fn f64_range(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng, _| rng.range_f64(lo, hi))
}

/// Vectors whose length grows with the size parameter (≤ size).
pub fn vec_of<T: 'static>(elem: Gen<T>) -> Gen<Vec<T>> {
    Gen::new(move |rng, size| {
        let len = (rng.next_u32() as usize) % (size.max(1));
        (0..len).map(|_| elem.sample(rng, size)).collect()
    })
}

/// Run `prop` on `cases` generated inputs with growing size; on failure,
/// retry with progressively smaller sizes to report a small counterexample.
///
/// Panics (test failure) with the seed + smallest failing input debug dump.
pub fn forall<T: std::fmt::Debug + 'static>(
    name: &str,
    gen: &Gen<T>,
    cases: usize,
    prop: impl Fn(&T) -> bool,
) {
    let base_seed = 0x5eed_0000u64 ^ name.len() as u64;
    for case in 0..cases {
        let size = 2 + case * 64 / cases.max(1);
        let mut rng = Pcg32::with_stream(base_seed + case as u64, 17);
        let value = gen.sample(&mut rng, size);
        if !prop(&value) {
            // shrink-lite: regenerate at smaller sizes from the same stream
            let mut smallest = value;
            for s in (1..size).rev() {
                let mut rng = Pcg32::with_stream(base_seed + case as u64, 17);
                let candidate = gen.sample(&mut rng, s);
                if !prop(&candidate) {
                    smallest = candidate;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {}):\n{smallest:#?}",
                base_seed + case as u64
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_range_respects_bounds() {
        let g = int_range(-5, 5);
        let mut rng = Pcg32::new(1);
        for _ in 0..1000 {
            let v = g.sample(&mut rng, 10);
            assert!((-5..=5).contains(&v));
        }
    }

    #[test]
    fn vec_lengths_scale_with_size() {
        let g = vec_of(int_range(0, 9));
        let mut rng = Pcg32::new(2);
        let small: Vec<usize> = (0..100).map(|_| g.sample(&mut rng, 3).len()).collect();
        assert!(small.iter().all(|&l| l < 3));
    }

    #[test]
    fn forall_passes_true_property() {
        forall("sum-commutes", &vec_of(int_range(0, 100)), 50, |v| {
            let s1: i64 = v.iter().sum();
            let mut r = v.clone();
            r.reverse();
            let s2: i64 = r.iter().sum();
            s1 == s2
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failures() {
        forall("always-fails", &int_range(0, 10), 5, |_| false);
    }

    #[test]
    fn map_transforms() {
        let g = int_range(1, 3).map(|v| v * 100);
        let mut rng = Pcg32::new(5);
        for _ in 0..50 {
            let v = g.sample(&mut rng, 4);
            assert!(v == 100 || v == 200 || v == 300);
        }
    }
}
