//! CLI: argument parser + subcommands (no clap offline — in-repo parser).
//!
//! ```text
//! radpipe gen-data  --out DIR [--scale F] [--seed N]
//! radpipe extract   --data DIR [--config FILE] [--backend auto|cpu|accelerated] [--json FILE]
//!                   [--engine-count N] [--batch-size N] [--batch-linger-ms MS]
//! radpipe table2    --data DIR [--backend ...]        # Table 2 harness
//! radpipe fig1      [--vertices N[,N..]]              # Fig 1 harness
//! radpipe fig2      [--list-devices]                  # Fig 2 harness
//! radpipe inspect   --mask FILE
//! ```

mod args;
mod commands;

pub use args::Args;

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> std::process::ExitCode {
    match commands::dispatch(argv) {
        Ok(()) => std::process::ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("radpipe: error: {e:#}");
            std::process::ExitCode::FAILURE
        }
    }
}
