//! Tiny long-option argument parser: `--key value`, `--flag`, positionals.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option keys that were consumed via accessors (for strict checking).
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse an argv tail. `--key value` pairs become options; a `--key`
    /// followed by another `--…` (or nothing) becomes a boolean flag.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare '--' is not supported");
                }
                let next = argv.get(i + 1);
                match next {
                    Some(v) if !v.starts_with("--") => {
                        if out.options.insert(key.to_string(), v.clone()).is_some() {
                            bail!("duplicate option --{key}");
                        }
                        i += 2;
                    }
                    _ => {
                        out.flags.push(key.to_string());
                        i += 1;
                    }
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.known.borrow_mut().push(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.opt(key).with_context(|| format!("missing required option --{key}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.known.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(raw) => match raw.parse() {
                Ok(v) => Ok(Some(v)),
                Err(e) => bail!("--{key}: cannot parse '{raw}': {e}"),
            },
        }
    }

    /// Error on any option/flag that no accessor asked about (typo guard).
    pub fn finish(&self) -> Result<()> {
        let known = self.known.borrow();
        for k in self.options.keys() {
            if !known.iter().any(|x| x == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !known.iter().any(|x| x == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn options_flags_positionals() {
        // NB: a `--flag` followed by a bare token would consume it as a
        // value (inherent ambiguity without a flag registry); positionals
        // therefore come before flags, which all radpipe commands follow.
        let a = Args::parse(&argv(&["cmd", "pos2", "--out", "dir", "--fast"])).unwrap();
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert_eq!(a.opt("out"), Some("dir"));
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
        a.finish().unwrap();
    }

    #[test]
    fn flag_before_option() {
        let a = Args::parse(&argv(&["--verbose", "--n", "3"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_parse::<usize>("n").unwrap(), Some(3));
    }

    #[test]
    fn missing_required() {
        let a = Args::parse(&argv(&[])).unwrap();
        assert!(a.req("data").is_err());
    }

    #[test]
    fn duplicate_rejected() {
        assert!(Args::parse(&argv(&["--x", "1", "--x", "2"])).is_err());
    }

    #[test]
    fn unknown_option_caught_by_finish() {
        let a = Args::parse(&argv(&["--bogus", "1"])).unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn parse_error_mentions_key() {
        let a = Args::parse(&argv(&["--n", "abc"])).unwrap();
        let err = a.opt_parse::<usize>("n").unwrap_err();
        assert!(err.to_string().contains("--n"));
    }
}
