//! Subcommand implementations (thin wrappers over [`crate::experiments`],
//! [`crate::pipeline`] and [`crate::synth`]).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::args::Args;
use crate::bench::{compare, load_dir, parse_tolerance, Tolerance};
use crate::cohort::{run_batch, BatchOptions};
use crate::config::{Backend, PipelineConfig};
use crate::dispatch::FeatureExtractor;
use crate::experiments;
use crate::gpusim::{cpu_profiles, gpu_profiles};
use crate::pipeline::{case_named_features, run_pipeline};
use crate::report::{JsonValue, Table};
use crate::synth::{generate_dataset, generate_multilabel_dataset, GenOptions};

const USAGE: &str = "\
radpipe — PyRadiomics-cuda reproduction pipeline

USAGE:
  radpipe gen-data  --out DIR [--scale F] [--seed N]
                    [--multilabel]       (3-case label-map fixture: labels
                                          1..3 plus a declared-empty 4)
  radpipe extract   --data DIR [--config FILE] [--backend auto|cpu|accelerated]
                    [--artifacts DIR] [--json FILE] [--csv FILE] [--workers N]
                    [--engine-count N] [--batch-size N] [--batch-linger-ms MS]
                    [--features shape,firstorder,glcm,glrlm,glszm,gldm,ngtdm|texture|all]
                    [--bin-width F] [--bin-count N] [--glcm-distances 1,2]
                    [--gldm-alpha F]
                    [--image-types original,log,wavelet|all] [--log-sigmas 1.0,3.0]
                    [--resampled-spacing MM] [--wavelet-levels N]
                    [--labels 1,3|all]   (label-map masks: which ROIs to
                                          extract, one result row per label)
                    [--slab-io]          (scan masks in z-slabs, materialise
                                          only the ROI crop)
                    [--memory-budget N[K|M|G|T]]
                                         (throttle case admission to cap
                                          in-flight pipeline bytes; 0 = off)
                    [--synthetic-image]  (stand-in intensities for cases
                                          without an image= manifest entry)
                    [--trace-out FILE]   (Chrome Trace Event JSON of the run)
                    [--metrics-out FILE] (radpipe.metrics/1 snapshot)
  radpipe batch     --manifest FILE [--journal FILE] [--resume]
                    [--cache-dir DIR] [--cache-max-bytes N[K|M|G|T]]
                    [--json FILE] [--csv FILE] (+ every extract tuning flag)
                    (cohort CSV manifest: case_id,mask[,image][,labels].
                     Per-case failures become status=failed report rows;
                     the journal checkpoint lets --resume re-execute only
                     unfinished cases; the content-addressed cache replays
                     identical inputs bit-for-bit with zero extractions)
  radpipe obs-check [--trace FILE] [--metrics FILE]
                    [--require-stages read,preprocess,mesh,diameters]
                    (validate observability outputs of an extract run)
  radpipe table2    --data DIR [--artifacts DIR] [--cpu-only]
  radpipe fig1      --data DIR [--threads N]
  radpipe fig2      --data DIR
  radpipe bench-check [--current DIR] [--baselines DIR] [--min-abs-ms F]
                    [--tolerance generous|strict|FACTOR]
                    [--bless] [--validate-only]
                    (gate current BENCH_*.json against checked-in baselines)
  radpipe inspect   --mask FILE
  radpipe devices   (list Table 1 device profiles)
  radpipe version
";

pub fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(&argv)?;
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "gen-data" => gen_data(&args),
        "extract" => extract(&args),
        "batch" => batch(&args),
        "obs-check" => obs_check(&args),
        "table2" => table2(&args),
        "fig1" => fig1(&args),
        "fig2" => fig2(&args),
        "bench-check" => bench_check(&args),
        "inspect" => inspect(&args),
        "devices" => devices(&args),
        "version" => {
            println!("radpipe {}", crate::version());
            Ok(())
        }
        "help" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn gen_data(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.req("out")?);
    let opts = GenOptions {
        scale: args.opt_parse::<f64>("scale")?.unwrap_or(0.125),
        seed: args.opt_parse::<u64>("seed")?.unwrap_or(7),
    };
    let multilabel = args.flag("multilabel");
    args.finish()?;
    let m = if multilabel {
        generate_multilabel_dataset(&out, &opts)?
    } else {
        generate_dataset(&out, &opts)?
    };
    if multilabel {
        let mut t = Table::new(vec!["case", "dims", "vertices", "labels"]);
        for e in &m.cases {
            let labels =
                e.labels.iter().map(|l| l.to_string()).collect::<Vec<_>>().join(",");
            t.row(vec![
                e.case_id.clone(),
                e.dims.map(|d| d.to_string()).unwrap_or_default(),
                e.target_vertices.to_string(),
                labels,
            ]);
        }
        print!("{}", t.to_text());
    } else {
        let mut t = Table::new(vec!["case", "dims", "vertices"]);
        for e in &m.cases {
            t.row(vec![
                e.case_id.clone(),
                e.dims.map(|d| d.to_string()).unwrap_or_default(),
                e.target_vertices.to_string(),
            ]);
        }
        print!("{}", t.to_text());
    }
    println!("wrote {} cases to {}", m.cases.len(), out.display());
    Ok(())
}

fn load_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => PipelineConfig::from_file(Path::new(path))?,
        None => PipelineConfig::default(),
    };
    if let Some(b) = args.opt("backend") {
        cfg.backend = Backend::parse(b)?;
    }
    if let Some(dir) = args.opt("artifacts") {
        cfg.artifact_dir = PathBuf::from(dir);
    }
    if let Some(w) = args.opt_parse::<usize>("workers")? {
        cfg.read_workers = w;
        cfg.feature_workers = w;
    }
    if let Some(n) = args.opt_parse::<usize>("engine-count")? {
        cfg.engine_count = n.max(1);
    }
    if let Some(n) = args.opt_parse::<usize>("batch-size")? {
        cfg.batch_size = n.max(1);
    }
    if let Some(ms) = args.opt_parse::<u64>("batch-linger-ms")? {
        cfg.batch_linger_ms = ms;
    }
    if let Some(list) = args.opt("features") {
        cfg.feature_classes = crate::config::FeatureClasses::parse(list)?;
    }
    if let Some(w) = args.opt_parse::<f64>("bin-width")? {
        anyhow::ensure!(w > 0.0 && w.is_finite(), "--bin-width must be positive");
        cfg.bin_width = w;
    }
    if let Some(n) = args.opt_parse::<usize>("bin-count")? {
        let max = crate::features::texture::MAX_GRAY_LEVELS;
        anyhow::ensure!(n <= max, "--bin-count {n} exceeds the maximum of {max}");
        cfg.bin_count = n;
    }
    if let Some(list) = args.opt("glcm-distances") {
        cfg.glcm_distances =
            crate::config::parse_distances(list).context("--glcm-distances")?;
    }
    if let Some(a) = args.opt_parse::<f64>("gldm-alpha")? {
        anyhow::ensure!(
            a >= 0.0 && a.is_finite(),
            "--gldm-alpha must be a non-negative finite number"
        );
        cfg.gldm_alpha = a;
    }
    if let Some(list) = args.opt("image-types") {
        cfg.image_types =
            crate::imgproc::ImageTypes::parse(list).context("--image-types")?;
    }
    if let Some(list) = args.opt("log-sigmas") {
        cfg.log_sigmas = crate::config::parse_sigmas(list).context("--log-sigmas")?;
    }
    if let Some(mm) = args.opt_parse::<f64>("resampled-spacing")? {
        anyhow::ensure!(
            mm >= 0.0 && mm.is_finite(),
            "--resampled-spacing must be >= 0 mm (0 disables resampling)"
        );
        cfg.resampled_spacing = mm;
    }
    if let Some(n) = args.opt_parse::<usize>("wavelet-levels")? {
        let max = crate::config::MAX_WAVELET_LEVELS;
        anyhow::ensure!(
            (1..=max).contains(&n),
            "--wavelet-levels must be in 1..={max}, got {n}"
        );
        cfg.wavelet_levels = n;
    }
    if let Some(list) = args.opt("labels") {
        cfg.labels = crate::config::LabelSelection::parse(list).context("--labels")?;
    }
    if args.flag("slab-io") {
        cfg.slab_io = true;
    }
    if let Some(s) = args.opt("memory-budget") {
        cfg.memory_budget = crate::config::parse_byte_size(s).context("--memory-budget")?;
    }
    if args.flag("synthetic-image") {
        cfg.synthetic_image = true;
    }
    if let Some(dir) = args.opt("cache-dir") {
        cfg.cache_dir = Some(PathBuf::from(dir));
    }
    if let Some(s) = args.opt("cache-max-bytes") {
        cfg.cache_max_bytes =
            crate::config::parse_byte_size(s).context("--cache-max-bytes")?;
    }
    if let Some(p) = args.opt("trace-out") {
        cfg.trace_out = Some(PathBuf::from(p));
    }
    if let Some(p) = args.opt("metrics-out") {
        cfg.metrics_out = Some(PathBuf::from(p));
    }
    cfg.validate()?;
    Ok(cfg)
}

fn extract(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let cfg = load_config(args)?;
    let json_out = args.opt("json").map(PathBuf::from);
    let csv_out = args.opt("csv").map(PathBuf::from);
    args.finish()?;

    // tracing on request only: install the session before the pipeline so
    // every worker/engine span lands in this run's sink (sessions are
    // serialized process-wide; with no --trace-out the tracer stays off)
    let trace_sink = cfg.trace_out.as_ref().map(|_| crate::trace::TraceSink::new());
    let session = trace_sink.clone().map(crate::trace::install);

    let manifest = crate::io::scan_dataset(&data)?;
    let extractor = FeatureExtractor::new(&cfg)?;
    let report = run_pipeline(&manifest, &cfg, &extractor)?;
    drop(session);
    if let (Some(path), Some(sink)) = (cfg.trace_out.as_ref(), trace_sink.as_ref()) {
        sink.write(path)?;
        eprintln!("wrote {}", path.display());
    }
    if let Some(path) = cfg.metrics_out.as_ref() {
        report.metrics.write(path)?;
        eprintln!("wrote {}", path.display());
    }

    let texture_on = cfg.feature_classes.texture();
    // one row per (case, label) under a labels selector; the label column
    // only appears then, so legacy single-ROI outputs are byte-stable
    let label_on = !matches!(cfg.labels, crate::config::LabelSelection::Unset);
    let mut headers = vec!["case"];
    if label_on {
        headers.push("label");
    }
    headers.extend([
        "verts", "MeshVolume", "SurfaceArea", "Max3DDiam", "path",
        "preprocess[ms]",
    ]);
    if texture_on {
        headers.push("texture[ms]");
    }
    headers.push("total[ms]");
    let mut t = Table::new(headers);
    for r in &report.results {
        let mut row = vec![r.case_id.clone()];
        if label_on {
            row.push(r.label.map(|l| l.to_string()).unwrap_or_default());
        }
        row.extend([
            r.features.vertex_count.to_string(),
            format!("{:.1}", r.features.mesh_volume),
            format!("{:.1}", r.features.surface_area),
            format!("{:.2}", r.features.maximum_3d_diameter),
            format!("{:?}", r.path),
            format!("{:.1}", r.timing.preprocess.as_secs_f64() * 1e3),
        ]);
        if texture_on {
            row.push(format!("{:.1}", r.timing.texture.as_secs_f64() * 1e3));
        }
        row.push(format!("{:.1}", r.timing.total().as_secs_f64() * 1e3));
        t.row(row);
    }
    print!("{}", t.to_text());
    for (case, err) in &report.failures {
        eprintln!("FAILED {case}: {err}");
    }
    eprintln!("--- metrics ---\n{}", report.metrics_text);
    eprintln!("wall: {:.2}s", report.wall.as_secs_f64());

    // the feature list per case feeds both report writers; with derived
    // images it is ~11× larger than before, so compute it exactly once
    let per_case: Vec<Vec<(String, f64)>> = if json_out.is_some() || csv_out.is_some() {
        report.results.iter().map(case_named_features).collect()
    } else {
        Vec::new()
    };

    if let Some(path) = json_out {
        let mut doc = JsonValue::obj();
        let mut cases = Vec::new();
        for (r, features) in report.results.iter().zip(&per_case) {
            let mut c = JsonValue::obj();
            c.set("case", r.case_id.as_str());
            if let Some(l) = r.label {
                c.set("label", l as usize);
            }
            c.set("path", format!("{:?}", r.path));
            for (name, value) in features {
                c.set(name, *value);
            }
            cases.push(c);
        }
        doc.set("cases", JsonValue::Arr(cases));
        doc.set("failures", report.failures.len());
        doc.set("metrics", report.metrics.to_json());
        std::fs::write(&path, doc.to_string())
            .with_context(|| format!("write {}", path.display()))?;
        eprintln!("wrote {}", path.display());
    }

    if let Some(path) = csv_out {
        // header: union of feature names in first-seen order (cases with an
        // empty ROI miss the intensity classes; their cells read NaN)
        let mut names: Vec<String> = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for features in &per_case {
            for (name, _) in features {
                if seen.insert(name.clone()) {
                    names.push(name.clone());
                }
            }
        }
        let mut headers = vec!["case".to_string()];
        if label_on {
            headers.push("label".to_string());
        }
        headers.push("path".to_string());
        headers.extend(names.iter().cloned());
        let mut csv = Table::new(headers);
        for (r, features) in report.results.iter().zip(&per_case) {
            let have: std::collections::HashMap<&str, f64> =
                features.iter().map(|(n, v)| (n.as_str(), *v)).collect();
            let mut row = vec![r.case_id.clone()];
            if label_on {
                row.push(r.label.map(|l| l.to_string()).unwrap_or_default());
            }
            row.push(format!("{:?}", r.path));
            row.extend(names.iter().map(|n| match have.get(n.as_str()) {
                Some(v) => format!("{v}"),
                None => "NaN".to_string(),
            }));
            csv.row(row);
        }
        std::fs::write(&path, csv.to_csv())
            .with_context(|| format!("write {}", path.display()))?;
        eprintln!("wrote {}", path.display());
    }
    if !report.failures.is_empty() {
        bail!("{} case(s) failed", report.failures.len());
    }
    Ok(())
}

/// Cohort batch mode: isolate per-case failures, checkpoint every
/// finished case to a journal, and replay the content-addressed feature
/// cache. The full report (status/error columns + stored feature
/// strings) goes to --csv/--json; the terminal gets a summary table.
fn batch(args: &Args) -> Result<()> {
    let manifest = PathBuf::from(args.req("manifest")?);
    let cfg = load_config(args)?;
    let json_out = args.opt("json").map(PathBuf::from);
    let csv_out = args.opt("csv").map(PathBuf::from);
    let journal = args.opt("journal").map(PathBuf::from);
    let resume = args.flag("resume");
    args.finish()?;

    let trace_sink = cfg.trace_out.as_ref().map(|_| crate::trace::TraceSink::new());
    let session = trace_sink.clone().map(crate::trace::install);

    let extractor = FeatureExtractor::new(&cfg)?;
    let opts = BatchOptions {
        manifest,
        cache_dir: cfg.cache_dir.clone(),
        cache_max_bytes: cfg.cache_max_bytes,
        journal,
        resume,
    };
    let outcome = run_batch(&cfg, &extractor, &opts)?;
    drop(session);
    if let (Some(path), Some(sink)) = (cfg.trace_out.as_ref(), trace_sink.as_ref()) {
        sink.write(path)?;
        eprintln!("wrote {}", path.display());
    }
    if let Some(path) = cfg.metrics_out.as_ref() {
        outcome.metrics.write(path)?;
        eprintln!("wrote {}", path.display());
    }

    let mut t = Table::new(vec!["case", "label", "status", "error"]);
    for r in &outcome.rows {
        // errors can be long and multi-line; the full text lives in the
        // CSV/JSON reports, the terminal gets one readable line
        let flat = r.error.replace(['\n', '\r'], " ");
        let short: String = flat.chars().take(72).collect();
        t.row(vec![
            r.case_id.clone(),
            r.label.map(|l| l.to_string()).unwrap_or_default(),
            r.status.to_string(),
            short,
        ]);
    }
    print!("{}", t.to_text());
    eprintln!(
        "cohort: {} case(s): {} ok, {} failed | {} executed, {} from cache, {} from journal | wall {:.2}s",
        outcome.total,
        outcome.succeeded,
        outcome.failed,
        outcome.executed,
        outcome.from_cache,
        outcome.from_journal,
        outcome.wall.as_secs_f64()
    );

    if let Some(path) = json_out {
        let mut doc = JsonValue::obj();
        doc.set("schema", "radpipe.batch/1");
        let mut rows = Vec::new();
        for r in &outcome.rows {
            let mut o = JsonValue::obj();
            o.set("case", r.case_id.as_str());
            match r.label {
                Some(l) => o.set("label", l as usize),
                None => o.set("label", JsonValue::Null),
            };
            o.set("status", r.status);
            o.set("error", r.error.as_str());
            let mut f = JsonValue::obj();
            // values as their stored strings: NaN/inf survive, and the
            // document is byte-stable across cold/warm/resumed runs
            for (name, value) in &r.features {
                f.set(name, value.as_str());
            }
            o.set("features", f);
            rows.push(o);
        }
        doc.set("rows", JsonValue::Arr(rows));
        doc.set("total", outcome.total);
        doc.set("executed", outcome.executed);
        doc.set("from_cache", outcome.from_cache);
        doc.set("from_journal", outcome.from_journal);
        doc.set("succeeded", outcome.succeeded);
        doc.set("failed", outcome.failed);
        doc.set("metrics", outcome.metrics.to_json());
        std::fs::write(&path, doc.to_string())
            .with_context(|| format!("write {}", path.display()))?;
        eprintln!("wrote {}", path.display());
    }

    if let Some(path) = csv_out {
        std::fs::write(&path, outcome.to_csv())
            .with_context(|| format!("write {}", path.display()))?;
        eprintln!("wrote {}", path.display());
    }

    if outcome.failed > 0 {
        bail!("{} case(s) failed", outcome.failed);
    }
    Ok(())
}

/// The observability gate: validate a run's trace and/or metrics outputs
/// with the same parsers library consumers use, and require that the
/// named pipeline stages actually show up in both. CI runs this against
/// a fresh `extract --trace-out --metrics-out` so a refactor that stops
/// emitting spans (or drifts the schema) fails the build, not a later
/// debugging session.
fn obs_check(args: &Args) -> Result<()> {
    let trace_path = args.opt("trace").map(PathBuf::from);
    let metrics_path = args.opt("metrics").map(PathBuf::from);
    let stages: Vec<String> = args
        .opt("require-stages")
        .unwrap_or("read,preprocess,mesh,diameters")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    args.finish()?;
    anyhow::ensure!(
        trace_path.is_some() || metrics_path.is_some(),
        "obs-check needs --trace FILE and/or --metrics FILE"
    );

    if let Some(path) = &trace_path {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let trace = crate::trace::chrome::parse(&text)
            .with_context(|| format!("validating trace {}", path.display()))?;
        let names = trace.span_names();
        anyhow::ensure!(!names.is_empty(), "trace {} contains no spans", path.display());
        for s in &stages {
            let want = format!("stage.{s}");
            anyhow::ensure!(
                names.contains(want.as_str()),
                "trace {} has no '{want}' span (have: {names:?})",
                path.display()
            );
        }
        println!(
            "trace OK: {} spans, {} counter samples, {} named threads, {} cases",
            trace.spans().count(),
            trace.counters().count(),
            trace.thread_names().len(),
            trace.span_cases().len(),
        );
    }

    if let Some(path) = &metrics_path {
        let snap = crate::metrics::snapshot::MetricsSnapshot::read(path)?;
        for s in &stages {
            let want = format!("stage.{s}");
            let recorded = snap.timer(&want).map(|t| t.count).unwrap_or(0);
            anyhow::ensure!(
                recorded > 0,
                "metrics {} recorded no '{want}' samples",
                path.display()
            );
        }
        println!(
            "metrics OK: {} timers, {} counters ({})",
            snap.timers.len(),
            snap.counters.len(),
            crate::metrics::snapshot::SCHEMA,
        );
    }
    Ok(())
}

fn table2(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let opts = experiments::table2::Table2Options {
        artifact_dir: PathBuf::from(args.opt("artifacts").unwrap_or("artifacts")),
        cpu_only: args.flag("cpu-only"),
    };
    args.finish()?;
    let manifest = crate::io::scan_dataset(&data)?;
    let out = experiments::run_table2(&manifest, &opts)?;
    print!("{}", experiments::table2::to_table(&out.rows).to_text());
    // aggregate stage view straight from the metrics snapshot
    println!("stage totals across {} cases:", out.rows.len());
    for (stage, total) in experiments::table2::stage_totals(&out.metrics) {
        println!("  {stage}: {:.1} ms", total.as_secs_f64() * 1e3);
    }
    let share_min = out.rows.iter().map(|r| r.diam_share).fold(f64::INFINITY, f64::min);
    let share_max = out.rows.iter().map(|r| r.diam_share).fold(0.0, f64::max);
    println!(
        "diameter share of post-read CPU time: {:.1}%..{:.1}% (paper: 95.7%..99.9%)",
        share_min * 100.0,
        share_max * 100.0
    );
    Ok(())
}

fn fig1(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    let threads = args.opt_parse::<usize>("threads")?.unwrap_or(0);
    args.finish()?;
    let manifest = crate::io::scan_dataset(&data)?;
    let rows = experiments::run_fig1(&manifest, threads)?;
    print!("{}", experiments::fig1::to_table(&rows).to_text());
    println!("winners per device:");
    for (dev, strat) in experiments::fig1::winners(&rows) {
        println!("  {dev}: {}", strat.label());
    }
    Ok(())
}

fn fig2(args: &Args) -> Result<()> {
    let data = PathBuf::from(args.req("data")?);
    args.finish()?;
    let manifest = crate::io::scan_dataset(&data)?;
    let rows = experiments::run_fig2(&manifest)?;
    print!("{}", experiments::fig2::to_table(&rows).to_text());
    Ok(())
}

/// The perf gate: validate the current `BENCH_*.json` reports and compare
/// them section-by-section against the checked-in baselines. `--bless`
/// copies the current reports over the baselines instead (the refresh
/// flow); `--validate-only` stops after schema validation (CI uses it to
/// reject malformed reports regardless of timings).
fn bench_check(args: &Args) -> Result<()> {
    let current_dir = PathBuf::from(args.opt("current").unwrap_or("target/bench-reports"));
    let baseline_dir = PathBuf::from(args.opt("baselines").unwrap_or("bench/baselines"));
    let rel = parse_tolerance(args.opt("tolerance").unwrap_or("generous"))?;
    let min_abs_ms = args.opt_parse::<f64>("min-abs-ms")?.unwrap_or(5.0);
    anyhow::ensure!(
        min_abs_ms.is_finite() && min_abs_ms >= 0.0,
        "--min-abs-ms must be a non-negative finite number"
    );
    let bless = args.flag("bless");
    let validate_only = args.flag("validate-only");
    args.finish()?;

    let current = load_dir(&current_dir)?;
    println!("validated {} report(s) under {}", current.len(), current_dir.display());
    if validate_only {
        return Ok(());
    }
    if bless {
        std::fs::create_dir_all(&baseline_dir)
            .with_context(|| format!("creating {}", baseline_dir.display()))?;
        for (path, report) in &current {
            let dest = baseline_dir.join(path.file_name().expect("BENCH file name"));
            std::fs::copy(path, &dest).with_context(|| format!("bless {}", dest.display()))?;
            println!("blessed {} -> {}", report.name, dest.display());
        }
        return Ok(());
    }
    let tol = Tolerance { rel, min_abs_s: min_abs_ms / 1e3 };
    let baselines = load_dir(&baseline_dir)?;
    let mut failures = 0usize;
    for (_, base) in &baselines {
        let Some((_, cur)) = current.iter().find(|(_, c)| c.name == base.name) else {
            eprintln!("FAIL {}: current run produced no BENCH_{}.json", base.name, base.name);
            failures += 1;
            continue;
        };
        let result = compare(base, cur, tol);
        println!("== {} ==", base.name);
        print!("{}", result.table().to_text());
        failures += result.failures();
    }
    if failures > 0 {
        bail!("bench-check: {failures} regression(s) against {}", baseline_dir.display());
    }
    println!("bench-check: all baseline sections within {rel:.2}x");
    Ok(())
}

fn inspect(args: &Args) -> Result<()> {
    let mask_path = PathBuf::from(args.req("mask")?);
    args.finish()?;
    let cfg = PipelineConfig { backend: Backend::Cpu, cpu_threads: 1, ..Default::default() };
    let ex = FeatureExtractor::new(&cfg)?;
    let out = ex.execute(&mask_path)?;
    let mut t = Table::new(vec!["feature", "value"]);
    for (name, value) in out.features.named() {
        t.row(vec![name.to_string(), format!("{value:.6}")]);
    }
    t.row(vec!["VertexCount".to_string(), out.features.vertex_count.to_string()]);
    t.row(vec!["VoxelCount".to_string(), out.features.voxel_count.to_string()]);
    print!("{}", t.to_text());
    Ok(())
}

fn devices(args: &Args) -> Result<()> {
    args.finish()?;
    let mut t = Table::new(vec!["device", "class", "cores", "clock[GHz]", "peak[GFLOPs]", "mem[GB/s]", "eff"]);
    for p in gpu_profiles().iter().chain(cpu_profiles().iter()) {
        t.row(vec![
            p.name.to_string(),
            format!("{:?}", p.class),
            p.cores.to_string(),
            format!("{:.2}", p.clock_ghz),
            format!("{:.0}", p.peak_gflops()),
            format!("{:.0}", p.mem_bw_gbs),
            format!("{:.4}", p.efficiency),
        ]);
    }
    print!("{}", t.to_text());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn no_command_prints_usage() {
        dispatch(argv(&[])).unwrap();
    }

    #[test]
    fn version_and_devices_run() {
        dispatch(argv(&["version"])).unwrap();
        dispatch(argv(&["devices"])).unwrap();
    }

    #[test]
    fn gen_data_and_inspect_roundtrip() {
        let dir = std::env::temp_dir().join("radpipe_cli_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        assert!(dir.join("cases.txt").exists());
        let mask = dir.join("00009-2.rvol.gz");
        dispatch(argv(&["inspect", "--mask", mask.to_str().unwrap()])).unwrap();
    }

    #[test]
    fn unknown_flag_rejected() {
        let err = dispatch(argv(&["devices", "--wat"])).unwrap_err();
        assert!(err.to_string().contains("--wat"));
    }

    #[test]
    fn extract_computes_texture_classes_and_writes_reports() {
        let dir = std::env::temp_dir().join("radpipe_cli_texture_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        let json = dir.join("out.json");
        let csv = dir.join("out.csv");
        dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--features",
            "all",
            "--bin-count",
            "8",
            "--glcm-distances",
            "1,2",
            "--json",
            json.to_str().unwrap(),
            "--csv",
            csv.to_str().unwrap(),
        ]))
        .unwrap();
        let json_text = std::fs::read_to_string(&json).unwrap();
        assert!(json_text.contains("Glcm_Contrast"), "texture features in JSON");
        assert!(json_text.contains("Glrlm_RunPercentage"));
        assert!(json_text.contains("Glszm_ZoneEntropy"), "GLSZM features in JSON");
        assert!(json_text.contains("Gldm_DependenceEntropy"), "GLDM features in JSON");
        assert!(json_text.contains("Ngtdm_Coarseness"), "NGTDM features in JSON");
        assert!(json_text.contains("Entropy"), "first-order features in JSON");
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("case,path,MeshVolume"));
        assert!(csv_text.contains("Glcm_Autocorrelation"));
        assert!(csv_text.contains("Glszm_SmallAreaEmphasis"));
        assert!(csv_text.contains("Gldm_LargeDependenceEmphasis"));
        assert!(csv_text.contains("Ngtdm_Strength"));
        // bad knobs are clear errors
        assert!(dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--features", "bogus",
        ]))
        .is_err());
        assert!(dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--glcm-distances", "0",
        ]))
        .is_err());
        assert!(dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--gldm-alpha", "-1",
        ]))
        .is_err());
    }

    #[test]
    fn extract_runs_region_classes_only() {
        // glszm/gldm/ngtdm-only extraction end-to-end (the CI
        // texture-matrix job mirrors this against the example dataset)
        let dir = std::env::temp_dir().join("radpipe_cli_region_texture_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        let json = dir.join("out.json");
        dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--features",
            "glszm,gldm,ngtdm",
            "--bin-count",
            "8",
            "--gldm-alpha",
            "1",
            "--json",
            json.to_str().unwrap(),
        ]))
        .unwrap();
        let json_text = std::fs::read_to_string(&json).unwrap();
        assert!(json_text.contains("Glszm_ZonePercentage"));
        assert!(json_text.contains("Gldm_SmallDependenceEmphasis"));
        assert!(json_text.contains("Ngtdm_Busyness"));
        assert!(!json_text.contains("Glcm_"), "GLCM must stay disabled");
        assert!(!json_text.contains("Glrlm_"), "GLRLM must stay disabled");
    }

    #[test]
    fn extract_emits_filter_qualified_derived_features() {
        let dir = std::env::temp_dir().join("radpipe_cli_imgproc_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        let json = dir.join("out.json");
        let csv = dir.join("out.csv");
        dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--features",
            "all",
            "--image-types",
            "all",
            "--log-sigmas",
            "1.0,2.0",
            "--bin-count",
            "8",
            "--json",
            json.to_str().unwrap(),
            "--csv",
            csv.to_str().unwrap(),
        ]))
        .unwrap();
        let json_text = std::fs::read_to_string(&json).unwrap();
        // 11 derived images: original (plain names) + 2 LoG + 8 wavelet
        assert!(json_text.contains("\"Entropy\""), "original keeps plain names");
        assert!(json_text.contains("log-sigma-1-0-mm_firstorder_Mean"));
        assert!(json_text.contains("log-sigma-2-0-mm_glcm_Contrast"));
        assert!(json_text.contains("wavelet-LLL_firstorder_Mean"));
        assert!(json_text.contains("wavelet-HHH_glrlm_RunPercentage"));
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.contains("log-sigma-2-0-mm_firstorder_Entropy"));
        assert!(csv_text.contains("wavelet-LHH_glcm_Idn"));
        // bad knobs are clear errors
        for bad in [
            vec!["extract", "--data", dir.to_str().unwrap(), "--image-types", "xray"],
            vec!["extract", "--data", dir.to_str().unwrap(), "--log-sigmas", "0"],
            vec!["extract", "--data", dir.to_str().unwrap(), "--wavelet-levels", "0"],
            vec!["extract", "--data", dir.to_str().unwrap(), "--resampled-spacing", "-1"],
        ] {
            assert!(dispatch(argv(&bad)).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn wavelet_levels_are_validated_at_the_cli_boundary() {
        // 0 and > MAX_WAVELET_LEVELS are rejected here with a clear
        // located message — never silently clamped downstream
        let dir = std::env::temp_dir().join("radpipe_cli_wavelet_levels_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        for bad in ["0", "9"] {
            let err = dispatch(argv(&[
                "extract", "--data", dir.to_str().unwrap(), "--wavelet-levels", bad,
            ]))
            .unwrap_err();
            assert!(
                err.to_string().contains("--wavelet-levels"),
                "level {bad}: {err:#}"
            );
        }
        // the boundary of the valid range still works end-to-end
        dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--features",
            "firstorder",
            "--image-types",
            "wavelet",
            "--wavelet-levels",
            "2",
        ]))
        .unwrap();
    }

    #[test]
    fn intensity_extraction_requires_an_image_or_the_optin() {
        let dir = std::env::temp_dir().join("radpipe_cli_optin_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        // strip the image= keys: a mask-only dataset with intensity
        // classes and no opt-in must fail (per-case errors → non-zero exit)
        let cases = dir.join("cases.txt");
        let text = std::fs::read_to_string(&cases).unwrap();
        let stripped: String = text
            .lines()
            .map(|l| {
                let kept: Vec<&str> =
                    l.split_whitespace().filter(|t| !t.starts_with("image=")).collect();
                kept.join(" ") + "\n"
            })
            .collect();
        std::fs::write(&cases, stripped).unwrap();
        let err = dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--backend", "cpu",
            "--features", "firstorder",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("failed"), "{err:#}");
        // the documented opt-in restores the old stand-in behaviour
        dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--backend", "cpu",
            "--features", "firstorder", "--synthetic-image",
        ]))
        .unwrap();
        // shape-only extraction never needed an image in the first place
        dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--backend", "cpu",
        ]))
        .unwrap();
    }

    #[test]
    fn bench_check_blesses_then_gates_an_injected_regression() {
        use crate::bench::{BenchReport, Measurement};
        let dir = std::env::temp_dir().join("radpipe_cli_benchcheck_test");
        let _ = std::fs::remove_dir_all(&dir);
        let current = dir.join("current");
        let baselines = dir.join("baselines");
        let c = current.to_str().unwrap();
        let b = baselines.to_str().unwrap();

        let mut rep = BenchReport::new("bench_demo", true, 0.004, 1);
        rep.section("glcm/serial", Measurement::from_samples(&[0.25, 0.5])).bit_exact(true);
        rep.write(&current).unwrap();

        // no baselines yet: a plain check must fail, blessing must not
        assert!(dispatch(argv(&["bench-check", "--current", c, "--baselines", b])).is_err());
        dispatch(argv(&["bench-check", "--current", c, "--baselines", b, "--bless"])).unwrap();
        assert!(baselines.join("BENCH_bench_demo.json").exists());

        // the identical run passes even at the strict tolerance
        dispatch(argv(&[
            "bench-check", "--current", c, "--baselines", b, "--tolerance", "strict",
            "--min-abs-ms", "1",
        ]))
        .unwrap();

        // inject a regression (100x, far over the 50ms floor): gate trips
        let mut slow = BenchReport::new("bench_demo", true, 0.004, 1);
        slow.section("glcm/serial", Measurement::from_samples(&[25.0, 50.0])).bit_exact(true);
        slow.write(&current).unwrap();
        let err = dispatch(argv(&[
            "bench-check", "--current", c, "--baselines", b, "--tolerance", "generous",
            "--min-abs-ms", "50",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("regression"), "{err:#}");

        // losing the bit_exact flag also trips the gate, even when fast
        let mut flagless = BenchReport::new("bench_demo", true, 0.004, 1);
        flagless.section("glcm/serial", Measurement::from_samples(&[0.25, 0.5]));
        flagless.write(&current).unwrap();
        assert!(dispatch(argv(&["bench-check", "--current", c, "--baselines", b])).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_check_validate_only_rejects_schema_drift() {
        use crate::bench::{BenchReport, Measurement};
        let dir = std::env::temp_dir().join("radpipe_cli_benchcheck_schema_test");
        let _ = std::fs::remove_dir_all(&dir);
        let current = dir.join("current");
        let c = current.to_str().unwrap();

        let mut rep = BenchReport::new("bench_ok", true, 0.004, 1);
        rep.section("s", Measurement::single(0.01));
        rep.write(&current).unwrap();
        dispatch(argv(&["bench-check", "--current", c, "--validate-only"])).unwrap();

        let drifted = rep.to_json().to_string().replace("radpipe.bench/1", "radpipe.bench/9");
        std::fs::write(current.join("BENCH_bench_ok.json"), drifted).unwrap();
        let e = dispatch(argv(&["bench-check", "--current", c, "--validate-only"])).unwrap_err();
        assert!(format!("{e:#}").contains("schema"), "{e:#}");

        // bad knobs are clear errors
        assert!(dispatch(argv(&["bench-check", "--tolerance", "loose"])).is_err());
        assert!(dispatch(argv(&["bench-check", "--min-abs-ms", "-3"])).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn extract_writes_trace_and_metrics_and_obs_check_validates_them() {
        let dir = std::env::temp_dir().join("radpipe_cli_obs_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        let trace = dir.join("trace.json");
        let metrics = dir.join("metrics.json");
        let json = dir.join("out.json");
        dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--trace-out",
            trace.to_str().unwrap(),
            "--metrics-out",
            metrics.to_str().unwrap(),
            "--json",
            json.to_str().unwrap(),
        ]))
        .unwrap();
        // the gate accepts both outputs of a healthy run
        dispatch(argv(&[
            "obs-check",
            "--trace",
            trace.to_str().unwrap(),
            "--metrics",
            metrics.to_str().unwrap(),
        ]))
        .unwrap();
        // the trace names every pipeline stage (superset-tolerant: sibling
        // tests in this process may run pipelines while our session holds
        // the global tracer, adding their spans to the same sink)
        let parsed =
            crate::trace::chrome::parse(&std::fs::read_to_string(&trace).unwrap()).unwrap();
        let names = parsed.span_names();
        for want in ["stage.read", "stage.preprocess", "stage.mesh", "stage.diameters", "case"] {
            assert!(names.contains(want), "{want} missing from {names:?}");
        }
        // the JSON report embeds the schema-versioned snapshot
        let json_text = std::fs::read_to_string(&json).unwrap();
        assert!(json_text.contains("\"schema\":\"radpipe.metrics/1\""), "snapshot in report");
        // a required stage that never ran trips the gate
        let err = dispatch(argv(&[
            "obs-check",
            "--metrics",
            metrics.to_str().unwrap(),
            "--require-stages",
            "read,texture",
        ]))
        .unwrap_err();
        assert!(format!("{err:#}").contains("stage.texture"), "{err:#}");
        // so does a corrupt document
        std::fs::write(&metrics, "{}").unwrap();
        assert!(dispatch(argv(&[
            "obs-check", "--metrics", metrics.to_str().unwrap(),
        ]))
        .is_err());
        // with nothing to validate the gate refuses to vacuously pass
        assert!(dispatch(argv(&["obs-check"])).is_err());
    }

    #[test]
    fn extract_accepts_batching_flags() {
        let dir = std::env::temp_dir().join("radpipe_cli_batch_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--engine-count",
            "2",
            "--batch-size",
            "4",
            "--batch-linger-ms",
            "1",
        ]))
        .unwrap();
        let err = dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--batch-size", "nope",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("--batch-size"));
    }

    #[test]
    fn extract_labels_all_writes_per_label_rows_and_isolated_failures() {
        // mirrors the CI texture-matrix multilabel step: `--labels all` on
        // the multilabel fixture yields one row per (case, label), and the
        // deliberately-empty declared label 4 is the run's only failure
        let dir = std::env::temp_dir().join("radpipe_cli_multilabel_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.003", "--seed", "5",
            "--multilabel",
        ]))
        .unwrap();
        let json = dir.join("out.json");
        let csv = dir.join("out.csv");
        let err = dispatch(argv(&[
            "extract",
            "--data",
            dir.to_str().unwrap(),
            "--backend",
            "cpu",
            "--features",
            "shape,firstorder",
            "--labels",
            "all",
            "--json",
            json.to_str().unwrap(),
            "--csv",
            csv.to_str().unwrap(),
        ]))
        .unwrap_err();
        // reports are written before the per-label failure turns the exit
        // status — the CI step relies on exactly this
        assert!(err.to_string().contains("failed"), "{err:#}");
        let json_text = std::fs::read_to_string(&json).unwrap();
        assert!(json_text.contains("\"failures\":1"), "only the empty label fails");
        assert!(json_text.contains("\"label\":1"));
        assert!(json_text.contains("\"label\":2"));
        assert!(json_text.contains("\"label\":3"));
        assert!(!json_text.contains("\"label\":4"), "the empty label has no row");
        let csv_text = std::fs::read_to_string(&csv).unwrap();
        assert!(csv_text.starts_with("case,label,path,MeshVolume"), "{csv_text}");
        // 3 cases × 3 populated labels + header
        assert_eq!(csv_text.lines().count(), 10, "{csv_text}");
    }

    #[test]
    fn extract_accepts_slab_and_budget_flags() {
        let dir = std::env::temp_dir().join("radpipe_cli_slab_budget_test");
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--backend", "cpu",
            "--slab-io", "--memory-budget", "64M",
        ]))
        .unwrap();
        // slab IO and resampling are mutually exclusive: caught at the
        // CLI boundary by cfg.validate(), not deep in a worker
        let err = dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--backend", "cpu",
            "--slab-io", "--resampled-spacing", "1.5",
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("slab_io"), "{err:#}");
        // bad knobs are clear errors
        assert!(dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--memory-budget", "wat",
        ]))
        .is_err());
        assert!(dispatch(argv(&[
            "extract", "--data", dir.to_str().unwrap(), "--labels", "0",
        ]))
        .is_err());
    }

    /// Generate a small dataset and derive a cohort CSV from its
    /// `cases.txt`, returning (dataset dir, cohort manifest path).
    fn cohort_fixture(tag: &str) -> (PathBuf, PathBuf) {
        let dir = std::env::temp_dir().join(format!("radpipe_cli_batch_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dispatch(argv(&[
            "gen-data", "--out", dir.to_str().unwrap(), "--scale", "0.002", "--seed", "3",
        ]))
        .unwrap();
        let m = crate::io::scan_dataset(&dir).unwrap();
        let mut csv = String::from("case_id,mask\n");
        for e in &m.cases {
            csv.push_str(&format!("{},{}\n", e.case_id, e.mask.display()));
        }
        let manifest = dir.join("cohort.csv");
        std::fs::write(&manifest, csv).unwrap();
        (dir, manifest)
    }

    #[test]
    fn batch_cold_then_warm_runs_are_byte_identical_with_zero_extractions() {
        let (dir, manifest) = cohort_fixture("warm");
        let cache = dir.join("cache");
        let csv1 = dir.join("b1.csv");
        let csv2 = dir.join("b2.csv");
        let metrics2 = dir.join("m2.json");
        let base = [
            "batch", "--manifest", manifest.to_str().unwrap(),
            "--backend", "cpu",
            "--cache-dir", cache.to_str().unwrap(),
        ];
        let mut cold: Vec<&str> = base.to_vec();
        cold.extend(["--csv", csv1.to_str().unwrap()]);
        dispatch(argv(&cold)).unwrap();
        let mut warm: Vec<&str> = base.to_vec();
        warm.extend([
            "--csv", csv2.to_str().unwrap(),
            "--metrics-out", metrics2.to_str().unwrap(),
        ]);
        dispatch(argv(&warm)).unwrap();
        assert_eq!(
            std::fs::read(&csv1).unwrap(),
            std::fs::read(&csv2).unwrap(),
            "warm-cache report must be byte-identical to the cold run"
        );
        let snap =
            crate::metrics::snapshot::MetricsSnapshot::read(&metrics2).unwrap();
        assert_eq!(snap.counter("batch.executed"), Some(0), "warm run extracts nothing");
        assert_eq!(snap.counter("cache.hit"), snap.counter("batch.succeeded"));
        assert_eq!(snap.counter("cache.miss"), Some(0));
    }

    #[test]
    fn batch_isolates_a_poisoned_case_and_exits_nonzero() {
        let (dir, manifest) = cohort_fixture("poison");
        // poison one case: its mask path points at garbage bytes
        let bad = dir.join("garbage.rvol.gz");
        std::fs::write(&bad, b"this is not a volume").unwrap();
        let mut text = std::fs::read_to_string(&manifest).unwrap();
        text.push_str("poisoned,garbage.rvol.gz\n");
        std::fs::write(&manifest, text).unwrap();
        let csv = dir.join("b.csv");
        let err = dispatch(argv(&[
            "batch", "--manifest", manifest.to_str().unwrap(),
            "--backend", "cpu",
            "--csv", csv.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(err.to_string().contains("1 case(s) failed"), "{err:#}");
        // the report still carries every healthy case plus the failed row
        let text = std::fs::read_to_string(&csv).unwrap();
        let failed: Vec<&str> =
            text.lines().filter(|l| l.starts_with("poisoned,")).collect();
        assert_eq!(failed.len(), 1, "{text}");
        assert!(failed[0].contains("failed"), "{failed:?}");
        assert!(
            text.lines().filter(|l| l.contains(",ok,")).count() >= 1,
            "healthy cases still extract: {text}"
        );
    }

    #[test]
    fn batch_resume_skips_journaled_cases() {
        let (dir, manifest) = cohort_fixture("resume");
        let journal = dir.join("run.journal");
        let m1 = dir.join("m1.json");
        dispatch(argv(&[
            "batch", "--manifest", manifest.to_str().unwrap(),
            "--backend", "cpu",
            "--journal", journal.to_str().unwrap(),
            "--metrics-out", m1.to_str().unwrap(),
        ]))
        .unwrap();
        let total = crate::metrics::snapshot::MetricsSnapshot::read(&m1)
            .unwrap()
            .counter("batch.cases")
            .unwrap();
        assert!(total > 0);
        // resume right after a completed run: nothing left to execute
        let m2 = dir.join("m2.json");
        dispatch(argv(&[
            "batch", "--manifest", manifest.to_str().unwrap(),
            "--backend", "cpu",
            "--journal", journal.to_str().unwrap(),
            "--resume",
            "--metrics-out", m2.to_str().unwrap(),
        ]))
        .unwrap();
        let snap = crate::metrics::snapshot::MetricsSnapshot::read(&m2).unwrap();
        assert_eq!(snap.counter("batch.executed"), Some(0));
        assert_eq!(snap.counter("batch.from_journal"), Some(total));
    }

    #[test]
    fn batch_rejects_bad_knobs_and_manifests() {
        let dir = std::env::temp_dir().join("radpipe_cli_batch_bad");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = dir.join("cohort.csv");
        std::fs::write(&manifest, "case_id,mask\na,m.rvol\n").unwrap();
        // u64-overflow byte size is a parse error, not a wrapped number
        let err = dispatch(argv(&[
            "batch", "--manifest", manifest.to_str().unwrap(),
            "--cache-max-bytes", "18446744073709551G",
        ]))
        .unwrap_err();
        assert!(format!("{err:#}").contains("--cache-max-bytes"), "{err:#}");
        // a manifest without the required columns is a located error
        std::fs::write(&manifest, "id,volume\na,m.rvol\n").unwrap();
        let err = dispatch(argv(&[
            "batch", "--manifest", manifest.to_str().unwrap(),
        ]))
        .unwrap_err();
        assert!(format!("{err:#}").contains("case_id column"), "{err:#}");
    }
}
