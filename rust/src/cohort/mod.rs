//! Cohort batch mode: `radpipe batch --manifest cohort.csv`.
//!
//! A cohort run is the HPC front-end over the streaming pipeline:
//!
//! * **Manifests** ([`manifest`]) — CSV rows of
//!   `(case_id, mask[, image][, labels])`, RFC-4180 quoted so hostile
//!   case ids round-trip.
//! * **Failure isolation** — a case that cannot be read or extracted
//!   becomes `status=failed` rows in the batch report; the run finishes
//!   the rest of the cohort.
//! * **Checkpoint/resume** ([`journal`]) — every finished case is
//!   appended to a journal the moment its outcome reaches the sink;
//!   `--resume` replays intact entries and re-executes only the rest.
//! * **Content-addressed cache** ([`cache`]) — feature rows keyed by
//!   SHA-256 of (config, mask bytes, image bytes, labels); a warm run
//!   replays stored rows bit-for-bit with zero extractions.
//!
//! Bit-identical replay is the load-bearing property: feature values are
//! stored as their Rust `Display` strings (shortest round-trip, and
//! `NaN`/`inf` survive where JSON numbers cannot), and the batch CSV is
//! assembled from those stored strings on every path — cold, warm and
//! resumed runs of the same cohort produce byte-identical reports.

pub mod cache;
pub mod journal;
pub mod manifest;
pub mod sha256;

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::PipelineConfig;
use crate::dispatch::FeatureExtractor;
use crate::io::{CaseEntry, DatasetManifest};
use crate::metrics::snapshot::MetricsSnapshot;
use crate::metrics::Metrics;
use crate::pipeline::{case_named_features, run_pipeline_with, CaseOutcome, CaseResult};
use crate::report::{JsonValue, Table};

pub use cache::{canonical_config, FeatureCache};
pub use journal::{Journal, JournalEntry};
pub use manifest::{load_cohort, parse_cohort_csv, CohortCase, CohortManifest};

/// One feature row as persisted by the journal and the cache: the label
/// it belongs to (`None` on the binary-mask path) and every feature as a
/// `(name, value-string)` pair.
///
/// Values are stored as Rust `Display` strings rather than JSON numbers:
/// `Display` for `f64` is shortest-round-trip (parsing the string yields
/// the exact same bits), and it can represent `NaN`/`inf`/`-inf`, which
/// a JSON number cannot. The batch CSV prints these strings verbatim, so
/// a replayed case is byte-identical to its original extraction.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredRow {
    pub label: Option<u16>,
    pub features: Vec<(String, String)>,
}

impl StoredRow {
    pub fn from_result(r: &CaseResult) -> StoredRow {
        StoredRow {
            label: r.label,
            features: case_named_features(r)
                .into_iter()
                .map(|(n, v)| (n, format!("{v}")))
                .collect(),
        }
    }

    pub fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        match self.label {
            Some(l) => o.set("label", l as usize),
            None => o.set("label", JsonValue::Null),
        };
        o.set(
            "features",
            JsonValue::Arr(
                self.features
                    .iter()
                    .map(|(n, v)| {
                        JsonValue::Arr(vec![
                            JsonValue::Str(n.clone()),
                            JsonValue::Str(v.clone()),
                        ])
                    })
                    .collect(),
            ),
        );
        o
    }

    pub fn from_json(v: &JsonValue) -> Result<StoredRow> {
        let label = match v.get("label") {
            None | Some(JsonValue::Null) => None,
            Some(l) => {
                let n = l.as_f64().context("stored row label is not a number")?;
                if n < 0.0 || n > f64::from(u16::MAX) || n.fract() != 0.0 {
                    anyhow::bail!("stored row label {n} is not a u16");
                }
                Some(n as u16)
            }
        };
        let features = v
            .get("features")
            .and_then(JsonValue::as_arr)
            .context("stored row has no features array")?
            .iter()
            .map(|pair| {
                let pair = pair.as_arr().context("feature entry is not a pair")?;
                match pair {
                    [n, val] => Ok((
                        n.as_str().context("feature name is not a string")?.to_string(),
                        val.as_str().context("feature value is not a string")?.to_string(),
                    )),
                    _ => anyhow::bail!("feature entry is not a [name, value] pair"),
                }
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(StoredRow { label, features })
    }
}

/// Knobs of one `radpipe batch` invocation.
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Cohort CSV manifest path.
    pub manifest: PathBuf,
    /// Feature cache directory; `None` disables the cache.
    pub cache_dir: Option<PathBuf>,
    /// Cache size bound for oldest-first eviction; 0 = unbounded.
    pub cache_max_bytes: u64,
    /// Journal path; defaults to `<manifest>.journal`.
    pub journal: Option<PathBuf>,
    /// Replay intact journal entries and execute only the remainder.
    pub resume: bool,
}

/// One row of the batch report: `status` is `"ok"` (a feature row) or
/// `"failed"` (an error row whose message sits in `error`).
#[derive(Debug, Clone, PartialEq)]
pub struct BatchRow {
    pub case_id: String,
    pub label: Option<u16>,
    pub status: &'static str,
    pub error: String,
    pub features: Vec<(String, String)>,
}

/// Outcome of a batch run: report rows in cohort-manifest order plus the
/// merged metrics snapshot and provenance tallies.
#[derive(Debug)]
pub struct BatchOutcome {
    pub rows: Vec<BatchRow>,
    /// Pipeline metrics merged with the cohort-level counters/timers
    /// (`cache.hit`, `cache.miss`, `stage.cache`, `batch.*`).
    pub metrics: MetricsSnapshot,
    /// Cohort size.
    pub total: usize,
    /// Cases actually run through the pipeline.
    pub executed: usize,
    /// Cases replayed from the feature cache.
    pub from_cache: usize,
    /// Cases replayed from the journal (`--resume`).
    pub from_journal: usize,
    pub succeeded: usize,
    pub failed: usize,
    pub wall: Duration,
}

impl BatchOutcome {
    /// The batch CSV: `case,label,status,error` plus the union of feature
    /// names in first-seen order. Cells are the stored value strings, so
    /// cold, warm and resumed runs of one cohort emit identical bytes
    /// (the RFC-4180 writer quotes hostile case ids and error text).
    pub fn to_csv(&self) -> String {
        let mut names: Vec<String> = Vec::new();
        let mut seen: HashSet<&str> = HashSet::new();
        for r in &self.rows {
            for (n, _) in &r.features {
                if seen.insert(n.as_str()) {
                    names.push(n.clone());
                }
            }
        }
        let mut headers = vec![
            "case".to_string(),
            "label".to_string(),
            "status".to_string(),
            "error".to_string(),
        ];
        headers.extend(names.iter().cloned());
        let mut t = Table::new(headers);
        for r in &self.rows {
            let mut cells = vec![
                r.case_id.clone(),
                r.label.map(|l| l.to_string()).unwrap_or_default(),
                r.status.to_string(),
                r.error.clone(),
            ];
            let by_name: HashMap<&str, &str> =
                r.features.iter().map(|(n, v)| (n.as_str(), v.as_str())).collect();
            for n in &names {
                cells.push(by_name.get(n.as_str()).map(|v| v.to_string()).unwrap_or_default());
            }
            t.row(cells);
        }
        t.to_csv()
    }
}

/// `<manifest>.journal`, next to the manifest.
fn default_journal_path(manifest: &std::path::Path) -> PathBuf {
    let mut os = manifest.as_os_str().to_os_string();
    os.push(".journal");
    PathBuf::from(os)
}

/// Run a cohort. See the module docs for the journal/cache contract.
pub fn run_batch(
    cfg: &PipelineConfig,
    extractor: &FeatureExtractor,
    opts: &BatchOptions,
) -> Result<BatchOutcome> {
    let start = Instant::now();
    let cohort = manifest::load_cohort(&opts.manifest)?;
    let metrics = Metrics::new();
    let journal_path = opts
        .journal
        .clone()
        .unwrap_or_else(|| default_journal_path(&opts.manifest));

    // 1. resume: replay intact journal entries for cases this cohort knows
    let mut done: BTreeMap<String, JournalEntry> = BTreeMap::new();
    if opts.resume {
        let known: HashSet<&str> = cohort.cases.iter().map(|c| c.case_id.as_str()).collect();
        for entry in Journal::load(&journal_path)
            .with_context(|| format!("resume from {}", journal_path.display()))?
        {
            if known.contains(entry.case_id.as_str()) {
                // later entries win (a case journaled twice keeps its newest outcome)
                done.insert(entry.case_id.clone(), entry);
            }
        }
    }
    let from_journal = done.len();
    metrics.set_counter("journal.replayed", from_journal as u64);
    let mut journal = if opts.resume {
        Journal::append_to(&journal_path)?
    } else {
        Journal::create(&journal_path)?
    };

    // 2. cache probe: replay hits, remember keys for post-run stores
    let cache = match &opts.cache_dir {
        Some(dir) => Some(FeatureCache::open(dir, opts.cache_max_bytes)?),
        None => None,
    };
    let canon = canonical_config(cfg);
    let mut keys: HashMap<String, String> = HashMap::new();
    let mut from_cache = 0usize;
    if let Some(cache) = &cache {
        let _sp = crate::trace::span("stage.cache");
        let timer = metrics.timer("stage.cache");
        for case in &cohort.cases {
            if done.contains_key(&case.case_id) {
                continue;
            }
            let t0 = Instant::now();
            match cache.case_key(&canon, case, &cohort.root) {
                Ok(key) => {
                    if let Some(rows) = cache.lookup(&key) {
                        metrics.counter("cache.hit").fetch_add(1, Ordering::Relaxed);
                        let entry = JournalEntry {
                            case_id: case.case_id.clone(),
                            rows,
                            failures: Vec::new(),
                        };
                        journal.append(&entry)?;
                        done.insert(case.case_id.clone(), entry);
                        from_cache += 1;
                    } else {
                        metrics.counter("cache.miss").fetch_add(1, Ordering::Relaxed);
                        keys.insert(case.case_id.clone(), key);
                    }
                }
                // an unreadable input cannot be keyed; count a miss and let
                // the pipeline's read stage report the real failure
                Err(_) => {
                    metrics.counter("cache.miss").fetch_add(1, Ordering::Relaxed);
                }
            }
            timer.record(t0.elapsed());
        }
    }

    // 3. run the remainder through the pipeline, journaling + caching each
    // case the moment its outcome reaches the sink
    let to_run: Vec<&CohortCase> =
        cohort.cases.iter().filter(|c| !done.contains_key(&c.case_id)).collect();
    let executed_count = to_run.len();
    let mut executed: BTreeMap<String, JournalEntry> = BTreeMap::new();
    let mut pipeline_metrics = MetricsSnapshot::default();
    let mut journal_err: Option<anyhow::Error> = None;
    if !to_run.is_empty() {
        let ds = DatasetManifest {
            root: cohort.root.clone(),
            cases: to_run
                .iter()
                .map(|c| CaseEntry {
                    case_id: c.case_id.clone(),
                    mask: c.mask.clone(),
                    image: c.image.clone(),
                    dims: None,
                    target_vertices: 0,
                    labels: c.labels.clone(),
                })
                .collect(),
        };
        let report = run_pipeline_with(&ds, cfg, extractor, &mut |o: &CaseOutcome| {
            let entry = JournalEntry {
                case_id: o.case_id.clone(),
                rows: o.rows.iter().map(StoredRow::from_result).collect(),
                failures: o.failures.iter().map(|(_, msg)| msg.clone()).collect(),
            };
            if let Err(e) = journal.append(&entry) {
                // keep extracting — losing the checkpoint is not worth
                // losing the cohort — but surface the first error afterwards
                if journal_err.is_none() {
                    journal_err = Some(e);
                }
            }
            if entry.is_success() {
                if let Some(cache) = &cache {
                    if let Some(key) = keys.get(&entry.case_id) {
                        let t0 = Instant::now();
                        if cache.store(key, &entry.case_id, &entry.rows).is_err() {
                            metrics.counter("cache.write_errors").fetch_add(1, Ordering::Relaxed);
                        }
                        metrics.timer("stage.cache").record(t0.elapsed());
                    }
                }
            }
            executed.insert(entry.case_id.clone(), entry);
        })?;
        pipeline_metrics = report.metrics;
    }
    if let Some(e) = journal_err {
        return Err(e).with_context(|| {
            format!("batch journal {} failed mid-run", journal_path.display())
        });
    }

    // 4. assemble the report in cohort-manifest order; rows within a case
    // sorted by label so every path (cold / cached / resumed) agrees
    let mut rows: Vec<BatchRow> = Vec::new();
    let mut succeeded = 0usize;
    let mut failed = 0usize;
    for case in &cohort.cases {
        let entry = done.get(&case.case_id).or_else(|| executed.get(&case.case_id));
        let Some(entry) = entry else {
            // the pipeline contract is one outcome per case; this is a
            // defensive row, not an expected path
            failed += 1;
            rows.push(BatchRow {
                case_id: case.case_id.clone(),
                label: None,
                status: "failed",
                error: "case produced no outcome (internal error)".to_string(),
                features: Vec::new(),
            });
            continue;
        };
        if entry.is_success() {
            succeeded += 1;
        } else {
            failed += 1;
        }
        let mut case_rows: Vec<&StoredRow> = entry.rows.iter().collect();
        case_rows.sort_by_key(|r| r.label);
        for r in case_rows {
            rows.push(BatchRow {
                case_id: case.case_id.clone(),
                label: r.label,
                status: "ok",
                error: String::new(),
                features: r.features.clone(),
            });
        }
        for msg in &entry.failures {
            rows.push(BatchRow {
                case_id: case.case_id.clone(),
                label: None,
                status: "failed",
                error: msg.clone(),
                features: Vec::new(),
            });
        }
        if entry.rows.is_empty() && entry.failures.is_empty() {
            rows.push(BatchRow {
                case_id: case.case_id.clone(),
                label: None,
                status: "failed",
                error: "no rows and no failures recorded (internal error)".to_string(),
                features: Vec::new(),
            });
        }
    }

    // 5. merge cohort-level metrics into the pipeline snapshot
    let mut snap = pipeline_metrics;
    let cohort_snap = metrics.snapshot();
    for (k, v) in cohort_snap.counters {
        *snap.counters.entry(k).or_insert(0) += v;
    }
    for (k, v) in cohort_snap.timers {
        snap.timers.insert(k, v);
    }
    snap.counters.insert("batch.cases".to_string(), cohort.cases.len() as u64);
    snap.counters.insert("batch.executed".to_string(), executed_count as u64);
    snap.counters.insert("batch.from_cache".to_string(), from_cache as u64);
    snap.counters.insert("batch.from_journal".to_string(), from_journal as u64);
    snap.counters.insert("batch.succeeded".to_string(), succeeded as u64);
    snap.counters.insert("batch.failed".to_string(), failed as u64);

    Ok(BatchOutcome {
        rows,
        metrics: snap,
        total: cohort.cases.len(),
        executed: executed_count,
        from_cache,
        from_journal,
        succeeded,
        failed,
        wall: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stored_row_round_trips_non_finite_values() {
        let r = StoredRow {
            label: None,
            features: vec![
                ("a".into(), "NaN".into()),
                ("b".into(), "inf".into()),
                ("c".into(), "-inf".into()),
                ("d".into(), "0.30000000000000004".into()),
            ],
        };
        let back = StoredRow::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // the stored strings parse back to the exact f64s Display printed
        assert!(back.features[0].1.parse::<f64>().unwrap().is_nan());
        assert_eq!(back.features[1].1.parse::<f64>().unwrap(), f64::INFINITY);
        assert_eq!(back.features[3].1.parse::<f64>().unwrap(), 0.1 + 0.2);
    }

    #[test]
    fn stored_row_label_round_trips_and_rejects_garbage() {
        let r = StoredRow { label: Some(65535), features: Vec::new() };
        assert_eq!(StoredRow::from_json(&r.to_json()).unwrap(), r);
        let bad = JsonValue::parse(r#"{"label": 70000, "features": []}"#).unwrap();
        assert!(StoredRow::from_json(&bad).is_err());
        let bad = JsonValue::parse(r#"{"label": 1.5, "features": []}"#).unwrap();
        assert!(StoredRow::from_json(&bad).is_err());
    }

    #[test]
    fn batch_csv_takes_the_feature_name_union_and_quotes_hostile_cells() {
        let outcome = BatchOutcome {
            rows: vec![
                BatchRow {
                    case_id: "plain".into(),
                    label: Some(1),
                    status: "ok",
                    error: String::new(),
                    features: vec![("f1".into(), "1".into()), ("f2".into(), "2".into())],
                },
                BatchRow {
                    case_id: "evil,case\n\"2\"".into(),
                    label: None,
                    status: "failed",
                    error: "read: mask \"m\" is, sadly,\nmissing".into(),
                    features: Vec::new(),
                },
                BatchRow {
                    case_id: "third".into(),
                    label: None,
                    status: "ok",
                    error: String::new(),
                    features: vec![("f3".into(), "3".into()), ("f1".into(), "9".into())],
                },
            ],
            metrics: MetricsSnapshot::default(),
            total: 3,
            executed: 3,
            from_cache: 0,
            from_journal: 0,
            succeeded: 2,
            failed: 1,
            wall: Duration::ZERO,
        };
        let csv = outcome.to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "case,label,status,error,f1,f2,f3");
        // the hostile row survives a parse through the cohort CSV reader
        // (rename columns so the strict parser maps case→case_id and the
        // always-non-empty status→mask)
        let header_and_rows =
            parse_cohort_csv(&csv.replace("case,label,status", "case_id,x,mask")).unwrap();
        assert_eq!(header_and_rows[1].case_id, "evil,case\n\"2\"");
        // absent features are empty cells, present ones keep their strings
        assert!(csv.contains("third,,ok,,9,,3"));
    }

    #[test]
    fn default_journal_path_sits_next_to_the_manifest() {
        assert_eq!(
            default_journal_path(std::path::Path::new("runs/cohort.csv")),
            PathBuf::from("runs/cohort.csv.journal")
        );
    }
}
