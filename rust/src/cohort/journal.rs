//! Append-only batch journal: one JSON line per finished case.
//!
//! `radpipe batch` appends an entry the moment a case's outcome reaches
//! the sink, so a killed run (OOM, SIGKILL, node eviction) loses at most
//! the in-flight cases. `--resume` replays the journal and re-executes
//! only cases with no entry. A kill can truncate the final line mid-write;
//! [`Journal::load`] therefore stops at the first unparseable line — a
//! killed run can only corrupt the tail, and everything before it is
//! intact by construction (each entry is flushed before the next starts).

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::report::JsonValue;

use super::StoredRow;

/// Journal line schema tag; bump on incompatible layout changes so a
/// resume never misreads an old journal.
pub const SCHEMA: &str = "radpipe.journal/1";

/// One finished case: either its feature rows or its failure messages
/// (a label-map case can have both — some labels extracted, some failed).
#[derive(Debug, Clone, PartialEq)]
pub struct JournalEntry {
    pub case_id: String,
    pub rows: Vec<StoredRow>,
    pub failures: Vec<String>,
}

impl JournalEntry {
    pub fn is_success(&self) -> bool {
        self.failures.is_empty() && !self.rows.is_empty()
    }

    pub fn to_json_line(&self) -> String {
        let mut doc = JsonValue::obj();
        doc.set("schema", SCHEMA);
        doc.set("case", self.case_id.as_str());
        doc.set("status", if self.is_success() { "ok" } else { "failed" });
        doc.set(
            "rows",
            self.rows.iter().map(StoredRow::to_json).collect::<Vec<_>>(),
        );
        doc.set(
            "failures",
            self.failures.iter().map(String::as_str).collect::<Vec<_>>(),
        );
        // JsonValue renders single-line (newlines in strings are escaped),
        // so one entry is always exactly one journal line
        doc.to_string()
    }

    pub fn from_json_line(line: &str) -> Result<JournalEntry> {
        let doc = JsonValue::parse(line).context("journal line is not valid JSON")?;
        match doc.get("schema").and_then(JsonValue::as_str) {
            Some(s) if s == SCHEMA => {}
            other => bail!(
                "journal line has schema {:?}, this build reads {SCHEMA:?}",
                other
            ),
        }
        let case_id = doc
            .get("case")
            .and_then(JsonValue::as_str)
            .context("journal line has no case id")?
            .to_string();
        let rows = doc
            .get("rows")
            .and_then(JsonValue::as_arr)
            .context("journal line has no rows array")?
            .iter()
            .map(StoredRow::from_json)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("journal entry for case '{case_id}'"))?;
        let failures = doc
            .get("failures")
            .and_then(JsonValue::as_arr)
            .context("journal line has no failures array")?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(str::to_string)
                    .context("journal failure message is not a string")
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(JournalEntry { case_id, rows, failures })
    }
}

/// Open journal handle; every [`Journal::append`] is flushed before it
/// returns so the entry survives a kill of this process.
pub struct Journal {
    file: File,
}

impl Journal {
    /// Start a fresh journal (truncates any previous one).
    pub fn create(path: &Path) -> Result<Journal> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create journal directory {}", parent.display()))?;
        }
        let file = File::create(path)
            .with_context(|| format!("create journal {}", path.display()))?;
        Ok(Journal { file })
    }

    /// Continue an existing journal (creates it if absent) — the resume
    /// path, where replayed entries must be preserved.
    pub fn append_to(path: &Path) -> Result<Journal> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("create journal directory {}", parent.display()))?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("open journal {}", path.display()))?;
        Ok(Journal { file })
    }

    pub fn append(&mut self, entry: &JournalEntry) -> Result<()> {
        let mut line = entry.to_json_line();
        line.push('\n');
        self.file
            .write_all(line.as_bytes())
            .and_then(|()| self.file.flush())
            .with_context(|| format!("append journal entry for case '{}'", entry.case_id))
    }

    /// Load every intact entry; a missing journal is an empty one. Parsing
    /// stops silently at the first damaged line (the truncated tail of a
    /// killed run) — those cases simply re-execute.
    pub fn load(path: &Path) -> Result<Vec<JournalEntry>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => {
                return Err(e).with_context(|| format!("read journal {}", path.display()))
            }
        };
        let mut entries = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match JournalEntry::from_json_line(line) {
                Ok(entry) => entries.push(entry),
                Err(_) => break,
            }
        }
        Ok(entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: &str) -> JournalEntry {
        JournalEntry {
            case_id: id.to_string(),
            rows: vec![StoredRow {
                label: Some(3),
                features: vec![
                    ("firstorder_Mean".into(), "12.5".into()),
                    ("firstorder_Skewness".into(), "NaN".into()),
                ],
            }],
            failures: Vec::new(),
        }
    }

    #[test]
    fn entry_round_trips_through_its_json_line() {
        let e = JournalEntry {
            case_id: "weird \"id\"\nwith newline".to_string(),
            rows: vec![
                StoredRow { label: None, features: vec![("shape_Volume".into(), "1e-300".into())] },
                StoredRow { label: Some(65535), features: Vec::new() },
            ],
            failures: vec!["read: no such file".to_string()],
        };
        let line = e.to_json_line();
        assert!(!line.contains('\n'), "an entry must be a single line: {line:?}");
        assert_eq!(JournalEntry::from_json_line(&line).unwrap(), e);
        assert!(!e.is_success(), "failures present → not a success");
        assert!(entry("x").is_success());
    }

    #[test]
    fn empty_rows_and_no_failures_is_not_a_success() {
        let e = JournalEntry { case_id: "e".into(), rows: Vec::new(), failures: Vec::new() };
        assert!(!e.is_success(), "no rows means nothing was extracted");
    }

    #[test]
    fn wrong_schema_is_rejected() {
        let line = entry("a").to_json_line().replace(SCHEMA, "radpipe.journal/999");
        let err = JournalEntry::from_json_line(&line).unwrap_err();
        assert!(format!("{err:#}").contains("schema"), "{err:#}");
    }

    #[test]
    fn append_then_load_round_trips() {
        let dir = std::env::temp_dir().join("radpipe_journal_test_rt");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("run.journal");
        let mut j = Journal::create(&path).unwrap();
        j.append(&entry("a")).unwrap();
        j.append(&entry("b")).unwrap();
        drop(j);
        // resume-style reopen appends, not truncates
        let mut j = Journal::append_to(&path).unwrap();
        j.append(&entry("c")).unwrap();
        drop(j);
        let got = Journal::load(&path).unwrap();
        assert_eq!(
            got.iter().map(|e| e.case_id.as_str()).collect::<Vec<_>>(),
            ["a", "b", "c"]
        );
        assert_eq!(got[0], entry("a"));
    }

    #[test]
    fn missing_journal_loads_empty() {
        let path = std::env::temp_dir().join("radpipe_journal_test_missing.journal");
        let _ = std::fs::remove_file(&path);
        assert!(Journal::load(&path).unwrap().is_empty());
    }

    #[test]
    fn truncated_tail_is_dropped_but_the_prefix_survives() {
        let dir = std::env::temp_dir().join("radpipe_journal_test_trunc");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("run.journal");
        let mut j = Journal::create(&path).unwrap();
        j.append(&entry("a")).unwrap();
        j.append(&entry("b")).unwrap();
        drop(j);
        // simulate a kill mid-write: chop the last line in half
        let text = std::fs::read_to_string(&path).unwrap();
        let cut = text.len() - 20;
        std::fs::write(&path, &text[..cut]).unwrap();
        let got = Journal::load(&path).unwrap();
        assert_eq!(got.len(), 1, "only the intact prefix survives");
        assert_eq!(got[0].case_id, "a");
    }

    #[test]
    fn create_truncates_a_previous_journal() {
        let dir = std::env::temp_dir().join("radpipe_journal_test_fresh");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("run.journal");
        let mut j = Journal::create(&path).unwrap();
        j.append(&entry("old")).unwrap();
        drop(j);
        let j = Journal::create(&path).unwrap();
        drop(j);
        assert!(Journal::load(&path).unwrap().is_empty());
    }
}
