//! Content-addressed feature cache.
//!
//! A cache entry is keyed by SHA-256 over (schema tag, canonicalized
//! config, mask file bytes, image file bytes, label selection) — the
//! complete set of inputs that determine feature values. Parallelism
//! knobs (threads, strategy, backend, slab vs whole-grid reads, queue
//! sizes) are deliberately **excluded**: the pipeline's determinism
//! contract guarantees bit-identical features across all of them, so a
//! cohort hashed on a laptop hits the cache on a 64-core node.
//!
//! Entries are JSON files under `<dir>/<key[..2]>/<key>.json`, written
//! via tmp-file + rename so a killed run never leaves a half-written
//! entry a later run could read. `--cache-max-bytes` evicts
//! oldest-modified-first after each store.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::PipelineConfig;
use crate::report::JsonValue;

use super::manifest::CohortCase;
use super::sha256::{hex, Sha256};
use super::StoredRow;

/// Cache entry schema tag; bump on incompatible layout changes.
pub const SCHEMA: &str = "radpipe.cache/1";

/// The value-affecting slice of the config, rendered to a stable string
/// for hashing. Anything that changes feature *values* must appear here;
/// anything that only changes *how fast* they are computed must not.
/// Rendering goes through `Debug`, so a `Debug` drift across builds reads
/// as a different config — a safe cache miss, never a wrong result.
pub fn canonical_config(cfg: &PipelineConfig) -> String {
    format!(
        "feature_classes={:?};bin_width={};bin_count={};glcm_distances={:?};\
         gldm_alpha={};image_types={:?};log_sigmas={:?};resampled_spacing={};\
         wavelet_levels={};synthetic_image={};labels={:?}",
        cfg.feature_classes,
        cfg.bin_width,
        cfg.bin_count,
        cfg.glcm_distances,
        cfg.gldm_alpha,
        cfg.image_types,
        cfg.log_sigmas,
        cfg.resampled_spacing,
        cfg.wavelet_levels,
        cfg.synthetic_image,
        cfg.labels,
    )
}

/// On-disk feature cache rooted at `dir`.
pub struct FeatureCache {
    dir: PathBuf,
    /// 0 = unbounded; otherwise evict oldest entries past this total.
    max_bytes: u64,
}

impl FeatureCache {
    pub fn open(dir: &Path, max_bytes: u64) -> Result<FeatureCache> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create cache directory {}", dir.display()))?;
        Ok(FeatureCache { dir: dir.to_path_buf(), max_bytes })
    }

    /// Compute the content key for one cohort case. Reads the mask and
    /// image files in full — an unreadable input is an error here, which
    /// callers treat as a miss so the pipeline reports the real failure.
    pub fn case_key(&self, cfg_canon: &str, case: &CohortCase, root: &Path) -> Result<String> {
        let mut h = Sha256::new();
        // length-prefix every part so (a,bc) and (ab,c) cannot collide
        let part = |h: &mut Sha256, bytes: &[u8]| {
            h.update(&(bytes.len() as u64).to_le_bytes());
            h.update(bytes);
        };
        part(&mut h, SCHEMA.as_bytes());
        part(&mut h, cfg_canon.as_bytes());
        let mask_path = root.join(&case.mask);
        let mask = std::fs::read(&mask_path)
            .with_context(|| format!("hash mask {}", mask_path.display()))?;
        part(&mut h, &mask);
        match &case.image {
            Some(rel) => {
                let image_path = root.join(rel);
                let image = std::fs::read(&image_path)
                    .with_context(|| format!("hash image {}", image_path.display()))?;
                part(&mut h, b"image");
                part(&mut h, &image);
            }
            None => part(&mut h, b"no-image"),
        }
        part(&mut h, format!("{:?}", case.labels).as_bytes());
        Ok(hex(&h.finalize()))
    }

    /// Entry path: two-hex-char fan-out directory keeps any one directory
    /// from accumulating an entire cohort of files.
    fn entry_path(&self, key: &str) -> PathBuf {
        self.dir.join(&key[..2]).join(format!("{key}.json"))
    }

    /// Fetch stored rows for a key. Any problem — absent file, schema
    /// drift, damaged JSON — is a miss, never an error: the pipeline can
    /// always recompute.
    pub fn lookup(&self, key: &str) -> Option<Vec<StoredRow>> {
        let text = std::fs::read_to_string(self.entry_path(key)).ok()?;
        let doc = JsonValue::parse(&text).ok()?;
        if doc.get("schema").and_then(JsonValue::as_str) != Some(SCHEMA) {
            return None;
        }
        doc.get("rows")?
            .as_arr()?
            .iter()
            .map(|r| StoredRow::from_json(r).ok())
            .collect()
    }

    /// Store rows for a key. Atomic: written to a tmp file in the same
    /// directory, then renamed over the final path.
    pub fn store(&self, key: &str, case_id: &str, rows: &[StoredRow]) -> Result<()> {
        let path = self.entry_path(key);
        let parent = path.parent().expect("entry path always has a parent");
        std::fs::create_dir_all(parent)
            .with_context(|| format!("create cache shard {}", parent.display()))?;
        let mut doc = JsonValue::obj();
        doc.set("schema", SCHEMA);
        doc.set("case", case_id);
        doc.set("key", key);
        doc.set("rows", rows.iter().map(StoredRow::to_json).collect::<Vec<_>>());
        let tmp = parent.join(format!(".tmp-{key}"));
        std::fs::write(&tmp, doc.to_string())
            .with_context(|| format!("write cache entry {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("publish cache entry {}", path.display()))?;
        self.evict()
    }

    /// Trim the cache to `max_bytes`, oldest-modified entries first
    /// (path as a deterministic tiebreak). No-op when unbounded.
    fn evict(&self) -> Result<()> {
        if self.max_bytes == 0 {
            return Ok(());
        }
        let mut entries: Vec<(std::time::SystemTime, PathBuf, u64)> = Vec::new();
        let mut total: u64 = 0;
        for shard in std::fs::read_dir(&self.dir)
            .with_context(|| format!("scan cache {}", self.dir.display()))?
        {
            let shard = shard?;
            if !shard.file_type()?.is_dir() {
                continue;
            }
            for f in std::fs::read_dir(shard.path())? {
                let f = f?;
                let path = f.path();
                if path.extension().map(|e| e != "json").unwrap_or(true) {
                    continue;
                }
                let meta = f.metadata()?;
                let mtime = meta.modified().unwrap_or(std::time::UNIX_EPOCH);
                total += meta.len();
                entries.push((mtime, path, meta.len()));
            }
        }
        if total <= self.max_bytes {
            return Ok(());
        }
        entries.sort();
        for (_, path, len) in entries {
            if total <= self.max_bytes {
                break;
            }
            // a concurrent run may have raced us to this entry; that is fine
            if std::fs::remove_file(&path).is_ok() {
                total = total.saturating_sub(len);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_cohort(tag: &str) -> (PathBuf, CohortCase) {
        let dir = std::env::temp_dir().join(format!("radpipe_cache_test_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.bin"), b"mask-bytes").unwrap();
        std::fs::write(dir.join("i.bin"), b"image-bytes").unwrap();
        let case = CohortCase {
            case_id: "a".into(),
            mask: "m.bin".into(),
            image: Some("i.bin".into()),
            labels: vec![1, 2],
        };
        (dir, case)
    }

    fn rows() -> Vec<StoredRow> {
        vec![StoredRow {
            label: Some(1),
            features: vec![("shape_Volume".into(), "42".into()), ("x".into(), "-inf".into())],
        }]
    }

    #[test]
    fn key_tracks_every_input_and_nothing_else() {
        let (dir, case) = tmp_cohort("key");
        let cache = FeatureCache::open(&dir.join("cache"), 0).unwrap();
        let mut cfg = PipelineConfig::default();
        let canon = canonical_config(&cfg);
        let base = cache.case_key(&canon, &case, &dir).unwrap();
        assert_eq!(base, cache.case_key(&canon, &case, &dir).unwrap(), "stable");

        // mask bytes change the key
        std::fs::write(dir.join("m.bin"), b"mask-bytes2").unwrap();
        assert_ne!(base, cache.case_key(&canon, &case, &dir).unwrap());
        std::fs::write(dir.join("m.bin"), b"mask-bytes").unwrap();

        // dropping the image changes the key
        let mut no_img = case.clone();
        no_img.image = None;
        assert_ne!(base, cache.case_key(&canon, &no_img, &dir).unwrap());

        // label selection changes the key
        let mut other_labels = case.clone();
        other_labels.labels = vec![1];
        assert_ne!(base, cache.case_key(&canon, &other_labels, &dir).unwrap());

        // a value-affecting config knob changes the key…
        cfg.bin_width *= 2.0;
        assert_ne!(base, cache.case_key(&canonical_config(&cfg), &case, &dir).unwrap());
        cfg.bin_width /= 2.0;

        // …but parallelism knobs do not (determinism contract)
        cfg.feature_workers = 17;
        cfg.slab_io = true;
        cfg.memory_budget = 12345;
        cfg.cpu_threads = 3;
        assert_eq!(base, cache.case_key(&canonical_config(&cfg), &case, &dir).unwrap());

        // the case id is NOT part of the key: identical content shares one entry
        let mut renamed = case.clone();
        renamed.case_id = "b".into();
        assert_eq!(base, cache.case_key(&canon, &renamed, &dir).unwrap());
    }

    #[test]
    fn unreadable_input_is_an_error_not_a_key() {
        let (dir, mut case) = tmp_cohort("unreadable");
        let cache = FeatureCache::open(&dir.join("cache"), 0).unwrap();
        case.mask = "missing.bin".into();
        let err = cache.case_key("cfg", &case, &dir).unwrap_err();
        assert!(format!("{err:#}").contains("missing.bin"), "{err:#}");
    }

    #[test]
    fn store_then_lookup_round_trips_and_misses_stay_misses() {
        let (dir, _case) = tmp_cohort("rt");
        let cache = FeatureCache::open(&dir.join("cache"), 0).unwrap();
        let key = "ab".to_string() + &"cd".repeat(31);
        assert!(cache.lookup(&key).is_none(), "cold cache misses");
        cache.store(&key, "case-a", &rows()).unwrap();
        assert_eq!(cache.lookup(&key).unwrap(), rows());
        // a damaged entry degrades to a miss, never an error
        let path = cache.entry_path(&key);
        std::fs::write(&path, "{not json").unwrap();
        assert!(cache.lookup(&key).is_none());
        // so does a schema-drifted one
        std::fs::write(&path, "{\"schema\":\"radpipe.cache/999\",\"rows\":[]}").unwrap();
        assert!(cache.lookup(&key).is_none());
    }

    #[test]
    fn eviction_drops_oldest_entries_to_fit_the_budget() {
        let (dir, _case) = tmp_cohort("evict");
        let cache = FeatureCache::open(&dir.join("cache"), 0).unwrap();
        let keys: Vec<String> = (0..4).map(|i| format!("{i:02x}") + &"00".repeat(31)).collect();
        for k in &keys {
            cache.store(k, "c", &rows()).unwrap();
            // mtime granularity on some filesystems is coarse; space the writes
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let entry_len = std::fs::metadata(cache.entry_path(&keys[0])).unwrap().len();
        // budget for two entries: the two oldest must go
        let bounded = FeatureCache::open(&dir.join("cache"), entry_len * 2).unwrap();
        bounded.evict().unwrap();
        assert!(bounded.lookup(&keys[0]).is_none(), "oldest evicted");
        assert!(bounded.lookup(&keys[1]).is_none(), "second-oldest evicted");
        assert!(bounded.lookup(&keys[2]).is_some());
        assert!(bounded.lookup(&keys[3]).is_some());
    }
}
