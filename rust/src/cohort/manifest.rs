//! Cohort manifests: the CSV front-door of `radpipe batch`.
//!
//! ```csv
//! case_id,mask,image,labels
//! patient-001,masks/001.rvol.gz,images/001.img.rvol.gz,
//! patient-002,masks/002.rvol.gz,,"1,2,4"
//! ```
//!
//! The header row names the columns (any order, unknown columns
//! ignored): `case_id` and `mask` are required, `image` and `labels` are
//! optional. Paths are resolved against the manifest's directory;
//! absolute paths stand as-is. Cells follow RFC 4180 — quoted fields may
//! carry commas, doubled quotes, and embedded line breaks, so hostile
//! case ids survive a write→parse round trip. Unlike `cases.txt`, cohort
//! rows declare no dims: the pipeline sizes budgets from file headers.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One row of a cohort manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct CohortCase {
    pub case_id: String,
    /// Mask path, relative to the manifest's directory (or absolute).
    pub mask: PathBuf,
    /// Optional intensity image path.
    pub image: Option<PathBuf>,
    /// Declared label inventory from the `labels` cell (sorted, deduped);
    /// feeds `--labels all` exactly like `labels=` in `cases.txt`.
    pub labels: Vec<u16>,
}

/// A loaded cohort: the manifest's directory plus its parsed rows.
#[derive(Debug, Clone)]
pub struct CohortManifest {
    pub root: PathBuf,
    pub cases: Vec<CohortCase>,
}

/// Read and parse a cohort CSV manifest.
pub fn load_cohort(path: &Path) -> Result<CohortManifest> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read cohort manifest {}", path.display()))?;
    let cases =
        parse_cohort_csv(&text).with_context(|| format!("parse {}", path.display()))?;
    let root = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    Ok(CohortManifest { root, cases })
}

/// Parse the manifest text. Errors carry the 1-based record number
/// (header = record 1).
pub fn parse_cohort_csv(text: &str) -> Result<Vec<CohortCase>> {
    let mut records = parse_csv(text)?;
    // a blank line parses as one empty field; drop those
    records.retain(|r| !(r.len() == 1 && r[0].is_empty()));
    if records.is_empty() {
        bail!("cohort manifest is empty (need a header row: case_id,mask[,image][,labels])");
    }
    let header = records.remove(0);
    let col = |name: &str| header.iter().position(|h| h.trim().eq_ignore_ascii_case(name));
    let ci = col("case_id")
        .context("cohort manifest header has no case_id column (case_id,mask[,image][,labels])")?;
    let mi = col("mask")
        .context("cohort manifest header has no mask column (case_id,mask[,image][,labels])")?;
    let ii = col("image");
    let li = col("labels");

    let mut seen: HashSet<String> = HashSet::new();
    let mut cases = Vec::with_capacity(records.len());
    for (n, rec) in records.iter().enumerate() {
        let rec_no = n + 2; // 1-based, after the header
        let get = |i: usize| rec.get(i).map(String::as_str).unwrap_or("");
        let case_id = get(ci);
        if case_id.is_empty() {
            bail!("cohort manifest record {rec_no}: empty case_id");
        }
        if !seen.insert(case_id.to_string()) {
            bail!("cohort manifest record {rec_no}: duplicate case_id '{case_id}'");
        }
        let mask = get(mi);
        if mask.is_empty() {
            bail!("cohort manifest record {rec_no}: case '{case_id}' has an empty mask path");
        }
        let image = ii
            .map(|i| get(i))
            .filter(|v| !v.is_empty())
            .map(PathBuf::from);
        let labels = match li {
            Some(i) => parse_labels(get(i))
                .with_context(|| format!("cohort manifest record {rec_no}: labels cell"))?,
            None => Vec::new(),
        };
        cases.push(CohortCase {
            case_id: case_id.to_string(),
            mask: PathBuf::from(mask),
            image,
            labels,
        });
    }
    if cases.is_empty() {
        bail!("cohort manifest has a header but no case rows");
    }
    Ok(cases)
}

/// Label inventory cell: ids separated by commas, semicolons or spaces
/// (commas require the cell to be quoted).
fn parse_labels(cell: &str) -> Result<Vec<u16>> {
    let mut out = Vec::new();
    for tok in cell.split([',', ';', ' ', '\t']) {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let id: u16 = tok.parse().with_context(|| format!("label id '{tok}'"))?;
        if id == 0 {
            bail!("label 0 is background and cannot be selected");
        }
        out.push(id);
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// RFC-4180 record reader: quoted fields may contain commas, doubled
/// quotes and raw CR/LF; records end at an unquoted LF or CRLF. Returns
/// the raw cell matrix; no trimming (cell bytes are significant).
fn parse_csv(text: &str) -> Result<Vec<Vec<String>>> {
    let mut records: Vec<Vec<String>> = Vec::new();
    let mut record: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    // a closing quote ends the field's content: only a separator (or a
    // re-opening doubled quote, handled inside the quoted state) may follow
    let mut after_close = false;
    let mut field_quoted = false;
    let mut chars = text.chars().peekable();

    while let Some(c) = chars.next() {
        if in_quotes {
            if c == '"' {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    field.push('"');
                } else {
                    in_quotes = false;
                    after_close = true;
                }
            } else {
                field.push(c);
            }
            continue;
        }
        match c {
            ',' => {
                record.push(std::mem::take(&mut field));
                after_close = false;
                field_quoted = false;
            }
            '\n' | '\r' => {
                if c == '\r' && chars.peek() == Some(&'\n') {
                    chars.next();
                }
                record.push(std::mem::take(&mut field));
                records.push(std::mem::take(&mut record));
                after_close = false;
                field_quoted = false;
            }
            '"' => {
                if !field.is_empty() || after_close {
                    bail!(
                        "CSV record {}: quote inside an unquoted field (quote the whole cell)",
                        records.len() + 1
                    );
                }
                in_quotes = true;
                field_quoted = true;
            }
            _ => {
                if after_close {
                    bail!(
                        "CSV record {}: content after a closing quote",
                        records.len() + 1
                    );
                }
                field.push(c);
            }
        }
    }
    if in_quotes {
        bail!("CSV record {}: unterminated quoted field", records.len() + 1);
    }
    // flush a final record with no trailing newline
    if !field.is_empty() || field_quoted || !record.is_empty() {
        record.push(field);
        records.push(record);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::Table;

    #[test]
    fn plain_manifest_parses() {
        let cases = parse_cohort_csv(
            "case_id,mask,image\n\
             a,masks/a.rvol.gz,images/a.img.rvol.gz\n\
             b,masks/b.rvol.gz,\n",
        )
        .unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].case_id, "a");
        assert_eq!(cases[0].image, Some(PathBuf::from("images/a.img.rvol.gz")));
        assert_eq!(cases[1].image, None, "empty image cell means no image");
    }

    #[test]
    fn header_columns_may_reorder_and_unknowns_are_ignored() {
        let cases = parse_cohort_csv(
            "site,image,case_id,mask\n\
             MGH,,p1,m1.rvol\n",
        )
        .unwrap();
        assert_eq!(cases[0].case_id, "p1");
        assert_eq!(cases[0].mask, PathBuf::from("m1.rvol"));
    }

    #[test]
    fn labels_cell_parses_sorted_and_rejects_zero() {
        let cases = parse_cohort_csv(
            "case_id,mask,labels\n\
             a,m.rvol,\"4,1,2,2\"\n\
             b,m2.rvol,1; 3\n\
             c,m3.rvol,\n",
        )
        .unwrap();
        assert_eq!(cases[0].labels, vec![1, 2, 4]);
        assert_eq!(cases[1].labels, vec![1, 3]);
        assert!(cases[2].labels.is_empty());
        let err = parse_cohort_csv("case_id,mask,labels\na,m.rvol,0\n").unwrap_err();
        assert!(format!("{err:#}").contains("background"), "{err:#}");
    }

    #[test]
    fn hostile_case_ids_survive_a_write_parse_round_trip() {
        // ids with commas, quotes, newlines and CRs — written through the
        // RFC-4180 Table writer, read back through this parser
        let ids = [
            "plain",
            "with,comma",
            "with \"quotes\"",
            "multi\nline",
            "cr\rhere",
            "all,of\n\"it\"\r together",
            " leading and trailing ",
        ];
        let mut t = Table::new(vec!["case_id", "mask"]);
        for id in &ids {
            t.row(vec![id.to_string(), "m.rvol".to_string()]);
        }
        let cases = parse_cohort_csv(&t.to_csv()).unwrap();
        let got: Vec<&str> = cases.iter().map(|c| c.case_id.as_str()).collect();
        assert_eq!(got, ids, "cell bytes must be preserved exactly");
    }

    #[test]
    fn crlf_and_blank_lines_are_tolerated() {
        let cases = parse_cohort_csv(
            "case_id,mask\r\n\
             \r\n\
             a,m.rvol\r\n\
             \n\
             b,n.rvol",
        )
        .unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[1].case_id, "b", "final record may lack a newline");
    }

    #[test]
    fn duplicate_and_missing_fields_are_located_errors() {
        let err = parse_cohort_csv("case_id,mask\na,m\na,n\n").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("record 3") && msg.contains("duplicate"), "{msg}");
        let err = parse_cohort_csv("case_id,mask\n,m\n").unwrap_err();
        assert!(format!("{err:#}").contains("empty case_id"), "{err:#}");
        let err = parse_cohort_csv("case_id,mask\na,\n").unwrap_err();
        assert!(format!("{err:#}").contains("empty mask"), "{err:#}");
        let err = parse_cohort_csv("mask\nm\n").unwrap_err();
        assert!(format!("{err:#}").contains("case_id column"), "{err:#}");
        let err = parse_cohort_csv("case_id,mask\n").unwrap_err();
        assert!(format!("{err:#}").contains("no case rows"), "{err:#}");
    }

    #[test]
    fn malformed_quoting_is_rejected_with_the_record_number() {
        for bad in [
            "case_id,mask\na\"b,m\n",      // quote mid-field
            "case_id,mask\n\"a\"x,m\n",    // content after closing quote
            "case_id,mask\n\"unterminated", // EOF inside quotes
        ] {
            let err = parse_cohort_csv(bad).unwrap_err();
            assert!(format!("{err:#}").contains("record 2"), "{bad:?}: {err:#}");
        }
    }

    #[test]
    fn load_cohort_resolves_root_to_the_manifest_directory() {
        let dir = std::env::temp_dir().join("radpipe_cohort_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cohort.csv");
        std::fs::write(&path, "case_id,mask\na,a.rvol\n").unwrap();
        let m = load_cohort(&path).unwrap();
        assert_eq!(m.root, dir);
        assert_eq!(m.cases.len(), 1);
        assert!(load_cohort(&dir.join("nope.csv")).is_err());
    }
}
