//! SIMT device cost model — the hardware-substitution substrate.
//!
//! The paper benchmarks its kernels on H100, RTX 4070 and T4 GPUs plus
//! three CPUs (Table 1). None of that silicon exists on this testbed, so
//! Fig. 1 / Fig. 2 are regenerated through a first-order SIMT cost model:
//! each [`crate::parallel::Strategy`] run tallies a [`WorkProfile`]
//! (pairs, atomics, block reductions, staged tile bytes, index arithmetic)
//! and the model prices that profile on a device description.
//!
//! The model is deliberately simple (roofline compute/memory term + serial
//! synchronisation terms) and *calibrated* against the paper's published
//! numbers (Table 2's ≈18× desktop computation speedup, Fig. 1's strategy
//! ordering per device, Fig. 2's 8–24× T4 / 50–2000× H100 speedups); the
//! calibration constants are documented inline. It answers the question
//! "which strategy wins on which device class, and by roughly how much" —
//! the *shape* of the paper's results, per DESIGN.md §Substitutions.

mod model;
mod profiles;

pub use model::{estimate_kernel_time, estimate_transfer_time, SimReport};
pub use profiles::{cpu_profiles, gpu_profiles, DeviceClass, DeviceProfile};
