//! The first-order SIMT cost model.
//!
//! For a kernel run described by a [`WorkProfile`] and a
//! [`crate::parallel::Strategy`], the time on a device is
//!
//! ```text
//! t = launch + max(t_compute, t_memory) + t_atomics + t_block_reduce
//! ```
//!
//! with per-strategy structural constants (ops-per-pair overhead, global
//! bytes-per-pair, load-imbalance and occupancy penalties) chosen so the
//! model reproduces the *qualitative* findings of the paper's §3:
//!
//! * T4 (small shared memory, slow atomics) → block reduction (2) wins;
//! * RTX 4070 (compute-bound regime) → local accumulators (4) win;
//! * H100 (fast atomics, global-memory sensitive) → 2D shared tiles (3) win;
//! * flat-1D (5) is never a significant improvement;
//! * baseline (1) loses everywhere on load imbalance.
//!
//! Absolute scales are calibrated against the paper's published timings
//! (see `profiles.rs` per-device `efficiency` notes).

use super::profiles::{DeviceClass, DeviceProfile};
use crate::parallel::{Strategy, WorkProfile};

/// Base arithmetic per vertex pair (3 sub, 3 mul, 2 add, compare/update ≈ 7).
const BASE_OPS_PER_PAIR: f64 = 15.0;

/// Per-strategy structural constants.
#[derive(Debug, Clone, Copy)]
struct StrategyCosts {
    /// Additional instructions per pair from the reduction style.
    extra_ops: f64,
    /// Global-memory bytes touched per pair (after cache/shared staging).
    bytes_per_pair: f64,
    /// Multiplier for load imbalance (contiguous-split triangular work).
    imbalance: f64,
    /// Occupancy penalty applied on devices with < 96 KiB shared memory
    /// per block (register/shared pressure).
    small_shared_penalty: f64,
}

fn strategy_costs(s: Strategy) -> StrategyCosts {
    match s {
        // Global-atomic max per row, contiguous row split.
        Strategy::EqualSplit => StrategyCosts {
            extra_ops: 2.0,
            bytes_per_pair: 8.0,
            imbalance: 1.9,
            small_shared_penalty: 1.0,
        },
        // Balanced queue + shared-memory tree reduction per block.
        Strategy::BlockReduction => StrategyCosts {
            extra_ops: 1.0,
            bytes_per_pair: 8.0,
            imbalance: 1.0,
            small_shared_penalty: 1.0,
        },
        // Staged 2D tiles: minimal global traffic, needs shared capacity.
        Strategy::Tiled2D => StrategyCosts {
            extra_ops: 1.0,
            bytes_per_pair: 1.0,
            imbalance: 1.0,
            small_shared_penalty: 1.22,
        },
        // Register accumulators: fewest ops, some register pressure, and
        // no staging — the vertex panel is re-read from global memory with
        // little reuse (why H100, which "needs more attention when
        // accessing global memory", prefers the tiled kernel).
        Strategy::LocalAccumulators => StrategyCosts {
            extra_ops: 0.5,
            bytes_per_pair: 8.0,
            imbalance: 1.0,
            small_shared_penalty: 1.15,
        },
        // 1D flattening: cheap indexing but poor locality.
        Strategy::Flat1D => StrategyCosts {
            extra_ops: 1.2,
            bytes_per_pair: 12.0,
            imbalance: 1.0,
            small_shared_penalty: 1.0,
        },
    }
}

/// Estimated kernel execution time in seconds.
pub fn estimate_kernel_time(
    profile: &WorkProfile,
    strategy: Strategy,
    device: &DeviceProfile,
) -> f64 {
    let c = strategy_costs(strategy);
    let pairs = profile.pairs as f64;

    let ops = pairs * (BASE_OPS_PER_PAIR + c.extra_ops);
    let sustained = device.peak_gflops() * 1e9 * device.efficiency;
    let mut t_compute = ops / sustained;
    if device.class == DeviceClass::Gpu {
        // structural penalties model GPU decomposition effects; the CPU
        // baseline is a single sequential loop with no imbalance/occupancy.
        t_compute *= c.imbalance;
        if device.shared_kib_per_block < 96 {
            t_compute *= c.small_shared_penalty;
        }
    }

    // Memory: CPU caches hide the panel re-reads; GPUs pay global traffic.
    let t_memory = if device.class == DeviceClass::Gpu {
        (pairs * c.bytes_per_pair + profile.tile_bytes as f64)
            / (device.mem_bw_gbs * 1e9)
    } else {
        0.0
    };

    let t_atomics = profile.global_atomics as f64 / (device.atomic_mops * 1e6);
    let t_reduce = profile.block_reductions as f64 * device.block_reduce_ns * 1e-9;
    device.launch_us * 1e-6 + t_compute.max(t_memory) + t_atomics + t_reduce
}

/// Host↔device transfer estimate in seconds (the Table 2 "D. tran" column).
pub fn estimate_transfer_time(bytes: u64, device: &DeviceProfile) -> f64 {
    if device.pcie_gbs.is_infinite() {
        return 0.0;
    }
    device.launch_us * 1e-6 + bytes as f64 / (device.pcie_gbs * 1e9)
}

/// One (device × strategy) pricing row for the Fig. 1 harness.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub device: &'static str,
    pub strategy: Strategy,
    pub seconds: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::profiles::{cpu_profiles, gpu_profiles};

    /// A work profile shaped like the paper's largest case (236 588
    /// vertices) under each strategy's accounting.
    fn paper_profile(strategy: Strategy) -> WorkProfile {
        let n: u64 = 236_588;
        let pairs = n * (n + 1) / 2;
        let mut p = WorkProfile {
            pairs,
            distance_ops: pairs,
            logical_threads: n,
            index_ops: pairs,
            ..Default::default()
        };
        match strategy {
            Strategy::EqualSplit => p.global_atomics = n,
            Strategy::BlockReduction => {
                p.global_atomics = n.div_ceil(256);
                p.block_reductions = n.div_ceil(256);
            }
            Strategy::Tiled2D => {
                let tiles = n.div_ceil(1024);
                p.global_atomics = tiles;
                p.block_reductions = tiles * tiles / 2;
                p.tile_bytes = tiles * tiles / 2 * 1024 * 12;
            }
            Strategy::LocalAccumulators => p.global_atomics = 64,
            Strategy::Flat1D => p.global_atomics = 64,
        }
        p
    }

    fn best_strategy(device: &DeviceProfile) -> Strategy {
        Strategy::ALL
            .into_iter()
            .min_by(|a, b| {
                let ta = estimate_kernel_time(&paper_profile(*a), *a, device);
                let tb = estimate_kernel_time(&paper_profile(*b), *b, device);
                ta.partial_cmp(&tb).unwrap()
            })
            .unwrap()
    }

    #[test]
    fn fig1_strategy_winners_match_paper() {
        let gpus = gpu_profiles();
        assert_eq!(best_strategy(&gpus[0]), Strategy::Tiled2D, "H100");
        assert_eq!(best_strategy(&gpus[1]), Strategy::LocalAccumulators, "RTX 4070");
        assert_eq!(best_strategy(&gpus[2]), Strategy::BlockReduction, "T4");
    }

    #[test]
    fn baseline_always_loses() {
        for d in gpu_profiles() {
            let t1 = estimate_kernel_time(
                &paper_profile(Strategy::EqualSplit),
                Strategy::EqualSplit,
                &d,
            );
            let best = best_strategy(&d);
            let tb = estimate_kernel_time(&paper_profile(best), best, &d);
            assert!(t1 > 1.3 * tb, "{}: baseline {t1} vs best {tb}", d.name);
        }
    }

    #[test]
    fn table2_desktop_calibration() {
        // RTX 4070, largest case: paper reports 1.856 s diameter time.
        let d = &gpu_profiles()[1];
        let t = estimate_kernel_time(
            &paper_profile(Strategy::LocalAccumulators),
            Strategy::LocalAccumulators,
            d,
        );
        assert!((t - 1.856).abs() / 1.856 < 0.25, "t={t}");
    }

    #[test]
    fn h100_biggest_case_order_of_59ms() {
        let d = &gpu_profiles()[0];
        let t = estimate_kernel_time(
            &paper_profile(Strategy::Tiled2D),
            Strategy::Tiled2D,
            d,
        );
        assert!(t > 0.02 && t < 0.12, "t={t}");
    }

    #[test]
    fn xeon_biggest_case_order_of_121s() {
        let d = cpu_profiles()
            .into_iter()
            .find(|p| p.name.contains("Xeon"))
            .unwrap();
        let t = estimate_kernel_time(
            &paper_profile(Strategy::EqualSplit),
            Strategy::EqualSplit,
            &d,
        );
        // single sequential loop; the calibration targets the paper's 121 s
        assert!(t > 80.0 && t < 200.0, "t={t}");
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let d = &gpu_profiles()[2]; // T4 PCIe gen3
        let small = estimate_transfer_time(1 << 20, d);
        let big = estimate_transfer_time(1 << 30, d);
        assert!(big > 50.0 * small);
        // ~1 GiB over ~10 GB/s ≈ 0.1 s
        assert!(big > 0.05 && big < 0.3, "{big}");
        // CPUs never pay transfer
        assert_eq!(estimate_transfer_time(1 << 30, &cpu_profiles()[0]), 0.0);
    }

    #[test]
    fn gpu_speedups_match_fig2_shape() {
        // Fig 2 right: vs Xeon baseline, T4 ≈ 8–24×, RTX 4070 ≈ 20–60×,
        // H100 ≥ several hundred ×, on the big cases.
        let xeon = cpu_profiles()
            .into_iter()
            .find(|p| p.name.contains("Xeon"))
            .unwrap();
        let base = estimate_kernel_time(
            &paper_profile(Strategy::BlockReduction),
            Strategy::BlockReduction,
            &xeon,
        );
        let gpus = gpu_profiles();
        let best = |d: &DeviceProfile| {
            let s = best_strategy(d);
            estimate_kernel_time(&paper_profile(s), s, d)
        };
        let su_h100 = base / best(&gpus[0]);
        let su_4070 = base / best(&gpus[1]);
        let su_t4 = base / best(&gpus[2]);
        assert!(su_t4 > 8.0 && su_t4 < 40.0, "T4 {su_t4}");
        assert!(su_4070 > 20.0 && su_4070 < 120.0, "4070 {su_4070}");
        assert!(su_h100 > 300.0, "H100 {su_h100}");
        assert!(su_h100 > su_4070 && su_4070 > su_t4);
    }
}
