//! Device descriptions (paper Table 1) plus per-device kernel-efficiency
//! calibration constants.

/// GPU or CPU device class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    Gpu,
    Cpu,
}

/// A priced execution platform.
///
/// GPU fields follow the paper's Table 1; derived throughput numbers use
/// public spec sheets. CPU profiles model PyRadiomics' single-threaded C
/// loop (the paper: "PyRadiomics is not able to utilize multiple CPU
/// cores").
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: &'static str,
    pub class: DeviceClass,
    /// CUDA cores (GPU) or usable cores for the workload (CPU: 1).
    pub cores: u32,
    /// Boost clock, GHz.
    pub clock_ghz: f64,
    /// FP32 FLOPs per core per cycle (FMA = 2).
    pub flops_per_core_cycle: f64,
    /// Global-memory bandwidth, GB/s.
    pub mem_bw_gbs: f64,
    /// Shared-memory per SM, KiB (0 for CPUs; L1 cache stands in).
    pub shared_kib_per_block: u32,
    /// Sustained global atomic throughput, Matomics/s. Modern GPUs have
    /// fast on-L2 atomics (H100); older parts serialise more (T4).
    pub atomic_mops: f64,
    /// Block-reduction cost, ns per block (tree reduce in shared memory).
    pub block_reduce_ns: f64,
    /// Host↔device copy bandwidth, GB/s (PCIe gen / NVLink).
    pub pcie_gbs: f64,
    /// Fixed kernel-launch / dispatch latency, µs.
    pub launch_us: f64,
    /// Achievable fraction of peak FLOPs for this (irregular,
    /// comparison-heavy) kernel family. Calibrated: the paper's desktop
    /// RTX 4070 computes a 236 588-vertex diameter in ≈1.86 s
    /// (Table 2, case 00001-1) — 2.8e10 pairs ≈ 15 pair-ops each.
    pub efficiency: f64,
}

/// The paper's three GPUs (Table 1).
pub fn gpu_profiles() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile {
            name: "NVIDIA H100",
            class: DeviceClass::Gpu,
            cores: 14_592,
            clock_ghz: 1.98,
            flops_per_core_cycle: 2.0,
            mem_bw_gbs: 3350.0,
            shared_kib_per_block: 228,
            atomic_mops: 16_000.0, // fast L2 atomics ("H100 offers fast atomic operations")
            block_reduce_ns: 180.0,
            pcie_gbs: 55.0, // PCIe gen5 x16 effective
            launch_us: 6.0,
            // Paper §3: the 236 588-vertex case runs in 59 ms end-to-end on
            // H100 (vs 121 s Xeon) → ~8.4e12 sustained pair-ops/s ≈ 14.5 %
            // of peak. The paper's own numbers imply wildly different
            // achieved efficiencies per device; we adopt them as-is.
            efficiency: 0.145,
        },
        DeviceProfile {
            name: "NVIDIA RTX 4070",
            class: DeviceClass::Gpu,
            cores: 5_888,
            clock_ghz: 2.48,
            flops_per_core_cycle: 2.0,
            mem_bw_gbs: 504.0,
            shared_kib_per_block: 100,
            atomic_mops: 6_000.0,
            block_reduce_ns: 220.0,
            pcie_gbs: 24.0, // PCIe gen4 x16 effective
            launch_us: 5.0,
            // Table 2, case 00001-1: 2.8e10 pairs ≈ 15 ops each in 1.856 s
            // → 226 Gop/s ≈ 0.78 % of the 29.2 TFLOP/s peak.
            efficiency: 0.0078,
        },
        DeviceProfile {
            name: "NVIDIA T4",
            class: DeviceClass::Gpu,
            cores: 2_560,
            clock_ghz: 1.59,
            flops_per_core_cycle: 2.0,
            mem_bw_gbs: 320.0,
            shared_kib_per_block: 64,
            atomic_mops: 900.0, // "on older T4 atomic operations are not as effective"
            block_reduce_ns: 260.0,
            pcie_gbs: 10.0, // PCIe gen3 x16 effective
            launch_us: 8.0,
            // Paper §3: T4 reaches 8–24× over its host Xeon E5649 in 3D
            // feature extraction → ≈5 s for the largest case → ~1 % of peak.
            efficiency: 0.0102,
        },
    ]
}

/// The paper's three CPUs (Table 1); PyRadiomics uses one core.
pub fn cpu_profiles() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile {
            name: "AMD EPYC 9534",
            class: DeviceClass::Cpu,
            cores: 1,
            clock_ghz: 2.45,
            flops_per_core_cycle: 16.0, // AVX-512-ish SIMD loop
            mem_bw_gbs: 40.0,
            shared_kib_per_block: 0,
            atomic_mops: 200.0,
            block_reduce_ns: 20.0,
            pcie_gbs: f64::INFINITY, // no transfer on CPU path
            launch_us: 0.0,
            efficiency: 0.12,
        },
        DeviceProfile {
            name: "AMD Ryzen 5 7600x",
            class: DeviceClass::Cpu,
            cores: 1,
            clock_ghz: 5.3,
            flops_per_core_cycle: 16.0,
            mem_bw_gbs: 45.0,
            shared_kib_per_block: 0,
            atomic_mops: 250.0,
            block_reduce_ns: 15.0,
            pcie_gbs: f64::INFINITY,
            launch_us: 0.0,
            // Calibrated: Table 2 case 00001-1: 2.8e10 pairs × ~15 ops in
            // 34.2 s → ~12.3 Gop/s ≈ 5.3 GHz × 16 × 0.145.
            efficiency: 0.145,
        },
        DeviceProfile {
            name: "Intel Xeon E5649",
            class: DeviceClass::Cpu,
            cores: 1,
            clock_ghz: 2.93,
            flops_per_core_cycle: 8.0, // SSE4-era SIMD
            mem_bw_gbs: 18.0,
            shared_kib_per_block: 0,
            atomic_mops: 80.0,
            block_reduce_ns: 40.0,
            pcie_gbs: f64::INFINITY,
            launch_us: 0.0,
            // Paper Fig. 2: 121 s for the 236 588-vertex case → ~3.5 Gop/s.
            efficiency: 0.148,
        },
    ]
}

impl DeviceProfile {
    /// Peak FP32 throughput, GFLOP/s.
    pub fn peak_gflops(&self) -> f64 {
        self.cores as f64 * self.clock_ghz * self.flops_per_core_cycle
    }

    pub fn by_name(name: &str) -> Option<DeviceProfile> {
        gpu_profiles()
            .into_iter()
            .chain(cpu_profiles())
            .find(|p| p.name.eq_ignore_ascii_case(name) || p.name.to_lowercase().contains(&name.to_lowercase()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_cover_table1() {
        let names: Vec<_> = gpu_profiles().iter().map(|p| p.name).collect();
        assert_eq!(names, ["NVIDIA H100", "NVIDIA RTX 4070", "NVIDIA T4"]);
        let cpus: Vec<_> = cpu_profiles().iter().map(|p| p.name).collect();
        assert_eq!(cpus.len(), 3);
        assert!(cpus.contains(&"Intel Xeon E5649"));
    }

    #[test]
    fn peak_flops_sane() {
        let h100 = DeviceProfile::by_name("H100").unwrap();
        // ~57.8 TFLOPs FP32 (spec: 67 boost; we model sustained clock).
        let peak = h100.peak_gflops();
        assert!(peak > 40_000.0 && peak < 80_000.0, "{peak}");
        let t4 = DeviceProfile::by_name("T4").unwrap();
        assert!(t4.peak_gflops() < 10_000.0);
    }

    #[test]
    fn by_name_fuzzy() {
        assert!(DeviceProfile::by_name("rtx 4070").is_some());
        assert!(DeviceProfile::by_name("xeon").is_some());
        assert!(DeviceProfile::by_name("a100").is_none());
    }

    #[test]
    fn gpu_ordering_is_h100_fastest() {
        let g = gpu_profiles();
        assert!(g[0].peak_gflops() > g[1].peak_gflops());
        assert!(g[1].peak_gflops() > g[2].peak_gflops());
    }
}
