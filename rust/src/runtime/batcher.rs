//! The batch scheduler between the extract pool and the engine pool.
//!
//! Per-case dispatch pays a fixed engine round-trip per mesh (channel hop,
//! request/reply bookkeeping, scheduling) — the fixed-cost regime that
//! dominates small ROIs in the paper's Table 2. The [`Batcher`] collects
//! diameter requests from concurrent extract workers, groups them by
//! pad-bucket (cases padded to the same static artifact shape share an
//! executable), and flushes a group as **one engine round-trip** when it
//! reaches `batch_size` or has lingered for `batch_linger_ms` — whichever
//! comes first. The engine executes the group's items back-to-back without
//! yielding between them and splits results onto the per-case reply
//! channels with per-phase [`ExecTiming`] attribution intact.
//!
//! What is amortised today is the per-request round-trip (and the cache-hot
//! back-to-back execution); each item still performs its own upload +
//! launch inside the engine. Folding a group into a single multi-case
//! artifact execution (`f32[batch, bucket, 3]` AOT shapes) is the natural
//! next step and slots in behind this same scheduler interface.
//!
//! The execution side is abstracted behind [`BatchBackend`] so the same
//! scheduler drives the PJRT [`super::pool::EnginePool`] in production and
//! a CPU loopback in tests/benches (where the conformance suite proves
//! batched == unbatched bit-for-bit without needing artifacts).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::buckets::bucket_for;
use super::engine::{BatchItem, ExecTiming};
use crate::features::{brute_force_diameters, Diameters};
use crate::geometry::Vec3;

/// Executes one pad-bucket group of diameter cases. Implementations must
/// answer **every** item's reply channel (success or error) — a dropped
/// reply turns into a clean error on the waiting worker, never a hang.
pub trait BatchBackend: Send + Sync {
    /// Sorted pad-buckets requests are grouped by.
    fn buckets(&self) -> &[usize];
    /// Execute a group routed to `bucket`, replying per item.
    fn execute_group(&self, bucket: usize, items: Vec<BatchItem>);
}

/// Batching knobs (see `PipelineConfig`: `batch_size`, `batch_linger_ms`).
#[derive(Debug, Clone, Copy)]
pub struct BatchConfig {
    /// Flush a bucket group at this many cases. `1` disables batching:
    /// every request is dispatched immediately (the seed behaviour).
    pub batch_size: usize,
    /// Maximum time a pending group waits for co-batchable cases.
    pub linger: Duration,
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { batch_size: 1, linger: Duration::from_millis(2) }
    }
}

/// Counters describing batching behaviour (occupancy = items / flushes).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BatchStatsSnapshot {
    /// Requests submitted to the batcher.
    pub submitted: u64,
    /// Groups flushed to the backend.
    pub flushes: u64,
    /// Total items across all flushed groups.
    pub flushed_items: u64,
    /// Groups flushed because they reached `batch_size`.
    pub full_flushes: u64,
    /// Groups flushed by the linger deadline (includes shutdown drains).
    pub linger_flushes: u64,
    /// Largest group ever flushed.
    pub max_occupancy: u64,
}

#[derive(Default)]
struct BatchStats {
    submitted: AtomicU64,
    flushes: AtomicU64,
    flushed_items: AtomicU64,
    full_flushes: AtomicU64,
    linger_flushes: AtomicU64,
    max_occupancy: AtomicU64,
}

impl BatchStats {
    fn snapshot(&self) -> BatchStatsSnapshot {
        BatchStatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            flushes: self.flushes.load(Ordering::Relaxed),
            flushed_items: self.flushed_items.load(Ordering::Relaxed),
            full_flushes: self.full_flushes.load(Ordering::Relaxed),
            linger_flushes: self.linger_flushes.load(Ordering::Relaxed),
            max_occupancy: self.max_occupancy.load(Ordering::Relaxed),
        }
    }
}

/// Lock a scheduler mutex, recovering from poisoning (same treatment as
/// `metrics::lock_recover`). A panicking engine thread must not take the
/// whole run down: the guarded state here (`pending` group map, the
/// loopback's `serial` token) is never left half-applied by the panic
/// sites — panics originate in backend execution, not inside these
/// critical sections — so continuing past the poison marker is sound and
/// every subsequent submitter keeps batching instead of panicking.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Pending groups keyed by pad-bucket, with the arrival time of each
/// group's oldest item (the linger clock).
struct Pending {
    groups: HashMap<usize, (Instant, Vec<BatchItem>)>,
    shutdown: bool,
}

struct Shared {
    backend: Arc<dyn BatchBackend>,
    cfg: BatchConfig,
    pending: Mutex<Pending>,
    wake: Condvar,
    stats: BatchStats,
}

impl Shared {
    fn flush(&self, bucket: usize, items: Vec<BatchItem>, by_size: bool) {
        let n = items.len() as u64;
        let _sp = crate::trace::span_args(
            "batch.flush",
            &[
                ("bucket", crate::trace::ArgV::Int(bucket as u64)),
                ("items", crate::trace::ArgV::Int(n)),
                ("trigger", crate::trace::ArgV::Str(if by_size { "size" } else { "linger" })),
            ],
        );
        self.stats.flushes.fetch_add(1, Ordering::Relaxed);
        self.stats.flushed_items.fetch_add(n, Ordering::Relaxed);
        self.stats.max_occupancy.fetch_max(n, Ordering::Relaxed);
        if by_size {
            self.stats.full_flushes.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.linger_flushes.fetch_add(1, Ordering::Relaxed);
        }
        self.backend.execute_group(bucket, items);
    }
}

/// The batch scheduler. Cheap to share behind the dispatcher; submitting
/// threads block only on their own reply, never on each other's compute.
pub struct Batcher {
    shared: Arc<Shared>,
    linger_thread: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn new(backend: Arc<dyn BatchBackend>, cfg: BatchConfig) -> Batcher {
        let shared = Arc::new(Shared {
            backend,
            cfg,
            pending: Mutex::new(Pending { groups: HashMap::new(), shutdown: false }),
            wake: Condvar::new(),
            stats: BatchStats::default(),
        });
        // The linger thread only exists when batching is on: with
        // batch_size == 1 every request flushes inline.
        let linger_thread = if cfg.batch_size > 1 {
            let shared = shared.clone();
            Some(
                std::thread::Builder::new()
                    .name("radpipe-batcher".into())
                    .spawn(move || linger_loop(&shared))
                    .expect("spawn radpipe-batcher"),
            )
        } else {
            None
        };
        Batcher { shared, linger_thread }
    }

    /// Counter snapshot for metrics reporting.
    pub fn stats(&self) -> BatchStatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Submit one case's f32[n,3] vertex buffer; blocks until its group is
    /// executed and returns this case's diameters + timing.
    pub fn diameters(&self, verts: Vec<f32>) -> Result<(Diameters, ExecTiming)> {
        let n = verts.len() / 3;
        let bucket = bucket_for(n, self.shared.backend.buckets())?;
        self.shared.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        let item = BatchItem { verts, reply };
        if self.shared.cfg.batch_size <= 1 {
            self.shared.flush(bucket, vec![item], true);
        } else {
            let full_group = {
                let mut g = lock_recover(&self.shared.pending);
                let entry = g
                    .groups
                    .entry(bucket)
                    .or_insert_with(|| (Instant::now(), Vec::new()));
                entry.1.push(item);
                if entry.1.len() >= self.shared.cfg.batch_size {
                    g.groups.remove(&bucket)
                } else {
                    None
                }
            };
            match full_group {
                // Size trigger: flush on the submitting thread (it is about
                // to block on its reply anyway).
                Some((_, items)) => self.shared.flush(bucket, items, true),
                // Otherwise the linger thread picks the group up.
                None => self.shared.wake.notify_one(),
            }
        }
        rx.recv().map_err(|_| anyhow!("batch backend dropped the request"))?
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        {
            let mut g = lock_recover(&self.shared.pending);
            g.shutdown = true;
        }
        self.shared.wake.notify_all();
        if let Some(t) = self.linger_thread.take() {
            let _ = t.join();
        }
    }
}

fn linger_loop(shared: &Shared) {
    let tick = shared.cfg.linger.max(Duration::from_millis(1));
    loop {
        let mut due: Vec<(usize, Vec<BatchItem>)> = Vec::new();
        let shutdown;
        {
            let g = lock_recover(&shared.pending);
            // a poisoned wait re-acquires the (recovered) guard the same way
            let (mut g, _timeout) = shared
                .wake
                .wait_timeout(g, tick)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            shutdown = g.shutdown;
            let now = Instant::now();
            let ready: Vec<usize> = g
                .groups
                .iter()
                .filter(|(_, (born, _))| {
                    shutdown || now.duration_since(*born) >= shared.cfg.linger
                })
                .map(|(&bucket, _)| bucket)
                .collect();
            for bucket in ready {
                if let Some((_, items)) = g.groups.remove(&bucket) {
                    due.push((bucket, items));
                }
            }
        }
        for (bucket, items) in due {
            shared.flush(bucket, items, false);
        }
        if shutdown {
            // One final drain pass in case something raced the shutdown.
            let drained: Vec<(usize, Vec<BatchItem>)> = {
                let mut g = lock_recover(&shared.pending);
                g.groups.drain().map(|(b, (_, items))| (b, items)).collect()
            };
            for (bucket, items) in drained {
                shared.flush(bucket, items, false);
            }
            return;
        }
    }
}

/// Test/bench backend: computes diameters on the CPU (brute force over the
/// f32 vertices, bit-identical to the reference oracle on the same input)
/// with a configurable fixed per-group overhead standing in for the engine
/// round-trip — which is exactly what batching amortises. Groups execute
/// under a lock, modelling the engine thread serialising its request queue.
pub struct CpuLoopbackBackend {
    buckets: Vec<usize>,
    overhead: Duration,
    serial: Mutex<()>,
}

impl CpuLoopbackBackend {
    pub fn new(overhead: Duration) -> CpuLoopbackBackend {
        // powers of two, 512 .. 131072 — mirrors the AOT bundle's ladder
        let buckets = (9..=17).map(|p| 1usize << p).collect();
        CpuLoopbackBackend { buckets, overhead, serial: Mutex::new(()) }
    }

    pub fn with_buckets(buckets: Vec<usize>, overhead: Duration) -> CpuLoopbackBackend {
        CpuLoopbackBackend { buckets, overhead, serial: Mutex::new(()) }
    }
}

impl BatchBackend for CpuLoopbackBackend {
    fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    fn execute_group(&self, bucket: usize, items: Vec<BatchItem>) {
        let _serial = lock_recover(&self.serial);
        if self.overhead > Duration::ZERO {
            // fixed per-round-trip cost, paid once per *group*
            std::thread::sleep(self.overhead);
        }
        for item in items {
            let t0 = Instant::now();
            let pts: Vec<Vec3> = item
                .verts
                .chunks_exact(3)
                .map(|c| Vec3::from([c[0], c[1], c[2]]))
                .collect();
            let d = brute_force_diameters(&pts);
            let timing = ExecTiming {
                transfer: Duration::ZERO,
                execute: t0.elapsed(),
                compile: Duration::ZERO,
                bucket,
            };
            let _ = item.reply.send(Ok((d, timing)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::Pcg32;

    fn cloud_f32(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::new(seed);
        (0..n * 3).map(|_| (rng.below(200) as f32) * 0.5).collect()
    }

    fn loopback(batch_size: usize) -> Batcher {
        Batcher::new(
            Arc::new(CpuLoopbackBackend::new(Duration::ZERO)),
            BatchConfig { batch_size, linger: Duration::from_millis(1) },
        )
    }

    #[test]
    fn passthrough_matches_brute_force() {
        let b = loopback(1);
        let verts = cloud_f32(100, 7);
        let pts: Vec<Vec3> =
            verts.chunks_exact(3).map(|c| Vec3::from([c[0], c[1], c[2]])).collect();
        let want = brute_force_diameters(&pts);
        let (got, timing) = b.diameters(verts).unwrap();
        assert_eq!(got.as_array(), want.as_array());
        assert_eq!(timing.bucket, 512);
        let s = b.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.flushes, 1);
        assert_eq!(s.full_flushes, 1);
    }

    #[test]
    fn batched_equals_unbatched_bit_for_bit() {
        let direct = loopback(1);
        let batched = loopback(4);
        let cases: Vec<Vec<f32>> = (0..12).map(|i| cloud_f32(40 + i * 17, i as u64)).collect();
        let direct_out: Vec<[f64; 4]> = cases
            .iter()
            .map(|v| direct.diameters(v.clone()).unwrap().0.as_array())
            .collect();
        // submit concurrently so groups actually fill
        let batched_out: Vec<[f64; 4]> = std::thread::scope(|scope| {
            let handles: Vec<_> = cases
                .iter()
                .map(|v| {
                    let batched = &batched;
                    let v = v.clone();
                    scope.spawn(move || batched.diameters(v).unwrap().0.as_array())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(direct_out, batched_out);
        let s = batched.stats();
        assert_eq!(s.submitted, 12);
        assert_eq!(s.flushed_items, 12);
        assert!(s.flushes <= 12);
        assert!(s.max_occupancy >= 1);
    }

    #[test]
    fn lone_request_is_flushed_by_linger() {
        let b = loopback(64); // far larger than one request
        let verts = cloud_f32(20, 3);
        let t0 = Instant::now();
        let (_, _) = b.diameters(verts).unwrap();
        // must return via the linger path well before any deadlock horizon
        assert!(t0.elapsed() < Duration::from_secs(5));
        let s = b.stats();
        assert_eq!(s.linger_flushes, 1);
        assert_eq!(s.full_flushes, 0);
    }

    #[test]
    fn oversized_input_errors() {
        // 9 verts but a tiny bucket ladder → routing must fail cleanly
        let tiny = Batcher::new(
            Arc::new(CpuLoopbackBackend::with_buckets(vec![4], Duration::ZERO)),
            BatchConfig { batch_size: 2, linger: Duration::from_millis(1) },
        );
        assert!(tiny.diameters(cloud_f32(9, 1)).is_err());
    }

    #[test]
    fn poisoned_pending_lock_does_not_kill_subsequent_submitters() {
        // One engine/worker thread panicking while it holds the pending
        // lock used to poison it for every later submitter — each
        // `.unwrap()` then panicked in turn, taking the whole run down.
        // With lock_recover, submissions keep flowing.
        let b = loopback(4);
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence the deliberate panic
        let _ = std::thread::spawn({
            let shared = b.shared.clone();
            move || {
                let _g = shared.pending.lock().unwrap();
                panic!("deliberate poison");
            }
        })
        .join();
        std::panic::set_hook(hook);
        assert!(b.shared.pending.is_poisoned(), "the lock must actually be poisoned");

        // concurrent submissions still batch and still match brute force
        let cases: Vec<Vec<f32>> = (0..8).map(|i| cloud_f32(30 + i * 11, i as u64)).collect();
        let out: Vec<[f64; 4]> = std::thread::scope(|scope| {
            let handles: Vec<_> = cases
                .iter()
                .map(|v| {
                    let b = &b;
                    let v = v.clone();
                    scope.spawn(move || b.diameters(v).unwrap().0.as_array())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (v, got) in cases.iter().zip(&out) {
            let pts: Vec<Vec3> =
                v.chunks_exact(3).map(|c| Vec3::from([c[0], c[1], c[2]])).collect();
            assert_eq!(*got, brute_force_diameters(&pts).as_array());
        }
        assert_eq!(b.stats().submitted, 8);
        // Drop (which locks pending to signal shutdown) must survive too.
        drop(b);
    }

    #[test]
    fn groups_are_keyed_by_bucket() {
        let b = loopback(2);
        // one small case (bucket 512) and one big (bucket 1024): they must
        // not co-batch; both arrive via linger
        let small = cloud_f32(10, 1);
        let big = cloud_f32(600, 2);
        std::thread::scope(|scope| {
            let b1 = &b;
            let b2 = &b;
            let h1 = scope.spawn(move || b1.diameters(small).unwrap().1.bucket);
            let h2 = scope.spawn(move || b2.diameters(big).unwrap().1.bucket);
            assert_eq!(h1.join().unwrap(), 512);
            assert_eq!(h2.join().unwrap(), 1024);
        });
        assert_eq!(b.stats().flushes, 2);
    }
}
