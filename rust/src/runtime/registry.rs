//! Artifact registry: parses `artifacts/manifest.txt` (written by
//! `python/compile/aot.py`) and indexes the available (kernel, bucket)
//! pairs. This is also the dispatcher's "is an accelerator present?" probe.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One artifact from the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub bucket: String,
    pub file: PathBuf,
    /// Declared input shapes, e.g. `["f32[4096,3]"]`.
    pub inputs: Vec<String>,
    pub outputs: usize,
}

/// Index over the artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    /// name → sorted numeric buckets (for `diameter` / `mesh_stats`).
    by_name: BTreeMap<String, Vec<ArtifactSpec>>,
}

impl ArtifactRegistry {
    /// Load `<dir>/manifest.txt`; verifies each referenced file exists.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {}", manifest.display()))?;
        let mut by_name: BTreeMap<String, Vec<ArtifactSpec>> = BTreeMap::new();
        for (no, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let spec = parse_line(line).with_context(|| format!("manifest line {}", no + 1))?;
            let path = dir.join(&spec.file);
            if !path.exists() {
                bail!("manifest references missing artifact {}", path.display());
            }
            by_name.entry(spec.name.clone()).or_default().push(spec);
        }
        if by_name.is_empty() {
            bail!("empty artifact manifest {}", manifest.display());
        }
        // sort numeric buckets ascending for bucket_for
        for specs in by_name.values_mut() {
            specs.sort_by_key(|s| s.bucket.parse::<usize>().unwrap_or(usize::MAX));
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), by_name })
    }

    /// All specs for a kernel name.
    pub fn specs(&self, name: &str) -> Option<&[ArtifactSpec]> {
        self.by_name.get(name).map(|v| v.as_slice())
    }

    /// Sorted numeric buckets for a kernel name.
    pub fn numeric_buckets(&self, name: &str) -> Vec<usize> {
        self.specs(name)
            .map(|s| s.iter().filter_map(|a| a.bucket.parse().ok()).collect())
            .unwrap_or_default()
    }

    /// Spec for an exact (name, bucket-key) pair.
    pub fn get(&self, name: &str, bucket: &str) -> Option<&ArtifactSpec> {
        self.specs(name)?.iter().find(|s| s.bucket == bucket)
    }

    /// Absolute path of an artifact file.
    pub fn path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    pub fn kernel_names(&self) -> Vec<&str> {
        self.by_name.keys().map(|s| s.as_str()).collect()
    }
}

fn parse_line(line: &str) -> Result<ArtifactSpec> {
    let mut name = None;
    let mut bucket = None;
    let mut file = None;
    let mut inputs = Vec::new();
    let mut outputs = 1usize;
    for tok in line.split_whitespace() {
        let Some((k, v)) = tok.split_once('=') else {
            bail!("bad token '{tok}'");
        };
        match k {
            "name" => name = Some(v.to_string()),
            "bucket" => bucket = Some(v.to_string()),
            "file" => file = Some(PathBuf::from(v)),
            "inputs" => inputs = v.split(';').map(|s| s.to_string()).collect(),
            "outputs" => outputs = v.parse().context("outputs")?,
            _ => {}
        }
    }
    Ok(ArtifactSpec {
        name: name.context("missing name=")?,
        bucket: bucket.context("missing bucket=")?,
        file: file.context("missing file=")?,
        inputs,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_registry(dir: &Path, lines: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
        std::fs::write(dir.join("manifest.txt"), lines).unwrap();
    }

    #[test]
    fn loads_and_sorts_buckets() {
        let dir = std::env::temp_dir().join("radpipe_registry_sorts");
        write_registry(
            &dir,
            "name=diameter bucket=4096 file=d4096.hlo.txt inputs=f32[4096,3] outputs=1\n\
             name=diameter bucket=512 file=d512.hlo.txt inputs=f32[512,3] outputs=1\n\
             name=mc_grid bucket=33x40x40 file=g.hlo.txt inputs=f32[33,40,40];f32[3] outputs=1\n",
            &["d4096.hlo.txt", "d512.hlo.txt", "g.hlo.txt"],
        );
        let r = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(r.numeric_buckets("diameter"), vec![512, 4096]);
        assert_eq!(r.kernel_names(), vec!["diameter", "mc_grid"]);
        let g = r.get("mc_grid", "33x40x40").unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert!(r.path(g).exists());
        assert!(r.get("diameter", "9999").is_none());
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("radpipe_registry_missing");
        write_registry(
            &dir,
            "name=diameter bucket=512 file=absent.hlo.txt inputs=f32[512,3] outputs=1\n",
            &[],
        );
        let err = ArtifactRegistry::load(&dir).unwrap_err();
        assert!(err.to_string().contains("missing artifact"));
    }

    #[test]
    fn empty_manifest_rejected() {
        let dir = std::env::temp_dir().join("radpipe_registry_empty");
        write_registry(&dir, "# nothing\n", &[]);
        assert!(ArtifactRegistry::load(&dir).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // When `make artifacts` has run, validate the real bundle.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return; // artifacts not built in this environment
        }
        let r = ArtifactRegistry::load(&dir).unwrap();
        assert!(r.specs("diameter").is_some());
        assert!(r.specs("mesh_stats").is_some());
        assert!(r.specs("mc_grid").is_some());
        let buckets = r.numeric_buckets("diameter");
        assert!(buckets.windows(2).all(|w| w[0] < w[1]), "sorted: {buckets:?}");
    }
}
