//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the request path. Python is never involved here.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so each PJRT
//! client is confined to one dedicated **engine thread** (the moral
//! equivalent of a CUDA stream): pipeline workers talk to it through an
//! MPSC request channel and get replies over per-request channels. The
//! engine compiles executables lazily per (kernel, bucket) and caches them.
//!
//! Scale-out layers on top:
//! * [`EnginePool`] — `engine_count` engine threads over one artifact
//!   bundle, fed round-robin with failure-aware rebalancing;
//! * [`Batcher`] — groups concurrent diameter requests by pad-bucket and
//!   flushes each group as one fused execution (size- or linger-triggered),
//!   amortising the per-case dispatch round-trip that dominates small ROIs.

mod registry;
mod engine;
mod buckets;
mod batcher;
mod pool;

pub use batcher::{BatchBackend, BatchConfig, BatchStatsSnapshot, Batcher, CpuLoopbackBackend};
pub use buckets::{bucket_for, pad_triangles, pad_vertices};
pub use engine::{BatchItem, Engine, EngineHandle, ExecTiming};
pub use pool::EnginePool;
pub use registry::{ArtifactRegistry, ArtifactSpec};
