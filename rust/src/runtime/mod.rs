//! PJRT runtime: loads the AOT HLO-text artifacts and executes them from
//! the request path. Python is never involved here.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so the whole
//! PJRT world is confined to one dedicated **engine thread** (the moral
//! equivalent of a CUDA stream): pipeline workers talk to it through an
//! MPSC request channel and get replies over per-request channels. The
//! engine compiles executables lazily per (kernel, bucket) and caches them.

mod registry;
mod engine;
mod buckets;

pub use buckets::{bucket_for, pad_triangles, pad_vertices};
pub use engine::{Engine, EngineHandle, ExecTiming};
pub use registry::{ArtifactRegistry, ArtifactSpec};
