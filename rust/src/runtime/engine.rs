//! The PJRT engine thread: owns the non-`Send` client, compiles artifacts
//! lazily, executes requests, reports per-phase timings.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::buckets::{bucket_for, pad_triangles, pad_vertices};
use super::registry::ArtifactRegistry;
use crate::features::Diameters;
use crate::trace::ArgV;

/// Phase timings of one artifact execution — the Table 2 GPU columns.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecTiming {
    /// Host → device buffer upload ("D. tran").
    pub transfer: Duration,
    /// Executable run + result download.
    pub execute: Duration,
    /// Lazily-compiled-this-call compile time (0 when cached).
    pub compile: Duration,
    /// Bucket the request was routed to.
    pub bucket: usize,
}

/// One case of a batched execution: its vertex data and the per-item reply
/// channel the engine answers on. Grouping is done upstream by
/// [`super::batcher::Batcher`]; the engine executes the group in one
/// request round-trip and splits results per item.
pub struct BatchItem {
    pub verts: Vec<f32>,
    pub reply: mpsc::Sender<Result<(Diameters, ExecTiming)>>,
}

enum Request {
    Diameters {
        verts: Vec<f32>,
        reply: mpsc::Sender<Result<(Diameters, ExecTiming)>>,
    },
    /// A pad-bucket group of diameter cases executed back-to-back in one
    /// channel round-trip (executable cache hot after the first item);
    /// each item keeps its own upload/launch and [`ExecTiming`].
    DiametersBatch {
        items: Vec<BatchItem>,
    },
    MeshStats {
        tris: Vec<f32>,
        reply: mpsc::Sender<Result<([f64; 2], ExecTiming)>>,
    },
    /// Pre-compile every artifact (warm start), reply with count.
    WarmUp {
        reply: mpsc::Sender<Result<usize>>,
    },
    Shutdown,
}

/// Cloneable handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Request>,
}

/// The engine: spawn with [`Engine::start`], talk through [`EngineHandle`].
pub struct Engine {
    handle: EngineHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Engine {
    /// Start the engine thread over an artifact directory. Fails fast (in
    /// the caller's thread) if the manifest is unreadable; PJRT client
    /// construction happens on the engine thread and surfaces on first use.
    pub fn start(artifact_dir: &std::path::Path) -> Result<Engine> {
        let registry = ArtifactRegistry::load(artifact_dir)?;
        Self::with_registry(registry)
    }

    /// Start an engine thread over an already-loaded registry (the pool
    /// loads the manifest once and hands a clone to each engine).
    pub fn with_registry(registry: ArtifactRegistry) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || engine_main(registry, rx))
            .context("spawn pjrt-engine")?;
        Ok(Engine { handle: EngineHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    /// Max 3D + planar diameters of f32[n,3] vertices via the AOT artifact.
    /// Returns squared diameters (artifact returns lengths; squared here
    /// for interface parity with the CPU path) and phase timings.
    pub fn diameters(&self, verts: Vec<f32>) -> Result<(Diameters, ExecTiming)> {
        let rx = self
            .diameters_async(verts)
            .map_err(|_| anyhow!("pjrt engine is down"))?;
        rx.recv().map_err(|_| anyhow!("pjrt engine dropped the request"))?
    }

    /// Non-blocking submit of a diameters request. On engine death the
    /// vertex buffer is handed back so the caller can retry on another
    /// engine (the [`super::pool::EnginePool`] failover path).
    pub fn diameters_async(
        &self,
        verts: Vec<f32>,
    ) -> std::result::Result<mpsc::Receiver<Result<(Diameters, ExecTiming)>>, Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        match self.tx.send(Request::Diameters { verts, reply }) {
            Ok(()) => Ok(rx),
            Err(e) => match e.0 {
                Request::Diameters { verts, .. } => Err(verts),
                _ => unreachable!("send returned a different request"),
            },
        }
    }

    /// Submit a fused batch. On engine death the items (with their intact
    /// reply channels) are handed back for re-dispatch elsewhere.
    pub fn submit_batch(
        &self,
        items: Vec<BatchItem>,
    ) -> std::result::Result<(), Vec<BatchItem>> {
        match self.tx.send(Request::DiametersBatch { items }) {
            Ok(()) => Ok(()),
            Err(e) => match e.0 {
                Request::DiametersBatch { items } => Err(items),
                _ => unreachable!("send returned a different request"),
            },
        }
    }

    /// Fused [volume, area] of an f32[t,9] triangle soup.
    pub fn mesh_stats(&self, tris: Vec<f32>) -> Result<([f64; 2], ExecTiming)> {
        let rx = self
            .mesh_stats_async(tris)
            .map_err(|_| anyhow!("pjrt engine is down"))?;
        rx.recv().map_err(|_| anyhow!("pjrt engine dropped the request"))?
    }

    /// Non-blocking submit of a mesh-stats request; hands the triangle soup
    /// back on engine death (pool failover).
    pub fn mesh_stats_async(
        &self,
        tris: Vec<f32>,
    ) -> std::result::Result<mpsc::Receiver<Result<([f64; 2], ExecTiming)>>, Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        match self.tx.send(Request::MeshStats { tris, reply }) {
            Ok(()) => Ok(rx),
            Err(e) => match e.0 {
                Request::MeshStats { tris, .. } => Err(tris),
                _ => unreachable!("send returned a different request"),
            },
        }
    }

    /// Compile all artifacts now; returns how many were compiled.
    pub fn warm_up(&self) -> Result<usize> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::WarmUp { reply })
            .map_err(|_| anyhow!("pjrt engine is down"))?;
        rx.recv().map_err(|_| anyhow!("pjrt engine dropped the request"))?
    }
}

/// Engine-thread state.
struct EngineState {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    /// (kernel, bucket-key) → compiled executable.
    cache: HashMap<(String, String), xla::PjRtLoadedExecutable>,
}

fn engine_main(registry: ArtifactRegistry, rx: mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Drain requests, failing each with the construction error.
            for req in rx {
                let _sp = crate::trace::span_args(
                    "engine.request",
                    &[
                        ("kind", ArgV::Str(request_kind(&req))),
                        ("outcome", ArgV::Str("init_failed")),
                    ],
                );
                let msg = format!("PJRT client init failed: {e}");
                match req {
                    Request::Diameters { reply, .. } => {
                        let _ = reply.send(Err(anyhow!(msg)));
                    }
                    Request::DiametersBatch { items } => {
                        for item in items {
                            let _ = item.reply.send(Err(anyhow!("{msg}")));
                        }
                    }
                    Request::MeshStats { reply, .. } => {
                        let _ = reply.send(Err(anyhow!(msg)));
                    }
                    Request::WarmUp { reply } => {
                        let _ = reply.send(Err(anyhow!(msg)));
                    }
                    Request::Shutdown => return,
                }
            }
            return;
        }
    };
    let mut state = EngineState { client, registry, cache: HashMap::new() };
    for req in rx {
        let kind = request_kind(&req);
        match req {
            Request::Diameters { verts, reply } => {
                let _sp = crate::trace::span_args("engine.request", &[("kind", ArgV::Str(kind))]);
                let _ = reply.send(run_diameters(&mut state, &verts));
            }
            Request::DiametersBatch { items } => {
                let _sp = crate::trace::span_args(
                    "engine.request",
                    &[("kind", ArgV::Str(kind)), ("items", ArgV::Int(items.len() as u64))],
                );
                for item in items {
                    let _ = item.reply.send(run_diameters(&mut state, &item.verts));
                }
            }
            Request::MeshStats { tris, reply } => {
                let _sp = crate::trace::span_args("engine.request", &[("kind", ArgV::Str(kind))]);
                let _ = reply.send(run_mesh_stats(&mut state, &tris));
            }
            Request::WarmUp { reply } => {
                let _sp = crate::trace::span_args("engine.request", &[("kind", ArgV::Str(kind))]);
                let _ = reply.send(warm_up(&mut state));
            }
            Request::Shutdown => break,
        }
    }
}

/// Trace label for a request variant.
fn request_kind(req: &Request) -> &'static str {
    match req {
        Request::Diameters { .. } => "diameters",
        Request::DiametersBatch { .. } => "diameters_batch",
        Request::MeshStats { .. } => "mesh_stats",
        Request::WarmUp { .. } => "warm_up",
        Request::Shutdown => "shutdown",
    }
}

fn compile<'a>(
    state: &'a mut EngineState,
    name: &str,
    bucket_key: &str,
) -> Result<(Duration, &'a xla::PjRtLoadedExecutable)> {
    let key = (name.to_string(), bucket_key.to_string());
    let mut took = Duration::ZERO;
    if !state.cache.contains_key(&key) {
        let _sp = crate::trace::span_args(
            "engine.compile",
            &[("kernel", ArgV::Str(name)), ("bucket", ArgV::Str(bucket_key))],
        );
        let spec = state
            .registry
            .get(name, bucket_key)
            .with_context(|| format!("no artifact {name}[{bucket_key}]"))?
            .clone();
        let path: PathBuf = state.registry.path(&spec);
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = state
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}[{bucket_key}]: {e}"))?;
        took = start.elapsed();
        state.cache.insert(key.clone(), exe);
    }
    Ok((took, state.cache.get(&key).unwrap()))
}

fn run_diameters(state: &mut EngineState, verts: &[f32]) -> Result<(Diameters, ExecTiming)> {
    let n = verts.len() / 3;
    let buckets = state.registry.numeric_buckets("diameter");
    if buckets.is_empty() {
        bail!("no diameter artifacts in registry");
    }
    let bucket = bucket_for(n, &buckets)?;
    let padded = pad_vertices(verts, bucket)?;

    let (compile_t, _) = compile(state, "diameter", &bucket.to_string())?;

    // transfer phase: host → device buffer
    let t0 = Instant::now();
    let buf = state
        .client
        .buffer_from_host_buffer::<f32>(&padded, &[bucket, 3], None)
        .map_err(|e| anyhow!("upload: {e}"))?;
    let transfer = t0.elapsed();
    crate::trace::complete_span(
        "engine.transfer",
        t0,
        transfer,
        &[("bucket", ArgV::Int(bucket as u64))],
    );

    // execute phase (+ result download)
    let exe = state.cache.get(&("diameter".to_string(), bucket.to_string())).unwrap();
    let t1 = Instant::now();
    let result = exe.execute_b::<xla::PjRtBuffer>(&[buf]).map_err(|e| anyhow!("execute: {e}"))?;
    let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("download: {e}"))?;
    let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
    let vals = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
    let execute = t1.elapsed();
    crate::trace::complete_span(
        "engine.execute",
        t1,
        execute,
        &[("bucket", ArgV::Int(bucket as u64))],
    );

    if vals.len() != 4 {
        bail!("diameter artifact returned {} values, want 4", vals.len());
    }
    // Artifact yields diameter *lengths* (NaN for empty planes); the
    // in-process interface speaks squared distances with -1 sentinels.
    let sq = |v: f32| {
        if v.is_nan() {
            -1.0
        } else {
            (v as f64) * (v as f64)
        }
    };
    let d = Diameters {
        d3d_sq: sq(vals[0]),
        dxy_sq: sq(vals[1]),
        dyz_sq: sq(vals[2]),
        dxz_sq: sq(vals[3]),
    };
    Ok((d, ExecTiming { transfer, execute, compile: compile_t, bucket }))
}

fn run_mesh_stats(state: &mut EngineState, tris: &[f32]) -> Result<([f64; 2], ExecTiming)> {
    let t = tris.len() / 9;
    let buckets = state.registry.numeric_buckets("mesh_stats");
    if buckets.is_empty() {
        bail!("no mesh_stats artifacts in registry");
    }
    let bucket = bucket_for(t, &buckets)?;
    let padded = pad_triangles(tris, bucket)?;

    let (compile_t, _) = compile(state, "mesh_stats", &bucket.to_string())?;

    let t0 = Instant::now();
    let buf = state
        .client
        .buffer_from_host_buffer::<f32>(&padded, &[bucket, 9], None)
        .map_err(|e| anyhow!("upload: {e}"))?;
    let transfer = t0.elapsed();
    crate::trace::complete_span(
        "engine.transfer",
        t0,
        transfer,
        &[("bucket", ArgV::Int(bucket as u64))],
    );

    let exe = state.cache.get(&("mesh_stats".to_string(), bucket.to_string())).unwrap();
    let t1 = Instant::now();
    let result = exe.execute_b::<xla::PjRtBuffer>(&[buf]).map_err(|e| anyhow!("execute: {e}"))?;
    let lit = result[0][0].to_literal_sync().map_err(|e| anyhow!("download: {e}"))?;
    let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
    let vals = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
    let execute = t1.elapsed();
    crate::trace::complete_span(
        "engine.execute",
        t1,
        execute,
        &[("bucket", ArgV::Int(bucket as u64))],
    );

    if vals.len() != 2 {
        bail!("mesh_stats artifact returned {} values, want 2", vals.len());
    }
    Ok((
        [vals[0] as f64, vals[1] as f64],
        ExecTiming { transfer, execute, compile: compile_t, bucket },
    ))
}

fn warm_up(state: &mut EngineState) -> Result<usize> {
    let mut compiled = 0;
    let pairs: Vec<(String, String)> = state
        .registry
        .kernel_names()
        .iter()
        .flat_map(|name| {
            state
                .registry
                .specs(name)
                .unwrap_or_default()
                .iter()
                .map(|s| (s.name.clone(), s.bucket.clone()))
                .collect::<Vec<_>>()
        })
        .collect();
    for (name, bucket) in pairs {
        let (took, _) = compile(state, &name, &bucket)?;
        if took > Duration::ZERO {
            compiled += 1;
        }
    }
    Ok(compiled)
}
