//! Size-bucket policy shared with `python/compile/model.py`: route a
//! dynamic size to the smallest static AOT bucket that fits, pad the data
//! in a result-preserving way.

use anyhow::{bail, Result};

/// Smallest bucket ≥ `count`. `buckets` must be sorted ascending.
pub fn bucket_for(count: usize, buckets: &[usize]) -> Result<usize> {
    for &b in buckets {
        if count <= b {
            return Ok(b);
        }
    }
    bail!(
        "count {count} exceeds the largest AOT bucket {:?} — regenerate artifacts with --full",
        buckets.last()
    )
}

/// Pad f32[n,3] vertex data to `bucket` rows by duplicating the first
/// vertex (duplicates can never increase a max-distance reduction).
pub fn pad_vertices(verts: &[f32], bucket: usize) -> Result<Vec<f32>> {
    if verts.len() % 3 != 0 {
        bail!("vertex buffer length {} not divisible by 3", verts.len());
    }
    let n = verts.len() / 3;
    if n == 0 {
        bail!("cannot pad an empty vertex buffer");
    }
    if n > bucket {
        bail!("{n} vertices exceed bucket {bucket}");
    }
    let mut out = Vec::with_capacity(bucket * 3);
    out.extend_from_slice(verts);
    let first = [verts[0], verts[1], verts[2]];
    for _ in n..bucket {
        out.extend_from_slice(&first);
    }
    Ok(out)
}

/// Pad f32[t,9] triangle-soup data to `bucket` rows with degenerate
/// all-zero triangles (zero area, zero signed volume).
pub fn pad_triangles(tris: &[f32], bucket: usize) -> Result<Vec<f32>> {
    if tris.len() % 9 != 0 {
        bail!("triangle buffer length {} not divisible by 9", tris.len());
    }
    let t = tris.len() / 9;
    if t > bucket {
        bail!("{t} triangles exceed bucket {bucket}");
    }
    let mut out = Vec::with_capacity(bucket * 9);
    out.extend_from_slice(tris);
    out.resize(bucket * 9, 0.0);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_policy() {
        let b = [512usize, 1024, 4096];
        assert_eq!(bucket_for(1, &b).unwrap(), 512);
        assert_eq!(bucket_for(512, &b).unwrap(), 512);
        assert_eq!(bucket_for(513, &b).unwrap(), 1024);
        assert_eq!(bucket_for(4096, &b).unwrap(), 4096);
        assert!(bucket_for(4097, &b).is_err());
    }

    #[test]
    fn vertex_padding_duplicates_first() {
        let v = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let p = pad_vertices(&v, 4).unwrap();
        assert_eq!(p.len(), 12);
        assert_eq!(&p[..6], &v[..]);
        assert_eq!(&p[6..9], &[1.0, 2.0, 3.0]);
        assert_eq!(&p[9..12], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn vertex_padding_errors() {
        assert!(pad_vertices(&[1.0, 2.0], 4).is_err()); // not /3
        assert!(pad_vertices(&[], 4).is_err()); // empty
        let v = vec![0.0f32; 15];
        assert!(pad_vertices(&v, 4).is_err()); // 5 > 4
    }

    #[test]
    fn triangle_padding_zero_fills() {
        let t = vec![1.0f32; 9];
        let p = pad_triangles(&t, 3).unwrap();
        assert_eq!(p.len(), 27);
        assert!(p[9..].iter().all(|&v| v == 0.0));
        // empty soup is fine for triangles (volume 0)
        assert_eq!(pad_triangles(&[], 2).unwrap(), vec![0.0; 18]);
    }
}
