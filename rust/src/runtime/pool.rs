//! Multi-engine sharding: a pool of [`Engine`] threads fed round-robin,
//! with failure-aware rebalancing.
//!
//! The single-engine design serialises every artifact execution on one
//! thread — the right model for one accelerator, but a scale-out ceiling
//! for dataset serving. `EnginePool` spins up `engine_count` engines over
//! the same artifact bundle (the moral equivalent of multiple devices or
//! streams) and shards work across them. An engine whose request channel
//! has died is marked dead and skipped; in-flight work is re-dispatched to
//! the next live engine, so a single wedged engine degrades throughput
//! instead of failing cases.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use super::batcher::BatchBackend;
use super::engine::{BatchItem, Engine, EngineHandle, ExecTiming};
use super::registry::ArtifactRegistry;
use crate::features::Diameters;

/// A pool of engine threads over one artifact directory.
pub struct EnginePool {
    engines: Vec<Engine>,
    alive: Vec<AtomicBool>,
    cursor: AtomicUsize,
    diameter_buckets: Vec<usize>,
}

impl EnginePool {
    /// Start `count` engines (at least one) over `artifact_dir`. Fails fast
    /// if the manifest is unreadable; PJRT construction surfaces per-engine
    /// on first use, exactly like [`Engine::start`].
    pub fn start(artifact_dir: &Path, count: usize) -> Result<EnginePool> {
        let count = count.max(1);
        // Load the registry once up front: fail-fast validation, the
        // diameter bucket list the batcher groups by, and one parse shared
        // by every engine instead of count+1 manifest reads.
        let registry = ArtifactRegistry::load(artifact_dir)?;
        let diameter_buckets = registry.numeric_buckets("diameter");
        let mut engines = Vec::with_capacity(count);
        for _ in 0..count {
            engines.push(Engine::with_registry(registry.clone())?);
        }
        let alive = (0..count).map(|_| AtomicBool::new(true)).collect();
        Ok(EnginePool { engines, alive, cursor: AtomicUsize::new(0), diameter_buckets })
    }

    /// Number of engines the pool was started with.
    pub fn engine_count(&self) -> usize {
        self.engines.len()
    }

    /// Engines still accepting work.
    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| a.load(Ordering::Relaxed)).count()
    }

    /// Sorted diameter pad-buckets of the artifact bundle.
    pub fn diameter_buckets(&self) -> &[usize] {
        &self.diameter_buckets
    }

    /// A handle to the next live engine (round-robin); falls back to engine
    /// 0 when everything is marked dead (the call will then error cleanly).
    pub fn handle(&self) -> EngineHandle {
        match self.next_alive() {
            Some(i) => self.engines[i].handle(),
            None => self.engines[0].handle(),
        }
    }

    fn next_alive(&self) -> Option<usize> {
        let n = self.engines.len();
        for _ in 0..n {
            let i = self.cursor.fetch_add(1, Ordering::Relaxed) % n;
            if self.alive[i].load(Ordering::Relaxed) {
                return Some(i);
            }
        }
        None
    }

    fn mark_dead(&self, i: usize) {
        self.alive[i].store(false, Ordering::Relaxed);
        eprintln!("radpipe: engine {i} is down; rebalancing onto the remaining pool");
    }

    /// Diameters with engine failover: a dead engine returns the buffer,
    /// which is resubmitted to the next live one.
    pub fn diameters(&self, verts: Vec<f32>) -> Result<(Diameters, ExecTiming)> {
        let mut verts = verts;
        for _ in 0..self.engines.len() {
            let Some(i) = self.next_alive() else { break };
            match self.engines[i].handle().diameters_async(verts) {
                Ok(rx) => {
                    return rx
                        .recv()
                        .map_err(|_| anyhow!("engine {i} dropped the request"))?;
                }
                Err(back) => {
                    self.mark_dead(i);
                    verts = back;
                }
            }
        }
        bail!("engine pool exhausted: no live engines")
    }

    /// Mesh stats with the same failover policy.
    pub fn mesh_stats(&self, tris: Vec<f32>) -> Result<([f64; 2], ExecTiming)> {
        let mut tris = tris;
        for _ in 0..self.engines.len() {
            let Some(i) = self.next_alive() else { break };
            match self.engines[i].handle().mesh_stats_async(tris) {
                Ok(rx) => {
                    return rx
                        .recv()
                        .map_err(|_| anyhow!("engine {i} dropped the request"))?;
                }
                Err(back) => {
                    self.mark_dead(i);
                    tris = back;
                }
            }
        }
        bail!("engine pool exhausted: no live engines")
    }

    /// Probe **every** engine with a tiny request so per-engine PJRT init
    /// errors surface at startup rather than mid-pipeline once the batcher
    /// shards work onto a broken engine.
    pub fn smoke_test(&self) -> Result<()> {
        for (i, engine) in self.engines.iter().enumerate() {
            engine
                .handle()
                .diameters(vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0])
                .with_context(|| format!("engine {i} smoke test"))?;
        }
        Ok(())
    }

    /// Warm every live engine's executable cache; returns the total number
    /// of fresh compilations across the pool.
    pub fn warm_up(&self) -> Result<usize> {
        let mut compiled = 0;
        for (i, engine) in self.engines.iter().enumerate() {
            if self.alive[i].load(Ordering::Relaxed) {
                compiled += engine.handle().warm_up()?;
            }
        }
        Ok(compiled)
    }

    /// Shard one batch onto the next live engine; on engine death the items
    /// come back intact and are re-dispatched. If the whole pool is down,
    /// every item's reply channel receives an error (no caller hangs).
    pub fn submit_batch(&self, items: Vec<BatchItem>) -> Result<()> {
        let mut items = items;
        for _ in 0..self.engines.len() {
            let Some(i) = self.next_alive() else { break };
            match self.engines[i].handle().submit_batch(items) {
                Ok(()) => return Ok(()),
                Err(back) => {
                    self.mark_dead(i);
                    items = back;
                }
            }
        }
        for item in items {
            let _ = item.reply.send(Err(anyhow!("engine pool exhausted: no live engines")));
        }
        bail!("engine pool exhausted: no live engines")
    }
}

impl BatchBackend for EnginePool {
    fn buckets(&self) -> &[usize] {
        &self.diameter_buckets
    }

    fn execute_group(&self, _bucket: usize, items: Vec<BatchItem>) {
        // Per-item errors were already delivered on total failure.
        let _ = self.submit_batch(items);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn missing_artifacts_fail_fast() {
        let err = EnginePool::start(&PathBuf::from("/nonexistent/artifacts"), 3).unwrap_err();
        assert!(format!("{err:#}").contains("manifest"));
    }

    fn fake_artifact_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("radpipe_pool_test_{tag}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("d512.hlo.txt"), "HloModule fake").unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "name=diameter bucket=512 file=d512.hlo.txt inputs=f32[512,3] outputs=1\n",
        )
        .unwrap();
        dir
    }

    #[test]
    fn pool_starts_engines_and_reads_buckets() {
        let dir = fake_artifact_dir("buckets");
        let pool = EnginePool::start(&dir, 2).unwrap();
        assert_eq!(pool.engine_count(), 2);
        assert_eq!(pool.alive_count(), 2);
        assert_eq!(pool.diameter_buckets(), &[512]);
    }

    #[test]
    fn requests_error_cleanly_without_pjrt() {
        // Engines start, but the vendored PJRT stub fails at client
        // construction — requests must return errors, not hang, and the
        // engines stay "alive" (the channel is fine; the runtime is not).
        let dir = fake_artifact_dir("nopjrt");
        let pool = EnginePool::start(&dir, 2).unwrap();
        let err = pool.diameters(vec![0.0, 0.0, 0.0, 1.0, 1.0, 1.0]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("PJRT") || msg.contains("unavailable"), "{msg}");
        assert_eq!(pool.alive_count(), 2, "runtime errors must not kill engines");
    }

    #[test]
    fn smoke_test_surfaces_engine_init_failures() {
        // With the PJRT stub every engine fails init; the smoke test must
        // report it (per-engine) instead of passing on a lucky round-robin.
        let dir = fake_artifact_dir("smoke");
        let pool = EnginePool::start(&dir, 3).unwrap();
        let err = pool.smoke_test().unwrap_err();
        assert!(format!("{err:#}").contains("engine 0 smoke test"), "{err:#}");
    }

    #[test]
    fn zero_engine_request_is_clamped_to_one() {
        let dir = fake_artifact_dir("clamp");
        let pool = EnginePool::start(&dir, 0).unwrap();
        assert_eq!(pool.engine_count(), 1);
    }
}
