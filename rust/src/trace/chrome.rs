//! Chrome Trace Event Format emitter and validating parser.
//!
//! The emitted document is the object form of the format —
//! `{"traceEvents": [...]}` — with three event phases:
//!
//! * `ph:"M"` metadata: one `thread_name` event per recording thread (plus
//!   one `process_name` event naming the process `radpipe`), so the
//!   chrome://tracing / Perfetto track labels show `read-0`, `extract-3`,
//!   `radpipe-batcher`, `pjrt-engine`, … instead of bare tids;
//! * `ph:"X"` complete events: one per recorded span, `ts`/`dur` in
//!   microseconds since the sink epoch, `cat:"radpipe"`, args verbatim;
//! * `ph:"C"` counter events: one per counter sample (`args.value`),
//!   rendered by the viewers as a filled counter track (e.g.
//!   `mem.resident_bytes`).
//!
//! [`parse`] is the inverse used by the `obs-check` CLI gate and the
//! trace tests: it accepts both the object form and the bare-array form,
//! validates phase-specific invariants (finite non-negative `ts`, `dur ≥ 0`
//! on complete events, positive integral `pid`/`tid`) and keeps args
//! available for assertions.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use super::{OwnedArg, TraceSink};
use crate::report::JsonValue;

/// Category tag stamped on every emitted span.
pub const CATEGORY: &str = "radpipe";

fn args_obj(args: &[(String, OwnedArg)]) -> JsonValue {
    let mut o = JsonValue::obj();
    for (k, v) in args {
        match v {
            OwnedArg::Str(s) => o.set(k, s.as_str()),
            OwnedArg::Num(n) => o.set(k, *n),
            OwnedArg::Int(i) => o.set(k, *i as f64),
        };
    }
    o
}

/// Serialize everything `sink` recorded as Chrome Trace Event JSON.
pub(super) fn emit(sink: &TraceSink) -> String {
    let pid = sink.pid() as f64;
    let mut events = Vec::new();

    let threads = sink.snapshot_threads();
    let process_tid = threads.keys().next().copied().unwrap_or(1);
    let mut pmeta = JsonValue::obj();
    let mut pargs = JsonValue::obj();
    pargs.set("name", "radpipe");
    pmeta.set("ph", "M").set("name", "process_name").set("pid", pid);
    pmeta.set("tid", process_tid as f64).set("args", pargs);
    events.push(pmeta);

    for (tid, name) in &threads {
        let mut meta = JsonValue::obj();
        let mut margs = JsonValue::obj();
        margs.set("name", name.as_str());
        meta.set("ph", "M").set("name", "thread_name").set("pid", pid);
        meta.set("tid", *tid as f64).set("args", margs);
        events.push(meta);
    }

    for sp in sink.snapshot_spans() {
        let mut ev = JsonValue::obj();
        ev.set("ph", "X").set("name", sp.name.as_str()).set("cat", CATEGORY);
        ev.set("ts", sp.ts_us as f64).set("dur", sp.dur_us as f64);
        ev.set("pid", pid).set("tid", sp.tid as f64);
        ev.set("args", args_obj(&sp.args));
        events.push(ev);
    }

    for c in sink.snapshot_counters() {
        let mut ev = JsonValue::obj();
        let mut cargs = JsonValue::obj();
        cargs.set("value", c.value);
        ev.set("ph", "C").set("name", c.track.as_str()).set("cat", CATEGORY);
        ev.set("ts", c.ts_us as f64).set("pid", pid).set("tid", c.tid as f64);
        ev.set("args", cargs);
        events.push(ev);
    }

    let mut doc = JsonValue::obj();
    doc.set("traceEvents", JsonValue::Arr(events));
    doc.to_string()
}

/// One parsed trace event (any phase).
#[derive(Debug, Clone)]
pub struct ChromeEvent {
    pub ph: char,
    pub name: String,
    pub pid: u64,
    pub tid: u64,
    /// Microseconds; 0 for metadata events that omit `ts`.
    pub ts: f64,
    /// Microseconds; only meaningful on `ph:'X'` events.
    pub dur: f64,
    pub args: BTreeMap<String, JsonValue>,
}

impl ChromeEvent {
    /// String-valued arg lookup.
    pub fn arg_str(&self, key: &str) -> Option<&str> {
        self.args.get(key).and_then(JsonValue::as_str)
    }

    /// Numeric arg lookup.
    pub fn arg_num(&self, key: &str) -> Option<f64> {
        self.args.get(key).and_then(JsonValue::as_f64)
    }

    /// Span end timestamp (`ts + dur`), in microseconds.
    pub fn end_ts(&self) -> f64 {
        self.ts + self.dur
    }
}

/// A parsed, validated Chrome trace document.
#[derive(Debug, Clone)]
pub struct ChromeTrace {
    pub events: Vec<ChromeEvent>,
}

impl ChromeTrace {
    /// Complete (`ph:'X'`) span events, in recorded order.
    pub fn spans(&self) -> impl Iterator<Item = &ChromeEvent> {
        self.events.iter().filter(|e| e.ph == 'X')
    }

    /// Counter (`ph:'C'`) sample events, in recorded order.
    pub fn counters(&self) -> impl Iterator<Item = &ChromeEvent> {
        self.events.iter().filter(|e| e.ph == 'C')
    }

    /// Distinct span names.
    pub fn span_names(&self) -> BTreeSet<&str> {
        self.spans().map(|e| e.name.as_str()).collect()
    }

    /// Distinct counter track names.
    pub fn counter_tracks(&self) -> BTreeSet<&str> {
        self.counters().map(|e| e.name.as_str()).collect()
    }

    /// Distinct values of the `"case"` arg across spans.
    pub fn span_cases(&self) -> BTreeSet<String> {
        self.spans().filter_map(|e| e.arg_str("case").map(str::to_string)).collect()
    }

    /// Thread names declared via `thread_name` metadata, keyed by tid.
    pub fn thread_names(&self) -> BTreeMap<u64, String> {
        self.events
            .iter()
            .filter(|e| e.ph == 'M' && e.name == "thread_name")
            .filter_map(|e| e.arg_str("name").map(|n| (e.tid, n.to_string())))
            .collect()
    }
}

fn field_u64(ev: &JsonValue, key: &str, i: usize) -> Result<u64> {
    let Some(n) = ev.get(key).and_then(JsonValue::as_f64) else {
        bail!("trace event #{i}: missing numeric field {key:?}");
    };
    if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > 2f64.powi(53) {
        bail!("trace event #{i}: field {key:?} is not a non-negative integer (got {n})");
    }
    Ok(n as u64)
}

/// Parse and validate a Chrome Trace Event JSON document. Accepts both
/// the `{"traceEvents": [...]}` object form (what [`emit`] writes) and a
/// bare event array.
pub fn parse(text: &str) -> Result<ChromeTrace> {
    let doc = JsonValue::parse(text)?;
    let events_json = match &doc {
        JsonValue::Arr(items) => items.as_slice(),
        JsonValue::Obj(_) => match doc.get("traceEvents").and_then(JsonValue::as_arr) {
            Some(items) => items,
            None => bail!("trace document has no \"traceEvents\" array"),
        },
        _ => bail!("trace document is neither an object nor an event array"),
    };

    let mut events = Vec::with_capacity(events_json.len());
    for (i, ev) in events_json.iter().enumerate() {
        let JsonValue::Obj(_) = ev else {
            bail!("trace event #{i} is not an object");
        };
        let Some(ph_str) = ev.get("ph").and_then(JsonValue::as_str) else {
            bail!("trace event #{i}: missing \"ph\" phase");
        };
        let ph = match ph_str {
            "M" => 'M',
            "X" => 'X',
            "C" => 'C',
            other => bail!("trace event #{i}: unsupported phase {other:?}"),
        };
        let Some(name) = ev.get("name").and_then(JsonValue::as_str) else {
            bail!("trace event #{i}: missing \"name\"");
        };
        if name.is_empty() {
            bail!("trace event #{i}: empty \"name\"");
        }
        let pid = field_u64(ev, "pid", i)?;
        let tid = field_u64(ev, "tid", i)?;
        if matches!(ph, 'X' | 'C') && (pid == 0 || tid == 0) {
            bail!("trace event #{i} ({name}): pid/tid must be >= 1, got pid={pid} tid={tid}");
        }

        let ts = match ev.get("ts").and_then(JsonValue::as_f64) {
            Some(t) => {
                if !t.is_finite() || t < 0.0 {
                    bail!("trace event #{i} ({name}): invalid ts {t}");
                }
                t
            }
            None if ph == 'M' => 0.0,
            None => bail!("trace event #{i} ({name}): missing \"ts\""),
        };
        let dur = match ev.get("dur").and_then(JsonValue::as_f64) {
            Some(d) => {
                if !d.is_finite() || d < 0.0 {
                    bail!("trace event #{i} ({name}): invalid dur {d}");
                }
                d
            }
            None if ph == 'X' => bail!("trace event #{i} ({name}): complete event without \"dur\""),
            None => 0.0,
        };
        if ph == 'C' {
            let value = ev.get("args").and_then(|a| a.get("value")).and_then(JsonValue::as_f64);
            if value.is_none() {
                bail!("trace event #{i} ({name}): counter event without numeric args.value");
            }
        }

        let args = match ev.get("args") {
            Some(JsonValue::Obj(m)) => m.clone(),
            Some(_) => bail!("trace event #{i} ({name}): \"args\" is not an object"),
            None => BTreeMap::new(),
        };
        events.push(ChromeEvent { ph, name: name.to_string(), pid, tid, ts, dur, args });
    }
    Ok(ChromeTrace { events })
}

#[cfg(test)]
mod tests {
    use std::time::{Duration, Instant};

    use super::*;
    use crate::trace::ArgV;

    fn sample_sink() -> std::sync::Arc<TraceSink> {
        let sink = TraceSink::new();
        let t0 = Instant::now();
        sink.record_span(
            "stage.read",
            t0,
            Duration::from_micros(120),
            &[("case", ArgV::Str("case-1"))],
        );
        sink.record_span(
            "stage.mesh",
            t0,
            Duration::from_micros(300),
            &[("case", ArgV::Str("case-1")), ("verts", ArgV::Int(42))],
        );
        sink.record_counter("mem.resident_bytes", 8192.0);
        sink
    }

    #[test]
    fn emit_parse_round_trip() {
        let sink = sample_sink();
        let json = sink.to_chrome_json();
        let trace = parse(&json).unwrap();

        assert_eq!(trace.spans().count(), 2);
        assert_eq!(trace.counters().count(), 1);
        assert!(trace.span_names().contains("stage.read"));
        assert!(trace.span_names().contains("stage.mesh"));
        assert!(trace.counter_tracks().contains("mem.resident_bytes"));
        assert_eq!(trace.span_cases().into_iter().collect::<Vec<_>>(), vec!["case-1"]);

        let mesh = trace.spans().find(|e| e.name == "stage.mesh").unwrap();
        assert_eq!(mesh.dur, 300.0);
        assert_eq!(mesh.arg_num("verts"), Some(42.0));
        assert_eq!(mesh.pid, std::process::id() as u64);
        assert!(mesh.tid >= 1);

        let counter = trace.counters().next().unwrap();
        assert_eq!(counter.arg_num("value"), Some(8192.0));

        // thread metadata names the recording thread
        let names = trace.thread_names();
        assert_eq!(names.len(), 1);
        assert!(!names.values().next().unwrap().is_empty());
    }

    #[test]
    fn accepts_bare_event_arrays() {
        let text = r#"[{"ph":"X","name":"s","pid":1,"tid":2,"ts":0,"dur":5}]"#;
        let trace = parse(text).unwrap();
        assert_eq!(trace.spans().count(), 1);
        assert_eq!(trace.spans().next().unwrap().end_ts(), 5.0);
    }

    #[test]
    fn rejects_malformed_documents() {
        for (bad, why) in [
            (r#"{"other":1}"#, "no traceEvents"),
            (r#"[{"name":"s","pid":1,"tid":1,"ts":0,"dur":1}]"#, "missing ph"),
            (r#"[{"ph":"B","name":"s","pid":1,"tid":1,"ts":0}]"#, "unsupported phase"),
            (r#"[{"ph":"X","pid":1,"tid":1,"ts":0,"dur":1}]"#, "missing name"),
            (r#"[{"ph":"X","name":"","pid":1,"tid":1,"ts":0,"dur":1}]"#, "empty name"),
            (r#"[{"ph":"X","name":"s","tid":1,"ts":0,"dur":1}]"#, "missing pid"),
            (r#"[{"ph":"X","name":"s","pid":0,"tid":1,"ts":0,"dur":1}]"#, "pid 0"),
            (r#"[{"ph":"X","name":"s","pid":1,"tid":1.5,"ts":0,"dur":1}]"#, "fractional tid"),
            (r#"[{"ph":"X","name":"s","pid":1,"tid":1,"dur":1}]"#, "missing ts"),
            (r#"[{"ph":"X","name":"s","pid":1,"tid":1,"ts":-1,"dur":1}]"#, "negative ts"),
            (r#"[{"ph":"X","name":"s","pid":1,"tid":1,"ts":0}]"#, "X without dur"),
            (r#"[{"ph":"X","name":"s","pid":1,"tid":1,"ts":0,"dur":-2}]"#, "negative dur"),
            (r#"[{"ph":"C","name":"c","pid":1,"tid":1,"ts":0}]"#, "counter without value"),
            (r#"[{"ph":"X","name":"s","pid":1,"tid":1,"ts":0,"dur":1,"args":3}]"#, "args not obj"),
            (r#"[1]"#, "event not an object"),
            (r#"not json"#, "not json"),
        ] {
            assert!(parse(bad).is_err(), "{why}: {bad}");
        }
    }

    #[test]
    fn empty_sink_still_emits_valid_document() {
        let sink = TraceSink::new();
        let trace = parse(&sink.to_chrome_json()).unwrap();
        assert_eq!(trace.spans().count(), 0);
        assert_eq!(trace.counters().count(), 0);
    }
}
