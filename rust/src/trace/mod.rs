//! Structured tracing: timestamped spans and counter samples from any
//! thread, attributed by thread name and case id, emitted as Chrome Trace
//! Event Format JSON (loadable in chrome://tracing or ui.perfetto.dev).
//!
//! The layer is std-only like the rest of the crate and built around one
//! contract: **tracing off is free**. Every public entry point starts with
//! a single relaxed atomic load of the global enable flag; when it is
//! clear, no clock is read, nothing is allocated and no lock is taken.
//! Instrumentation can therefore stay in the hot path permanently — the
//! determinism sweeps run with the flag clear and see bit-identical
//! results.
//!
//! ## Model
//!
//! * A [`TraceSink`] collects *complete spans* (`ph:"X"`: name, start
//!   timestamp, duration, args) and *counter samples* (`ph:"C"`: track,
//!   timestamp, value) relative to its creation instant ("epoch").
//! * [`install`] publishes a sink process-globally and raises the enable
//!   flag; the returned [`TraceSession`] guard lowers the flag and
//!   unpublishes on drop. Sessions are serialized process-wide so
//!   concurrent tests cannot interleave sinks (a second `install` blocks
//!   until the first session drops — never nest two sessions on one
//!   thread).
//! * [`span`] / [`span_args`] return an RAII [`SpanGuard`] that records a
//!   complete event on drop; [`complete_span`] records a back-dated span
//!   measured elsewhere (e.g. engine-side transfer time surfaced on the
//!   dispatching thread).
//! * [`case_scope`] tags the current thread with a case id; spans recorded
//!   under the scope automatically carry a `"case"` arg, which is how the
//!   per-case breakdown stays visible across worker pools.
//! * Threads are identified by a stable process-unique `tid` and their
//!   `std::thread` name (first event wins), emitted as Chrome
//!   `thread_name` metadata.
//!
//! The emitter and the validating parser for the JSON format live in
//! [`chrome`].

pub mod chrome;

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

/// Global enable flag — the only thing the disabled fast path touches.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The installed sink. Guarded by a mutex (not swapped atomically) so a
/// session teardown cannot race a concurrent event into a half-cleared
/// global; events clone the `Arc` out under the lock and record lock-free
/// against the sink afterwards.
static SINK: OnceLock<Mutex<Option<Arc<TraceSink>>>> = OnceLock::new();

/// Serializes trace sessions process-wide (lib tests run concurrently in
/// one process; two overlapping sinks would steal each other's events).
static SESSION: Mutex<()> = Mutex::new(());

fn sink_slot() -> &'static Mutex<Option<Arc<TraceSink>>> {
    SINK.get_or_init(|| Mutex::new(None))
}

/// Lock recovery mirroring `metrics::lock_recover`: a panicking traced
/// thread must not poison tracing for the rest of the process.
fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

thread_local! {
    /// Process-unique thread id (Chrome `tid`), assigned on first use.
    static TID: Cell<u64> = const { Cell::new(0) };
    /// Case id attached to spans recorded on this thread (see [`case_scope`]).
    static CASE: RefCell<Option<String>> = const { RefCell::new(None) };
}

fn thread_tid() -> u64 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    TID.with(|c| {
        let mut tid = c.get();
        if tid == 0 {
            tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            c.set(tid);
        }
        tid
    })
}

/// Is tracing currently enabled? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Clone the installed sink if tracing is enabled.
fn active_sink() -> Option<Arc<TraceSink>> {
    if !enabled() {
        return None;
    }
    lock_recover(sink_slot()).clone()
}

/// A span argument value. Borrowed so that building the arg slice for a
/// disabled span allocates nothing.
#[derive(Debug, Clone, Copy)]
pub enum ArgV<'a> {
    Str(&'a str),
    Num(f64),
    Int(u64),
}

/// Owned mirror of [`ArgV`], stored in recorded events.
#[derive(Debug, Clone)]
enum OwnedArg {
    Str(String),
    Num(f64),
    Int(u64),
}

impl ArgV<'_> {
    fn to_owned_arg(self) -> OwnedArg {
        match self {
            ArgV::Str(s) => OwnedArg::Str(s.to_string()),
            ArgV::Num(n) => OwnedArg::Num(n),
            ArgV::Int(i) => OwnedArg::Int(i),
        }
    }
}

fn own_args(args: &[(&str, ArgV<'_>)]) -> Vec<(String, OwnedArg)> {
    args.iter().map(|(k, v)| (k.to_string(), v.to_owned_arg())).collect()
}

/// A recorded complete span (`ph:"X"`).
#[derive(Debug, Clone)]
struct SpanEvent {
    name: String,
    /// Microseconds since the sink epoch.
    ts_us: u64,
    dur_us: u64,
    tid: u64,
    args: Vec<(String, OwnedArg)>,
}

/// A recorded counter sample (`ph:"C"`).
#[derive(Debug, Clone)]
struct CounterEvent {
    track: String,
    ts_us: u64,
    tid: u64,
    value: f64,
}

/// Collects spans and counter samples from any thread. Create with
/// [`TraceSink::new`], publish with [`install`], serialize with
/// [`TraceSink::to_chrome_json`] / [`TraceSink::write`].
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    pid: u32,
    spans: Mutex<Vec<SpanEvent>>,
    counters: Mutex<Vec<CounterEvent>>,
    /// tid → thread name (first event from a thread wins).
    threads: Mutex<BTreeMap<u64, String>>,
}

impl TraceSink {
    pub fn new() -> Arc<TraceSink> {
        Arc::new(TraceSink {
            epoch: Instant::now(),
            pid: std::process::id(),
            spans: Mutex::new(Vec::new()),
            counters: Mutex::new(Vec::new()),
            threads: Mutex::new(BTreeMap::new()),
        })
    }

    fn pid(&self) -> u32 {
        self.pid
    }

    /// Register the calling thread in the name table and return its tid.
    fn register_thread(&self) -> u64 {
        let tid = thread_tid();
        let mut g = lock_recover(&self.threads);
        g.entry(tid).or_insert_with(|| {
            std::thread::current()
                .name()
                .map(str::to_string)
                .unwrap_or_else(|| format!("thread-{tid}"))
        });
        tid
    }

    fn ts_us(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record a complete span directly (the RAII guards funnel here; also
    /// public so emitter/parser tests can build sinks without installing
    /// one globally). The current thread's [`case_scope`], if any, is
    /// attached as a `"case"` arg unless the caller already supplied one.
    pub fn record_span(&self, name: &str, start: Instant, dur: Duration, args: &[(&str, ArgV)]) {
        self.push_span(name.to_string(), start, dur, own_args(args));
    }

    fn push_span(
        &self,
        name: String,
        start: Instant,
        dur: Duration,
        mut args: Vec<(String, OwnedArg)>,
    ) {
        let tid = self.register_thread();
        if !args.iter().any(|(k, _)| k == "case") {
            CASE.with(|c| {
                if let Some(case) = c.borrow().as_deref() {
                    args.push(("case".to_string(), OwnedArg::Str(case.to_string())));
                }
            });
        }
        let ev = SpanEvent {
            name,
            ts_us: self.ts_us(start),
            dur_us: dur.as_micros() as u64,
            tid,
            args,
        };
        lock_recover(&self.spans).push(ev);
    }

    /// Record a counter sample on the named track.
    pub fn record_counter(&self, track: &str, value: f64) {
        let tid = self.register_thread();
        let ev = CounterEvent {
            track: track.to_string(),
            ts_us: self.ts_us(Instant::now()),
            tid,
            value,
        };
        lock_recover(&self.counters).push(ev);
    }

    pub fn span_count(&self) -> usize {
        lock_recover(&self.spans).len()
    }

    pub fn counter_count(&self) -> usize {
        lock_recover(&self.counters).len()
    }

    pub fn is_empty(&self) -> bool {
        self.span_count() == 0 && self.counter_count() == 0
    }

    /// Serialize everything recorded so far as Chrome Trace Event JSON.
    pub fn to_chrome_json(&self) -> String {
        chrome::emit(self)
    }

    /// Write the Chrome Trace Event JSON to a file.
    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_chrome_json())
            .with_context(|| format!("writing trace to {}", path.display()))
    }

    fn snapshot_spans(&self) -> Vec<SpanEvent> {
        lock_recover(&self.spans).clone()
    }

    fn snapshot_counters(&self) -> Vec<CounterEvent> {
        lock_recover(&self.counters).clone()
    }

    fn snapshot_threads(&self) -> BTreeMap<u64, String> {
        lock_recover(&self.threads).clone()
    }
}

/// RAII guard for an installed trace session. Dropping it lowers the
/// enable flag and unpublishes the sink; events recorded by spans that are
/// still live keep going to the sink `Arc` they captured at creation.
#[derive(Debug)]
pub struct TraceSession {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for TraceSession {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::Relaxed);
        lock_recover(sink_slot()).take();
    }
}

/// Publish `sink` as the process-global trace sink and enable tracing
/// until the returned [`TraceSession`] drops. Blocks while another session
/// is live (sessions are process-serial); do not nest two sessions on one
/// thread — the second `install` would deadlock.
pub fn install(sink: Arc<TraceSink>) -> TraceSession {
    let serial = lock_recover(&SESSION);
    *lock_recover(sink_slot()) = Some(sink);
    ENABLED.store(true, Ordering::Relaxed);
    TraceSession { _serial: serial }
}

/// Live half of a [`SpanGuard`]: everything captured at span entry.
#[derive(Debug)]
struct SpanLive {
    sink: Arc<TraceSink>,
    name: String,
    args: Vec<(String, OwnedArg)>,
    t0: Instant,
}

/// RAII span: records a complete event (entry time + elapsed duration) on
/// drop. When tracing is disabled the guard is inert — no clock read, no
/// allocation.
#[derive(Debug)]
#[must_use = "a span measures the scope it is alive in"]
pub struct SpanGuard {
    live: Option<SpanLive>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(live) = self.live.take() {
            let dur = live.t0.elapsed();
            live.sink.push_span(live.name, live.t0, dur, live.args);
        }
    }
}

/// Open a span with no args. See [`span_args`].
pub fn span(name: &str) -> SpanGuard {
    span_args(name, &[])
}

/// Open a named span covering the guard's lifetime, with key/value args
/// that surface in the trace viewer's detail pane.
pub fn span_args(name: &str, args: &[(&str, ArgV<'_>)]) -> SpanGuard {
    let Some(sink) = active_sink() else {
        return SpanGuard { live: None };
    };
    SpanGuard {
        live: Some(SpanLive {
            sink,
            name: name.to_string(),
            args: own_args(args),
            t0: Instant::now(),
        }),
    }
}

/// Record a back-dated complete span measured elsewhere (e.g. device
/// transfer time reported by the engine after the fact). `start` must be
/// at or after the sink epoch; earlier instants clamp to 0.
pub fn complete_span(name: &str, start: Instant, dur: Duration, args: &[(&str, ArgV<'_>)]) {
    if let Some(sink) = active_sink() {
        sink.push_span(name.to_string(), start, dur, own_args(args));
    }
}

/// Record a counter sample (Chrome `ph:"C"`) on the named track.
pub fn counter(track: &str, value: f64) {
    if let Some(sink) = active_sink() {
        sink.record_counter(track, value);
    }
}

/// [`counter`] for integer gauges (byte counts, queue depths).
pub fn counter_u64(track: &str, value: u64) {
    counter(track, value as f64);
}

/// RAII case tag: while alive, spans recorded on this thread carry a
/// `"case"` arg. Scopes nest; the previous tag is restored on drop.
/// Inert (and free) while tracing is disabled.
#[derive(Debug)]
#[must_use = "a case scope tags spans recorded while it is alive"]
pub struct CaseScope {
    prev: Option<String>,
    active: bool,
}

impl Drop for CaseScope {
    fn drop(&mut self) {
        if self.active {
            CASE.with(|c| *c.borrow_mut() = self.prev.take());
        }
    }
}

/// Tag spans recorded on the current thread with `case` until the
/// returned scope drops.
pub fn case_scope(case: &str) -> CaseScope {
    if !enabled() {
        return CaseScope { prev: None, active: false };
    }
    let prev = CASE.with(|c| c.borrow_mut().replace(case.to_string()));
    CaseScope { prev, active: true }
}

#[cfg(test)]
mod tests {
    // Lib tests share one process and run concurrently; any test that
    // *installs* a global session would race sibling tests whose
    // instrumented production paths emit into the installed sink. The
    // session/case-scope/zero-cost semantics are therefore covered in the
    // serialized integration binary `tests/trace.rs`; here we only test
    // what works against a local, uninstalled sink.
    use super::*;

    #[test]
    fn sink_records_spans_counters_and_thread_names() {
        let sink = TraceSink::new();
        let t0 = Instant::now();
        sink.record_span(
            "stage.mesh",
            t0,
            Duration::from_micros(250),
            &[("case", ArgV::Str("case-7")), ("verts", ArgV::Int(123))],
        );
        sink.record_counter("mem.resident_bytes", 4096.0);
        assert_eq!(sink.span_count(), 1);
        assert_eq!(sink.counter_count(), 1);
        assert!(!sink.is_empty());

        let spans = sink.snapshot_spans();
        assert_eq!(spans[0].name, "stage.mesh");
        assert_eq!(spans[0].dur_us, 250);
        assert!(spans[0].args.iter().any(|(k, _)| k == "verts"));

        let threads = sink.snapshot_threads();
        assert_eq!(threads.len(), 1, "one recording thread registered");
        let (tid, name) = threads.iter().next().unwrap();
        assert!(*tid >= 1);
        assert!(!name.is_empty());
    }

    #[test]
    fn back_dated_span_timestamp_is_the_given_start() {
        let sink = TraceSink::new();
        let start = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        sink.record_span("stage.transfer", start, Duration::from_micros(40), &[]);
        let spans = sink.snapshot_spans();
        assert_eq!(spans[0].name, "stage.transfer");
        assert_eq!(spans[0].dur_us, 40);
        // recorded ~2ms after `start`, but the span timestamp is `start`
        let wall_us = sink.ts_us(Instant::now());
        assert!(spans[0].ts_us < wall_us);
    }

    #[test]
    fn pre_epoch_starts_clamp_to_zero() {
        let before = Instant::now();
        std::thread::sleep(Duration::from_millis(1));
        let sink = TraceSink::new();
        sink.record_span("early", before, Duration::from_micros(1), &[]);
        assert_eq!(sink.snapshot_spans()[0].ts_us, 0);
    }

    #[test]
    fn tids_are_stable_per_thread_and_unique_across_threads() {
        let a = thread_tid();
        assert_eq!(a, thread_tid(), "tid is stable within a thread");
        let b = std::thread::spawn(thread_tid).join().unwrap();
        assert_ne!(a, b, "tids are unique across threads");
        assert!(a >= 1 && b >= 1);
    }
}
