//! The fused marching-tetrahedra pass: mesh + unique vertices + statistics
//! in a single walk over the cells (the paper's "marching cubes fused
//! parallel kernels" on the CPU side).

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use super::tets::{CaseTable, CORNER_OFFSETS, TETS, TET_EDGES};
use crate::geometry::{Triangle, Vec3};
use crate::volume::VoxelGrid;

/// Multiplicative hasher for the (already well-mixed) packed lattice-edge
/// keys. The std SipHash was ~20 % of the whole mesh walk in profiles
/// (EXPERIMENTS.md §Perf); splitmix64 finalisation is plenty for these keys.
#[derive(Default)]
struct EdgeKeyHasher(u64);

impl Hasher for EdgeKeyHasher {
    #[inline]
    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("EdgeKeyHasher is only used with u64 keys");
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        // splitmix64 finaliser
        let mut z = v.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        self.0 = z ^ (z >> 31);
    }
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

type EdgeMap = HashMap<u64, u32, BuildHasherDefault<EdgeKeyHasher>>;

/// Fused accumulators produced by the mesh walk.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MeshStats {
    /// Enclosed volume in mm³ (absolute value of the signed sum).
    pub volume: f64,
    /// Total surface area in mm².
    pub area: f64,
}

/// Isosurface mesh of an ROI.
#[derive(Debug, Clone, Default)]
pub struct Mesh {
    /// Unique vertices (deduplicated on lattice-edge identity), world mm.
    pub vertices: Vec<Vec3>,
    /// Triangles as vertex-index triples, oriented outward.
    pub triangles: Vec<[u32; 3]>,
    /// Fused volume/area accumulators.
    pub stats: MeshStats,
}

impl Mesh {
    /// Triangle geometry accessor.
    pub fn triangle(&self, i: usize) -> Triangle {
        let [a, b, c] = self.triangles[i];
        Triangle::new(
            self.vertices[a as usize],
            self.vertices[b as usize],
            self.vertices[c as usize],
        )
    }

    /// Flatten to the f32[T, 9] layout of the `mesh_stats` AOT artifact.
    pub fn triangle_soup_f32(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.triangles.len() * 9);
        for i in 0..self.triangles.len() {
            let t = self.triangle(i);
            for v in [t.a, t.b, t.c] {
                let f = v.to_f32();
                out.extend_from_slice(&f);
            }
        }
        out
    }

    /// Flatten vertices to the f32[N, 3] layout of the `diameter` artifact.
    pub fn vertices_f32(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.vertices.len() * 3);
        for v in &self.vertices {
            out.extend_from_slice(&v.to_f32());
        }
        out
    }
}

/// Key identifying a mesh vertex by the *absolute lattice edge* it sits on.
/// Edges are canonicalised to (component-wise-min endpoint, direction code),
/// so the same geometric edge referenced from neighbouring cells (or from
/// different tets of one cell) maps to the same key — dedup is exact, with
/// no floating-point quantisation involved.
#[inline]
fn edge_key(x: usize, y: usize, z: usize, c0: usize, c1: usize) -> u64 {
    let o0 = CORNER_OFFSETS[c0];
    let o1 = CORNER_OFFSETS[c1];
    // absolute lattice endpoints
    let p0 = [x as u64 + o0[0] as u64, y as u64 + o0[1] as u64, z as u64 + o0[2] as u64];
    let p1 = [x as u64 + o1[0] as u64, y as u64 + o1[1] as u64, z as u64 + o1[2] as u64];
    let pmin = [p0[0].min(p1[0]), p0[1].min(p1[1]), p0[2].min(p1[2])];
    // direction bits: which components differ (edge spans 0/1 per axis)
    let d = (p0[0] != p1[0]) as u64 | ((p0[1] != p1[1]) as u64) << 1 | ((p0[2] != p1[2]) as u64) << 2;
    debug_assert!(pmin.iter().all(|&v| v < 1 << 19));
    (pmin[0] << 41) | (pmin[1] << 22) | (pmin[2] << 3) | d
}

/// Marching tetrahedra over a binary mask (iso = 0.5): the fused pass.
///
/// Returns the watertight isosurface mesh with unique vertices, outward
/// orientation and the volume/area accumulated on the fly. The mask should
/// have a 1-voxel zero margin (see [`crate::volume::crop_to_roi`]); the
/// walk spans `dims - 1` cells per axis, so a surface touching the margin
/// is closed.
pub fn mesh_roi(mask: &VoxelGrid<u8>) -> Mesh {
    let table = CaseTable::get();
    let sp = mask.spacing;
    let (nx, ny, nz) = (mask.dims.x, mask.dims.y, mask.dims.z);
    let mut mesh = Mesh::default();
    let mut vert_ids = EdgeMap::default();
    let mut signed_volume = 0.0f64;

    // Corner world-position offsets, precomputed in mm.
    let corner_mm: [Vec3; 8] = std::array::from_fn(|c| {
        let o = CORNER_OFFSETS[c];
        Vec3::new(o[0] as f64 * sp.x, o[1] as f64 * sp.y, o[2] as f64 * sp.z)
    });

    for z in 0..nz.saturating_sub(1) {
        for y in 0..ny.saturating_sub(1) {
            for x in 0..nx.saturating_sub(1) {
                // Gather the 8 corner occupancies.
                let mut occ = [false; 8];
                let mut any = false;
                let mut all = true;
                for (c, o) in CORNER_OFFSETS.iter().enumerate() {
                    let v = mask.get(x + o[0] as usize, y + o[1] as usize, z + o[2] as usize)
                        != 0;
                    occ[c] = v;
                    any |= v;
                    all &= v;
                }
                if !any || all {
                    continue; // cell entirely outside or inside
                }
                let base = mask.world(x, y, z);
                for tet in TETS.iter() {
                    let tin: [bool; 4] = std::array::from_fn(|i| occ[tet[i]]);
                    let case = (tin[0] as u8)
                        | (tin[1] as u8) << 1
                        | (tin[2] as u8) << 2
                        | (tin[3] as u8) << 3;
                    let n = table.ntris[case as usize];
                    if n == 0 {
                        continue;
                    }
                    // Inside/outside centroids give the outward direction.
                    let mut cin = Vec3::ZERO;
                    let mut cout = Vec3::ZERO;
                    let mut n_in = 0.0;
                    for i in 0..4 {
                        let p = corner_mm[tet[i]];
                        if tin[i] {
                            cin += p;
                            n_in += 1.0;
                        } else {
                            cout += p;
                        }
                    }
                    let dir = cout / (4.0 - n_in) - cin / n_in;

                    for tri in &table.tris[case as usize][..n] {
                        let mut ids = [0u32; 3];
                        let mut pts = [Vec3::ZERO; 3];
                        for (m, &e) in tri.iter().enumerate() {
                            let (i0, i1) = TET_EDGES[e];
                            let (c0, c1) = (tet[i0], tet[i1]);
                            let key = edge_key(x, y, z, c0, c1);
                            // Binary mask ⇒ midpoint interpolation (t = ½).
                            let p = base + (corner_mm[c0] + corner_mm[c1]) / 2.0;
                            let next = mesh.vertices.len() as u32;
                            let id = *vert_ids.entry(key).or_insert_with(|| {
                                mesh.vertices.push(p);
                                next
                            });
                            ids[m] = id;
                            pts[m] = p;
                        }
                        // Orientation: normal must point inside → outside.
                        let normal = (pts[1] - pts[0]).cross(pts[2] - pts[0]);
                        if normal.dot(dir) < 0.0 {
                            ids.swap(1, 2);
                            pts.swap(1, 2);
                        }
                        let t = Triangle::new(pts[0], pts[1], pts[2]);
                        signed_volume += t.signed_volume();
                        mesh.stats.area += t.area();
                        mesh.triangles.push(ids);
                    }
                }
            }
        }
    }
    mesh.stats.volume = signed_volume.abs();
    mesh
}

/// Planar diameters computed by plane-grouping instead of all-pairs masking:
/// vertices are bucketed by the shared coordinate; only intra-bucket pairs
/// are compared. Exact same semantics as the kernel's masked reduction (and
/// PyRadiomics `cshape`), but O(Σ nᵦ²) — used by the CPU fallback path and
/// as an independent oracle in tests.
///
/// Returns squared diameters `[dxy², dyz², dxz²]`; -1 when a plane family
/// has no pair.
pub fn planar_diameters_grouped(vertices: &[Vec3]) -> [f64; 3] {
    let mut out = [-1.0f64; 3];
    // (dropped axis, output slot): z → XY, x → YZ, y → XZ.
    for (slot, axis) in [(0usize, 2usize), (1, 0), (2, 1)] {
        let mut groups: HashMap<u64, Vec<usize>> = HashMap::new();
        for (i, v) in vertices.iter().enumerate() {
            // Exact grouping on the f64 bit pattern (mesh coordinates are
            // derived identically for co-planar vertices).
            groups.entry(v[axis].to_bits()).or_default().push(i);
        }
        let mut best = -1.0f64;
        for idxs in groups.values() {
            for (k, &i) in idxs.iter().enumerate() {
                for &j in &idxs[k..] {
                    let d = vertices[i].dist_sq(vertices[j]);
                    if d > best {
                        best = d;
                    }
                }
            }
        }
        out[slot] = best;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::volume::{Dims, VoxelGrid};

    fn sphere_mask(n: usize, r: f64) -> VoxelGrid<u8> {
        let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
        let c = n as f64 / 2.0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let dx = x as f64 - c;
                    let dy = y as f64 - c;
                    let dz = z as f64 - c;
                    if dx * dx + dy * dy + dz * dz <= r * r {
                        m.set(x, y, z, 1);
                    }
                }
            }
        }
        m
    }

    #[test]
    fn empty_mask_empty_mesh() {
        let m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let mesh = mesh_roi(&m);
        assert!(mesh.vertices.is_empty());
        assert!(mesh.triangles.is_empty());
        assert_eq!(mesh.stats, MeshStats::default());
    }

    #[test]
    fn single_voxel_octahedron() {
        let mut m = VoxelGrid::zeros(Dims::new(3, 3, 3), Vec3::splat(1.0));
        m.set(1, 1, 1, 1);
        let mesh = mesh_roi(&m);
        // Python oracle (mt_stats_ref): volume 0.5, area 3.6213203.
        assert!((mesh.stats.volume - 0.5).abs() < 1e-9, "{:?}", mesh.stats);
        assert!((mesh.stats.area - 3.621_320_343_559_642).abs() < 1e-9);
        assert!(!mesh.vertices.is_empty());
    }

    #[test]
    fn sphere_volume_and_area_close_to_analytic() {
        let r = 8.0;
        let mesh = mesh_roi(&sphere_mask(24, r));
        let vol = 4.0 / 3.0 * std::f64::consts::PI * r * r * r;
        let area = 4.0 * std::f64::consts::PI * r * r;
        assert!((mesh.stats.volume - vol).abs() / vol < 0.05, "{}", mesh.stats.volume);
        // MT on binary masks facets the surface: area overshoots ~25 %.
        assert!(mesh.stats.area > area && mesh.stats.area < 1.45 * area);
    }

    #[test]
    fn sphere_matches_python_oracle() {
        // Locked against ref.mt_stats_ref(sphere(24, r=8)) = [2099.0, 1004.24225].
        let mesh = mesh_roi(&sphere_mask(24, 8.0));
        assert!((mesh.stats.volume - 2099.0).abs() < 0.5, "{}", mesh.stats.volume);
        assert!((mesh.stats.area - 1004.242).abs() < 0.5, "{}", mesh.stats.area);
    }

    #[test]
    fn watertight_signed_volume_translation_invariant() {
        let mesh = mesh_roi(&sphere_mask(16, 5.0));
        let shift = Vec3::new(17.0, -3.0, 9.0);
        let mut signed0 = 0.0;
        let mut signed1 = 0.0;
        for i in 0..mesh.triangles.len() {
            let t = mesh.triangle(i);
            signed0 += t.signed_volume();
            let t2 = Triangle::new(t.a + shift, t.b + shift, t.c + shift);
            signed1 += t2.signed_volume();
        }
        assert!((signed0 - signed1).abs() < 1e-6 * signed0.abs().max(1.0));
    }

    #[test]
    fn vertices_are_unique() {
        let mesh = mesh_roi(&sphere_mask(16, 5.0));
        let mut seen = std::collections::HashSet::new();
        for v in &mesh.vertices {
            let key = (v.x.to_bits(), v.y.to_bits(), v.z.to_bits());
            assert!(seen.insert(key), "duplicate vertex {v:?}");
        }
    }

    #[test]
    fn triangle_indices_in_range() {
        let mesh = mesh_roi(&sphere_mask(12, 4.0));
        for t in &mesh.triangles {
            for &i in t {
                assert!((i as usize) < mesh.vertices.len());
            }
        }
    }

    #[test]
    fn anisotropic_spacing_scales_volume() {
        let mut iso = sphere_mask(12, 4.0);
        iso.spacing = Vec3::splat(1.0);
        let v1 = mesh_roi(&iso).stats.volume;
        let mut aniso = iso.clone();
        aniso.spacing = Vec3::new(2.0, 1.0, 1.0);
        let v2 = mesh_roi(&aniso).stats.volume;
        assert!((v2 - 2.0 * v1).abs() < 1e-9);
    }

    #[test]
    fn grouped_planar_matches_brute_force() {
        let mesh = mesh_roi(&sphere_mask(14, 4.5));
        let v = &mesh.vertices;
        let grouped = planar_diameters_grouped(v);
        // brute force with the same exact-equality semantics
        let mut brute = [-1.0f64; 3];
        for (slot, axis) in [(0usize, 2usize), (1, 0), (2, 1)] {
            for i in 0..v.len() {
                for j in i..v.len() {
                    if v[i][axis] == v[j][axis] {
                        brute[slot] = brute[slot].max(v[i].dist_sq(v[j]));
                    }
                }
            }
        }
        for k in 0..3 {
            assert!((grouped[k] - brute[k]).abs() < 1e-12, "slot {k}");
        }
    }

    #[test]
    fn surface_touching_border_is_closed() {
        // Mask fills the whole grid: with no margin the mesher still closes
        // the surface at the walkable boundary (dims-1 cells) — callers use
        // crop_to_roi to add the margin; this just checks watertightness.
        let mut m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        for z in 1..3 {
            for y in 1..3 {
                for x in 1..3 {
                    m.set(x, y, z, 1);
                }
            }
        }
        let mesh = mesh_roi(&m);
        // 2×2×2 solid: volume must be close to 8 minus bevel.
        assert!(mesh.stats.volume > 5.0 && mesh.stats.volume <= 8.0);
    }
}
