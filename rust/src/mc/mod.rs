//! Isosurface meshing: marching tetrahedra over the Freudenthal
//! decomposition, with the paper's *fused* statistics accumulation.
//!
//! PyRadiomics uses a table-driven marching cubes; this repo substitutes
//! marching tetrahedra (see DESIGN.md §Substitutions): the 16 per-tet cases
//! are generated mechanically (no transcribed tables to get wrong), the
//! Freudenthal 6-tet decomposition tiles space consistently so the surface
//! is watertight, and the same generator exists in
//! `python/compile/kernels/mt_tables.py` — cross-language agreement is
//! integration-tested.
//!
//! [`mesh_roi`] is the fused pass the paper describes: one walk over the
//! cells produces the triangle mesh, the unique-vertex list (for the
//! diameter kernels) and the volume/area accumulators simultaneously.

mod tets;
mod mesher;

pub use mesher::{mesh_roi, planar_diameters_grouped, Mesh, MeshStats};
pub use tets::{case_triangles, CaseTable, CORNER_OFFSETS, TETS, TET_EDGES};
