//! Generated marching-tetrahedra tables (mirror of
//! `python/compile/kernels/mt_tables.py` — keep the two in sync).

use std::sync::OnceLock;

/// Cube corner id = `x | y << 1 | z << 2`; offsets in `(x, y, z)`.
pub const CORNER_OFFSETS: [[i32; 3]; 8] = [
    [0, 0, 0],
    [1, 0, 0],
    [0, 1, 0],
    [1, 1, 0],
    [0, 0, 1],
    [1, 0, 1],
    [0, 1, 1],
    [1, 1, 1],
];

/// The 6 tetrahedra of the Freudenthal decomposition: monotone lattice paths
/// from corner 0 to corner 7, one per permutation of the three axes
/// (enumerated in the same order as `itertools.permutations(range(3))`).
pub const TETS: [[usize; 4]; 6] = [
    [0, 1, 3, 7], // x, y, z
    [0, 1, 5, 7], // x, z, y
    [0, 2, 3, 7], // y, x, z
    [0, 2, 6, 7], // y, z, x
    [0, 4, 5, 7], // z, x, y
    [0, 4, 6, 7], // z, y, x
];

/// The 6 edges of a tetrahedron as (vertex, vertex) index pairs.
pub const TET_EDGES: [(usize, usize); 6] = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)];

fn edge_id(a: usize, b: usize) -> usize {
    let (a, b) = if a < b { (a, b) } else { (b, a) };
    TET_EDGES
        .iter()
        .position(|&(x, y)| (x, y) == (a, b))
        .expect("valid tet edge")
}

/// Triangles (as tet-edge-id triples) for one of the 16 inside/outside
/// cases. Bit `i` of `case` set ⇔ tet vertex `i` is inside. Orientation of
/// the triples is arbitrary; the mesher normalises it geometrically.
pub fn case_triangles(case: u8) -> Vec<[usize; 3]> {
    let inside: Vec<usize> = (0..4).filter(|i| case >> i & 1 == 1).collect();
    let outside: Vec<usize> = (0..4).filter(|i| case >> i & 1 == 0).collect();
    match inside.len() {
        0 | 4 => vec![],
        1 => {
            let a = inside[0];
            let e: Vec<usize> = outside.iter().map(|&o| edge_id(a, o)).collect();
            vec![[e[0], e[1], e[2]]]
        }
        3 => {
            let a = outside[0];
            let e: Vec<usize> = inside.iter().map(|&i| edge_id(a, i)).collect();
            vec![[e[0], e[1], e[2]]]
        }
        2 => {
            // 2-2 split: cyclic quad e(a,c) — e(a,d) — e(b,d) — e(b,c).
            let (a, b) = (inside[0], inside[1]);
            let (c, d) = (outside[0], outside[1]);
            let q = [edge_id(a, c), edge_id(a, d), edge_id(b, d), edge_id(b, c)];
            vec![[q[0], q[1], q[2]], [q[0], q[2], q[3]]]
        }
        _ => unreachable!(),
    }
}

/// Dense case table: `tris[case]` holds up to 2 triangles (edge-id triples),
/// `ntris[case]` the count. Built once, lazily.
pub struct CaseTable {
    pub tris: [[[usize; 3]; 2]; 16],
    pub ntris: [usize; 16],
}

impl CaseTable {
    pub fn get() -> &'static CaseTable {
        static TABLE: OnceLock<CaseTable> = OnceLock::new();
        TABLE.get_or_init(|| {
            let mut tris = [[[0usize; 3]; 2]; 16];
            let mut ntris = [0usize; 16];
            for case in 0..16u8 {
                let ts = case_triangles(case);
                ntris[case as usize] = ts.len();
                for (k, t) in ts.iter().enumerate() {
                    tris[case as usize][k] = *t;
                }
            }
            CaseTable { tris, ntris }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tets_are_monotone_paths() {
        for tet in TETS {
            assert_eq!(tet[0], 0);
            assert_eq!(tet[3], 7);
            for w in tet.windows(2) {
                let d = w[0] ^ w[1];
                assert!(d == 1 || d == 2 || d == 4, "one axis bit per step");
            }
        }
    }

    #[test]
    fn tets_tile_the_cube() {
        // Σ |det| / 6 over the 6 tets = unit cube volume.
        let mut total = 0.0f64;
        for tet in TETS {
            let p: Vec<[f64; 3]> = tet
                .iter()
                .map(|&c| {
                    let o = CORNER_OFFSETS[c];
                    [o[0] as f64, o[1] as f64, o[2] as f64]
                })
                .collect();
            let u = [p[1][0] - p[0][0], p[1][1] - p[0][1], p[1][2] - p[0][2]];
            let v = [p[2][0] - p[0][0], p[2][1] - p[0][1], p[2][2] - p[0][2]];
            let w = [p[3][0] - p[0][0], p[3][1] - p[0][1], p[3][2] - p[0][2]];
            let det = u[0] * (v[1] * w[2] - v[2] * w[1]) - u[1] * (v[0] * w[2] - v[2] * w[0])
                + u[2] * (v[0] * w[1] - v[1] * w[0]);
            total += det.abs() / 6.0;
        }
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn case_triangle_counts() {
        for case in 0..16u8 {
            let inside = (case.count_ones()) as usize;
            let expect = [0, 1, 2, 1, 0][inside];
            assert_eq!(case_triangles(case).len(), expect, "case {case}");
        }
    }

    #[test]
    fn case_edges_cross_the_boundary() {
        for case in 1..15u8 {
            for t in case_triangles(case) {
                for e in t {
                    let (a, b) = TET_EDGES[e];
                    let ain = case >> a & 1 == 1;
                    let bin = case >> b & 1 == 1;
                    assert_ne!(ain, bin, "edge must cross the isosurface");
                }
            }
        }
    }

    #[test]
    fn complementary_cases_share_edges() {
        for case in 1..8u8 {
            let mut a: Vec<usize> =
                case_triangles(case).into_iter().flatten().collect();
            let mut b: Vec<usize> =
                case_triangles(15 - case).into_iter().flatten().collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "case {case}");
        }
    }

    #[test]
    fn dense_table_matches_generator() {
        let t = CaseTable::get();
        for case in 0..16u8 {
            let ts = case_triangles(case);
            assert_eq!(t.ntris[case as usize], ts.len());
            for (k, tri) in ts.iter().enumerate() {
                assert_eq!(&t.tris[case as usize][k], tri);
            }
        }
    }
}
