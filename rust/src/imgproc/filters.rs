//! Separable Gaussian and Laplacian-of-Gaussian filtering.
//!
//! Sigmas are **millimetre**-denominated (PyRadiomics `sigma` semantics):
//! each axis converts to voxel units through the grid spacing, so
//! anisotropic volumes are filtered isotropically in physical space.
//! Borders are edge-clamped (the nearest in-bounds sample repeats), kernel
//! accumulation is f64 and every pass stores f32 — bit-identical across
//! strategies and thread counts (see the module docs of
//! [`crate::imgproc`]).
//!
//! The LoG is *scale-normalised*: the response is multiplied by `sigma²`
//! (SimpleITK `NormalizeAcrossScale`, which PyRadiomics uses), so blob
//! responses are comparable across sigmas. The second-derivative kernels
//! are sampled-Gaussian kernels corrected to zero sum (flat fields give
//! exactly 0) and to second moment 2 (quadratic fields give exactly the
//! analytic Laplacian) — `tests/conformance.rs` locks the response on a
//! Gaussian blob against the closed form and the `ref.py` oracle.

use anyhow::{bail, Result};

use super::lines::{map_lines, Axis};
use crate::parallel::Strategy;
use crate::volume::VoxelGrid;

/// Kernel radius ceiling. A sigma far larger than the volume (or a
/// sub-micron spacing) would otherwise quietly build a megasample kernel;
/// failing loudly points at the misconfigured sigma/spacing instead.
pub const MAX_KERNEL_RADIUS: usize = 1024;

/// Truncation of the sampled kernels, in sigmas (the scipy default).
const TRUNCATE_SIGMAS: f64 = 4.0;

fn kernel_radius(sigma_vox: f64) -> Result<usize> {
    let r = (TRUNCATE_SIGMAS * sigma_vox).ceil() as usize;
    let r = r.max(1);
    if r > MAX_KERNEL_RADIUS {
        bail!(
            "Gaussian kernel radius {r} exceeds {MAX_KERNEL_RADIUS} \
             (sigma is {sigma_vox:.1} voxels — check sigma/spacing units)"
        );
    }
    Ok(r)
}

/// The sampled, normalised (sum = 1) Gaussian kernel for a sigma in voxel
/// units; taps cover `[-r, r]` with `r = ceil(4·sigma)` clamped to
/// [`MAX_KERNEL_RADIUS`]. Errors on non-positive/non-finite sigma.
pub fn gaussian_kernel(sigma_vox: f64) -> Result<Vec<f64>> {
    if !(sigma_vox > 0.0 && sigma_vox.is_finite()) {
        bail!("sigma must be a positive finite number, got {sigma_vox}");
    }
    let r = kernel_radius(sigma_vox)?;
    let mut k: Vec<f64> = (-(r as isize)..=r as isize)
        .map(|i| (-((i * i) as f64) / (2.0 * sigma_vox * sigma_vox)).exp())
        .collect();
    let sum: f64 = k.iter().sum();
    for v in &mut k {
        *v /= sum;
    }
    Ok(k)
}

/// The sampled second-derivative-of-Gaussian kernel (voxel units),
/// corrected to zero sum and normalised so its second moment
/// `Σ k_i · i²` equals exactly 2 — convolving a quadratic `x²` yields the
/// analytic `d²/dx² = 2`. At tiny sigmas this degrades gracefully to the
/// discrete `[1, -2, 1]` Laplacian stencil.
fn gaussian_d2_kernel(sigma_vox: f64) -> Result<Vec<f64>> {
    if !(sigma_vox > 0.0 && sigma_vox.is_finite()) {
        bail!("sigma must be a positive finite number, got {sigma_vox}");
    }
    let r = kernel_radius(sigma_vox)?;
    let s2 = sigma_vox * sigma_vox;
    let mut k: Vec<f64> = (-(r as isize)..=r as isize)
        .map(|i| {
            let x2 = (i * i) as f64;
            (x2 - s2) / (s2 * s2) * (-x2 / (2.0 * s2)).exp()
        })
        .collect();
    // zero-sum: flat fields must respond exactly 0
    let mean = k.iter().sum::<f64>() / k.len() as f64;
    for v in &mut k {
        *v -= mean;
    }
    // second-moment calibration: response to x² must be exactly 2
    let m2: f64 = k
        .iter()
        .enumerate()
        .map(|(j, v)| {
            let i = j as f64 - r as f64;
            v * i * i
        })
        .sum();
    for v in &mut k {
        *v *= 2.0 / m2;
    }
    Ok(k)
}

/// Convolve one line with `kernel` (odd length, centre at `len/2`),
/// edge-clamping out-of-range samples. f64 accumulation, f32 output.
fn convolve_line_clamped(line: &[f32], kernel: &[f64], out: &mut Vec<f32>) {
    let n = line.len() as isize;
    let r = (kernel.len() / 2) as isize;
    for i in 0..n {
        let mut acc = 0.0f64;
        for (j, &k) in kernel.iter().enumerate() {
            let src = (i + j as isize - r).clamp(0, n - 1);
            acc += k * line[src as usize] as f64;
        }
        out.push(acc as f32);
    }
}

/// Per-axis sigmas in voxel units for a mm-denominated sigma.
fn sigma_voxels(img: &VoxelGrid<f32>, sigma_mm: f64) -> Result<[f64; 3]> {
    if !(sigma_mm > 0.0 && sigma_mm.is_finite()) {
        bail!("sigma must be a positive finite number of millimetres, got {sigma_mm}");
    }
    super::check_spacing("filtered image", img.spacing)?;
    let sp = img.spacing;
    Ok([sigma_mm / sp.x, sigma_mm / sp.y, sigma_mm / sp.z])
}

/// Separable Gaussian smoothing with a mm-denominated `sigma_mm`
/// (edge-clamped borders; x, then y, then z pass).
pub fn gaussian_smooth(
    img: &VoxelGrid<f32>,
    sigma_mm: f64,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<f32>> {
    if img.dims.is_empty() {
        bail!("cannot filter an empty volume {}", img.dims);
    }
    let sigmas = sigma_voxels(img, sigma_mm)?;
    // first pass reads the input directly — no upfront clone
    let mut out: Option<VoxelGrid<f32>> = None;
    for (axis, &sv) in Axis::ALL.iter().zip(&sigmas) {
        let kernel = gaussian_kernel(sv)?;
        let src = out.as_ref().unwrap_or(img);
        out = Some(map_lines(src, *axis, strategy, threads, |line, o| {
            convolve_line_clamped(line, &kernel, o);
        }));
    }
    Ok(out.expect("three axis passes"))
}

/// Scale-normalised Laplacian-of-Gaussian with a mm-denominated
/// `sigma_mm`: `sigma² · Σ_a ∂²/∂a² (G ∗ img)` in physical (mm) units.
///
/// Separable implementation: for each axis the second-derivative kernel
/// (divided by `spacing²` to convert voxel⁻² to mm⁻²) replaces the
/// smoothing kernel along that axis, and the three directional responses
/// are summed voxel-wise in fixed x + y + z order.
pub fn log_filter(
    img: &VoxelGrid<f32>,
    sigma_mm: f64,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<f32>> {
    if img.dims.is_empty() {
        bail!("cannot filter an empty volume {}", img.dims);
    }
    let sigmas = sigma_voxels(img, sigma_mm)?;
    let spacing = [img.spacing.x, img.spacing.y, img.spacing.z];
    // Directional terms are accumulated one at a time into an f64 buffer
    // instead of materialising all three term volumes: peak residency
    // drops from 4+ volumes to the accumulator plus one in-flight term.
    // The fixed left-to-right x + y + z f64 sum is the same operation
    // sequence as the previous all-at-once form, so the output is
    // bit-identical.
    let mut acc: Vec<f64> = Vec::new();
    for d2_axis in 0..3 {
        let mut t: Option<VoxelGrid<f32>> = None;
        for (a, axis) in Axis::ALL.iter().enumerate() {
            let kernel = if a == d2_axis {
                let scale = 1.0 / (spacing[a] * spacing[a]);
                gaussian_d2_kernel(sigmas[a])?
                    .into_iter()
                    .map(|k| k * scale)
                    .collect()
            } else {
                gaussian_kernel(sigmas[a])?
            };
            let src = t.as_ref().unwrap_or(img);
            t = Some(map_lines(src, *axis, strategy, threads, |line, o| {
                convolve_line_clamped(line, &kernel, o);
            }));
        }
        let term = t.expect("three axis passes");
        if d2_axis == 0 {
            acc = term.data().iter().map(|&v| v as f64).collect();
        } else {
            for (s, &v) in acc.iter_mut().zip(term.data()) {
                *s += v as f64;
            }
        }
    }
    let norm = sigma_mm * sigma_mm;
    let mut out = VoxelGrid::zeros(img.dims, img.spacing);
    for (v, &s) in out.data_mut().iter_mut().zip(&acc) {
        *v = (s * norm) as f32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn constant(dims: Dims, v: f32) -> VoxelGrid<f32> {
        let mut g = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        g.data_mut().fill(v);
        g
    }

    #[test]
    fn gaussian_kernel_is_normalised_and_symmetric() {
        let k = gaussian_kernel(1.5).unwrap();
        assert_eq!(k.len(), 13, "radius ceil(4·1.5) = 6");
        assert!((k.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        for i in 0..k.len() / 2 {
            assert_eq!(k[i], k[k.len() - 1 - i]);
        }
        assert!(gaussian_kernel(0.0).is_err());
        assert!(gaussian_kernel(f64::NAN).is_err());
        assert!(gaussian_kernel(1e9).is_err(), "radius ceiling");
    }

    #[test]
    fn d2_kernel_zero_sum_and_second_moment() {
        for sigma in [0.1, 0.7, 1.0, 2.5] {
            let k = gaussian_d2_kernel(sigma).unwrap();
            assert!(k.iter().sum::<f64>().abs() < 1e-12, "sigma {sigma}");
            let r = (k.len() / 2) as f64;
            let m2: f64 =
                k.iter().enumerate().map(|(j, v)| v * (j as f64 - r).powi(2)).sum();
            assert!((m2 - 2.0).abs() < 1e-12, "sigma {sigma}");
        }
        // tiny sigma → the discrete [1, -2, 1] Laplacian stencil
        let k = gaussian_d2_kernel(0.1).unwrap();
        assert_eq!(k.len(), 3);
        assert!((k[0] - 1.0).abs() < 1e-9 && (k[1] + 2.0).abs() < 1e-9);
    }

    #[test]
    fn smoothing_preserves_constants_exactly() {
        let g = constant(Dims::new(6, 5, 4), 7.25);
        let s = gaussian_smooth(&g, 2.0, Strategy::EqualSplit, 1).unwrap();
        assert_eq!(s, g, "edge-clamped smoothing of a constant is the constant");
    }

    #[test]
    fn smoothing_conserves_mass_of_an_interior_impulse() {
        let mut g = constant(Dims::new(17, 17, 17), 0.0);
        g.set(8, 8, 8, 1.0);
        let s = gaussian_smooth(&g, 1.0, Strategy::EqualSplit, 1).unwrap();
        let sum: f64 = s.data().iter().map(|&v| v as f64).sum();
        assert!((sum - 1.0).abs() < 1e-6, "kernel mass {sum}");
        // symmetric response around the impulse
        assert_eq!(s.get(7, 8, 8), s.get(9, 8, 8));
        assert_eq!(s.get(8, 7, 8), s.get(8, 9, 8));
        assert!(s.get(8, 8, 8) > s.get(8, 8, 7));
    }

    #[test]
    fn log_of_flat_field_is_zero() {
        let g = constant(Dims::new(8, 8, 8), 42.0);
        let l = log_filter(&g, 1.5, Strategy::EqualSplit, 1).unwrap();
        assert!(l.data().iter().all(|&v| v.abs() < 1e-4), "max {:?}", l.data()[0]);
    }

    #[test]
    fn log_of_quadratic_matches_the_analytic_laplacian() {
        // f = x² (spacing 1): ∇²f = 2, so the sigma²-normalised response
        // is exactly 2·sigma² away from the borders.
        let dims = Dims::new(25, 9, 9);
        let mut g = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    g.set(x, y, z, (x * x) as f32);
                }
            }
        }
        let sigma = 1.5f64;
        let l = log_filter(&g, sigma, Strategy::EqualSplit, 1).unwrap();
        let want = 2.0 * sigma * sigma;
        let got = l.get(12, 4, 4) as f64;
        assert!((got - want).abs() < 1e-3, "{got} vs {want}");
    }

    #[test]
    fn anisotropic_spacing_scales_the_kernels() {
        // sigma 2 mm on 1 mm spacing == sigma 1 mm on 0.5 mm spacing in
        // voxel units; compare the physical response of a centred blob.
        let mut a = VoxelGrid::zeros(Dims::new(21, 21, 21), Vec3::splat(1.0));
        a.set(10, 10, 10, 1.0);
        let mut b = VoxelGrid::zeros(Dims::new(21, 21, 21), Vec3::splat(0.5));
        b.set(10, 10, 10, 1.0);
        let sa = gaussian_smooth(&a, 2.0, Strategy::EqualSplit, 1).unwrap();
        let sb = gaussian_smooth(&b, 1.0, Strategy::EqualSplit, 1).unwrap();
        // same voxel-unit sigma → identical voxel responses
        assert_eq!(sa.get(10, 10, 10), sb.get(10, 10, 10));
        assert_eq!(sa.get(12, 10, 10), sb.get(12, 10, 10));
    }

    #[test]
    fn filters_reject_bad_inputs() {
        let g = constant(Dims::new(4, 4, 4), 1.0);
        assert!(log_filter(&g, 0.0, Strategy::EqualSplit, 1).is_err());
        assert!(log_filter(&g, f64::INFINITY, Strategy::EqualSplit, 1).is_err());
        assert!(gaussian_smooth(&g, -1.0, Strategy::EqualSplit, 1).is_err());
        let bad = VoxelGrid::<f32>::zeros(Dims::new(4, 4, 4), Vec3::new(0.0, 1.0, 1.0));
        let err = gaussian_smooth(&bad, 1.0, Strategy::EqualSplit, 1).unwrap_err();
        assert!(format!("{err:#}").contains("spacing"));
    }
}
