//! Derived-image preprocessing: isotropic resampling, Gaussian /
//! Laplacian-of-Gaussian filtering and 3D Haar wavelet decomposition.
//!
//! In PyRadiomics the bulk of high-throughput work comes from *derived
//! images*: every enabled image type (Original, LoG at several sigmas, the
//! 8 wavelet sub-bands) re-runs first-order and texture extraction, which
//! multiplies the per-case workload by the number of derived images. This
//! module is that filter bank, organised so every pass runs through the
//! same deterministic parallel engine:
//!
//! * [`resample_image`] / [`resample_mask`] — trilinear (intensity) and
//!   nearest-neighbour (mask) resampling onto a target spacing;
//! * [`gaussian_smooth`] / [`log_filter`] — separable Gaussian and
//!   scale-normalised Laplacian-of-Gaussian at mm-denominated sigmas;
//! * [`haar_decompose`] — one-level undecimated 3D Haar transform
//!   producing the 8 LLL…HHH sub-bands (same dims as the input, so every
//!   band stays voxel-aligned with the segmentation mask).
//!
//! # Determinism contract
//!
//! Every pass decomposes its work into *lines* (or output slices) handed
//! to [`crate::parallel::fold_chunks`]: a [`Strategy`] picks the
//! decomposition, workers compute disjoint output ranges into per-thread
//! partials, and the partials are scattered into the output in fixed
//! order. Each line's arithmetic is independent of the decomposition, so
//! the output is **bit-for-bit identical for every strategy and thread
//! count** — the same contract as the texture subsystem, and asserted by
//! `tests/conformance.rs` and `benches/bench_imgproc.rs`.

mod filters;
mod lines;
mod resample;
mod wavelet;

pub use filters::{gaussian_kernel, gaussian_smooth, log_filter, MAX_KERNEL_RADIUS};
pub use lines::Axis;
pub use resample::{
    resample_image, resample_image_to_grid, resample_mask, resampled_dims,
    MAX_RESAMPLED_VOXELS,
};
pub use wavelet::{haar_decompose, haar_reconstruct, SUB_BANDS};

use anyhow::{bail, Result};

use crate::parallel::Strategy;
use crate::volume::VoxelGrid;

/// Shared grid-spacing guard: every imgproc entry point rejects
/// non-positive / non-finite spacings with the same located error.
pub(crate) fn check_spacing(name: &str, sp: crate::geometry::Vec3) -> Result<()> {
    if !(sp.x > 0.0 && sp.y > 0.0 && sp.z > 0.0)
        || !(sp.x.is_finite() && sp.y.is_finite() && sp.z.is_finite())
    {
        bail!("{name} spacing must be positive and finite, got {sp:?}");
    }
    Ok(())
}

/// Which derived-image families the extractor computes features on.
///
/// `original` is the unfiltered image; `log` adds one derived image per
/// configured sigma ([`log_filter`]); `wavelet` adds the 8 Haar sub-bands
/// per decomposition level ([`haar_decompose`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageTypes {
    /// Extract from the unfiltered image.
    pub original: bool,
    /// Extract from Laplacian-of-Gaussian filtered images.
    pub log: bool,
    /// Extract from the Haar wavelet sub-bands.
    pub wavelet: bool,
}

impl Default for ImageTypes {
    fn default() -> Self {
        ImageTypes { original: true, log: false, wavelet: false }
    }
}

impl ImageTypes {
    /// Parse a comma-separated type list, e.g. `"original,log"`.
    /// Accepted names: `original`, `log`, `wavelet`, `all`. At least one
    /// type must be named — an empty list is an error.
    pub fn parse(s: &str) -> Result<ImageTypes> {
        let mut c = ImageTypes { original: false, log: false, wavelet: false };
        let mut recognized = 0usize;
        for tok in s.split(',') {
            let tok = tok.trim();
            if !tok.is_empty() {
                recognized += 1;
            }
            match tok {
                "" => {}
                "original" => c.original = true,
                "log" => c.log = true,
                "wavelet" => c.wavelet = true,
                "all" => {
                    c.original = true;
                    c.log = true;
                    c.wavelet = true;
                }
                other => bail!("unknown image type '{other}' (original|log|wavelet|all)"),
            }
        }
        if recognized == 0 {
            bail!("image type list is empty; name at least one type, e.g. \"original\"");
        }
        Ok(c)
    }

    /// Number of derived images this selection produces per case.
    pub fn image_count(&self, n_sigmas: usize, wavelet_levels: usize) -> usize {
        let mut n = 0;
        if self.original {
            n += 1;
        }
        if self.log {
            n += n_sigmas;
        }
        if self.wavelet {
            n += 8 * wavelet_levels.max(1);
        }
        n
    }
}

/// Knobs for [`derive_images`] (config/CLI plumb these through).
#[derive(Debug, Clone, PartialEq)]
pub struct ImgprocOptions {
    /// Which derived-image families to produce.
    pub image_types: ImageTypes,
    /// LoG sigmas in millimetres (one derived image per sigma).
    pub log_sigmas: Vec<f64>,
    /// Haar decomposition levels (level `k` re-decomposes the previous
    /// level's LLL band with a doubled dilation step); each level emits
    /// all 8 sub-bands.
    pub wavelet_levels: usize,
    /// Work decomposition for the parallel passes.
    pub strategy: Strategy,
    /// Worker threads (`0` = all cores, `1` = serial).
    pub threads: usize,
}

impl Default for ImgprocOptions {
    fn default() -> Self {
        ImgprocOptions {
            image_types: ImageTypes::default(),
            log_sigmas: vec![2.0],
            wavelet_levels: 1,
            strategy: Strategy::LocalAccumulators,
            threads: 0,
        }
    }
}

/// One derived image: the filter-qualified name prefix plus the filtered
/// volume (always the same dims/spacing as the input image).
#[derive(Debug, Clone, PartialEq)]
pub struct DerivedImage {
    /// PyRadiomics-convention image-type prefix: `original`,
    /// `log-sigma-2-0-mm`, `wavelet-LLH`, `wavelet2-LLH`, …
    pub name: String,
    /// The derived volume.
    pub image: VoxelGrid<f32>,
}

/// The PyRadiomics-convention name prefix of a LoG image, e.g.
/// `log-sigma-2-0-mm` for `sigma = 2.0` or `log-sigma-2-25-mm` for `2.25`.
pub fn log_sigma_name(sigma: f64) -> String {
    let s = if sigma.fract() == 0.0 { format!("{sigma:.1}") } else { format!("{sigma}") };
    format!("log-sigma-{}-mm", s.replace('.', "-"))
}

/// The name prefix of a wavelet sub-band: `wavelet-LLH` at level 1,
/// `wavelet2-LLH` at level 2, …
pub fn wavelet_band_name(level: usize, band: &str) -> String {
    if level <= 1 {
        format!("wavelet-{band}")
    } else {
        format!("wavelet{level}-{band}")
    }
}

/// Produce every enabled derived image of `image`, in a fixed order:
/// `original`, then one LoG image per sigma (config order), then the 8
/// wavelet sub-bands of each level ([`SUB_BANDS`] order).
///
/// All filtering runs through the deterministic parallel engine (see the
/// module docs); the output is bit-identical for any strategy / thread
/// count. Errors on invalid sigmas and degenerate volumes.
pub fn derive_images(
    image: &VoxelGrid<f32>,
    opts: &ImgprocOptions,
) -> Result<Vec<DerivedImage>> {
    let mut out = Vec::with_capacity(
        opts.image_types.image_count(opts.log_sigmas.len(), opts.wavelet_levels),
    );
    if opts.image_types.original {
        out.push(DerivedImage { name: "original".to_string(), image: image.clone() });
    }
    if opts.image_types.log {
        if opts.log_sigmas.is_empty() {
            bail!("image type 'log' is enabled but log_sigmas is empty");
        }
        for &sigma in &opts.log_sigmas {
            let filtered = log_filter(image, sigma, opts.strategy, opts.threads)?;
            out.push(DerivedImage { name: log_sigma_name(sigma), image: filtered });
        }
    }
    if opts.image_types.wavelet {
        let levels = opts.wavelet_levels.max(1);
        let mut input = image.clone();
        for level in 1..=levels {
            let bands = haar_decompose(&input, level, opts.strategy, opts.threads)?;
            // the LLL band seeds the next level before the move below
            if level < levels {
                input = bands[0].clone();
            }
            for (band, name) in bands.into_iter().zip(SUB_BANDS) {
                out.push(DerivedImage {
                    name: wavelet_band_name(level, name),
                    image: band,
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn patterned(n: usize) -> VoxelGrid<f32> {
        let mut img = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    img.set(x, y, z, ((3 * x + 5 * y + 7 * z) % 17) as f32);
                }
            }
        }
        img
    }

    #[test]
    fn image_types_parse() {
        let t = ImageTypes::parse("original, log").unwrap();
        assert!(t.original && t.log && !t.wavelet);
        let t = ImageTypes::parse("all").unwrap();
        assert!(t.original && t.log && t.wavelet);
        assert_eq!(t.image_count(2, 1), 11, "original + 2 LoG + 8 wavelet");
        assert!(ImageTypes::parse("bogus").is_err());
        assert!(ImageTypes::parse("").is_err());
        assert!(ImageTypes::parse(" , ").is_err());
    }

    #[test]
    fn log_sigma_names_follow_pyradiomics() {
        assert_eq!(log_sigma_name(2.0), "log-sigma-2-0-mm");
        assert_eq!(log_sigma_name(0.5), "log-sigma-0-5-mm");
        assert_eq!(log_sigma_name(2.25), "log-sigma-2-25-mm");
    }

    #[test]
    fn wavelet_band_names_carry_the_level() {
        assert_eq!(wavelet_band_name(1, "LLH"), "wavelet-LLH");
        assert_eq!(wavelet_band_name(2, "HHH"), "wavelet2-HHH");
    }

    #[test]
    fn derive_images_order_and_count() {
        let img = patterned(8);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            wavelet_levels: 1,
            threads: 1,
            ..Default::default()
        };
        let derived = derive_images(&img, &opts).unwrap();
        assert_eq!(derived.len(), 11);
        assert_eq!(derived[0].name, "original");
        assert_eq!(derived[1].name, "log-sigma-1-0-mm");
        assert_eq!(derived[2].name, "log-sigma-2-0-mm");
        assert_eq!(derived[3].name, "wavelet-LLL");
        assert_eq!(derived[10].name, "wavelet-HHH");
        for d in &derived {
            assert_eq!(d.image.dims, img.dims, "{}", d.name);
            assert_eq!(d.image.spacing, img.spacing, "{}", d.name);
        }
        assert_eq!(derived[0].image, img, "original is the unfiltered image");
    }

    #[test]
    fn multi_level_wavelet_emits_eight_bands_per_level() {
        let img = patterned(8);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("wavelet").unwrap(),
            wavelet_levels: 2,
            threads: 1,
            ..Default::default()
        };
        let derived = derive_images(&img, &opts).unwrap();
        assert_eq!(derived.len(), 16);
        assert_eq!(derived[0].name, "wavelet-LLL");
        assert_eq!(derived[8].name, "wavelet2-LLL");
        assert_eq!(derived[15].name, "wavelet2-HHH");
    }

    #[test]
    fn empty_sigma_list_with_log_enabled_is_an_error() {
        let img = patterned(4);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("log").unwrap(),
            log_sigmas: vec![],
            threads: 1,
            ..Default::default()
        };
        let err = derive_images(&img, &opts).unwrap_err();
        assert!(format!("{err:#}").contains("log_sigmas"));
    }

    #[test]
    fn derived_images_are_strategy_and_thread_invariant() {
        let img = patterned(10);
        let base = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.5],
            wavelet_levels: 2,
            strategy: Strategy::EqualSplit,
            threads: 1,
        };
        let want = derive_images(&img, &base).unwrap();
        for strategy in Strategy::ALL {
            for threads in [2usize, 3, 8] {
                let opts = ImgprocOptions { strategy, threads, ..base.clone() };
                let got = derive_images(&img, &opts).unwrap();
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }
}
