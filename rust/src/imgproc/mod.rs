//! Derived-image preprocessing: isotropic resampling, Gaussian /
//! Laplacian-of-Gaussian filtering and 3D Haar wavelet decomposition.
//!
//! In PyRadiomics the bulk of high-throughput work comes from *derived
//! images*: every enabled image type (Original, LoG at several sigmas, the
//! 8 wavelet sub-bands) re-runs first-order and texture extraction, which
//! multiplies the per-case workload by the number of derived images. This
//! module is that filter bank, organised so every pass runs through the
//! same deterministic parallel engine:
//!
//! * [`resample_image`] / [`resample_mask`] — trilinear (intensity) and
//!   nearest-neighbour (mask) resampling onto a target spacing;
//! * [`gaussian_smooth`] / [`log_filter`] — separable Gaussian and
//!   scale-normalised Laplacian-of-Gaussian at mm-denominated sigmas;
//! * [`haar_decompose`] — one-level undecimated 3D Haar transform
//!   producing the 8 LLL…HHH sub-bands (same dims as the input, so every
//!   band stays voxel-aligned with the segmentation mask).
//!
//! # Streaming memory model
//!
//! Derived images feed the extractor through the streaming visitor
//! [`for_each_derived_image`]: one volume is produced, handed to the
//! callback, and dropped before the next is built, so peak derived-image
//! residency is ≤ 2 crop-sized volumes at `wavelet_levels ≤ 2` and ≤ 3
//! beyond (in-flight image + up to two wavelet LLL seeds at intermediate
//! levels) **regardless of how many derived images are configured**. [`derive_images`] is the thin collect-based wrapper
//! for callers that genuinely need the whole bank at once; both paths
//! emit bit-identical volumes in the same order, and both feed the
//! process-wide [`peak_derived_bytes`] meter behind the pipeline's
//! `mem.peak_derived_bytes` metric.
//!
//! # Determinism contract
//!
//! Every pass decomposes its work into *lines* (or output slices) handed
//! to [`crate::parallel::fold_chunks`]: a [`Strategy`] picks the
//! decomposition, workers compute disjoint output ranges into per-thread
//! partials, and the partials are scattered into the output in fixed
//! order. Each line's arithmetic is independent of the decomposition, so
//! the output is **bit-for-bit identical for every strategy and thread
//! count** — the same contract as the texture subsystem, and asserted by
//! `tests/conformance.rs` and `benches/bench_imgproc.rs`.

mod filters;
mod lines;
mod mem;
mod resample;
mod wavelet;

pub use filters::{gaussian_kernel, gaussian_smooth, log_filter, MAX_KERNEL_RADIUS};
pub use lines::Axis;
pub use mem::{
    peak_derived_bytes, peak_pipeline_bytes, reset_peak_derived_bytes,
    reset_peak_pipeline_bytes, BudgetGuard, MemoryBudget,
};
pub(crate) use mem::PipelineHold;
pub use resample::{
    resample_image, resample_image_to_grid, resample_labels, resample_mask,
    resampled_dims, MAX_RESAMPLED_VOXELS,
};
pub use wavelet::{haar_band, haar_decompose, haar_reconstruct, SUB_BANDS};

use anyhow::{bail, Result};

use crate::parallel::Strategy;
use crate::volume::VoxelGrid;

/// Shared grid-spacing guard: every imgproc entry point rejects
/// non-positive / non-finite spacings with the same located error.
pub(crate) fn check_spacing(name: &str, sp: crate::geometry::Vec3) -> Result<()> {
    if !(sp.x > 0.0 && sp.y > 0.0 && sp.z > 0.0)
        || !(sp.x.is_finite() && sp.y.is_finite() && sp.z.is_finite())
    {
        bail!("{name} spacing must be positive and finite, got {sp:?}");
    }
    Ok(())
}

/// Which derived-image families the extractor computes features on.
///
/// `original` is the unfiltered image; `log` adds one derived image per
/// configured sigma ([`log_filter`]); `wavelet` adds the 8 Haar sub-bands
/// per decomposition level ([`haar_decompose`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageTypes {
    /// Extract from the unfiltered image.
    pub original: bool,
    /// Extract from Laplacian-of-Gaussian filtered images.
    pub log: bool,
    /// Extract from the Haar wavelet sub-bands.
    pub wavelet: bool,
}

impl Default for ImageTypes {
    fn default() -> Self {
        ImageTypes { original: true, log: false, wavelet: false }
    }
}

impl ImageTypes {
    /// Parse a comma-separated type list, e.g. `"original,log"`.
    /// Accepted names: `original`, `log`, `wavelet`, `all`. At least one
    /// type must be named — an empty list is an error.
    pub fn parse(s: &str) -> Result<ImageTypes> {
        let mut c = ImageTypes { original: false, log: false, wavelet: false };
        let mut recognized = 0usize;
        for tok in s.split(',') {
            let tok = tok.trim();
            if !tok.is_empty() {
                recognized += 1;
            }
            match tok {
                "" => {}
                "original" => c.original = true,
                "log" => c.log = true,
                "wavelet" => c.wavelet = true,
                "all" => {
                    c.original = true;
                    c.log = true;
                    c.wavelet = true;
                }
                other => bail!("unknown image type '{other}' (original|log|wavelet|all)"),
            }
        }
        if recognized == 0 {
            bail!("image type list is empty; name at least one type, e.g. \"original\"");
        }
        Ok(c)
    }

    /// Number of derived images this selection produces per case.
    /// `wavelet_levels == 0` contributes zero images — it is rejected at
    /// the config/CLI boundary and by [`for_each_derived_image`], never
    /// silently clamped.
    pub fn image_count(&self, n_sigmas: usize, wavelet_levels: usize) -> usize {
        let mut n = 0;
        if self.original {
            n += 1;
        }
        if self.log {
            n += n_sigmas;
        }
        if self.wavelet {
            n += 8 * wavelet_levels;
        }
        n
    }
}

/// Knobs for [`derive_images`] (config/CLI plumb these through).
#[derive(Debug, Clone, PartialEq)]
pub struct ImgprocOptions {
    /// Which derived-image families to produce.
    pub image_types: ImageTypes,
    /// LoG sigmas in millimetres (one derived image per sigma).
    pub log_sigmas: Vec<f64>,
    /// Haar decomposition levels (level `k` re-decomposes the previous
    /// level's LLL band with a doubled dilation step); each level emits
    /// all 8 sub-bands.
    pub wavelet_levels: usize,
    /// Work decomposition for the parallel passes.
    pub strategy: Strategy,
    /// Worker threads (`0` = all cores, `1` = serial).
    pub threads: usize,
}

impl Default for ImgprocOptions {
    fn default() -> Self {
        ImgprocOptions {
            image_types: ImageTypes::default(),
            log_sigmas: vec![2.0],
            wavelet_levels: 1,
            strategy: Strategy::LocalAccumulators,
            threads: 0,
        }
    }
}

/// One derived image: the filter-qualified name prefix plus the filtered
/// volume (always the same dims/spacing as the input image).
#[derive(Debug, Clone, PartialEq)]
pub struct DerivedImage {
    /// PyRadiomics-convention image-type prefix: `original`,
    /// `log-sigma-2-0-mm`, `wavelet-LLH`, `wavelet2-LLH`, …
    pub name: String,
    /// The derived volume.
    pub image: VoxelGrid<f32>,
}

/// The PyRadiomics-convention name prefix of a LoG image, e.g.
/// `log-sigma-2-0-mm` for `sigma = 2.0` or `log-sigma-2-25-mm` for `2.25`.
pub fn log_sigma_name(sigma: f64) -> String {
    let s = if sigma.fract() == 0.0 { format!("{sigma:.1}") } else { format!("{sigma}") };
    format!("log-sigma-{}-mm", s.replace('.', "-"))
}

/// The name prefix of a wavelet sub-band: `wavelet-LLH` at level 1,
/// `wavelet2-LLH` at level 2, …
pub fn wavelet_band_name(level: usize, band: &str) -> String {
    if level <= 1 {
        format!("wavelet-{band}")
    } else {
        format!("wavelet{level}-{band}")
    }
}

/// Borrowed view of one derived image, handed to the
/// [`for_each_derived_image`] callback. The volume lives only for the
/// duration of the call (the `original` image is the caller's own volume,
/// borrowed — never cloned); clone it only if you genuinely need it to
/// outlive the callback, because that is exactly the residency the
/// streaming visitor exists to avoid.
#[derive(Debug)]
pub struct DerivedImageRef<'a> {
    /// PyRadiomics-convention image-type prefix (see [`DerivedImage`]).
    pub name: String,
    /// The derived volume, resident only for this callback.
    pub image: &'a VoxelGrid<f32>,
}

/// What one [`for_each_derived_image`] call did: how many images it
/// emitted and the high-water mark of derived-image bytes it held at
/// once (the in-flight volume plus, for multi-level wavelets, one LLL
/// seed — two at intermediate levels when `wavelet_levels ≥ 3` — the
/// `original` image is borrowed and counts zero).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeriveStats {
    /// Derived images emitted (== `image_count` on success).
    pub images: usize,
    /// Peak bytes of derived volumes this call held concurrently.
    pub peak_resident_bytes: u64,
}

/// Stream every enabled derived image of `image` through `f`, one at a
/// time, in the fixed order `original`, then one LoG image per sigma
/// (config order), then the 8 wavelet sub-bands of each level
/// ([`SUB_BANDS`] order) — the exact list [`derive_images`] collects.
///
/// Unlike the collect-based wrapper, peak residency does **not** scale
/// with the number of derived images: the `original` is borrowed (not
/// cloned), each LoG image is dropped before the next sigma starts, and
/// wavelet bands are recomputed per band ([`haar_band`]) so only the
/// current band plus the LLL seed(s) are alive — ≤ 2 crop-sized volumes
/// at `wavelet_levels ≤ 2`, ≤ 3 at deeper levels (an intermediate level
/// holds both the previous and the next level's seed), vs. the
/// full bank (≈ 19 volumes at `all` × 2 levels) when materialised. The
/// per-band recomputation applies the same x → y → z pass composition as
/// [`haar_decompose`], so every emitted volume is **bit-identical** to
/// the materialised path for every strategy and thread count.
///
/// Errors on invalid options (empty sigma list, `wavelet_levels == 0` —
/// both already rejected at the config/CLI boundary) before emitting
/// anything; callback errors abort the stream and propagate.
pub fn for_each_derived_image<F>(
    image: &VoxelGrid<f32>,
    opts: &ImgprocOptions,
    mut f: F,
) -> Result<DeriveStats>
where
    F: FnMut(DerivedImageRef<'_>) -> Result<()>,
{
    if opts.image_types.log && opts.log_sigmas.is_empty() {
        bail!("image type 'log' is enabled but log_sigmas is empty");
    }
    if opts.image_types.wavelet && opts.wavelet_levels == 0 {
        bail!(
            "wavelet_levels must be >= 1 (0 is rejected at the config/CLI \
             boundary; reaching the image-derivation visitor with it is a bug)"
        );
    }

    let mut tally = mem::ResidentTally::default();
    let mut images = 0usize;

    if opts.image_types.original {
        f(DerivedImageRef { name: "original".to_string(), image })?;
        images += 1;
    }

    if opts.image_types.log {
        for &sigma in &opts.log_sigmas {
            let filtered = log_filter(image, sigma, opts.strategy, opts.threads)?;
            let held = tally.hold(&filtered);
            f(DerivedImageRef { name: log_sigma_name(sigma), image: &filtered })?;
            tally.release(held);
            images += 1;
        }
    }

    if opts.image_types.wavelet {
        let levels = opts.wavelet_levels;
        // previous level's LLL band (and its held byte count) — the à
        // trous seed; level 1 decomposes the borrowed input directly
        let mut seed: Option<(VoxelGrid<f32>, u64)> = None;
        for level in 1..=levels {
            let mut next_seed: Option<(VoxelGrid<f32>, u64)> = None;
            {
                let input: &VoxelGrid<f32> = match &seed {
                    Some((grid, _)) => grid,
                    None => image,
                };
                for (band, name) in SUB_BANDS.into_iter().enumerate() {
                    let vol = haar_band(input, level, band, opts.strategy, opts.threads)?;
                    let held = tally.hold(&vol);
                    f(DerivedImageRef {
                        name: wavelet_band_name(level, name),
                        image: &vol,
                    })?;
                    images += 1;
                    if band == 0 && level < levels {
                        // LLL stays resident: it seeds the next level
                        next_seed = Some((vol, held));
                    } else {
                        tally.release(held);
                    }
                }
            }
            if let Some((_, held)) = seed.take() {
                tally.release(held);
            }
            seed = next_seed;
        }
    }

    Ok(DeriveStats { images, peak_resident_bytes: tally.peak() })
}

/// Produce every enabled derived image of `image`, in a fixed order:
/// `original`, then one LoG image per sigma (config order), then the 8
/// wavelet sub-bands of each level ([`SUB_BANDS`] order).
///
/// A thin collect-based wrapper over [`for_each_derived_image`]: same
/// order, same bits — but it clones every emitted volume into the
/// returned `Vec`, so peak residency is the whole bank (tracked by
/// [`peak_derived_bytes`]). Prefer the streaming visitor on memory-bound
/// devices. Errors on invalid sigmas and degenerate volumes.
pub fn derive_images(
    image: &VoxelGrid<f32>,
    opts: &ImgprocOptions,
) -> Result<Vec<DerivedImage>> {
    let mut out: Vec<DerivedImage> = Vec::with_capacity(
        opts.image_types.image_count(opts.log_sigmas.len(), opts.wavelet_levels),
    );
    // account the collected clones so `mem.peak_derived_bytes` reflects
    // the materialised bank (released when the tally drops at return —
    // ownership passes to the caller)
    let mut tally = mem::ResidentTally::default();
    for_each_derived_image(image, opts, |d| {
        tally.hold(d.image);
        out.push(DerivedImage { name: d.name, image: d.image.clone() });
        Ok(())
    })?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn patterned(n: usize) -> VoxelGrid<f32> {
        let mut img = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::splat(1.0));
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    img.set(x, y, z, ((3 * x + 5 * y + 7 * z) % 17) as f32);
                }
            }
        }
        img
    }

    #[test]
    fn image_types_parse() {
        let t = ImageTypes::parse("original, log").unwrap();
        assert!(t.original && t.log && !t.wavelet);
        let t = ImageTypes::parse("all").unwrap();
        assert!(t.original && t.log && t.wavelet);
        assert_eq!(t.image_count(2, 1), 11, "original + 2 LoG + 8 wavelet");
        assert!(ImageTypes::parse("bogus").is_err());
        assert!(ImageTypes::parse("").is_err());
        assert!(ImageTypes::parse(" , ").is_err());
    }

    #[test]
    fn log_sigma_names_follow_pyradiomics() {
        assert_eq!(log_sigma_name(2.0), "log-sigma-2-0-mm");
        assert_eq!(log_sigma_name(0.5), "log-sigma-0-5-mm");
        assert_eq!(log_sigma_name(2.25), "log-sigma-2-25-mm");
    }

    #[test]
    fn wavelet_band_names_carry_the_level() {
        assert_eq!(wavelet_band_name(1, "LLH"), "wavelet-LLH");
        assert_eq!(wavelet_band_name(2, "HHH"), "wavelet2-HHH");
    }

    #[test]
    fn derive_images_order_and_count() {
        let img = patterned(8);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            wavelet_levels: 1,
            threads: 1,
            ..Default::default()
        };
        let derived = derive_images(&img, &opts).unwrap();
        assert_eq!(derived.len(), 11);
        assert_eq!(derived[0].name, "original");
        assert_eq!(derived[1].name, "log-sigma-1-0-mm");
        assert_eq!(derived[2].name, "log-sigma-2-0-mm");
        assert_eq!(derived[3].name, "wavelet-LLL");
        assert_eq!(derived[10].name, "wavelet-HHH");
        for d in &derived {
            assert_eq!(d.image.dims, img.dims, "{}", d.name);
            assert_eq!(d.image.spacing, img.spacing, "{}", d.name);
        }
        assert_eq!(derived[0].image, img, "original is the unfiltered image");
    }

    #[test]
    fn multi_level_wavelet_emits_eight_bands_per_level() {
        let img = patterned(8);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("wavelet").unwrap(),
            wavelet_levels: 2,
            threads: 1,
            ..Default::default()
        };
        let derived = derive_images(&img, &opts).unwrap();
        assert_eq!(derived.len(), 16);
        assert_eq!(derived[0].name, "wavelet-LLL");
        assert_eq!(derived[8].name, "wavelet2-LLL");
        assert_eq!(derived[15].name, "wavelet2-HHH");
    }

    #[test]
    fn empty_sigma_list_with_log_enabled_is_an_error() {
        let img = patterned(4);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("log").unwrap(),
            log_sigmas: vec![],
            threads: 1,
            ..Default::default()
        };
        let err = derive_images(&img, &opts).unwrap_err();
        assert!(format!("{err:#}").contains("log_sigmas"));
    }

    #[test]
    fn wavelet_levels_zero_is_an_error_not_a_clamp() {
        // 0 is rejected at the config/CLI boundary; the derivation layer
        // must refuse it too instead of silently computing one level
        let img = patterned(4);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("wavelet").unwrap(),
            wavelet_levels: 0,
            threads: 1,
            ..Default::default()
        };
        let err = derive_images(&img, &opts).unwrap_err();
        assert!(format!("{err:#}").contains("wavelet_levels"), "{err:#}");
        let err = for_each_derived_image(&img, &opts, |_| Ok(())).unwrap_err();
        assert!(format!("{err:#}").contains("wavelet_levels"), "{err:#}");
        // image_count no longer clamps either
        assert_eq!(opts.image_types.image_count(0, 0), 0);
        assert_eq!(opts.image_types.image_count(0, 2), 16);
    }

    #[test]
    fn visitor_streams_the_materialised_list_bit_for_bit() {
        let img = patterned(10);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            wavelet_levels: 2,
            threads: 1,
            ..Default::default()
        };
        let want = derive_images(&img, &opts).unwrap();
        assert_eq!(want.len(), 19, "original + 2 LoG + 16 wavelet");
        let mut got: Vec<DerivedImage> = Vec::new();
        let stats = for_each_derived_image(&img, &opts, |d| {
            got.push(DerivedImage { name: d.name, image: d.image.clone() });
            Ok(())
        })
        .unwrap();
        assert_eq!(got, want);
        assert_eq!(stats.images, 19);
        // residency cap: in-flight volume + LLL seed, never the full bank
        let vol_bytes = (img.dims.len() * std::mem::size_of::<f32>()) as u64;
        assert!(
            stats.peak_resident_bytes <= 2 * vol_bytes,
            "streaming held {} bytes, cap is {}",
            stats.peak_resident_bytes,
            2 * vol_bytes
        );
    }

    #[test]
    fn deep_wavelet_levels_cap_at_three_resident_volumes() {
        // at wavelet_levels >= 3 an intermediate level holds the previous
        // AND the next level's LLL seed next to the in-flight band — the
        // documented ≤ 3-volume ceiling, still independent of depth
        let img = patterned(12);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("wavelet").unwrap(),
            wavelet_levels: 3,
            threads: 1,
            ..Default::default()
        };
        let stats = for_each_derived_image(&img, &opts, |_| Ok(())).unwrap();
        assert_eq!(stats.images, 24);
        let vol_bytes = (img.dims.len() * std::mem::size_of::<f32>()) as u64;
        assert!(stats.peak_resident_bytes > 2 * vol_bytes, "two seeds + band");
        assert!(stats.peak_resident_bytes <= 3 * vol_bytes);
    }

    #[test]
    fn visitor_borrows_the_original_image() {
        // original-only: no derived volume is ever allocated or held
        let img = patterned(6);
        let opts = ImgprocOptions { threads: 1, ..Default::default() };
        let mut seen = 0usize;
        let stats = for_each_derived_image(&img, &opts, |d| {
            assert_eq!(d.name, "original");
            assert!(std::ptr::eq(d.image, &img), "must borrow, not clone");
            seen += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(seen, 1);
        assert_eq!(stats.peak_resident_bytes, 0);
    }

    #[test]
    fn visitor_callback_errors_abort_the_stream() {
        let img = patterned(6);
        let opts = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0],
            wavelet_levels: 1,
            threads: 1,
            ..Default::default()
        };
        let mut calls = 0usize;
        let err = for_each_derived_image(&img, &opts, |d| {
            calls += 1;
            if d.name.starts_with("log-") {
                bail!("stop at {}", d.name);
            }
            Ok(())
        })
        .unwrap_err();
        assert!(format!("{err:#}").contains("log-sigma-1-0-mm"));
        assert_eq!(calls, 2, "original + the failing LoG image, nothing after");
    }

    #[test]
    fn derived_images_are_strategy_and_thread_invariant() {
        let img = patterned(10);
        let base = ImgprocOptions {
            image_types: ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.5],
            wavelet_levels: 2,
            strategy: Strategy::EqualSplit,
            threads: 1,
        };
        let want = derive_images(&img, &base).unwrap();
        for strategy in Strategy::ALL {
            for threads in [2usize, 3, 8] {
                let opts = ImgprocOptions { strategy, threads, ..base.clone() };
                let got = derive_images(&img, &opts).unwrap();
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }
}
