//! Undecimated one-level 3D Haar wavelet decomposition.
//!
//! Each axis pass maps a line `x` to a low band `L_i = (x_i + x_{i+s})/2`
//! and a high band `H_i = (x_i - x_{i+s})/2` (dilation step `s = 2^(k-1)`
//! at level `k`, edge-clamped neighbour). The transform is *undecimated*
//! (à trous): every sub-band keeps the input dims, so the bands stay
//! voxel-aligned with the segmentation mask — the same property
//! PyRadiomics gets from `pywt.swtn`. The `/2` normalisation keeps the
//! arithmetic exact on dyadic inputs: `x_i = L_i + H_i` holds **bit-for-
//! bit**, so decomposition followed by [`haar_reconstruct`] is exact on
//! integer volumes (property-tested in `tests/proptests.rs`).

use anyhow::{bail, Result};

use super::lines::{map_lines, Axis};
use crate::parallel::Strategy;
use crate::volume::VoxelGrid;

/// The 8 sub-band names in output order. Letter order is `[x, y, z]`:
/// `HLL` is high-pass along x, low-pass along y and z.
pub const SUB_BANDS: [&str; 8] = ["LLL", "HLL", "LHL", "HHL", "LLH", "HLH", "LHH", "HHH"];

/// One Haar pass along `axis` with dilation `step`: low band when
/// `high == false`, high band otherwise.
fn haar_pass(
    img: &VoxelGrid<f32>,
    axis: Axis,
    step: usize,
    high: bool,
    strategy: Strategy,
    threads: usize,
) -> VoxelGrid<f32> {
    map_lines(img, axis, strategy, threads, |line, out| {
        let n = line.len();
        for (i, &a) in line.iter().enumerate() {
            let b = line[(i + step).min(n - 1)];
            let v = if high {
                (a as f64 - b as f64) / 2.0
            } else {
                (a as f64 + b as f64) / 2.0
            };
            out.push(v as f32);
        }
    })
}

/// Shared input guard: empty volumes and out-of-range levels (0 is
/// rejected at the config/CLI boundary and must not be silently clamped
/// here) are located errors.
fn check_decompose_input(img: &VoxelGrid<f32>, level: usize) -> Result<()> {
    if img.dims.is_empty() {
        bail!("cannot decompose an empty volume {}", img.dims);
    }
    if level == 0 {
        bail!("wavelet level must be >= 1 (0 is rejected at the config/CLI boundary)");
    }
    if level > 20 {
        bail!("wavelet level {level} is out of range (max 20)");
    }
    Ok(())
}

/// Decompose `img` into its 8 undecimated Haar sub-bands at `level`
/// (dilation step `2^(level-1)`), in [`SUB_BANDS`] order.
///
/// Levels above 1 are meant to be fed the previous level's LLL band —
/// the à trous construction — which
/// [`crate::imgproc::for_each_derived_image`] does. Errors on an empty
/// volume, a zero level, or a level so deep that the dilation step
/// overflows. When only one band is needed at a time, [`haar_band`]
/// produces the identical bits while holding a single volume.
pub fn haar_decompose(
    img: &VoxelGrid<f32>,
    level: usize,
    strategy: Strategy,
    threads: usize,
) -> Result<[VoxelGrid<f32>; 8]> {
    check_decompose_input(img, level)?;
    let step = 1usize << (level - 1);
    // one band per bit pattern: bit 0 = x high-pass, bit 1 = y, bit 2 = z
    let mut bands: Vec<VoxelGrid<f32>> = vec![img.clone()];
    for axis in Axis::ALL {
        let mut next = Vec::with_capacity(bands.len() * 2);
        for high in [false, true] {
            for g in &bands {
                next.push(haar_pass(g, axis, step, high, strategy, threads));
            }
        }
        bands = next;
    }
    let mut it = bands.into_iter();
    Ok(std::array::from_fn(|_| it.next().expect("8 sub-bands")))
}

/// Compute one undecimated Haar sub-band of `img` at `level`; `band`
/// indexes [`SUB_BANDS`] (bit 0 = x high-pass, bit 1 = y, bit 2 = z).
///
/// Applies the identical x → y → z pass composition as [`haar_decompose`]
/// — the returned volume is **bit-for-bit equal** to `haar_decompose(img,
/// level, …)[band]` — but materialises only the requested band (peak: one
/// in-flight intermediate instead of up to eight band volumes). A full
/// decomposition shares intermediate passes (14 total) where eight
/// `haar_band` calls pay 24; the streaming visitor takes that ~1.7× pass
/// trade to cap peak memory.
pub fn haar_band(
    img: &VoxelGrid<f32>,
    level: usize,
    band: usize,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<f32>> {
    check_decompose_input(img, level)?;
    if band >= 8 {
        bail!("sub-band index {band} is out of range (0..8, see SUB_BANDS)");
    }
    let step = 1usize << (level - 1);
    let mut out = haar_pass(img, Axis::X, step, band & 1 != 0, strategy, threads);
    out = haar_pass(&out, Axis::Y, step, band & 2 != 0, strategy, threads);
    out = haar_pass(&out, Axis::Z, step, band & 4 != 0, strategy, threads);
    Ok(out)
}

/// Reconstruct the input of one [`haar_decompose`] call: with the `/2`
/// normalisation the inverse is simply the voxel-wise sum of the 8
/// sub-bands (`x = Σ bands`), which is exact — bit-for-bit on dyadic
/// inputs such as integer volumes.
pub fn haar_reconstruct(bands: &[VoxelGrid<f32>; 8]) -> VoxelGrid<f32> {
    let mut out = VoxelGrid::zeros(bands[0].dims, bands[0].spacing);
    let out_data = out.data_mut();
    for band in bands {
        for (o, &v) in out_data.iter_mut().zip(band.data()) {
            *o += v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn patterned(dims: Dims) -> VoxelGrid<f32> {
        let mut g = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    g.set(x, y, z, ((7 * x + 11 * y + 13 * z) % 31) as f32);
                }
            }
        }
        g
    }

    #[test]
    fn constant_volume_concentrates_in_lll() {
        let mut g = VoxelGrid::zeros(Dims::new(6, 5, 4), Vec3::splat(1.0));
        g.data_mut().fill(3.5);
        let bands = haar_decompose(&g, 1, Strategy::EqualSplit, 1).unwrap();
        assert_eq!(bands[0], g, "LLL of a constant is the constant");
        for (b, name) in bands.iter().zip(SUB_BANDS).skip(1) {
            assert!(b.data().iter().all(|&v| v == 0.0), "{name} must vanish");
        }
    }

    #[test]
    fn known_1d_pair_decomposes_exactly() {
        // line [6, 2]: L = [(6+2)/2, 2] = [4, 2] (edge clamp pairs the last
        // sample with itself), H = [(6-2)/2, 0] = [2, 0]
        let mut g = VoxelGrid::zeros(Dims::new(2, 1, 1), Vec3::splat(1.0));
        g.set(0, 0, 0, 6.0);
        g.set(1, 0, 0, 2.0);
        let bands = haar_decompose(&g, 1, Strategy::EqualSplit, 1).unwrap();
        let lll = &bands[0];
        let hll = &bands[1];
        assert_eq!((lll.get(0, 0, 0), lll.get(1, 0, 0)), (4.0, 2.0));
        assert_eq!((hll.get(0, 0, 0), hll.get(1, 0, 0)), (2.0, 0.0));
        for b in &bands[2..] {
            assert!(b.data().iter().all(|&v| v == 0.0), "no y/z structure");
        }
    }

    #[test]
    fn reconstruction_is_bit_exact_on_integer_volumes() {
        let g = patterned(Dims::new(7, 6, 5));
        for level in 1..=2 {
            let bands = haar_decompose(&g, level, Strategy::EqualSplit, 1).unwrap();
            let back = haar_reconstruct(&bands);
            assert_eq!(back, g, "level {level}");
        }
    }

    #[test]
    fn band_letters_match_the_axis_structure() {
        // a field varying only along z puts all detail energy into LLH
        let mut g = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        for z in 0..4 {
            for y in 0..4 {
                for x in 0..4 {
                    g.set(x, y, z, (z * z) as f32);
                }
            }
        }
        let bands = haar_decompose(&g, 1, Strategy::EqualSplit, 1).unwrap();
        let energy = |b: &VoxelGrid<f32>| -> f64 {
            b.data().iter().map(|&v| (v as f64) * (v as f64)).sum()
        };
        let idx_llh = SUB_BANDS.iter().position(|&n| n == "LLH").unwrap();
        assert!(energy(&bands[idx_llh]) > 0.0);
        for (i, name) in SUB_BANDS.iter().enumerate() {
            if i != 0 && i != idx_llh {
                assert_eq!(energy(&bands[i]), 0.0, "{name}");
            }
        }
    }

    #[test]
    fn decompose_rejects_bad_inputs() {
        let g = patterned(Dims::new(4, 4, 4));
        assert!(haar_decompose(&g, 21, Strategy::EqualSplit, 1).is_err());
        assert!(haar_decompose(&g, 0, Strategy::EqualSplit, 1).is_err(), "no silent clamp");
        let empty = VoxelGrid::<f32>::zeros(Dims::new(0, 4, 4), Vec3::splat(1.0));
        assert!(haar_decompose(&empty, 1, Strategy::EqualSplit, 1).is_err());
        assert!(haar_band(&g, 0, 0, Strategy::EqualSplit, 1).is_err());
        assert!(haar_band(&g, 1, 8, Strategy::EqualSplit, 1).is_err());
    }

    #[test]
    fn haar_band_matches_the_full_decomposition_bit_for_bit() {
        let g = patterned(Dims::new(7, 6, 5));
        for level in 1..=2 {
            let bands = haar_decompose(&g, level, Strategy::EqualSplit, 1).unwrap();
            for (b, name) in SUB_BANDS.iter().enumerate() {
                let one = haar_band(&g, level, b, Strategy::LocalAccumulators, 2).unwrap();
                assert_eq!(one, bands[b], "level {level} band {name}");
            }
        }
    }
}
