//! The deterministic line/slice-parallel engine behind every imgproc pass.
//!
//! A separable 3D pass is a 1D transform applied independently to every
//! grid *line* along one axis. [`map_lines`] decomposes the set of lines
//! with [`crate::parallel::fold_chunks`] — each worker computes whole
//! output lines into a per-thread partial, and the partials are scattered
//! into the output buffer afterwards. Every line is written exactly once
//! and its arithmetic does not depend on the decomposition, so the result
//! is bit-for-bit identical for any [`Strategy`] and thread count.
//!
//! Scratch is **chunk-granular**: each work chunk appends all of its
//! output lines into one contiguous buffer (one allocation per chunk, ~
//! [`LINE_CHUNK`]× fewer allocations than the earlier one-`Vec`-per-line
//! partials) and the scatter walks the buffer in fixed line order, which
//! preserves the determinism contract unchanged.

use crate::parallel::{fold_chunks, Strategy};
use crate::volume::{Dims, VoxelGrid};

/// Lines per work unit for the dynamic-queue strategies — small enough to
/// load-balance, large enough to amortise the queue traffic.
const LINE_CHUNK: usize = 16;

/// A grid axis. `X` is the fastest-varying storage dimension (see
/// [`VoxelGrid::index`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Axis {
    /// The x (fastest, stride 1) axis.
    X,
    /// The y (stride `dims.x`) axis.
    Y,
    /// The z (slowest, stride `dims.x * dims.y`) axis.
    Z,
}

impl Axis {
    /// All three axes in storage order.
    pub const ALL: [Axis; 3] = [Axis::X, Axis::Y, Axis::Z];

    /// Length of a line along this axis.
    pub fn line_len(&self, dims: Dims) -> usize {
        match self {
            Axis::X => dims.x,
            Axis::Y => dims.y,
            Axis::Z => dims.z,
        }
    }

    /// Number of lines along this axis (product of the other two dims).
    pub fn line_count(&self, dims: Dims) -> usize {
        match self {
            Axis::X => dims.y * dims.z,
            Axis::Y => dims.x * dims.z,
            Axis::Z => dims.x * dims.y,
        }
    }

    /// Element stride of a line along this axis.
    fn stride(&self, dims: Dims) -> usize {
        match self {
            Axis::X => 1,
            Axis::Y => dims.x,
            Axis::Z => dims.x * dims.y,
        }
    }

    /// Flat index of the first element of line `l` (lines are numbered
    /// with the lower-stride perpendicular axis varying fastest).
    fn line_base(&self, dims: Dims, l: usize) -> usize {
        match self {
            // l = y + dims.y * z  →  index = dims.x * l
            Axis::X => dims.x * l,
            // l = x + dims.x * z  →  index = x + dims.x * dims.y * z
            Axis::Y => (l % dims.x) + dims.x * dims.y * (l / dims.x),
            // l = x + dims.x * y  →  index = l
            Axis::Z => l,
        }
    }
}

/// Apply `line_fn` to every line of `src` along `axis`, in parallel.
///
/// `line_fn(input, output)` receives one gathered input line and must
/// **append** exactly `axis.line_len(dims)` samples to `output` (which
/// may already hold earlier lines of the same work chunk — never clear
/// it). The function must be pure — its appended samples may depend only
/// on the input line — which makes the whole pass deterministic for any
/// strategy and thread count (each output line is written exactly once).
pub(crate) fn map_lines<F>(
    src: &VoxelGrid<f32>,
    axis: Axis,
    strategy: Strategy,
    threads: usize,
    line_fn: F,
) -> VoxelGrid<f32>
where
    F: Fn(&[f32], &mut Vec<f32>) + Sync,
{
    let dims = src.dims;
    if dims.is_empty() {
        return VoxelGrid::zeros(dims, src.spacing);
    }
    let len = axis.line_len(dims);
    let n_lines = axis.line_count(dims);
    let stride = axis.stride(dims);
    let data = src.data();

    // chunk-granular partials: (first line index, every output line of
    // the chunk concatenated in line order) — one scratch allocation per
    // work chunk instead of one `Vec` per output line
    let partials: Vec<(usize, Vec<f32>)> = fold_chunks(
        strategy,
        n_lines,
        LINE_CHUNK,
        threads,
        Vec::new,
        |acc: &mut Vec<(usize, Vec<f32>)>, range| {
            let mut input = vec![0.0f32; len];
            let mut chunk_out = Vec::with_capacity(range.len() * len);
            let first = range.start;
            for l in range {
                let base = axis.line_base(dims, l);
                for (i, v) in input.iter_mut().enumerate() {
                    *v = data[base + i * stride];
                }
                let before = chunk_out.len();
                line_fn(&input, &mut chunk_out);
                debug_assert_eq!(
                    chunk_out.len() - before,
                    len,
                    "line_fn must append exactly one output line"
                );
            }
            acc.push((first, chunk_out));
        },
        |acc, part| acc.extend(part),
    );

    // scatter: chunks cover disjoint line ranges and each line is written
    // exactly once, so the fill order cannot change the result
    let mut out = VoxelGrid::zeros(dims, src.spacing);
    let out_data = out.data_mut();
    for (first, chunk_out) in partials {
        for (j, line) in chunk_out.chunks_exact(len).enumerate() {
            let base = axis.line_base(dims, first + j);
            for (i, &v) in line.iter().enumerate() {
                out_data[base + i * stride] = v;
            }
        }
    }
    out
}

/// Build a grid of `dims`/`spacing` by computing whole z-slices in
/// parallel: `slice_fn(z, out)` fills `out` (cleared beforehand) with the
/// `dims.x * dims.y` samples of slice `z` in storage order. Same
/// determinism argument as [`map_lines`].
pub(crate) fn build_slices<T, F>(
    dims: Dims,
    spacing: crate::geometry::Vec3,
    strategy: Strategy,
    threads: usize,
    slice_fn: F,
) -> VoxelGrid<T>
where
    T: Copy + Default + Send,
    F: Fn(usize, &mut Vec<T>) + Sync,
{
    let slice_len = dims.x * dims.y;
    let partials: Vec<(usize, Vec<T>)> = fold_chunks(
        strategy,
        dims.z,
        1,
        threads,
        Vec::new,
        |acc: &mut Vec<(usize, Vec<T>)>, range| {
            for z in range {
                let mut out = Vec::with_capacity(slice_len);
                slice_fn(z, &mut out);
                debug_assert_eq!(out.len(), slice_len, "slice_fn must fill the slice");
                acc.push((z, out));
            }
        },
        |acc, part| acc.extend(part),
    );

    let mut out = VoxelGrid::zeros(dims, spacing);
    let out_data = out.data_mut();
    for (z, slice) in partials {
        out_data[z * slice_len..(z + 1) * slice_len].copy_from_slice(&slice);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;

    fn numbered(dims: Dims) -> VoxelGrid<f32> {
        let mut g = VoxelGrid::zeros(dims, Vec3::splat(1.0));
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    g.set(x, y, z, (x + 10 * y + 100 * z) as f32);
                }
            }
        }
        g
    }

    #[test]
    fn identity_line_fn_reproduces_the_grid() {
        let g = numbered(Dims::new(4, 3, 5));
        for axis in Axis::ALL {
            let out = map_lines(&g, axis, Strategy::EqualSplit, 2, |line, out| {
                out.extend_from_slice(line);
            });
            assert_eq!(out, g, "{axis:?}");
        }
    }

    #[test]
    fn reverse_line_fn_flips_only_that_axis() {
        let g = numbered(Dims::new(4, 3, 2));
        let out = map_lines(&g, Axis::X, Strategy::Flat1D, 3, |line, out| {
            out.extend(line.iter().rev());
        });
        for z in 0..2 {
            for y in 0..3 {
                for x in 0..4 {
                    assert_eq!(out.get(x, y, z), g.get(3 - x, y, z));
                }
            }
        }
    }

    #[test]
    fn line_geometry_covers_every_element_once() {
        let dims = Dims::new(5, 4, 3);
        for axis in Axis::ALL {
            let mut seen = vec![0u32; dims.len()];
            let stride = axis.stride(dims);
            for l in 0..axis.line_count(dims) {
                let base = axis.line_base(dims, l);
                for i in 0..axis.line_len(dims) {
                    seen[base + i * stride] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{axis:?}");
        }
    }

    #[test]
    fn map_lines_is_strategy_and_thread_invariant() {
        let g = numbered(Dims::new(6, 5, 4));
        let smooth = |line: &[f32], out: &mut Vec<f32>| {
            for i in 0..line.len() {
                let prev = line[i.saturating_sub(1)];
                let next = line[(i + 1).min(line.len() - 1)];
                out.push((prev as f64 * 0.25 + line[i] as f64 * 0.5 + next as f64 * 0.25) as f32);
            }
        };
        let want = map_lines(&g, Axis::Y, Strategy::EqualSplit, 1, smooth);
        for strategy in Strategy::ALL {
            for threads in [1usize, 2, 3, 8] {
                let got = map_lines(&g, Axis::Y, strategy, threads, smooth);
                assert_eq!(got, want, "{strategy:?} threads={threads}");
            }
        }
    }

    #[test]
    fn chunked_buffers_match_the_per_line_reference_on_random_dims() {
        // the old implementation allocated one Vec per output line; the
        // chunk-granular buffers must reproduce it exactly — replay the
        // per-line gather/transform/scatter inline as the reference
        use crate::testkit::Pcg32;
        let mut rng = Pcg32::new(0x11ECD);
        // asymmetric taps: order-sensitive, catches scatter/index mix-ups
        let line_fn = |line: &[f32], out: &mut Vec<f32>| {
            for i in 0..line.len() {
                let prev = line[i.saturating_sub(1)] as f64;
                let next = line[(i + 1).min(line.len() - 1)] as f64;
                out.push((0.5 * prev + line[i] as f64 - 0.25 * next) as f32);
            }
        };
        for trial in 0..25 {
            let dims = Dims::new(
                1 + rng.below(9) as usize,
                1 + rng.below(9) as usize,
                1 + rng.below(9) as usize,
            );
            let mut g = VoxelGrid::zeros(dims, Vec3::splat(1.0));
            for v in g.data_mut() {
                *v = rng.below(997) as f32;
            }
            for axis in Axis::ALL {
                let len = axis.line_len(dims);
                let stride = axis.stride(dims);
                let mut want = VoxelGrid::zeros(dims, g.spacing);
                for l in 0..axis.line_count(dims) {
                    let base = axis.line_base(dims, l);
                    let input: Vec<f32> =
                        (0..len).map(|i| g.data()[base + i * stride]).collect();
                    let mut line = Vec::with_capacity(len);
                    line_fn(&input, &mut line);
                    for (i, v) in line.into_iter().enumerate() {
                        want.data_mut()[base + i * stride] = v;
                    }
                }
                for strategy in Strategy::ALL {
                    for threads in [1usize, 2, 5] {
                        let got = map_lines(&g, axis, strategy, threads, line_fn);
                        assert_eq!(
                            got, want,
                            "trial {trial} {axis:?} {strategy:?} threads={threads}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn build_slices_fills_in_storage_order() {
        let dims = Dims::new(3, 2, 4);
        let g: VoxelGrid<f32> =
            build_slices(dims, Vec3::splat(1.0), Strategy::BlockReduction, 3, |z, out| {
                for i in 0..6 {
                    out.push((100 * z + i) as f32);
                }
            });
        assert_eq!(g.get(0, 0, 2), 200.0);
        assert_eq!(g.get(2, 1, 3), 305.0);
    }
}
