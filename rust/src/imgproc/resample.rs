//! Grid resampling: trilinear for intensity images, nearest-neighbour for
//! segmentation masks.
//!
//! Sample mapping follows the corner-lattice convention of
//! [`VoxelGrid::world`]: output voxel `i` along an axis sits at physical
//! position `i · new_spacing` mm, which maps to the fractional source
//! index `i · (new_spacing / old_spacing)`. When the target spacing
//! equals the source spacing the ratio is exactly 1 and resampling is the
//! **bit-exact identity** (property-tested); trilinear interpolation
//! exactly reproduces fields that are trilinear polynomials of the
//! physical coordinates. Out-of-range corners clamp to the volume edge.

use anyhow::{bail, Result};

use super::check_spacing;
use super::lines::build_slices;
use crate::geometry::Vec3;
use crate::parallel::Strategy;
use crate::volume::{Dims, VoxelGrid};

/// Output-volume ceiling for spacing-driven resampling: a misconfigured
/// target (say `resampled_spacing = 1e-9`) must fail with a pointed error
/// instead of attempting a multi-terabyte allocation. 2²⁸ voxels ≈ 1 GiB
/// of f32 — far above any realistic medical volume.
pub const MAX_RESAMPLED_VOXELS: usize = 1 << 28;

/// Samples along one axis when resampling `n` samples at spacing `old`
/// onto spacing `new`: every output sample whose physical position stays
/// within the source lattice `[0, (n-1)·old]`. The epsilon absorbs the
/// float rounding of `old/new` (0.3/0.1 is 2.999…96), which would
/// otherwise silently drop the final in-extent sample plane; an output
/// sample nudged just past the lattice edge reads the clamped edge value.
fn axis_samples(n: usize, old: f64, new: f64) -> usize {
    if n == 0 {
        return 0;
    }
    (((n - 1) as f64 * (old / new) + 1e-9).floor() as usize) + 1
}

fn check_output_volume(dims: Dims) -> Result<()> {
    let total = (dims.x as u128) * (dims.y as u128) * (dims.z as u128);
    if total > MAX_RESAMPLED_VOXELS as u128 {
        bail!(
            "resampled grid {dims} has {total} voxels (max {MAX_RESAMPLED_VOXELS}) — \
             check the target spacing"
        );
    }
    Ok(())
}

/// Output dims when resampling `dims` at `old` spacing onto `new` spacing.
/// Identity when the spacings are equal.
pub fn resampled_dims(dims: Dims, old: Vec3, new: Vec3) -> Dims {
    Dims::new(
        axis_samples(dims.x, old.x, new.x),
        axis_samples(dims.y, old.y, new.y),
        axis_samples(dims.z, old.z, new.z),
    )
}

/// Trilinear-resample `img` onto `new_spacing` (per-axis mm). The output
/// covers the source physical extent (see [`resampled_dims`]); equal
/// spacings return a bit-exact copy.
pub fn resample_image(
    img: &VoxelGrid<f32>,
    new_spacing: Vec3,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<f32>> {
    if img.dims.is_empty() {
        bail!("cannot resample an empty image volume {}", img.dims);
    }
    check_spacing("source image", img.spacing)?;
    check_spacing("target", new_spacing)?;
    let dims = resampled_dims(img.dims, img.spacing, new_spacing);
    check_output_volume(dims)?;
    resample_image_to_grid(img, dims, new_spacing, strategy, threads)
}

/// Trilinear-resample `img` onto an explicit target grid (`dims` voxels at
/// `spacing` mm) — the workhorse behind [`resample_image`] and the
/// dispatcher's automatic image→mask grid alignment. Output voxel
/// positions map through the spacing ratio; source corners clamp at the
/// volume edges. Errors on empty volumes and non-positive spacings.
pub fn resample_image_to_grid(
    img: &VoxelGrid<f32>,
    dims: Dims,
    spacing: Vec3,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<f32>> {
    if img.dims.is_empty() {
        bail!("cannot resample an empty image volume {}", img.dims);
    }
    check_spacing("source image", img.spacing)?;
    check_spacing("target", spacing)?;
    if dims.is_empty() {
        bail!("target grid {dims} is empty");
    }
    let (sd, src) = (img.dims, img.data());
    let r = Vec3::new(
        spacing.x / img.spacing.x,
        spacing.y / img.spacing.y,
        spacing.z / img.spacing.z,
    );
    let grid = build_slices(dims, spacing, strategy, threads, |z, out| {
        let fz = z as f64 * r.z;
        let z0 = (fz.floor() as usize).min(sd.z - 1);
        let z1 = (z0 + 1).min(sd.z - 1);
        let tz = fz - z0 as f64;
        for y in 0..dims.y {
            let fy = y as f64 * r.y;
            let y0 = (fy.floor() as usize).min(sd.y - 1);
            let y1 = (y0 + 1).min(sd.y - 1);
            let ty = fy - y0 as f64;
            for x in 0..dims.x {
                let fx = x as f64 * r.x;
                let x0 = (fx.floor() as usize).min(sd.x - 1);
                let x1 = (x0 + 1).min(sd.x - 1);
                let tx = fx - x0 as f64;
                let at = |xi: usize, yi: usize, zi: usize| -> f64 {
                    src[xi + sd.x * (yi + sd.y * zi)] as f64
                };
                let c00 = at(x0, y0, z0) * (1.0 - tx) + at(x1, y0, z0) * tx;
                let c10 = at(x0, y1, z0) * (1.0 - tx) + at(x1, y1, z0) * tx;
                let c01 = at(x0, y0, z1) * (1.0 - tx) + at(x1, y0, z1) * tx;
                let c11 = at(x0, y1, z1) * (1.0 - tx) + at(x1, y1, z1) * tx;
                let c0 = c00 * (1.0 - ty) + c10 * ty;
                let c1 = c01 * (1.0 - ty) + c11 * ty;
                out.push((c0 * (1.0 - tz) + c1 * tz) as f32);
            }
        }
    });
    Ok(grid)
}

/// Nearest-neighbour-resample a segmentation mask onto `new_spacing`:
/// label values pass through untouched (no interpolated half-labels).
/// Equal spacings return a bit-exact copy.
pub fn resample_mask(
    mask: &VoxelGrid<u8>,
    new_spacing: Vec3,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<u8>> {
    if mask.dims.is_empty() {
        bail!("cannot resample an empty mask volume {}", mask.dims);
    }
    check_spacing("source mask", mask.spacing)?;
    check_spacing("target", new_spacing)?;
    let dims = resampled_dims(mask.dims, mask.spacing, new_spacing);
    check_output_volume(dims)?;
    let (sd, src) = (mask.dims, mask.data());
    let r = Vec3::new(
        new_spacing.x / mask.spacing.x,
        new_spacing.y / mask.spacing.y,
        new_spacing.z / mask.spacing.z,
    );
    let grid = build_slices(dims, new_spacing, strategy, threads, |z, out| {
        let zi = ((z as f64 * r.z).round() as usize).min(sd.z - 1);
        for y in 0..dims.y {
            let yi = ((y as f64 * r.y).round() as usize).min(sd.y - 1);
            for x in 0..dims.x {
                let xi = ((x as f64 * r.x).round() as usize).min(sd.x - 1);
                out.push(src[xi + sd.x * (yi + sd.y * zi)]);
            }
        }
    });
    Ok(grid)
}

/// [`resample_mask`] for integer label volumes: the exact same
/// nearest-neighbour index arithmetic on a `u16` grid, so a label volume
/// resampled and *then* binarised per label is bit-identical to
/// binarising first and resampling with [`resample_mask`] — the identity
/// the multi-label dispatcher's single shared resample pass relies on.
pub fn resample_labels(
    labels: &VoxelGrid<u16>,
    new_spacing: Vec3,
    strategy: Strategy,
    threads: usize,
) -> Result<VoxelGrid<u16>> {
    if labels.dims.is_empty() {
        bail!("cannot resample an empty label volume {}", labels.dims);
    }
    check_spacing("source mask", labels.spacing)?;
    check_spacing("target", new_spacing)?;
    let dims = resampled_dims(labels.dims, labels.spacing, new_spacing);
    check_output_volume(dims)?;
    let (sd, src) = (labels.dims, labels.data());
    let r = Vec3::new(
        new_spacing.x / labels.spacing.x,
        new_spacing.y / labels.spacing.y,
        new_spacing.z / labels.spacing.z,
    );
    let grid = build_slices(dims, new_spacing, strategy, threads, |z, out| {
        let zi = ((z as f64 * r.z).round() as usize).min(sd.z - 1);
        for y in 0..dims.y {
            let yi = ((y as f64 * r.y).round() as usize).min(sd.y - 1);
            for x in 0..dims.x {
                let xi = ((x as f64 * r.x).round() as usize).min(sd.x - 1);
                out.push(src[xi + sd.x * (yi + sd.y * zi)]);
            }
        }
    });
    Ok(grid)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient_image(dims: Dims, spacing: Vec3) -> VoxelGrid<f32> {
        let mut g = VoxelGrid::zeros(dims, spacing);
        for z in 0..dims.z {
            for y in 0..dims.y {
                for x in 0..dims.x {
                    let p = g.world(x, y, z);
                    g.set(x, y, z, (2.0 * p.x + 3.0 * p.y - p.z) as f32);
                }
            }
        }
        g
    }

    #[test]
    fn identity_at_source_spacing_is_bit_exact() {
        let img = gradient_image(Dims::new(5, 4, 3), Vec3::new(0.9, 1.1, 2.3));
        let out = resample_image(&img, img.spacing, Strategy::EqualSplit, 1).unwrap();
        assert_eq!(out, img);
        let mut mask: VoxelGrid<u8> = VoxelGrid::zeros(img.dims, img.spacing);
        mask.set(2, 1, 1, 1);
        mask.set(4, 3, 2, 7);
        let out = resample_mask(&mask, mask.spacing, Strategy::EqualSplit, 1).unwrap();
        assert_eq!(out, mask);
    }

    #[test]
    fn resampled_dims_cover_the_physical_extent() {
        // 9 samples at 1 mm span 8 mm → 17 samples at 0.5 mm, 5 at 2 mm
        let d = resampled_dims(Dims::new(9, 9, 9), Vec3::splat(1.0), Vec3::splat(0.5));
        assert_eq!(d, Dims::new(17, 17, 17));
        let d = resampled_dims(Dims::new(9, 9, 9), Vec3::splat(1.0), Vec3::splat(2.0));
        assert_eq!(d, Dims::new(5, 5, 5));
        // float rounding must not drop the final in-extent plane:
        // 0.3/0.1 is 2.999…96 in f64, yet 8 × 0.3 mm spans exactly 24 of
        // the 0.1 mm steps → 25 samples
        let d = resampled_dims(Dims::new(9, 9, 9), Vec3::splat(0.3), Vec3::splat(0.1));
        assert_eq!(d, Dims::new(25, 25, 25));
    }

    #[test]
    fn absurd_target_spacing_is_a_located_error_not_an_allocation() {
        let img = gradient_image(Dims::new(64, 64, 64), Vec3::splat(1.0));
        let err =
            resample_image(&img, Vec3::splat(1e-9), Strategy::EqualSplit, 1).unwrap_err();
        assert!(format!("{err:#}").contains("voxels"), "{err:#}");
        let mask: VoxelGrid<u8> = VoxelGrid::zeros(Dims::new(64, 64, 64), Vec3::splat(1.0));
        assert!(resample_mask(&mask, Vec3::splat(1e-9), Strategy::EqualSplit, 1).is_err());
    }

    #[test]
    fn trilinear_reproduces_a_linear_field() {
        let img = gradient_image(Dims::new(9, 9, 9), Vec3::splat(1.0));
        let out = resample_image(&img, Vec3::splat(0.5), Strategy::EqualSplit, 1).unwrap();
        assert_eq!(out.dims, Dims::new(17, 17, 17));
        for z in 0..out.dims.z {
            for y in 0..out.dims.y {
                for x in 0..out.dims.x {
                    let p = out.world(x, y, z);
                    let want = 2.0 * p.x + 3.0 * p.y - p.z;
                    let got = out.get(x, y, z) as f64;
                    assert!((got - want).abs() < 1e-5, "({x},{y},{z}): {got} vs {want}");
                }
            }
        }
    }

    #[test]
    fn nearest_mask_keeps_label_values() {
        let mut mask: VoxelGrid<u8> = VoxelGrid::zeros(Dims::new(8, 8, 8), Vec3::splat(1.0));
        for z in 2..6 {
            for y in 2..6 {
                for x in 2..6 {
                    mask.set(x, y, z, 3);
                }
            }
        }
        let out = resample_mask(&mask, Vec3::splat(0.5), Strategy::EqualSplit, 1).unwrap();
        assert!(out.data().iter().all(|&v| v == 0 || v == 3), "no blended labels");
        // 4³ voxels at 1 mm ≈ 7³ at 0.5 mm (corner-lattice rounding)
        let kept = out.data().iter().filter(|&&v| v == 3).count();
        assert!(kept >= 6 * 6 * 6 && kept <= 9 * 9 * 9, "kept {kept}");
    }

    #[test]
    fn label_resample_commutes_with_per_label_binarisation() {
        // resample_labels then binarise == binarise then resample_mask,
        // for every label — the shared-pass identity, bit for bit
        let mut labels: VoxelGrid<u16> =
            VoxelGrid::zeros(Dims::new(7, 6, 5), Vec3::new(1.0, 1.3, 0.8));
        for z in 1..4 {
            for y in 1..4 {
                labels.set(2, y, z, 2);
                labels.set(4, y, z, 9);
            }
        }
        labels.set(6, 5, 4, 300); // label above u8 range
        for new in [Vec3::splat(0.5), Vec3::splat(1.7), Vec3::new(0.9, 1.0, 1.1)] {
            let resampled =
                resample_labels(&labels, new, Strategy::EqualSplit, 2).unwrap();
            for label in [2u16, 9, 300] {
                let want = resample_mask(
                    &labels.map(|v| u8::from(v == label)),
                    new,
                    Strategy::EqualSplit,
                    2,
                )
                .unwrap();
                let got = resampled.map(|v| u8::from(v == label));
                assert_eq!(got, want, "label {label} at {new:?}");
            }
        }
        // identity at source spacing, like the u8 path
        let id = resample_labels(&labels, labels.spacing, Strategy::EqualSplit, 1).unwrap();
        assert_eq!(id, labels);
    }

    #[test]
    fn downsampling_halves_the_grid() {
        let img = gradient_image(Dims::new(9, 9, 9), Vec3::splat(1.0));
        let out = resample_image(&img, Vec3::splat(2.0), Strategy::EqualSplit, 1).unwrap();
        assert_eq!(out.dims, Dims::new(5, 5, 5));
        // on-lattice samples are exact
        assert_eq!(out.get(1, 1, 1), img.get(2, 2, 2));
    }

    #[test]
    fn to_grid_aligns_a_coarser_image_onto_a_finer_mask_grid() {
        let img = gradient_image(Dims::new(5, 5, 5), Vec3::splat(2.0));
        let out = resample_image_to_grid(
            &img,
            Dims::new(9, 9, 9),
            Vec3::splat(1.0),
            Strategy::EqualSplit,
            1,
        )
        .unwrap();
        assert_eq!(out.dims, Dims::new(9, 9, 9));
        assert_eq!(out.spacing, Vec3::splat(1.0));
        for (x, y, z) in [(0usize, 0usize, 0usize), (3, 5, 7), (8, 8, 8)] {
            let p = out.world(x, y, z);
            let want = 2.0 * p.x + 3.0 * p.y - p.z;
            assert!((out.get(x, y, z) as f64 - want).abs() < 1e-5);
        }
    }

    #[test]
    fn resample_rejects_bad_inputs() {
        let img = gradient_image(Dims::new(4, 4, 4), Vec3::splat(1.0));
        assert!(resample_image(&img, Vec3::new(0.0, 1.0, 1.0), Strategy::EqualSplit, 1)
            .is_err());
        assert!(resample_image(&img, Vec3::splat(f64::NAN), Strategy::EqualSplit, 1)
            .is_err());
        let empty = VoxelGrid::<f32>::zeros(Dims::new(0, 3, 3), Vec3::splat(1.0));
        assert!(resample_image(&empty, Vec3::splat(1.0), Strategy::EqualSplit, 1).is_err());
        let mask: VoxelGrid<u8> = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        assert!(resample_mask(&mask, Vec3::splat(-1.0), Strategy::EqualSplit, 1).is_err());
    }
}
