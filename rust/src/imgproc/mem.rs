//! Peak-resident derived-image byte accounting.
//!
//! The streaming visitor ([`crate::imgproc::for_each_derived_image`]) caps
//! how many derived volumes are alive at once; this module is the meter
//! that proves it. Two levels of accounting:
//!
//! * a **process-wide high-water mark** (atomics) that every derivation —
//!   streaming or collect-based — feeds; the pipeline snapshots it into
//!   the `mem.peak_derived_bytes` metric at the end of a run;
//! * a per-call [`ResidentTally`] the visitor threads through its own
//!   volumes, returned as `peak_resident_bytes` in
//!   [`crate::imgproc::DeriveStats`] so tests can assert the streaming
//!   residency cap without interference from concurrently-running cases.
//!
//! When tracing is enabled ([`crate::trace`]), every resident-bytes
//! transition is additionally sampled onto the `mem.resident_bytes`
//! counter track, so the footprint is visible over time in the trace
//! viewer rather than only as an end-of-run high-water mark.
//!
//! Only whole derived-image volumes are tracked (the in-flight image, the
//! multi-level wavelet LLL seed, and the collected clones of the
//! materialised wrapper). Per-pass filter scratch — the line chunks of
//! [`crate::imgproc::lines`], the LoG f64 accumulator — is bounded by a
//! few volume-equivalents *per case* regardless of how many derived
//! images are configured, which is exactly the property the metric is
//! there to watch, so it is excluded by design.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::volume::VoxelGrid;

static CURRENT: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

// Pipeline-wide accounting: every case volume (mask + image payloads) the
// read stage materialises, held from read until extraction finishes.
static PIPE_CURRENT: AtomicU64 = AtomicU64::new(0);
static PIPE_PEAK: AtomicU64 = AtomicU64::new(0);

fn lock_recover<T>(r: Result<T, std::sync::PoisonError<T>>) -> T {
    r.unwrap_or_else(|e| e.into_inner())
}

/// Payload bytes of one derived f32 volume.
pub(crate) fn grid_bytes(g: &VoxelGrid<f32>) -> u64 {
    (g.dims.len() * std::mem::size_of::<f32>()) as u64
}

fn note_alloc(bytes: u64) {
    let now = CURRENT.fetch_add(bytes, Ordering::Relaxed) + bytes;
    PEAK.fetch_max(now, Ordering::Relaxed);
    crate::trace::counter_u64("mem.resident_bytes", now);
}

fn note_free(bytes: u64) {
    let now = CURRENT.fetch_sub(bytes, Ordering::Relaxed).saturating_sub(bytes);
    crate::trace::counter_u64("mem.resident_bytes", now);
}

/// Process-wide high-water mark of derived-image bytes resident at once,
/// in bytes, since the last [`reset_peak_derived_bytes`]. Concurrent
/// cases (e.g. `feature_workers > 1`) sum into the same meter, so this is
/// the whole-process derived-image footprint — what actually bounds a
/// budget device.
pub fn peak_derived_bytes() -> u64 {
    PEAK.load(Ordering::Relaxed)
}

/// Reset the high-water mark to the currently-resident total (not zero:
/// volumes held by in-flight cases stay accounted). `run_pipeline` calls
/// this at startup so the final gauge describes that run.
pub fn reset_peak_derived_bytes() {
    PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
}

fn note_pipeline_alloc(bytes: u64) {
    let now = PIPE_CURRENT.fetch_add(bytes, Ordering::Relaxed) + bytes;
    PIPE_PEAK.fetch_max(now, Ordering::Relaxed);
    crate::trace::counter_u64("mem.pipeline_bytes", now);
}

fn note_pipeline_free(bytes: u64) {
    let now = PIPE_CURRENT.fetch_sub(bytes, Ordering::Relaxed).saturating_sub(bytes);
    crate::trace::counter_u64("mem.pipeline_bytes", now);
}

/// Process-wide high-water mark of *pipeline* case bytes — the mask and
/// image payloads the read stage has materialised and extraction has not
/// yet released — since the last [`reset_peak_pipeline_bytes`]. With slab
/// IO this is crop-proportional; with whole-grid reads it scales with the
/// file dims, which is exactly the contrast the slab bench leg asserts.
pub fn peak_pipeline_bytes() -> u64 {
    PIPE_PEAK.load(Ordering::Relaxed)
}

/// Reset the pipeline high-water mark to the currently-held total (not
/// zero: in-flight cases stay accounted). `run_pipeline` calls this at
/// startup so the final `mem.peak_pipeline_bytes` gauge describes that
/// run.
pub fn reset_peak_pipeline_bytes() {
    PIPE_PEAK.store(PIPE_CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// RAII hold on the pipeline-wide meter: created by the read stage when a
/// case's volumes are materialised, dropped when extraction is done with
/// them. Feeds [`peak_pipeline_bytes`] and the `mem.pipeline_bytes` trace
/// counter track.
#[derive(Debug)]
pub(crate) struct PipelineHold(u64);

impl PipelineHold {
    pub(crate) fn new(bytes: u64) -> PipelineHold {
        if bytes > 0 {
            note_pipeline_alloc(bytes);
        }
        PipelineHold(bytes)
    }
}

impl Drop for PipelineHold {
    fn drop(&mut self) {
        if self.0 > 0 {
            note_pipeline_free(self.0);
        }
    }
}

/// A byte budget the read stage respects by throttling in-flight cases.
///
/// `acquire(bytes)` blocks while admitting the request would push the
/// admitted total past the limit **and** at least one other case is still
/// in flight — a single case is always admitted even if it alone exceeds
/// the budget, so an undersized limit degrades to serial execution
/// instead of deadlocking. A limit of `0` means unlimited (every acquire
/// is immediate). The returned [`BudgetGuard`] releases its bytes on drop
/// and wakes the waiters.
#[derive(Debug)]
pub struct MemoryBudget {
    limit: u64,
    held: Mutex<u64>,
    cv: Condvar,
}

impl MemoryBudget {
    /// New budget of `limit` bytes (`0` = unlimited).
    pub fn new(limit: u64) -> Arc<MemoryBudget> {
        Arc::new(MemoryBudget { limit, held: Mutex::new(0), cv: Condvar::new() })
    }

    /// Block until `bytes` fit under the limit (see type docs for the
    /// no-deadlock admission rule), then account them.
    pub fn acquire(self: &Arc<Self>, bytes: u64) -> BudgetGuard {
        if self.limit == 0 {
            return BudgetGuard { budget: Arc::clone(self), bytes: 0 };
        }
        let mut held = lock_recover(self.held.lock());
        while *held > 0 && *held + bytes > self.limit {
            held = lock_recover(self.cv.wait(held));
        }
        *held += bytes;
        BudgetGuard { budget: Arc::clone(self), bytes }
    }
}

/// Admission held against a [`MemoryBudget`]; released on drop.
#[derive(Debug)]
pub struct BudgetGuard {
    budget: Arc<MemoryBudget>,
    bytes: u64,
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        if self.bytes > 0 {
            let mut held = lock_recover(self.budget.held.lock());
            *held = held.saturating_sub(self.bytes);
            self.budget.cv.notify_all();
        }
    }
}

/// Single-owner tally of the volumes one derivation holds. Mirrors every
/// hold/release into the process-wide meter; `Drop` releases whatever is
/// still held, so an early error cannot leak global accounting.
#[derive(Default)]
pub(crate) struct ResidentTally {
    current: u64,
    peak: u64,
}

impl ResidentTally {
    /// Account `g` as resident; returns the held byte count for the
    /// matching [`ResidentTally::release`].
    pub(crate) fn hold(&mut self, g: &VoxelGrid<f32>) -> u64 {
        let bytes = grid_bytes(g);
        self.current += bytes;
        self.peak = self.peak.max(self.current);
        note_alloc(bytes);
        bytes
    }

    pub(crate) fn release(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.current, "release more than held");
        self.current -= bytes;
        note_free(bytes);
    }

    /// Highest concurrently-held byte count this tally has seen.
    pub(crate) fn peak(&self) -> u64 {
        self.peak
    }
}

impl Drop for ResidentTally {
    fn drop(&mut self) {
        if self.current > 0 {
            note_free(self.current);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    // NB: the process-wide CURRENT/PEAK atomics are shared with every
    // concurrently-running test that derives images (dispatch, pipeline),
    // so only the per-call tally is asserted exactly here; the global
    // meter is exercised end-to-end by `benches/bench_imgproc.rs` (a
    // single-threaded process) and the pipeline metric test.

    #[test]
    fn tally_tracks_a_high_water_mark() {
        let g = VoxelGrid::<f32>::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let bytes = grid_bytes(&g);
        assert_eq!(bytes, 4 * 4 * 4 * 4);

        let mut tally = ResidentTally::default();
        let a = tally.hold(&g);
        let b = tally.hold(&g);
        assert_eq!(tally.peak(), 2 * bytes);
        tally.release(a);
        let c = tally.hold(&g);
        assert_eq!(tally.peak(), 2 * bytes, "peak is a high-water mark");
        tally.release(b);
        tally.release(c);
        assert_eq!(tally.peak(), 2 * bytes);
    }

    #[test]
    fn dropping_a_loaded_tally_is_safe() {
        // early-error path: a tally dropped with volumes still held must
        // release its outstanding global bytes exactly once (Drop) — run
        // many cycles so a leak would compound into an observable drift
        let g = VoxelGrid::<f32>::zeros(Dims::new(8, 8, 8), Vec3::splat(1.0));
        for _ in 0..64 {
            let mut tally = ResidentTally::default();
            tally.hold(&g);
            tally.hold(&g);
        }
        // the paired-release path agrees with Drop about what was held
        let mut tally = ResidentTally::default();
        let a = tally.hold(&g);
        tally.release(a);
        assert_eq!(tally.current, 0);
    }

    #[test]
    fn budget_admits_one_oversized_case_and_throttles_the_rest() {
        let budget = MemoryBudget::new(100);
        // a single case larger than the whole budget is admitted (no
        // deadlock): the budget degrades to serial execution
        let big = budget.acquire(250);
        drop(big);

        // within the limit, concurrent holds coexist
        let a = budget.acquire(40);
        let b = budget.acquire(40);

        // a third acquire that would overflow blocks until a release; run
        // it on a helper thread and assert it only lands after the drop
        let released = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (budget2, released2) = (std::sync::Arc::clone(&budget), released.clone());
        let waiter = std::thread::spawn(move || {
            let g = budget2.acquire(40);
            assert!(
                released2.load(Ordering::SeqCst),
                "acquire returned before any release"
            );
            drop(g);
        });
        std::thread::sleep(std::time::Duration::from_millis(50));
        released.store(true, Ordering::SeqCst);
        drop(a);
        waiter.join().unwrap();
        drop(b);

        // unlimited budget never blocks and its guards are free
        let unlimited = MemoryBudget::new(0);
        let g1 = unlimited.acquire(u64::MAX);
        let g2 = unlimited.acquire(u64::MAX);
        drop(g1);
        drop(g2);
    }

    #[test]
    fn pipeline_holds_feed_the_pipeline_peak() {
        // process-wide atomics are shared across tests (see note above):
        // assert monotone facts only — the peak covers this hold
        reset_peak_pipeline_bytes();
        let hold = PipelineHold::new(4096);
        assert!(peak_pipeline_bytes() >= 4096);
        drop(hold);
        let zero = PipelineHold::new(0);
        drop(zero); // a zero hold must not underflow the meter
    }
}
