//! Synthetic KiTS19-like dataset generator (DESIGN.md §Substitutions #2).
//!
//! The paper selects 20 KiTS19 cases spanning 2 700 – 236 588 mesh vertices
//! (Table 2). That data is not redistributable here, so this module
//! generates deterministic kidney/tumour-like ROIs — a lobulated ellipsoid
//! with low-frequency angular perturbation — sized per case to the paper's
//! image dimensions and tuned to approximate the paper's vertex counts.
//! Every generated mask records its *actual* mesh vertex count in the
//! manifest; the experiment harnesses report those.

mod cases;
mod generator;

pub use cases::{paper_cases, PaperCase, PAPER_CASE_COUNT};
pub use generator::{
    generate_case, generate_dataset, generate_multilabel_dataset, synthesize_image, GenOptions,
};
