//! The deterministic ROI generator.
//!
//! Shape model: a lobulated ellipsoid. For a voxel at unit-sphere direction
//! `u` from the centre, the inside test is
//!
//! ```text
//! |p_ellip(u)| ≤ 1 + Σₖ aₖ·sin(fₖ·θ + φₖ)·sin(gₖ·φ + ψₖ)
//! ```
//!
//! i.e. an ellipsoid whose radius is modulated by a few low-frequency
//! angular harmonics — a decent stand-in for kidney/tumour ROIs: smooth but
//! not spherical, occasionally bi-lobed. All randomness comes from
//! [`Pcg32`] seeded with the case index: datasets are bit-reproducible.

use std::path::Path;

use anyhow::Result;

use super::cases::{paper_cases, PaperCase};
use crate::geometry::Vec3;
use crate::io::{write_rvol, CaseEntry, DatasetManifest};
use crate::mc::mesh_roi;
use crate::testkit::Pcg32;
use crate::volume::{Dims, VoxelGrid};

/// Generator options.
#[derive(Debug, Clone)]
pub struct GenOptions {
    /// Global vertex-count scale relative to the paper (1.0 = paper scale).
    /// The default dataset uses 1/8 — see DESIGN.md (single-core testbed).
    pub scale: f64,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions { scale: 0.125, seed: 7 }
    }
}

/// Angular harmonic of the radius modulation.
#[derive(Debug, Clone, Copy)]
struct Harmonic {
    amp: f64,
    f_theta: f64,
    f_phi: f64,
    p_theta: f64,
    p_phi: f64,
}

/// Vertex count of a blob scales with its surface area in voxel units;
/// calibration on spheres gives ≈ 4.4 vertices per voxel² of area, so
/// r ≈ sqrt(target / (4.4·4π)).
fn radius_for_vertices(target: f64) -> f64 {
    (target / (4.4 * 4.0 * std::f64::consts::PI)).sqrt()
}

/// Generate one case mask. The ROI is scaled from the paper dims by
/// `opts.scale` in vertex count (√scale in linear size), bounding dims
/// shrink accordingly (keeping proportions), and the actual mesh vertex
/// count is measured and returned.
pub fn generate_case(case: &PaperCase, opts: &GenOptions) -> (VoxelGrid<u8>, usize) {
    let mut rng = Pcg32::with_stream(opts.seed, case_stream(case.case_id));

    // Linear shrink factor: vertex count ~ area ~ linear².
    let lin = opts.scale.sqrt();
    let dims = Dims::new(
        ((case.dims.x as f64 * lin).ceil() as usize).max(8),
        ((case.dims.y as f64 * lin).ceil() as usize).max(8),
        ((case.dims.z as f64 * lin).ceil() as usize).max(8),
    );
    // KiTS-like anisotropic spacing.
    let spacing = Vec3::new(0.78, 0.78, 3.0 * rng.range_f64(0.25, 0.5));

    let target = case.vertices as f64 * opts.scale;
    let r_base = radius_for_vertices(target);

    // Ellipsoid semi-axes: random eccentricity around r_base, clamped into
    // the volume.
    let half = Vec3::new(
        dims.x as f64 * 0.5 - 2.0,
        dims.y as f64 * 0.5 - 2.0,
        dims.z as f64 * 0.5 - 2.0,
    );
    let ecc = [rng.range_f64(0.7, 1.4), rng.range_f64(0.7, 1.4), rng.range_f64(0.7, 1.4)];
    // Normalise eccentricities so the geometric-mean radius stays r_base.
    let gm = (ecc[0] * ecc[1] * ecc[2]).cbrt();
    let axes = Vec3::new(
        (r_base * ecc[0] / gm).min(half.x).max(2.0),
        (r_base * ecc[1] / gm).min(half.y).max(2.0),
        (r_base * ecc[2] / gm).min(half.z).max(2.0),
    );

    let nharm = 3 + rng.below(3) as usize;
    let harmonics: Vec<Harmonic> = (0..nharm)
        .map(|_| Harmonic {
            amp: rng.range_f64(0.03, 0.12),
            f_theta: rng.below(4) as f64 + 1.0,
            f_phi: rng.below(4) as f64 + 1.0,
            p_theta: rng.range_f64(0.0, std::f64::consts::TAU),
            p_phi: rng.range_f64(0.0, std::f64::consts::TAU),
        })
        .collect();

    let centre = Vec3::new(dims.x as f64 / 2.0, dims.y as f64 / 2.0, dims.z as f64 / 2.0);
    let mut mask = VoxelGrid::zeros(dims, spacing);
    for z in 0..dims.z {
        for y in 0..dims.y {
            for x in 0..dims.x {
                let d = Vec3::new(
                    (x as f64 - centre.x) / axes.x,
                    (y as f64 - centre.y) / axes.y,
                    (z as f64 - centre.z) / axes.z,
                );
                let r = d.norm();
                if r > 1.35 {
                    continue; // outside even max modulation
                }
                let theta = d.z.atan2(d.x.hypot(d.y).max(1e-12));
                let phi = d.y.atan2(d.x);
                let mut rho = 1.0;
                for h in &harmonics {
                    rho += h.amp
                        * (h.f_theta * theta + h.p_theta).sin()
                        * (h.f_phi * phi + h.p_phi).sin();
                }
                if r <= rho {
                    mask.set(x, y, z, 1);
                }
            }
        }
    }
    let vertex_count = mesh_roi(&mask).vertices.len();
    (mask, vertex_count)
}

/// Synthesize a CT-like intensity image for a mask: smooth background
/// gradient, elevated ROI contrast, deterministic voxel noise. Feeds the
/// first-order feature class ([`crate::features::compute_first_order`]).
pub fn synthesize_image(mask: &VoxelGrid<u8>, seed: u64) -> VoxelGrid<f32> {
    let mut rng = Pcg32::with_stream(seed, 0x1234);
    let dims = mask.dims;
    let mut img: VoxelGrid<f32> = VoxelGrid::zeros(dims, mask.spacing);
    for z in 0..dims.z {
        for y in 0..dims.y {
            for x in 0..dims.x {
                let bg = -80.0
                    + 30.0 * (x as f64 / dims.x.max(1) as f64)
                    + 20.0 * (z as f64 / dims.z.max(1) as f64);
                let roi = if mask.get(x, y, z) != 0 { 120.0 } else { 0.0 };
                let noise = rng.normal() * 12.0;
                img.set(x, y, z, (bg + roi + noise) as f32);
            }
        }
    }
    img
}

fn case_stream(case_id: &str) -> u64 {
    // FNV-1a over the id — stable stream per case.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in case_id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Generate the full 20-case dataset into `root` (rvol.gz + cases.txt).
/// Every case ships a (mask, image) pair: the mask as u8, plus a paired
/// CT-like f32 intensity volume (per-case deterministic seed) recorded
/// under the manifest's `image=` key — so pipeline runs with intensity
/// classes exercise the real image path, not the synthetic stand-in.
pub fn generate_dataset(root: &Path, opts: &GenOptions) -> Result<DatasetManifest> {
    std::fs::create_dir_all(root)?;
    let mut entries = Vec::new();
    for case in paper_cases() {
        let (mask, nverts) = generate_case(&case, opts);
        let fname = format!("{}.rvol.gz", case.case_id);
        write_rvol(&root.join(&fname), &mask)?;
        let image = synthesize_image(&mask, opts.seed ^ case_stream(case.case_id));
        let iname = format!("{}.img.rvol.gz", case.case_id);
        write_rvol(&root.join(&iname), &image)?;
        entries.push(CaseEntry {
            case_id: case.case_id.to_string(),
            mask: fname.into(),
            image: Some(iname.into()),
            dims: Some(mask.dims),
            target_vertices: nverts, // record the *measured* vertex count
            labels: Vec::new(),
        });
    }
    let manifest = DatasetManifest { root: root.to_path_buf(), cases: entries };
    manifest.save()?;
    Ok(manifest)
}

/// Split a binary ROI into three labels by x-bands of its bounding box —
/// a deterministic multi-label segmentation with spatially coherent,
/// non-empty ROIs (the generator's blobs are convex-ish, so every band of
/// the box contains voxels).
fn relabel_by_x_bands(mask: &VoxelGrid<u8>) -> VoxelGrid<u16> {
    let (mut minx, mut maxx) = (usize::MAX, 0usize);
    for (x, _, _) in mask.iter_roi() {
        minx = minx.min(x);
        maxx = maxx.max(x);
    }
    let mut out: VoxelGrid<u16> = VoxelGrid::zeros(mask.dims, mask.spacing);
    if minx > maxx {
        return out; // empty mask
    }
    let w = maxx - minx + 1;
    let (a, b) = (minx + w / 3, minx + 2 * w / 3);
    for (x, y, z) in mask.iter_roi() {
        let label = if x < a {
            1
        } else if x < b {
            2
        } else {
            3
        };
        out.set(x, y, z, label);
    }
    out
}

/// Generate a small deterministic **multi-label** dataset: 3 cases, each a
/// u16 label map carrying labels `{1, 2, 3}` (the binary blob split into
/// x-bands) plus a paired intensity image. The first case's manifest entry
/// additionally declares label `4`, which no voxel carries — so a
/// `--labels all` run surfaces exactly one per-label failure (the
/// declared-but-empty label) while every present label extracts. This is
/// the fixture the label-map conformance tests and the CI texture-matrix
/// job run against.
pub fn generate_multilabel_dataset(root: &Path, opts: &GenOptions) -> Result<DatasetManifest> {
    std::fs::create_dir_all(root)?;
    let mut entries = Vec::new();
    for (i, case) in paper_cases().into_iter().take(3).enumerate() {
        let (mask, nverts) = generate_case(&case, opts);
        let labels = relabel_by_x_bands(&mask);
        let fname = format!("{}.rvol.gz", case.case_id);
        write_rvol(&root.join(&fname), &labels)?;
        let image = synthesize_image(&mask, opts.seed ^ case_stream(case.case_id));
        let iname = format!("{}.img.rvol.gz", case.case_id);
        write_rvol(&root.join(&iname), &image)?;
        entries.push(CaseEntry {
            case_id: case.case_id.to_string(),
            mask: fname.into(),
            image: Some(iname.into()),
            dims: Some(mask.dims),
            target_vertices: nverts,
            // the first case declares a label that is deliberately absent
            labels: if i == 0 { vec![1, 2, 3, 4] } else { vec![1, 2, 3] },
        });
    }
    let manifest = DatasetManifest { root: root.to_path_buf(), cases: entries };
    manifest.save()?;
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_opts() -> GenOptions {
        GenOptions { scale: 0.02, seed: 7 }
    }

    #[test]
    fn deterministic() {
        let case = &paper_cases()[9]; // 00004-2, smallest dims
        let (a, na) = generate_case(case, &small_opts());
        let (b, nb) = generate_case(case, &small_opts());
        assert_eq!(a, b);
        assert_eq!(na, nb);
    }

    #[test]
    fn different_cases_differ() {
        let cases = paper_cases();
        let (a, _) = generate_case(&cases[9], &small_opts());
        let (b, _) = generate_case(&cases[19], &small_opts());
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn vertex_count_tracks_target() {
        // With scale s, measured vertices should be within ~3× of
        // target·s (the generator is calibrated, not exact).
        let case = &paper_cases()[3]; // 00001-2: 8928 vertices
        let opts = GenOptions { scale: 0.125, seed: 7 };
        let (_, n) = generate_case(case, &opts);
        let target = case.vertices as f64 * opts.scale;
        assert!(
            n as f64 > target / 3.0 && (n as f64) < target * 3.0,
            "n={n} target={target}"
        );
    }

    #[test]
    fn roi_not_touching_border() {
        let case = &paper_cases()[9];
        let (mask, _) = generate_case(case, &small_opts());
        for (x, y, z) in mask.iter_roi() {
            assert!(x > 0 && y > 0 && z > 0);
            assert!(x < mask.dims.x - 1 && y < mask.dims.y - 1 && z < mask.dims.z - 1);
        }
    }

    #[test]
    fn generate_dataset_writes_manifest_and_files() {
        let root = std::env::temp_dir().join("radpipe_synth_test");
        let _ = std::fs::remove_dir_all(&root);
        let opts = GenOptions { scale: 0.005, seed: 3 };
        let m = generate_dataset(&root, &opts).unwrap();
        assert_eq!(m.cases.len(), 20);
        for e in &m.cases {
            assert!(m.mask_path(e).exists(), "{:?}", e.mask);
            let image = m.image_path(e).expect("every generated case pairs an image");
            assert!(image.exists(), "{image:?}");
            assert!(e.target_vertices > 0, "{}: no vertices", e.case_id);
        }
        // reload via scanner
        let back = crate::io::scan_dataset(&root).unwrap();
        assert_eq!(back.cases.len(), 20);
        assert!(back.cases.iter().all(|e| e.image.is_some()));
        // the paired image reads back as real intensities on the mask grid,
        // and distinct cases get distinct images (per-case seeds)
        let a = crate::io::read_image(&back.image_path(&back.cases[0]).unwrap()).unwrap();
        let mask_a = crate::io::read_mask(&back.mask_path(&back.cases[0])).unwrap();
        assert_eq!(a.dims, mask_a.dims);
        let b = crate::io::read_image(&back.image_path(&back.cases[1]).unwrap()).unwrap();
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn multilabel_dataset_has_three_labels_and_one_declared_empty() {
        let root = std::env::temp_dir().join("radpipe_synth_multilabel");
        let _ = std::fs::remove_dir_all(&root);
        let opts = GenOptions { scale: 0.005, seed: 3 };
        let m = generate_multilabel_dataset(&root, &opts).unwrap();
        assert_eq!(m.cases.len(), 3);
        assert_eq!(m.cases[0].labels, vec![1, 2, 3, 4], "declares the empty label");
        assert_eq!(m.cases[1].labels, vec![1, 2, 3]);
        for e in &m.cases {
            let lm = crate::io::read_label_mask(&m.mask_path(e)).unwrap();
            assert_eq!(lm.labels, vec![1, 2, 3], "{}: observed inventory", e.case_id);
            assert!(lm.binary(4).count_nonzero() == 0, "{}: label 4 empty", e.case_id);
            assert!(m.image_path(e).unwrap().exists());
        }
        // deterministic: a second generation is bit-identical
        let root2 = std::env::temp_dir().join("radpipe_synth_multilabel2");
        let _ = std::fs::remove_dir_all(&root2);
        generate_multilabel_dataset(&root2, &opts).unwrap();
        for e in &m.cases {
            let a = std::fs::read(m.mask_path(e)).unwrap();
            let b = std::fs::read(root2.join(e.mask.clone())).unwrap();
            assert_eq!(a, b, "{}", e.case_id);
        }
    }
}
