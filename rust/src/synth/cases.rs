//! The paper's Table 2 case list: image dims, vertex counts and the
//! published timings (used for paper-vs-measured comparison columns).

use crate::volume::Dims;

/// One row of the paper's Table 2.
#[derive(Debug, Clone, Copy)]
pub struct PaperCase {
    pub case_id: &'static str,
    pub dims: Dims,
    /// "vertices in 3D space" column.
    pub vertices: usize,
    /// File-reading time, ms (PyRadiomics column).
    pub t_read_ms: f64,
    /// Marching-cubes time, ms (CPU).
    pub t_mc_cpu_ms: f64,
    /// Diameter time, ms (CPU).
    pub t_diam_cpu_ms: f64,
    /// GPU transfer / MC / diameter / total, ms (RTX 4070).
    pub t_tran_gpu_ms: f64,
    pub t_mc_gpu_ms: f64,
    pub t_diam_gpu_ms: f64,
    /// Published computation speedup ("Comp." column).
    pub speedup_comp: f64,
    /// Published overall speedup (incl. file reading).
    pub speedup_overall: f64,
}

pub const PAPER_CASE_COUNT: usize = 20;

/// All 20 rows of Table 2, transcribed from the paper.
pub fn paper_cases() -> Vec<PaperCase> {
    let c = |case_id,
             (dx, dy, dz),
             vertices,
             t_read_ms,
             t_mc_cpu_ms,
             t_diam_cpu_ms,
             t_tran_gpu_ms,
             t_mc_gpu_ms,
             t_diam_gpu_ms,
             speedup_comp,
             speedup_overall| PaperCase {
        case_id,
        dims: Dims::new(dx, dy, dz),
        vertices,
        t_read_ms,
        t_mc_cpu_ms,
        t_diam_cpu_ms,
        t_tran_gpu_ms,
        t_mc_gpu_ms,
        t_diam_gpu_ms,
        speedup_comp,
        speedup_overall,
    };
    vec![
        c("00000-1", (231, 104, 264), 124406, 2346.0, 20.7, 9516.5, 8.0, 7.2, 514.8, 18.0, 4.1),
        c("00000-2", (28, 30, 59), 6132, 2350.0, 0.4, 25.3, 0.3, 0.2, 2.4, 8.8, 1.0),
        c("00001-1", (322, 126, 219), 236588, 2494.0, 29.5, 34210.3, 9.7, 11.0, 1855.8, 18.2, 8.4),
        c("00001-2", (51, 62, 135), 8928, 2521.0, 2.3, 51.4, 0.7, 0.6, 3.4, 11.5, 1.0),
        c("00002-1", (230, 109, 163), 83098, 1032.0, 13.4, 4256.2, 5.1, 4.8, 231.8, 17.7, 4.2),
        c("00002-2", (50, 45, 44), 9206, 1024.0, 0.6, 56.9, 0.5, 0.3, 3.9, 12.3, 1.1),
        c("00003-1", (237, 122, 135), 77560, 1105.0, 12.7, 3731.0, 4.8, 4.6, 204.1, 17.5, 3.7),
        c("00003-2", (39, 35, 31), 4568, 1097.0, 0.2, 14.7, 0.3, 0.2, 1.6, 7.1, 1.0),
        c("00004-1", (254, 70, 36), 31838, 254.0, 2.5, 677.2, 0.8, 1.1, 37.8, 17.1, 3.2),
        c("00004-2", (35, 37, 10), 2742, 255.0, 0.1, 5.7, 0.3, 0.1, 1.1, 4.0, 1.0),
        c("00005-1", (167, 94, 285), 126446, 3150.0, 15.0, 9780.9, 5.6, 5.6, 531.5, 18.1, 3.5),
        c("00005-2", (51, 53, 121), 22024, 3203.0, 1.9, 305.6, 0.6, 0.7, 18.0, 15.9, 1.1),
        c("00006-1", (308, 102, 36), 65436, 710.0, 4.4, 2828.1, 1.1, 2.0, 153.7, 18.1, 4.1),
        c("00006-2", (41, 43, 13), 3676, 712.0, 0.1, 10.0, 0.3, 0.2, 1.1, 6.5, 1.0),
        c("00007-1", (265, 101, 39), 49912, 255.0, 4.1, 1634.9, 1.0, 1.7, 90.1, 17.7, 5.4),
        c("00007-2", (39, 43, 12), 3498, 250.0, 0.1, 9.3, 0.3, 0.1, 1.2, 6.0, 1.0),
        c("00008-1", (288, 177, 54), 57362, 967.0, 9.3, 2089.4, 3.3, 3.1, 113.7, 17.5, 2.8),
        c("00008-2", (127, 154, 41), 47484, 972.0, 3.2, 1436.9, 0.8, 1.4, 78.7, 17.8, 2.3),
        c("00009-1", (241, 95, 47), 37576, 337.0, 3.8, 916.2, 1.1, 1.5, 50.5, 17.4, 3.2),
        c("00009-2", (39, 33, 11), 2700, 340.0, 0.1, 5.7, 0.3, 0.1, 1.1, 3.9, 1.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_cases() {
        let cases = paper_cases();
        assert_eq!(cases.len(), PAPER_CASE_COUNT);
        // vertex range from the paper's abstract/§3
        let min = cases.iter().map(|c| c.vertices).min().unwrap();
        let max = cases.iter().map(|c| c.vertices).max().unwrap();
        assert_eq!(min, 2700);
        assert_eq!(max, 236588);
    }

    #[test]
    fn diameter_dominates_cpu_time() {
        // §3: diameter is 95.7–99.9 % of post-read processing time.
        for c in paper_cases() {
            let frac = c.t_diam_cpu_ms / (c.t_diam_cpu_ms + c.t_mc_cpu_ms);
            assert!(frac > 0.955, "{}: {frac}", c.case_id);
        }
    }

    #[test]
    fn published_comp_speedups_consistent() {
        // Comp. ≈ cpu_total / gpu_total (within rounding of the table).
        for c in paper_cases() {
            let cpu = c.t_mc_cpu_ms + c.t_diam_cpu_ms;
            let gpu = c.t_tran_gpu_ms + c.t_mc_gpu_ms + c.t_diam_gpu_ms;
            let ratio = cpu / gpu;
            assert!(
                (ratio - c.speedup_comp).abs() / c.speedup_comp < 0.35,
                "{}: table={} recomputed={ratio:.1}",
                c.case_id,
                c.speedup_comp
            );
        }
    }

    #[test]
    fn ids_unique() {
        let cases = paper_cases();
        let ids: std::collections::HashSet<_> = cases.iter().map(|c| c.case_id).collect();
        assert_eq!(ids.len(), cases.len());
    }
}
