//! Column-aligned table builder for terminal + markdown + CSV output.

/// A simple right-ragged table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Space-padded plain text (what the harness binaries print).
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
            out.push('\n');
        }
        out
    }

    /// GitHub-flavoured markdown (EXPERIMENTS.md blocks).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(self.headers.len())));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    /// RFC-4180 CSV: cells containing commas, quotes, or CR/LF are quoted
    /// (embedded quotes doubled). Case ids come from user filenames, so
    /// every hostile cell must survive a write→parse round trip.
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| -> String {
            if c.contains(',') || c.contains('"') || c.contains('\n') || c.contains('\r') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new(vec!["case", "ms"]);
        t.row(vec!["a", "1.5"]);
        t.row(vec!["bb", "20"]);
        t
    }

    #[test]
    fn text_is_aligned() {
        let txt = sample().to_text();
        let lines: Vec<_> = txt.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("case"));
        assert!(lines[2].ends_with("1.5"));
        assert!(lines[3].ends_with(" 20"));
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.starts_with("| case | ms |\n|---|---|\n"));
        assert!(md.contains("| bb | 20 |"));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["x,y", "say \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"say \"\"hi\"\"\""));
    }

    #[test]
    fn csv_quotes_embedded_newlines_and_carriage_returns() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["two\nlines", "cr\rhere", "plain"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"two\nlines\""), "{csv}");
        assert!(csv.contains("\"cr\rhere\""), "{csv}");
        assert!(csv.contains(",plain\n"), "unremarkable cells stay bare: {csv}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        Table::new(vec!["a"]).row(vec!["1", "2"]);
    }
}
