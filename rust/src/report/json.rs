//! Minimal JSON document builder (output only; the pipeline never parses
//! JSON). Handles escaping, NaN→null (JSON has no NaN) and stable key
//! order for diffable outputs.

use std::collections::BTreeMap;
use std::fmt::Write;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn obj() -> JsonValue {
        JsonValue::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — builder misuse).
    pub fn set(&mut self, key: &str, value: impl Into<JsonValue>) -> &mut Self {
        match self {
            JsonValue::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // NaN/inf are not JSON
                }
            }
            JsonValue::Str(s) => Self::escape(s, out),
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        JsonValue::Num(v)
    }
}
impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        JsonValue::Num(v as f64)
    }
}
impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        JsonValue::Bool(v)
    }
}
impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        JsonValue::Str(v.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        JsonValue::Str(v)
    }
}
impl<T: Into<JsonValue>> From<Vec<T>> for JsonValue {
    fn from(v: Vec<T>) -> Self {
        JsonValue::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_nested_documents() {
        let mut doc = JsonValue::obj();
        doc.set("name", "case-1").set("vol", 12.5).set("ok", true);
        doc.set("diams", vec![1.0, 2.0]);
        let mut inner = JsonValue::obj();
        inner.set("n", 3usize);
        doc.set("meta", inner);
        assert_eq!(
            doc.to_string(),
            r#"{"diams":[1,2],"meta":{"n":3},"name":"case-1","ok":true,"vol":12.5}"#
        );
    }

    #[test]
    fn nan_becomes_null() {
        let mut doc = JsonValue::obj();
        doc.set("d", f64::NAN);
        assert_eq!(doc.to_string(), r#"{"d":null}"#);
    }

    #[test]
    fn strings_escaped() {
        let v = JsonValue::from("a\"b\\c\nd\u{1}");
        assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn stable_key_order() {
        let mut a = JsonValue::obj();
        a.set("z", 1.0).set("a", 2.0);
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }
}
