//! Minimal JSON document builder and parser. The pipeline only *writes*
//! JSON reports, but the bench trajectory gate ([`crate::bench`]) reads
//! `BENCH_*.json` baselines back, so [`JsonValue::parse`] implements the
//! inverse. Writing handles escaping, NaN→null (JSON has no NaN) and
//! stable key order for diffable outputs.

use std::collections::BTreeMap;
use std::fmt::Write;

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn obj() -> JsonValue {
        JsonValue::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects — builder misuse).
    pub fn set(&mut self, key: &str, value: impl Into<JsonValue>) -> &mut Self {
        match self {
            JsonValue::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // NaN/inf are not JSON
                }
            }
            JsonValue::Str(s) => Self::escape(s, out),
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Parse a JSON document. Strict enough for round-tripping our own
    /// reports: one top-level value, no trailing garbage, located errors.
    pub fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {} of JSON document", p.pos);
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects or missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Recursive-descent parser over the document bytes.
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Nesting bound: a hostile/corrupt document must not overflow the stack.
const MAX_DEPTH: usize = 128;

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {} of JSON document", b as char, self.pos);
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        let end = self.pos + word.len();
        if self.bytes.get(self.pos..end) == Some(word.as_bytes()) {
            self.pos = end;
            Ok(v)
        } else {
            bail!("invalid literal at byte {} of JSON document", self.pos);
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue> {
        if depth > MAX_DEPTH {
            bail!("JSON nesting exceeds {MAX_DEPTH} levels");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => bail!("unexpected input at byte {} of JSON document", self.pos),
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value(depth + 1)?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {} of JSON document", self.pos),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {} of JSON document", self.pos),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        let Some(chunk) = self.bytes.get(self.pos..end) else {
            bail!("truncated \\u escape at byte {} of JSON document", self.pos);
        };
        let s = std::str::from_utf8(chunk)
            .ok()
            .and_then(|s| u32::from_str_radix(s, 16).ok());
        match s {
            Some(v) => {
                self.pos = end;
                Ok(v)
            }
            None => bail!("invalid \\u escape at byte {} of JSON document", self.pos),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string in JSON document"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair: a \uDC00..DFFF must follow
                                if self.bytes.get(self.pos..self.pos + 2) != Some(&b"\\u"[..]) {
                                    bail!("lone surrogate in JSON string");
                                }
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    bail!("invalid surrogate pair in JSON string");
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else if (0xDC00..0xE000).contains(&hi) {
                                bail!("lone surrogate in JSON string");
                            } else {
                                hi
                            };
                            match char::from_u32(cp) {
                                Some(c) => out.push(c),
                                None => bail!("invalid code point in JSON string"),
                            }
                            continue; // pos already past the escape
                        }
                        _ => bail!("invalid escape at byte {} of JSON document", self.pos),
                    }
                    self.pos += 1;
                }
                Some(b) if b < 0x20 => {
                    bail!("unescaped control byte in JSON string");
                }
                Some(_) => {
                    // consume one UTF-8 code point (input is &str, so the
                    // boundaries are valid by construction)
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).expect("input was a &str");
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii");
        match text.parse::<f64>() {
            Ok(n) if n.is_finite() => Ok(JsonValue::Num(n)),
            _ => bail!("invalid number '{text}' at byte {start} of JSON document"),
        }
    }
}

impl From<f64> for JsonValue {
    fn from(v: f64) -> Self {
        JsonValue::Num(v)
    }
}
impl From<usize> for JsonValue {
    fn from(v: usize) -> Self {
        JsonValue::Num(v as f64)
    }
}
impl From<bool> for JsonValue {
    fn from(v: bool) -> Self {
        JsonValue::Bool(v)
    }
}
impl From<&str> for JsonValue {
    fn from(v: &str) -> Self {
        JsonValue::Str(v.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(v: String) -> Self {
        JsonValue::Str(v)
    }
}
impl<T: Into<JsonValue>> From<Vec<T>> for JsonValue {
    fn from(v: Vec<T>) -> Self {
        JsonValue::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_nested_documents() {
        let mut doc = JsonValue::obj();
        doc.set("name", "case-1").set("vol", 12.5).set("ok", true);
        doc.set("diams", vec![1.0, 2.0]);
        let mut inner = JsonValue::obj();
        inner.set("n", 3usize);
        doc.set("meta", inner);
        assert_eq!(
            doc.to_string(),
            r#"{"diams":[1,2],"meta":{"n":3},"name":"case-1","ok":true,"vol":12.5}"#
        );
    }

    #[test]
    fn nan_becomes_null() {
        let mut doc = JsonValue::obj();
        doc.set("d", f64::NAN);
        assert_eq!(doc.to_string(), r#"{"d":null}"#);
    }

    #[test]
    fn strings_escaped() {
        let v = JsonValue::from("a\"b\\c\nd\u{1}");
        assert_eq!(v.to_string(), "\"a\\\"b\\\\c\\nd\\u0001\"");
    }

    #[test]
    fn stable_key_order() {
        let mut a = JsonValue::obj();
        a.set("z", 1.0).set("a", 2.0);
        assert_eq!(a.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn parse_round_trips_writer_output() {
        let mut doc = JsonValue::obj();
        doc.set("name", "bench_texture").set("scale", 0.004).set("ok", true);
        doc.set("tags", vec!["a\"b".to_string(), "c\\d".to_string()]);
        let mut inner = JsonValue::obj();
        inner.set("iters", 3usize).set("none", JsonValue::Null);
        doc.set("meta", inner);
        let text = doc.to_string();
        let parsed = JsonValue::parse(&text).unwrap();
        assert_eq!(parsed, doc);
        // and the re-serialization is byte-identical (stable key order)
        assert_eq!(parsed.to_string(), text);
    }

    #[test]
    fn parse_handles_whitespace_escapes_and_numbers() {
        let text = " { \"a\" : [ 1.5e2 , -0.25 , \"x\\u0041\\n\" , null , false ] } ";
        let v = JsonValue::parse(text).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(150.0));
        assert_eq!(arr[1].as_f64(), Some(-0.25));
        assert_eq!(arr[2].as_str(), Some("xA\n"));
        assert_eq!(arr[3], JsonValue::Null);
        assert_eq!(arr[4].as_bool(), Some(false));
    }

    #[test]
    fn parse_surrogate_pairs() {
        let v = JsonValue::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        assert!(JsonValue::parse("\"\\ud83d\"").is_err(), "lone surrogate");
    }

    #[test]
    fn parse_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\":1} extra",
            "{'a':1}",
            "\"unterminated",
            "01a",
            "1e+",
            "nul",
            "{\"a\" 1}",
        ] {
            assert!(JsonValue::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn parse_depth_is_bounded() {
        let deep = "[".repeat(500) + &"]".repeat(500);
        let err = JsonValue::parse(&deep).unwrap_err();
        assert!(err.to_string().contains("nesting"), "{err}");
    }

    #[test]
    fn accessors_return_none_on_type_mismatch() {
        let v = JsonValue::parse("{\"n\":1}").unwrap();
        assert!(v.as_f64().is_none() && v.as_str().is_none());
        assert!(v.get("n").unwrap().as_f64() == Some(1.0));
        assert!(v.get("missing").is_none());
        assert!(JsonValue::Num(1.0).get("x").is_none());
    }
}
