//! Report emitters: markdown tables, CSV and a minimal JSON writer for the
//! experiment harnesses (no serde offline — part of the deliverable).

mod json;
mod table;

pub use json::JsonValue;
pub use table::Table;
