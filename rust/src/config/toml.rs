//! The TOML-subset parser behind [`super::PipelineConfig`].

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// `section → key → value` document map.
pub type TomlDoc = BTreeMap<String, BTreeMap<String, TomlValue>>;

fn parse_value(raw: &str, line_no: usize) -> Result<TomlValue> {
    let raw = raw.trim();
    if raw.starts_with('"') {
        if !raw.ends_with('"') || raw.len() < 2 {
            bail!("line {line_no}: unterminated string");
        }
        let inner = &raw[1..raw.len() - 1];
        if inner.contains('"') {
            bail!("line {line_no}: escapes/embedded quotes unsupported");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if raw == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if raw.starts_with('[') || raw.starts_with('{') {
        bail!("line {line_no}: arrays/inline tables are not supported by this subset");
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {line_no}: cannot parse value '{raw}'")
}

/// Strip a trailing `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse the supported TOML subset into a section map. Keys before any
/// `[section]` land in the `""` section.
pub fn parse_toml(text: &str) -> Result<TomlDoc> {
    let mut doc: TomlDoc = BTreeMap::new();
    let mut section = String::new();
    for (no, raw_line) in text.lines().enumerate() {
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                bail!("line {}: malformed section header '{line}'", no + 1);
            };
            if name.contains('[') || name.contains('.') {
                bail!("line {}: nested tables unsupported ('{name}')", no + 1);
            }
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value', got '{line}'", no + 1);
        };
        let key = key.trim().to_string();
        if key.is_empty() {
            bail!("line {}: empty key", no + 1);
        }
        let value = parse_value(value, no + 1)?;
        let prev = doc.entry(section.clone()).or_default().insert(key.clone(), value);
        if prev.is_some() {
            bail!("line {}: duplicate key '{key}'", no + 1);
        }
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        let doc = parse_toml(
            "a = 1\nb = -2\nc = 3.5\nd = true\ne = \"hi\"\n[s]\nf = false\n",
        )
        .unwrap();
        let root = &doc[""];
        assert_eq!(root["a"], TomlValue::Int(1));
        assert_eq!(root["b"], TomlValue::Int(-2));
        assert_eq!(root["c"], TomlValue::Float(3.5));
        assert_eq!(root["d"], TomlValue::Bool(true));
        assert_eq!(root["e"], TomlValue::Str("hi".into()));
        assert_eq!(doc["s"]["f"], TomlValue::Bool(false));
    }

    #[test]
    fn comments_stripped_outside_strings() {
        let doc = parse_toml("a = 1 # trailing\nb = \"x # y\"\n").unwrap();
        assert_eq!(doc[""]["a"], TomlValue::Int(1));
        assert_eq!(doc[""]["b"], TomlValue::Str("x # y".into()));
    }

    #[test]
    fn duplicate_keys_rejected() {
        assert!(parse_toml("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn arrays_rejected_loudly() {
        let err = parse_toml("a = [1, 2]\n").unwrap_err();
        assert!(err.to_string().contains("not supported"));
    }

    #[test]
    fn nested_tables_rejected() {
        assert!(parse_toml("[a.b]\n").is_err());
    }

    #[test]
    fn malformed_lines_carry_line_numbers() {
        let err = parse_toml("ok = 1\nnonsense\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
    }

    #[test]
    fn value_accessors() {
        assert_eq!(TomlValue::Int(5).as_usize().unwrap(), 5);
        assert!(TomlValue::Int(-1).as_usize().is_err());
        assert_eq!(TomlValue::Int(2).as_f64().unwrap(), 2.0);
        assert!(TomlValue::Str("x".into()).as_bool().is_err());
    }
}
