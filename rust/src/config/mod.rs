//! Config system: a TOML-subset parser (no external codec crates offline)
//! plus the typed [`PipelineConfig`] the launcher builds from it.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"…"`), integer, float and boolean values, `#` comments. That covers
//! every knob the pipeline exposes; nested tables/arrays are rejected with
//! a clear error instead of being silently misparsed.

mod toml;

pub use toml::{parse_toml, TomlValue};

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Which compute path the dispatcher should take.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Probe for artifacts/PJRT and fall back to CPU — the paper's default.
    Auto,
    /// Force the CPU fallback.
    Cpu,
    /// Force the accelerated path; error if artifacts are unavailable.
    Accelerated,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "auto" => Backend::Auto,
            "cpu" => Backend::Cpu,
            "accelerated" | "gpu" => Backend::Accelerated,
            other => bail!("unknown backend '{other}' (auto|cpu|accelerated)"),
        })
    }
}

/// Which feature classes the extractor computes.
///
/// Shape is always on — it is the paper's pipeline and every report keys
/// off it; the flag exists so `"shape"` parses in class lists. The
/// intensity classes (first-order plus the five texture matrix classes
/// GLCM, GLRLM, GLSZM, GLDM, NGTDM) are opt-in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FeatureClasses {
    pub shape: bool,
    pub first_order: bool,
    pub glcm: bool,
    pub glrlm: bool,
    pub glszm: bool,
    pub gldm: bool,
    pub ngtdm: bool,
}

impl Default for FeatureClasses {
    fn default() -> Self {
        FeatureClasses {
            shape: true,
            first_order: false,
            glcm: false,
            glrlm: false,
            glszm: false,
            gldm: false,
            ngtdm: false,
        }
    }
}

impl FeatureClasses {
    /// Parse a comma-separated class list, e.g. `"shape,glcm,glszm"`.
    /// Accepted names: `shape`, `firstorder`, `glcm`, `glrlm`, `glszm`,
    /// `gldm`, `ngtdm`, `texture` (= all five matrix classes), `all`. At
    /// least one class must be named — an empty list is an error, not a
    /// silent shape-only run.
    pub fn parse(s: &str) -> Result<FeatureClasses> {
        let mut c = FeatureClasses::default();
        let mut recognized = 0usize;
        for tok in s.split(',') {
            let tok = tok.trim();
            if !tok.is_empty() {
                recognized += 1;
            }
            match tok {
                "" => {}
                "shape" => c.shape = true,
                "firstorder" | "first-order" | "first_order" => c.first_order = true,
                "glcm" => c.glcm = true,
                "glrlm" => c.glrlm = true,
                "glszm" => c.glszm = true,
                "gldm" => c.gldm = true,
                "ngtdm" => c.ngtdm = true,
                "texture" => {
                    c.glcm = true;
                    c.glrlm = true;
                    c.glszm = true;
                    c.gldm = true;
                    c.ngtdm = true;
                }
                "all" => {
                    c.first_order = true;
                    c.glcm = true;
                    c.glrlm = true;
                    c.glszm = true;
                    c.gldm = true;
                    c.ngtdm = true;
                }
                other => bail!(
                    "unknown feature class '{other}' \
                     (shape|firstorder|glcm|glrlm|glszm|gldm|ngtdm|texture|all)"
                ),
            }
        }
        if recognized == 0 {
            bail!("feature class list is empty; name at least one class, e.g. \"shape\"");
        }
        Ok(c)
    }

    /// True when any enabled class needs image intensities.
    pub fn needs_image(&self) -> bool {
        self.first_order || self.texture()
    }

    /// True when a texture matrix class is enabled.
    pub fn texture(&self) -> bool {
        self.glcm || self.glrlm || self.glszm || self.gldm || self.ngtdm
    }
}

/// Parse a comma-separated distance list, e.g. `"1,2"` (GLCM offsets).
/// Shared by the TOML key and the `--glcm-distances` CLI flag.
pub fn parse_distances(s: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let d: usize = tok
            .parse()
            .with_context(|| format!("bad glcm distance '{tok}' (positive integers)"))?;
        if d == 0 {
            bail!("glcm distances must be >= 1");
        }
        out.push(d);
    }
    if out.is_empty() {
        bail!("glcm_distances must name at least one distance, e.g. \"1\"");
    }
    Ok(out)
}

/// Parse a comma-separated sigma list in millimetres, e.g. `"1.0, 3.0"`
/// (LoG scales). Shared by the TOML key and the `--log-sigmas` CLI flag.
pub fn parse_sigmas(s: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let sigma: f64 = tok
            .parse()
            .with_context(|| format!("bad LoG sigma '{tok}' (positive mm values)"))?;
        if !(sigma > 0.0 && sigma.is_finite()) {
            bail!("LoG sigmas must be positive finite mm values, got {sigma}");
        }
        // duplicates would produce two derived images with the same
        // filter-qualified name, silently colliding in JSON/CSV output
        if out.contains(&sigma) {
            bail!("duplicate LoG sigma {sigma}");
        }
        out.push(sigma);
    }
    if out.is_empty() {
        bail!("log_sigmas must name at least one sigma, e.g. \"2.0\"");
    }
    Ok(out)
}

/// Ceiling on `wavelet_levels`: each level dilates the Haar step 2×, so
/// anything deeper than this exceeds any realistic ROI extent.
pub const MAX_WAVELET_LEVELS: usize = 8;

/// Which labels of a label-map mask to extract.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum LabelSelection {
    /// No selector: masks must be binary or single-label (collapsed to
    /// 0/1); a multi-label mask is a per-case error naming the labels
    /// found.
    #[default]
    Unset,
    /// Extract every label — the union of the labels observed in the mask
    /// and any inventory the manifest declares (`labels=`), so a
    /// declared-but-empty label surfaces as a per-label error.
    All,
    /// Extract exactly these label ids (kept sorted and distinct).
    List(Vec<u16>),
}

impl LabelSelection {
    /// Parse `"all"` or a comma-separated id list like `"1,3"`. Label 0
    /// is background and cannot be selected; an empty list is an error.
    pub fn parse(s: &str) -> Result<LabelSelection> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("all") {
            return Ok(LabelSelection::All);
        }
        let mut ids = Vec::new();
        for tok in s.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let id: u16 = tok
                .parse()
                .with_context(|| format!("bad label id '{tok}' (u16, or \"all\")"))?;
            if id == 0 {
                bail!("label 0 is background and cannot be extracted");
            }
            ids.push(id);
        }
        if ids.is_empty() {
            bail!("labels must name at least one id, e.g. \"1,3\", or \"all\"");
        }
        ids.sort_unstable();
        ids.dedup();
        Ok(LabelSelection::List(ids))
    }

    /// True when a selector was given (per-label extraction mode).
    pub fn is_set(&self) -> bool {
        !matches!(self, LabelSelection::Unset)
    }
}

/// Parse a byte size: a plain integer (bytes) or one with a binary
/// K/M/G/T suffix, e.g. `"512M"`. Shared by the `memory_budget` /
/// `cache_max_bytes` TOML keys and the `--memory-budget` /
/// `--cache-max-bytes` CLI flags. Values whose scaled result exceeds
/// `u64::MAX` are a located parse error, never a wrap or silent
/// saturation.
pub fn parse_byte_size(s: &str) -> Result<u64> {
    let s = s.trim();
    let mut chars = s.chars();
    let Some(last) = chars.next_back() else {
        bail!("empty byte size (use e.g. \"512M\" or a byte count)");
    };
    let (num, shift) = match last.to_ascii_uppercase() {
        'K' => (chars.as_str(), 10u32),
        'M' => (chars.as_str(), 20),
        'G' => (chars.as_str(), 30),
        'T' => (chars.as_str(), 40),
        _ => (s, 0),
    };
    let num = num.trim();
    // Integral sizes (the common case) go through checked integer
    // arithmetic so an overflowing suffix multiplication is an error.
    if let Ok(v) = num.parse::<u64>() {
        return v.checked_mul(1u64 << shift).ok_or_else(|| {
            anyhow::anyhow!("byte size '{s}' overflows u64 (max {} bytes)", u64::MAX)
        });
    }
    // Fractional sizes ("1.5M") take the float path with an explicit
    // range check; `u64::MAX as f64` rounds up to 2^64, so `>=` rejects
    // everything not representable.
    let v: f64 = num
        .parse()
        .with_context(|| format!("bad byte size '{s}' (e.g. \"512M\", \"2G\", or bytes)"))?;
    if !(v >= 0.0 && v.is_finite()) {
        bail!("byte size must be non-negative and finite, got '{s}'");
    }
    let scaled = v * (1u64 << shift) as f64;
    if scaled >= u64::MAX as f64 {
        bail!("byte size '{s}' overflows u64 (max {} bytes)", u64::MAX);
    }
    Ok(scaled as u64)
}

/// Typed pipeline configuration (defaults reflect the single-core testbed).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Artifact directory (the AOT HLO bundle).
    pub artifact_dir: PathBuf,
    /// Worker threads for the read stage.
    pub read_workers: usize,
    /// Worker threads for the mesh stage.
    pub mesh_workers: usize,
    /// Worker threads for the feature/dispatch stage.
    pub feature_workers: usize,
    /// Bounded-channel capacity between stages (backpressure knob).
    pub queue_capacity: usize,
    /// Backend selection policy.
    pub backend: Backend,
    /// Thread count handed to the CPU diameter strategies (0 = auto).
    pub cpu_threads: usize,
    /// Diameter strategy for the CPU path.
    pub strategy: crate::parallel::Strategy,
    /// Engine threads in the accelerated pool (sharded round-robin).
    pub engine_count: usize,
    /// Cases per fused engine batch (1 = per-case dispatch, the classic
    /// behaviour; ≥ 2 enables pad-bucket batching).
    pub batch_size: usize,
    /// Max milliseconds a partial batch waits for co-batchable cases.
    pub batch_linger_ms: u64,
    /// Feature classes to compute (shape is always on).
    pub feature_classes: FeatureClasses,
    /// Gray-level bin width for first-order histograms and texture
    /// discretization (PyRadiomics default 25), used when `bin_count` is 0.
    pub bin_width: f64,
    /// Fixed gray-level bin count for texture discretization and the
    /// first-order histogram; `0` selects fixed-width binning via
    /// `bin_width`.
    pub bin_count: usize,
    /// GLCM neighbour distances in voxels.
    pub glcm_distances: Vec<usize>,
    /// GLDM dependence threshold: a 26-neighbour counts as *dependent*
    /// when its gray level differs by at most this much (PyRadiomics
    /// `gldm_a`, default 0 = exactly equal levels).
    pub gldm_alpha: f64,
    /// Derived-image families the intensity classes run on (original /
    /// LoG / wavelet; shape always uses the mask geometry).
    pub image_types: crate::imgproc::ImageTypes,
    /// LoG sigmas in millimetres — one derived image per sigma when the
    /// `log` image type is enabled.
    pub log_sigmas: Vec<f64>,
    /// Isotropic target spacing in millimetres for resampling image and
    /// mask before extraction; `0` disables resampling (native grids).
    pub resampled_spacing: f64,
    /// Haar wavelet decomposition levels (each level emits 8 sub-bands).
    pub wavelet_levels: usize,
    /// Opt-in: substitute a deterministic synthetic intensity image when a
    /// case enables intensity classes but carries no image volume. Off by
    /// default — such cases fail with an error naming the remedies instead
    /// of silently computing features from fabricated intensities.
    pub synthetic_image: bool,
    /// Write a Chrome Trace Event JSON of the run to this path (enables
    /// the in-process tracer; `None` keeps tracing fully off).
    pub trace_out: Option<PathBuf>,
    /// Write the `radpipe.metrics/1` snapshot of the run to this path.
    pub metrics_out: Option<PathBuf>,
    /// Label selector for multi-label masks: unset (binary masks only),
    /// `all`, or an explicit id list. When set, each case yields one
    /// extraction per selected label from a single read/resample/derive
    /// pass.
    pub labels: LabelSelection,
    /// Slab-streamed reading: scan each mask in z-planes to find the ROI
    /// bounding box, then materialise only the crop — never the full
    /// grid. Requires native grids (incompatible with resampling) and an
    /// image on the same grid as its mask.
    pub slab_io: bool,
    /// Pipeline-wide memory budget in bytes for in-flight case volumes;
    /// the read stage throttles admission to stay under it (one case is
    /// always admitted, so an undersized budget degrades to serial
    /// execution). `0` = unlimited.
    pub memory_budget: u64,
    /// Content-addressed feature cache directory for `radpipe batch`:
    /// completed cases are stored keyed by (mask bytes, image bytes,
    /// canonicalized config) and replayed bit-for-bit on re-runs. `None`
    /// disables caching.
    pub cache_dir: Option<PathBuf>,
    /// Soft size cap on the feature cache in bytes; oldest entries are
    /// evicted after a write pushes the store over it. `0` = unbounded.
    pub cache_max_bytes: u64,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            artifact_dir: PathBuf::from("artifacts"),
            read_workers: 1,
            mesh_workers: 1,
            feature_workers: 1,
            queue_capacity: 4,
            backend: Backend::Auto,
            cpu_threads: 0,
            strategy: crate::parallel::Strategy::LocalAccumulators,
            engine_count: 1,
            batch_size: 1,
            batch_linger_ms: 2,
            feature_classes: FeatureClasses::default(),
            bin_width: 25.0,
            bin_count: 0,
            glcm_distances: vec![1],
            gldm_alpha: 0.0,
            image_types: crate::imgproc::ImageTypes::default(),
            log_sigmas: vec![2.0],
            resampled_spacing: 0.0,
            wavelet_levels: 1,
            synthetic_image: false,
            trace_out: None,
            metrics_out: None,
            labels: LabelSelection::Unset,
            slab_io: false,
            memory_budget: 0,
            cache_dir: None,
            cache_max_bytes: 0,
        }
    }
}

impl PipelineConfig {
    /// Load from a TOML file ([pipeline] section).
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = parse_toml(text)?;
        let mut cfg = PipelineConfig::default();
        let empty = BTreeMap::new();
        let section = doc.get("pipeline").unwrap_or(&empty);
        for (key, value) in section {
            match key.as_str() {
                "artifact_dir" => cfg.artifact_dir = PathBuf::from(value.as_str()?),
                "read_workers" => cfg.read_workers = value.as_usize()?,
                "mesh_workers" => cfg.mesh_workers = value.as_usize()?,
                "feature_workers" => cfg.feature_workers = value.as_usize()?,
                "queue_capacity" => cfg.queue_capacity = value.as_usize()?.max(1),
                "backend" => cfg.backend = Backend::parse(value.as_str()?)?,
                "cpu_threads" => cfg.cpu_threads = value.as_usize()?,
                "strategy" => {
                    cfg.strategy = crate::parallel::Strategy::from_label(value.as_str()?)
                        .with_context(|| format!("unknown strategy '{}'", value.as_str().unwrap_or("")))?
                }
                "engine_count" => cfg.engine_count = value.as_usize()?.max(1),
                "batch_size" => cfg.batch_size = value.as_usize()?.max(1),
                "batch_linger_ms" => cfg.batch_linger_ms = value.as_usize()? as u64,
                "feature_classes" => {
                    cfg.feature_classes = FeatureClasses::parse(value.as_str()?)?
                }
                "bin_width" => {
                    cfg.bin_width = value.as_f64()?;
                    if cfg.bin_width <= 0.0 || !cfg.bin_width.is_finite() {
                        bail!("bin_width must be a positive number");
                    }
                }
                "bin_count" => {
                    cfg.bin_count = value.as_usize()?;
                    let max = crate::features::texture::MAX_GRAY_LEVELS;
                    if cfg.bin_count > max {
                        bail!("bin_count {} exceeds the maximum of {max}", cfg.bin_count);
                    }
                }
                "glcm_distances" => cfg.glcm_distances = parse_distances(value.as_str()?)?,
                "gldm_alpha" => {
                    cfg.gldm_alpha = value.as_f64()?;
                    if !(cfg.gldm_alpha >= 0.0 && cfg.gldm_alpha.is_finite()) {
                        bail!("gldm_alpha must be a non-negative finite number");
                    }
                }
                "image_types" => {
                    cfg.image_types = crate::imgproc::ImageTypes::parse(value.as_str()?)?
                }
                "log_sigmas" => cfg.log_sigmas = parse_sigmas(value.as_str()?)?,
                "resampled_spacing" => {
                    cfg.resampled_spacing = value.as_f64()?;
                    if !(cfg.resampled_spacing >= 0.0 && cfg.resampled_spacing.is_finite())
                    {
                        bail!("resampled_spacing must be >= 0 mm (0 disables resampling)");
                    }
                }
                "wavelet_levels" => {
                    cfg.wavelet_levels = value.as_usize()?;
                    if cfg.wavelet_levels == 0 || cfg.wavelet_levels > MAX_WAVELET_LEVELS {
                        bail!(
                            "wavelet_levels must be in 1..={MAX_WAVELET_LEVELS}, got {}",
                            cfg.wavelet_levels
                        );
                    }
                }
                "synthetic_image" => cfg.synthetic_image = value.as_bool()?,
                "trace_out" => cfg.trace_out = Some(PathBuf::from(value.as_str()?)),
                "metrics_out" => cfg.metrics_out = Some(PathBuf::from(value.as_str()?)),
                "labels" => cfg.labels = LabelSelection::parse(value.as_str()?)?,
                "slab_io" => cfg.slab_io = value.as_bool()?,
                "memory_budget" => {
                    cfg.memory_budget = if let Ok(s) = value.as_str() {
                        parse_byte_size(s)?
                    } else {
                        value.as_usize()? as u64
                    }
                }
                "cache_dir" => cfg.cache_dir = Some(PathBuf::from(value.as_str()?)),
                "cache_max_bytes" => {
                    cfg.cache_max_bytes = if let Ok(s) = value.as_str() {
                        parse_byte_size(s)?
                    } else {
                        value.as_usize()? as u64
                    }
                }
                other => bail!("unknown [pipeline] key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-key validation — run after TOML parse and again after CLI
    /// flags overlay the config.
    pub fn validate(&self) -> Result<()> {
        if self.slab_io && self.resampled_spacing > 0.0 {
            bail!(
                "slab_io is incompatible with resampled_spacing: resampling needs the \
                 full source grid in memory (disable one of the two)"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = PipelineConfig::default();
        assert_eq!(c.backend, Backend::Auto);
        assert!(c.queue_capacity >= 1);
    }

    #[test]
    fn parse_full_config() {
        let text = r#"
# experiment config
[pipeline]
artifact_dir = "artifacts"
read_workers = 2
mesh_workers = 3
feature_workers = 4
queue_capacity = 16
backend = "cpu"
cpu_threads = 8
strategy = "2-block-reduction"
engine_count = 3
batch_size = 16
batch_linger_ms = 5
"#;
        let c = PipelineConfig::from_toml(text).unwrap();
        assert_eq!(c.read_workers, 2);
        assert_eq!(c.mesh_workers, 3);
        assert_eq!(c.feature_workers, 4);
        assert_eq!(c.queue_capacity, 16);
        assert_eq!(c.backend, Backend::Cpu);
        assert_eq!(c.cpu_threads, 8);
        assert_eq!(c.strategy, crate::parallel::Strategy::BlockReduction);
        assert_eq!(c.engine_count, 3);
        assert_eq!(c.batch_size, 16);
        assert_eq!(c.batch_linger_ms, 5);
    }

    #[test]
    fn batching_defaults_preserve_per_case_dispatch() {
        let c = PipelineConfig::default();
        assert_eq!(c.engine_count, 1);
        assert_eq!(c.batch_size, 1);
        assert!(c.batch_linger_ms > 0);
    }

    #[test]
    fn zero_engine_count_and_batch_size_clamp_to_one() {
        let c = PipelineConfig::from_toml("[pipeline]\nengine_count = 0\nbatch_size = 0\n")
            .unwrap();
        assert_eq!(c.engine_count, 1);
        assert_eq!(c.batch_size, 1);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = PipelineConfig::from_toml("[pipeline]\nbogus = 1\n").unwrap_err();
        assert!(err.to_string().contains("bogus"));
    }

    #[test]
    fn bad_backend_rejected() {
        let err =
            PipelineConfig::from_toml("[pipeline]\nbackend = \"quantum\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("quantum"));
    }

    #[test]
    fn empty_config_gives_defaults() {
        let c = PipelineConfig::from_toml("").unwrap();
        assert_eq!(c.queue_capacity, PipelineConfig::default().queue_capacity);
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("auto").unwrap(), Backend::Auto);
        assert_eq!(Backend::parse("gpu").unwrap(), Backend::Accelerated);
        assert!(Backend::parse("x").is_err());
    }

    #[test]
    fn feature_class_defaults_are_shape_only() {
        let c = PipelineConfig::default();
        assert!(c.feature_classes.shape);
        assert!(!c.feature_classes.needs_image());
        assert_eq!(c.bin_width, 25.0);
        assert_eq!(c.bin_count, 0);
        assert_eq!(c.glcm_distances, vec![1]);
        assert_eq!(c.gldm_alpha, 0.0);
    }

    #[test]
    fn feature_class_list_parses() {
        let c = FeatureClasses::parse("shape, glcm").unwrap();
        assert!(c.shape && c.glcm && !c.glrlm && !c.first_order);
        let c = FeatureClasses::parse("texture").unwrap();
        assert!(c.glcm && c.glrlm && c.glszm && c.gldm && c.ngtdm && !c.first_order);
        let c = FeatureClasses::parse("all").unwrap();
        assert!(c.first_order && c.glcm && c.glrlm && c.needs_image() && c.texture());
        assert!(c.glszm && c.gldm && c.ngtdm);
        assert!(FeatureClasses::parse("bogus").is_err());
        // an empty list is a user error, not a silent shape-only run
        assert!(FeatureClasses::parse("").is_err());
        assert!(FeatureClasses::parse(" , ").is_err());
    }

    #[test]
    fn region_classes_parse_individually() {
        for (name, pick) in [
            ("glszm", 0usize),
            ("gldm", 1),
            ("ngtdm", 2),
        ] {
            let c = FeatureClasses::parse(name).unwrap();
            assert_eq!(c.glszm, pick == 0, "{name}");
            assert_eq!(c.gldm, pick == 1, "{name}");
            assert_eq!(c.ngtdm, pick == 2, "{name}");
            assert!(!c.glcm && !c.glrlm && !c.first_order, "{name}");
            assert!(c.texture() && c.needs_image(), "{name}");
        }
    }

    #[test]
    fn texture_knobs_parse_from_toml() {
        let text = r#"
[pipeline]
feature_classes = "firstorder,texture"
bin_width = 10.5
bin_count = 16
glcm_distances = "1, 2,3"
gldm_alpha = 1.5
"#;
        let c = PipelineConfig::from_toml(text).unwrap();
        assert!(c.feature_classes.first_order && c.feature_classes.glcm);
        assert!(c.feature_classes.glszm && c.feature_classes.gldm && c.feature_classes.ngtdm);
        assert_eq!(c.bin_width, 10.5);
        assert_eq!(c.bin_count, 16);
        assert_eq!(c.glcm_distances, vec![1, 2, 3]);
        assert_eq!(c.gldm_alpha, 1.5);
    }

    #[test]
    fn synthetic_image_is_an_explicit_opt_in() {
        assert!(!PipelineConfig::default().synthetic_image, "off by default");
        let c = PipelineConfig::from_toml("[pipeline]\nsynthetic_image = true\n").unwrap();
        assert!(c.synthetic_image);
        let c = PipelineConfig::from_toml("[pipeline]\nsynthetic_image = false\n").unwrap();
        assert!(!c.synthetic_image);
        // non-boolean values are a clear error
        assert!(PipelineConfig::from_toml("[pipeline]\nsynthetic_image = 1\n").is_err());
    }

    #[test]
    fn observability_outputs_are_off_by_default_and_parse_from_toml() {
        let c = PipelineConfig::default();
        assert!(c.trace_out.is_none() && c.metrics_out.is_none());
        let text = r#"
[pipeline]
trace_out = "run-trace.json"
metrics_out = "run-metrics.json"
"#;
        let c = PipelineConfig::from_toml(text).unwrap();
        assert_eq!(c.trace_out, Some(PathBuf::from("run-trace.json")));
        assert_eq!(c.metrics_out, Some(PathBuf::from("run-metrics.json")));
        // non-string values are a clear error
        assert!(PipelineConfig::from_toml("[pipeline]\ntrace_out = 1\n").is_err());
    }

    #[test]
    fn imgproc_defaults_are_original_only() {
        let c = PipelineConfig::default();
        assert!(c.image_types.original && !c.image_types.log && !c.image_types.wavelet);
        assert_eq!(c.log_sigmas, vec![2.0]);
        assert_eq!(c.resampled_spacing, 0.0, "resampling is opt-in");
        assert_eq!(c.wavelet_levels, 1);
    }

    #[test]
    fn imgproc_knobs_parse_from_toml() {
        let text = r#"
[pipeline]
image_types = "original, log, wavelet"
log_sigmas = "1.0, 2.5"
resampled_spacing = 1.5
wavelet_levels = 2
"#;
        let c = PipelineConfig::from_toml(text).unwrap();
        assert!(c.image_types.original && c.image_types.log && c.image_types.wavelet);
        assert_eq!(c.log_sigmas, vec![1.0, 2.5]);
        assert_eq!(c.resampled_spacing, 1.5);
        assert_eq!(c.wavelet_levels, 2);
    }

    #[test]
    fn bad_imgproc_knobs_rejected() {
        assert!(PipelineConfig::from_toml("[pipeline]\nimage_types = \"xray\"\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nlog_sigmas = \"0\"\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nlog_sigmas = \"\"\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nlog_sigmas = \"-2.0\"\n").is_err());
        assert!(
            PipelineConfig::from_toml("[pipeline]\nresampled_spacing = -1.0\n").is_err()
        );
        assert!(PipelineConfig::from_toml("[pipeline]\nwavelet_levels = 0\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nwavelet_levels = 9\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nwavelet_levels = 8\n").is_ok());
    }

    #[test]
    fn label_selection_parses() {
        assert_eq!(LabelSelection::parse("all").unwrap(), LabelSelection::All);
        assert_eq!(LabelSelection::parse("ALL").unwrap(), LabelSelection::All);
        assert_eq!(
            LabelSelection::parse("3, 1,3").unwrap(),
            LabelSelection::List(vec![1, 3]),
            "sorted, deduped"
        );
        assert!(LabelSelection::parse("0").is_err(), "background not selectable");
        assert!(LabelSelection::parse("").is_err());
        assert!(LabelSelection::parse("x").is_err());
        assert!(!LabelSelection::Unset.is_set());
        assert!(LabelSelection::All.is_set());
    }

    #[test]
    fn out_of_core_knobs_parse_from_toml() {
        let c = PipelineConfig::default();
        assert_eq!(c.labels, LabelSelection::Unset);
        assert!(!c.slab_io);
        assert_eq!(c.memory_budget, 0, "unlimited by default");
        let text = r#"
[pipeline]
labels = "1,3"
slab_io = true
memory_budget = "512M"
"#;
        let c = PipelineConfig::from_toml(text).unwrap();
        assert_eq!(c.labels, LabelSelection::List(vec![1, 3]));
        assert!(c.slab_io);
        assert_eq!(c.memory_budget, 512 << 20);
        // integer byte counts work too
        let c = PipelineConfig::from_toml("[pipeline]\nmemory_budget = 4096\n").unwrap();
        assert_eq!(c.memory_budget, 4096);
        assert!(PipelineConfig::from_toml("[pipeline]\nlabels = \"0\"\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nmemory_budget = \"wat\"\n").is_err());
    }

    #[test]
    fn byte_sizes_parse_with_binary_suffixes() {
        assert_eq!(parse_byte_size("4096").unwrap(), 4096);
        assert_eq!(parse_byte_size("2K").unwrap(), 2048);
        assert_eq!(parse_byte_size("1.5m").unwrap(), 3 << 19);
        assert_eq!(parse_byte_size("2G").unwrap(), 2 << 30);
        assert_eq!(parse_byte_size("1T").unwrap(), 1 << 40);
        assert!(parse_byte_size("").is_err());
        assert!(parse_byte_size("-1K").is_err());
        assert!(parse_byte_size("many").is_err());
    }

    #[test]
    fn byte_size_overflow_is_a_parse_error_not_a_wrap() {
        // exact u64 boundaries: the largest value that fits per suffix...
        assert_eq!(parse_byte_size(&u64::MAX.to_string()).unwrap(), u64::MAX);
        assert_eq!(parse_byte_size("17179869183G").unwrap(), ((1u64 << 34) - 1) << 30);
        assert_eq!(parse_byte_size("16777215T").unwrap(), ((1u64 << 24) - 1) << 40);
        // ...and the first value that does not: a located error, never a
        // silent wrap or saturation
        for over in ["18446744073709551G", "17179869184G", "16777216T", "18446744073709551616"]
        {
            let err = parse_byte_size(over).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("overflow") || msg.contains("bad byte size"),
                "{over}: {msg}"
            );
            assert!(msg.contains(over) || msg.contains("u64"), "{over}: {msg}");
        }
        // huge fractional values take the float path and still error
        assert!(parse_byte_size("99999999999999999999.5G").is_err());
        assert!(parse_byte_size("inf").is_err());
    }

    #[test]
    fn cache_knobs_parse_from_toml() {
        let c = PipelineConfig::default();
        assert_eq!(c.cache_dir, None, "caching is opt-in");
        assert_eq!(c.cache_max_bytes, 0, "unbounded by default");
        let text = r#"
[pipeline]
cache_dir = "feature-cache"
cache_max_bytes = "64M"
"#;
        let c = PipelineConfig::from_toml(text).unwrap();
        assert_eq!(c.cache_dir, Some(PathBuf::from("feature-cache")));
        assert_eq!(c.cache_max_bytes, 64 << 20);
        // integer byte counts work too, and overflow is rejected
        let c = PipelineConfig::from_toml("[pipeline]\ncache_max_bytes = 4096\n").unwrap();
        assert_eq!(c.cache_max_bytes, 4096);
        assert!(PipelineConfig::from_toml(
            "[pipeline]\ncache_max_bytes = \"18446744073709551G\"\n"
        )
        .is_err());
    }

    #[test]
    fn slab_io_conflicts_with_resampling() {
        let text = "[pipeline]\nslab_io = true\nresampled_spacing = 1.5\n";
        let err = PipelineConfig::from_toml(text).unwrap_err();
        assert!(err.to_string().contains("incompatible"), "{err}");
        assert!(PipelineConfig::from_toml("[pipeline]\nslab_io = true\n").is_ok());
        // the standalone validator catches a CLI-built conflict too
        let c = PipelineConfig {
            slab_io: true,
            resampled_spacing: 2.0,
            ..PipelineConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn sigma_list_parses() {
        assert_eq!(parse_sigmas("1.0, 3").unwrap(), vec![1.0, 3.0]);
        assert!(parse_sigmas("nope").is_err());
        assert!(parse_sigmas("inf").is_err());
        // "2" and "2.0" are the same sigma — one derived-image name
        let err = parse_sigmas("2, 2.0").unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
    }

    #[test]
    fn bad_texture_knobs_rejected() {
        assert!(PipelineConfig::from_toml("[pipeline]\nbin_width = 0\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nbin_width = -5.0\n").is_err());
        // out-of-range bin_count fails at config time, not per-case at runtime
        assert!(PipelineConfig::from_toml("[pipeline]\nbin_count = 600\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\nbin_count = 512\n").is_ok());
        assert!(
            PipelineConfig::from_toml("[pipeline]\nglcm_distances = \"0\"\n").is_err()
        );
        assert!(
            PipelineConfig::from_toml("[pipeline]\nglcm_distances = \"\"\n").is_err()
        );
        assert!(
            PipelineConfig::from_toml("[pipeline]\nfeature_classes = \"wat\"\n").is_err()
        );
        assert!(PipelineConfig::from_toml("[pipeline]\ngldm_alpha = -1.0\n").is_err());
        assert!(PipelineConfig::from_toml("[pipeline]\ngldm_alpha = 0\n").is_ok());
        assert!(PipelineConfig::from_toml("[pipeline]\ngldm_alpha = 2.0\n").is_ok());
    }
}
