//! Closed-form eigenvalues of a symmetric 3×3 matrix.
//!
//! PyRadiomics derives `MajorAxisLength`, `MinorAxisLength`,
//! `LeastAxisLength`, `Elongation` and `Flatness` from the eigenvalues of the
//! voxel-coordinate covariance matrix (its "principal moments"). We use the
//! standard trigonometric solution (Smith 1961 / the method used by Eigen's
//! `SelfAdjointEigenSolver` fast path), which is branch-light and accurate
//! enough for covariance matrices of well-conditioned ROIs.

/// Symmetric 3×3 matrix stored as the six unique entries.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Sym3 {
    pub xx: f64,
    pub yy: f64,
    pub zz: f64,
    pub xy: f64,
    pub xz: f64,
    pub yz: f64,
}

impl Sym3 {
    pub fn trace(&self) -> f64 {
        self.xx + self.yy + self.zz
    }

    /// Covariance matrix of a point cloud given coordinate accumulators.
    /// `n` points, `s*` coordinate sums, `s**` product sums.
    #[allow(clippy::too_many_arguments)]
    pub fn covariance(
        n: f64,
        sx: f64,
        sy: f64,
        sz: f64,
        sxx: f64,
        syy: f64,
        szz: f64,
        sxy: f64,
        sxz: f64,
        syz: f64,
    ) -> Sym3 {
        // Population covariance (divide by n), matching numpy.cov(..., bias=1)
        // which PyRadiomics uses via `numpy.linalg.eigvals(cov)` on physical
        // coordinates.
        let mx = sx / n;
        let my = sy / n;
        let mz = sz / n;
        Sym3 {
            xx: sxx / n - mx * mx,
            yy: syy / n - my * my,
            zz: szz / n - mz * mz,
            xy: sxy / n - mx * my,
            xz: sxz / n - mx * mz,
            yz: syz / n - my * mz,
        }
    }
}

/// Eigenvalues of a symmetric 3×3 matrix, ascending: `[least, minor, major]`.
///
/// Uses the trigonometric closed form; falls back to the diagonal for
/// (near-)diagonal input to avoid cancellation noise.
pub fn sym3_eigenvalues(m: Sym3) -> [f64; 3] {
    let p1 = m.xy * m.xy + m.xz * m.xz + m.yz * m.yz;
    if p1 < 1e-300 {
        // Already diagonal.
        let mut d = [m.xx, m.yy, m.zz];
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        return d;
    }
    let q = m.trace() / 3.0;
    let dxx = m.xx - q;
    let dyy = m.yy - q;
    let dzz = m.zz - q;
    let p2 = dxx * dxx + dyy * dyy + dzz * dzz + 2.0 * p1;
    let p = (p2 / 6.0).sqrt();
    // B = (A - q I) / p ; r = det(B) / 2 clamped to [-1, 1].
    let b = Sym3 {
        xx: dxx / p,
        yy: dyy / p,
        zz: dzz / p,
        xy: m.xy / p,
        xz: m.xz / p,
        yz: m.yz / p,
    };
    let detb = b.xx * (b.yy * b.zz - b.yz * b.yz) - b.xy * (b.xy * b.zz - b.yz * b.xz)
        + b.xz * (b.xy * b.yz - b.yy * b.xz);
    let r = (detb / 2.0).clamp(-1.0, 1.0);
    let phi = r.acos() / 3.0;
    let e1 = q + 2.0 * p * phi.cos(); // largest
    let e3 = q + 2.0 * p * (phi + 2.0 * std::f64::consts::PI / 3.0).cos(); // smallest
    let e2 = 3.0 * q - e1 - e3;
    [e3, e2, e1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() < tol, "{a} vs {b}");
    }

    #[test]
    fn diagonal_matrix() {
        let m = Sym3 { xx: 3.0, yy: 1.0, zz: 2.0, ..Default::default() };
        let e = sym3_eigenvalues(m);
        assert_eq!(e, [1.0, 2.0, 3.0]);
    }

    #[test]
    fn known_symmetric() {
        // [[2,1,0],[1,2,0],[0,0,3]] → eigenvalues 1, 3, 3.
        let m = Sym3 { xx: 2.0, yy: 2.0, zz: 3.0, xy: 1.0, xz: 0.0, yz: 0.0 };
        let e = sym3_eigenvalues(m);
        // repeated eigenvalues: the trigonometric form is ~1e-8 accurate
        assert_close(e[0], 1.0, 1e-7);
        assert_close(e[1], 3.0, 1e-7);
        assert_close(e[2], 3.0, 1e-7);
    }

    #[test]
    fn trace_preserved() {
        let m = Sym3 { xx: 4.0, yy: -1.0, zz: 2.5, xy: 0.3, xz: -0.7, yz: 1.2 };
        let e = sym3_eigenvalues(m);
        assert_close(e.iter().sum::<f64>(), m.trace(), 1e-10);
        // ascending
        assert!(e[0] <= e[1] && e[1] <= e[2]);
    }

    #[test]
    fn characteristic_polynomial_root() {
        let m = Sym3 { xx: 4.0, yy: -1.0, zz: 2.5, xy: 0.3, xz: -0.7, yz: 1.2 };
        for lam in sym3_eigenvalues(m) {
            // det(A - lam I) ≈ 0
            let a = m.xx - lam;
            let b = m.yy - lam;
            let c = m.zz - lam;
            let det = a * (b * c - m.yz * m.yz) - m.xy * (m.xy * c - m.yz * m.xz)
                + m.xz * (m.xy * m.yz - b * m.xz);
            assert!(det.abs() < 1e-8, "det={det} for lam={lam}");
        }
    }

    #[test]
    fn covariance_of_axis_aligned_cloud() {
        // Points along x at ±1: variance 1 on x, 0 elsewhere.
        let s = Sym3::covariance(2.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        let e = sym3_eigenvalues(s);
        assert_close(e[2], 1.0, 1e-12);
        assert_close(e[0], 0.0, 1e-12);
        assert_close(e[1], 0.0, 1e-12);
    }
}
