//! Minimal 3-component f64 vector used throughout the mesher and features.

use std::ops::{Add, AddAssign, Div, Index, Mul, Neg, Sub};

/// A 3D point / vector with `f64` components (`x`, `y`, `z`).
///
/// Physical coordinates are always millimetres (voxel index × spacing),
/// matching PyRadiomics' convention of computing shape features in world
/// space rather than index space.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };

    #[inline]
    pub const fn new(x: f64, y: f64, z: f64) -> Self {
        Vec3 { x, y, z }
    }

    /// All components set to `v`.
    #[inline]
    pub const fn splat(v: f64) -> Self {
        Vec3::new(v, v, v)
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline]
    pub fn norm_sq(self) -> f64 {
        self.dot(self)
    }

    #[inline]
    pub fn norm(self) -> f64 {
        self.norm_sq().sqrt()
    }

    /// Squared Euclidean distance to `o`.
    #[inline]
    pub fn dist_sq(self, o: Vec3) -> f64 {
        (self - o).norm_sq()
    }

    #[inline]
    pub fn dist(self, o: Vec3) -> f64 {
        self.dist_sq(o).sqrt()
    }

    /// Component-wise minimum.
    #[inline]
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    /// Component-wise maximum.
    #[inline]
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    /// Component-wise multiplication (e.g. index × spacing).
    #[inline]
    pub fn scale(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x * o.x, self.y * o.y, self.z * o.z)
    }

    /// Unit vector; returns `None` for (near-)zero vectors.
    pub fn normalized(self) -> Option<Vec3> {
        let n = self.norm();
        if n < 1e-300 {
            None
        } else {
            Some(self / n)
        }
    }

    /// Lossy conversion to three `f32`s (the PJRT artifact input layout).
    #[inline]
    pub fn to_f32(self) -> [f32; 3] {
        [self.x as f32, self.y as f32, self.z as f32]
    }
}

impl Add for Vec3 {
    type Output = Vec3;
    #[inline]
    fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }
}

impl AddAssign for Vec3 {
    #[inline]
    fn add_assign(&mut self, o: Vec3) {
        *self = *self + o;
    }
}

impl Sub for Vec3 {
    type Output = Vec3;
    #[inline]
    fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }
}

impl Mul<f64> for Vec3 {
    type Output = Vec3;
    #[inline]
    fn mul(self, s: f64) -> Vec3 {
        Vec3::new(self.x * s, self.y * s, self.z * s)
    }
}

impl Div<f64> for Vec3 {
    type Output = Vec3;
    #[inline]
    fn div(self, s: f64) -> Vec3 {
        Vec3::new(self.x / s, self.y / s, self.z / s)
    }
}

impl Neg for Vec3 {
    type Output = Vec3;
    #[inline]
    fn neg(self) -> Vec3 {
        Vec3::new(-self.x, -self.y, -self.z)
    }
}

impl Index<usize> for Vec3 {
    type Output = f64;
    #[inline]
    fn index(&self, i: usize) -> &f64 {
        match i {
            0 => &self.x,
            1 => &self.y,
            2 => &self.z,
            _ => panic!("Vec3 index out of range: {i}"),
        }
    }
}

impl From<[f64; 3]> for Vec3 {
    fn from(a: [f64; 3]) -> Self {
        Vec3::new(a[0], a[1], a[2])
    }
}

impl From<[f32; 3]> for Vec3 {
    fn from(a: [f32; 3]) -> Self {
        Vec3::new(a[0] as f64, a[1] as f64, a[2] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::splat(3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(b / 2.0, Vec3::new(2.0, 2.5, 3.0));
        assert_eq!(-a, Vec3::new(-1.0, -2.0, -3.0));
    }

    #[test]
    fn dot_and_cross() {
        let x = Vec3::new(1.0, 0.0, 0.0);
        let y = Vec3::new(0.0, 1.0, 0.0);
        let z = Vec3::new(0.0, 0.0, 1.0);
        assert_eq!(x.dot(y), 0.0);
        assert_eq!(x.cross(y), z);
        assert_eq!(y.cross(z), x);
        assert_eq!(z.cross(x), y);
        // anti-commutativity
        assert_eq!(y.cross(x), -z);
    }

    #[test]
    fn norms_and_distances() {
        let v = Vec3::new(3.0, 4.0, 0.0);
        assert_eq!(v.norm(), 5.0);
        assert_eq!(v.norm_sq(), 25.0);
        let w = Vec3::new(0.0, 0.0, 12.0);
        assert_eq!(v.dist(w), 13.0);
        assert_eq!(v.dist_sq(w), 169.0);
    }

    #[test]
    fn normalized() {
        let v = Vec3::new(0.0, 3.0, 4.0).normalized().unwrap();
        assert!((v.norm() - 1.0).abs() < 1e-12);
        assert!(Vec3::ZERO.normalized().is_none());
    }

    #[test]
    fn min_max_scale_index() {
        let a = Vec3::new(1.0, 5.0, 3.0);
        let b = Vec3::new(2.0, 4.0, 3.0);
        assert_eq!(a.min(b), Vec3::new(1.0, 4.0, 3.0));
        assert_eq!(a.max(b), Vec3::new(2.0, 5.0, 3.0));
        assert_eq!(a.scale(b), Vec3::new(2.0, 20.0, 9.0));
        assert_eq!(a[0], 1.0);
        assert_eq!(a[1], 5.0);
        assert_eq!(a[2], 3.0);
    }

    #[test]
    fn conversions() {
        let v: Vec3 = [1.0f32, 2.0, 3.0].into();
        assert_eq!(v, Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(v.to_f32(), [1.0f32, 2.0, 3.0]);
    }
}
