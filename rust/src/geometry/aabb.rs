//! Axis-aligned bounding box over [`Vec3`] points.

use super::Vec3;

/// Axis-aligned bounding box. Used for ROI cropping (the pipeline crops each
/// mask to its bounding box before meshing, exactly as PyRadiomics does) and
/// as a cheap sanity invariant for meshes (all vertices inside the padded
/// voxel AABB).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Aabb {
    pub min: Vec3,
    pub max: Vec3,
}

impl Aabb {
    /// An "empty" box that any point will expand.
    pub fn empty() -> Self {
        Aabb {
            min: Vec3::splat(f64::INFINITY),
            max: Vec3::splat(f64::NEG_INFINITY),
        }
    }

    pub fn new(min: Vec3, max: Vec3) -> Self {
        Aabb { min, max }
    }

    /// Build the tight box over an iterator of points.
    pub fn from_points<I: IntoIterator<Item = Vec3>>(pts: I) -> Self {
        let mut b = Aabb::empty();
        for p in pts {
            b.expand(p);
        }
        b
    }

    /// Grow to include `p`.
    pub fn expand(&mut self, p: Vec3) {
        self.min = self.min.min(p);
        self.max = self.max.max(p);
    }

    /// Grow by `pad` on every side.
    pub fn padded(&self, pad: f64) -> Aabb {
        Aabb::new(self.min - Vec3::splat(pad), self.max + Vec3::splat(pad))
    }

    /// True when no point was ever added.
    pub fn is_empty(&self) -> bool {
        self.min.x > self.max.x
    }

    pub fn contains(&self, p: Vec3) -> bool {
        p.x >= self.min.x
            && p.y >= self.min.y
            && p.z >= self.min.z
            && p.x <= self.max.x
            && p.y <= self.max.y
            && p.z <= self.max.z
    }

    /// Edge lengths (zero for empty boxes).
    pub fn extent(&self) -> Vec3 {
        if self.is_empty() {
            Vec3::ZERO
        } else {
            self.max - self.min
        }
    }

    /// Length of the space diagonal — an upper bound for the max 3D diameter
    /// of any point set inside the box (used as a property-test invariant).
    pub fn diagonal(&self) -> f64 {
        self.extent().norm()
    }

    pub fn center(&self) -> Vec3 {
        (self.min + self.max) / 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_box() {
        let b = Aabb::empty();
        assert!(b.is_empty());
        assert_eq!(b.extent(), Vec3::ZERO);
        assert_eq!(b.diagonal(), 0.0);
    }

    #[test]
    fn from_points() {
        let b = Aabb::from_points([
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::new(-1.0, 5.0, 0.0),
            Vec3::new(0.5, 0.0, 10.0),
        ]);
        assert_eq!(b.min, Vec3::new(-1.0, 0.0, 0.0));
        assert_eq!(b.max, Vec3::new(1.0, 5.0, 10.0));
        assert!(b.contains(Vec3::new(0.0, 1.0, 1.0)));
        assert!(!b.contains(Vec3::new(2.0, 1.0, 1.0)));
    }

    #[test]
    fn padding_and_center() {
        let b = Aabb::new(Vec3::ZERO, Vec3::splat(2.0)).padded(1.0);
        assert_eq!(b.min, Vec3::splat(-1.0));
        assert_eq!(b.max, Vec3::splat(3.0));
        assert_eq!(b.center(), Vec3::splat(1.0));
    }

    #[test]
    fn diagonal_bounds_pairwise_distance() {
        let pts = [
            Vec3::new(0.0, 0.0, 0.0),
            Vec3::new(1.0, 2.0, 2.0),
            Vec3::new(0.5, 1.0, 0.0),
        ];
        let b = Aabb::from_points(pts);
        for p in pts {
            for q in pts {
                assert!(p.dist(q) <= b.diagonal() + 1e-12);
            }
        }
    }
}
