//! Triangle primitive with the two accumulations the paper fuses into its
//! marching-cubes kernel: signed tetrahedron volume and surface area.

use super::Vec3;

/// One oriented mesh triangle (vertices in world/mm coordinates).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Triangle {
    pub a: Vec3,
    pub b: Vec3,
    pub c: Vec3,
}

impl Triangle {
    pub fn new(a: Vec3, b: Vec3, c: Vec3) -> Self {
        Triangle { a, b, c }
    }

    /// Signed volume of the tetrahedron (origin, a, b, c):
    /// `det(a, b, c) / 6`. Summed over a closed, consistently-oriented mesh
    /// this yields the enclosed (mesh) volume — PyRadiomics' `MeshVolume`.
    #[inline]
    pub fn signed_volume(&self) -> f64 {
        self.a.dot(self.b.cross(self.c)) / 6.0
    }

    /// Triangle area: `|(b-a) × (c-a)| / 2` — summed this is `SurfaceArea`.
    #[inline]
    pub fn area(&self) -> f64 {
        (self.b - self.a).cross(self.c - self.a).norm() / 2.0
    }

    /// Centroid (used by the synthetic generator's sanity checks).
    #[inline]
    pub fn centroid(&self) -> Vec3 {
        (self.a + self.b + self.c) / 3.0
    }

    /// Flip orientation (swaps the sign of [`Self::signed_volume`]).
    pub fn flipped(&self) -> Triangle {
        Triangle::new(self.a, self.c, self.b)
    }

    /// Degenerate triangles (zero area) are what the AOT artifacts use as
    /// padding; they contribute nothing to either accumulator.
    pub fn is_degenerate(&self) -> bool {
        self.area() == 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_right_triangle() -> Triangle {
        Triangle::new(
            Vec3::new(0.0, 0.0, 1.0),
            Vec3::new(1.0, 0.0, 1.0),
            Vec3::new(0.0, 1.0, 1.0),
        )
    }

    #[test]
    fn area_of_unit_right_triangle() {
        assert!((unit_right_triangle().area() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn signed_volume_flips_with_orientation() {
        let t = unit_right_triangle();
        assert!((t.signed_volume() + t.flipped().signed_volume()).abs() < 1e-12);
    }

    #[test]
    fn closed_tetrahedron_volume() {
        // Regular tetrahedron on unit axes: volume = 1/6.
        let o = Vec3::ZERO;
        let x = Vec3::new(1.0, 0.0, 0.0);
        let y = Vec3::new(0.0, 1.0, 0.0);
        let z = Vec3::new(0.0, 0.0, 1.0);
        // Outward-oriented faces.
        let faces = [
            Triangle::new(o, y, x),
            Triangle::new(o, x, z),
            Triangle::new(o, z, y),
            Triangle::new(x, y, z),
        ];
        let vol: f64 = faces.iter().map(|t| t.signed_volume()).sum();
        assert!((vol.abs() - 1.0 / 6.0).abs() < 1e-12, "vol={vol}");
        let area: f64 = faces.iter().map(|t| t.area()).sum();
        // 3 right triangles of area 1/2 + equilateral side sqrt(2): sqrt(3)/2.
        let expect = 1.5 + (3.0f64).sqrt() / 2.0;
        assert!((area - expect).abs() < 1e-12);
    }

    #[test]
    fn degenerate_padding_contributes_nothing() {
        let t = Triangle::new(Vec3::ZERO, Vec3::ZERO, Vec3::ZERO);
        assert!(t.is_degenerate());
        assert_eq!(t.area(), 0.0);
        assert_eq!(t.signed_volume(), 0.0);
    }

    #[test]
    fn translation_invariance_of_closed_mesh_volume() {
        let o = Vec3::new(10.0, -4.0, 2.5);
        let x = o + Vec3::new(1.0, 0.0, 0.0);
        let y = o + Vec3::new(0.0, 1.0, 0.0);
        let z = o + Vec3::new(0.0, 0.0, 1.0);
        let faces = [
            Triangle::new(o, y, x),
            Triangle::new(o, x, z),
            Triangle::new(o, z, y),
            Triangle::new(x, y, z),
        ];
        let vol: f64 = faces.iter().map(|t| t.signed_volume()).sum();
        assert!((vol.abs() - 1.0 / 6.0).abs() < 1e-9, "vol={vol}");
    }
}
