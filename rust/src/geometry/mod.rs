//! Small 3D geometry substrate: vectors, bounding boxes, triangles and a
//! symmetric 3×3 eigensolver (needed for the PCA-based axis-length features).
//!
//! Everything here is dependency-free and heavily unit-tested: the shape
//! features in [`crate::features`] and the marching-cubes mesher in
//! [`crate::mc`] are built on top of these primitives.

mod vec3;
mod aabb;
mod triangle;
mod eigen;

pub use aabb::Aabb;
pub use eigen::{sym3_eigenvalues, Sym3};
pub use triangle::Triangle;
pub use vec3::Vec3;
