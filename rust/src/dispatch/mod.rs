//! The transparent dispatcher — the paper's §2 integration contribution.
//!
//! PyRadiomics-cuda swaps one call inside the C extension for a dispatcher
//! that probes for a CUDA device and falls back to the original CPU code.
//! Here the probe is: artifact manifest resolves **and** the PJRT engine
//! answers a warm-up request. The public entry point
//! [`FeatureExtractor::execute`] mirrors
//! `RadiomicsFeatureExtractor().execute(image, mask)` and returns the same
//! feature map regardless of the backend chosen — "no changes to existing
//! code" (§2), and tested to produce equal values on both paths.

use std::borrow::Cow;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{Backend, FeatureClasses, PipelineConfig};
use crate::features::texture::Discretization;
use crate::features::{
    brute_force_diameters, compute_first_order_with, compute_shape_features,
    compute_texture, FirstOrderFeatures, ShapeFeatures, TextureFeatures, TextureOptions,
};
use crate::geometry::Vec3;
use crate::imgproc::{for_each_derived_image, ImgprocOptions};
use crate::mc::{mesh_roi, planar_diameters_grouped};
use crate::parallel::{compute_diameters, Strategy};
use crate::runtime::{
    BatchConfig, BatchStatsSnapshot, Batcher, EngineHandle, EnginePool, ExecTiming,
};
use crate::volume::{crop_box, crop_to_roi, crop_to_roi_labels, LabelMask, MaskStats, VoxelGrid};

/// Seed for the synthetic stand-in intensities used when a case has no
/// image volume *and* the `synthetic_image` opt-in is set; fixed so the
/// stand-in features are reproducible run-to-run. Without the opt-in, a
/// case that enables intensity classes but supplies no image is an error —
/// never a silent substitution.
const SYNTH_IMAGE_SEED: u64 = 42;

/// Case grids after alignment (mask, optional image) — borrowed when no
/// resampling was needed, owned when a grid had to be rebuilt.
type PreparedGrids<'a> = (Cow<'a, VoxelGrid<u8>>, Option<Cow<'a, VoxelGrid<f32>>>);

/// Which path actually computed a result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathTaken {
    /// PJRT artifact executed on the engine.
    Accelerated,
    /// CPU fallback (requested or after probe/runtime failure).
    CpuFallback,
}

/// Per-phase timing breakdown of one case — the Table 2 row ingredients
/// plus the intensity-class phase. `preprocess` covers grid alignment
/// (resampling), ROI cropping and derived-image filtering (LoG /
/// wavelet); `texture` covers discretization, first-order and all five
/// texture matrix classes (GLCM, GLRLM, GLSZM, GLDM, NGTDM) over every
/// derived image.
#[derive(Debug, Clone, Copy, Default)]
pub struct CaseTiming {
    pub read: Duration,
    /// Image-volume read time (zero for mask-only / shape-only cases).
    pub read_image: Duration,
    pub preprocess: Duration,
    pub marching: Duration,
    pub transfer: Duration,
    pub diameters: Duration,
    pub texture: Duration,
    pub derive: Duration,
}

impl CaseTiming {
    /// Post-read computation total (the paper's "Comp." denominator base).
    pub fn compute_total(&self) -> Duration {
        self.preprocess
            + self.marching
            + self.transfer
            + self.diameters
            + self.texture
            + self.derive
    }

    pub fn total(&self) -> Duration {
        self.read + self.read_image + self.compute_total()
    }
}

/// The intensity-class features of one derived image (original / LoG /
/// wavelet sub-band).
#[derive(Debug, Clone, PartialEq)]
pub struct DerivedImageFeatures {
    /// Filter-qualified image prefix: `original`, `log-sigma-2-0-mm`,
    /// `wavelet-LLH`, …
    pub image: String,
    /// First-order features, when the class is enabled.
    pub first_order: Option<FirstOrderFeatures>,
    /// Texture features, when a texture class is enabled.
    pub texture: Option<TextureFeatures>,
}

impl DerivedImageFeatures {
    /// Every computed (name, value) pair of this derived image.
    ///
    /// The `original` image keeps the historical plain names (`Entropy`,
    /// `Glcm_Contrast`) so existing reports stay stable; every other
    /// derived image is qualified in PyRadiomics convention —
    /// `log-sigma-2-0-mm_firstorder_Mean`, `wavelet-LLH_glcm_Contrast`.
    pub fn named(&self) -> Vec<(String, f64)> {
        let qualify = self.image != "original";
        let mut out = Vec::new();
        if let Some(fo) = &self.first_order {
            for (name, value) in fo.named() {
                if qualify {
                    out.push((format!("{}_firstorder_{name}", self.image), value));
                } else {
                    out.push((name.to_string(), value));
                }
            }
        }
        if let Some(tex) = &self.texture {
            for (name, value) in tex.named() {
                if qualify {
                    // "Glcm_Contrast" → "<image>_glcm_Contrast"
                    let (class, feat) = name.split_once('_').unwrap_or(("texture", name));
                    out.push((
                        format!("{}_{}_{feat}", self.image, class.to_lowercase()),
                        value,
                    ));
                } else {
                    out.push((name.to_string(), value));
                }
            }
        }
        out
    }
}

/// One extraction result. `first_order`/`texture` mirror the *original*
/// image's entry in `derived` (when the `original` image type and the
/// corresponding class are enabled and the ROI is non-empty); `derived`
/// holds one entry per enabled derived image, in
/// [`crate::imgproc::derive_images`] order.
#[derive(Debug, Clone)]
pub struct Extraction {
    pub features: ShapeFeatures,
    pub first_order: Option<FirstOrderFeatures>,
    pub texture: Option<TextureFeatures>,
    pub derived: Vec<DerivedImageFeatures>,
    pub timing: CaseTiming,
    pub path: PathTaken,
}

/// The PyRadiomics-compatible extractor with the transparent dispatcher.
///
/// The accelerated side is an [`EnginePool`] (`cfg.engine_count` engine
/// threads, round-robin sharded) fronted by a [`Batcher`] that groups
/// concurrent diameter requests by pad-bucket (`cfg.batch_size`,
/// `cfg.batch_linger_ms`). With the defaults (1 engine, batch size 1) the
/// behaviour is identical to per-case dispatch.
pub struct FeatureExtractor {
    pool: Option<Arc<EnginePool>>,
    batcher: Option<Batcher>,
    backend: Backend,
    strategy: Strategy,
    cpu_threads: usize,
    classes: FeatureClasses,
    bin_width: f64,
    bin_count: usize,
    glcm_distances: Vec<usize>,
    gldm_alpha: f64,
    image_types: crate::imgproc::ImageTypes,
    log_sigmas: Vec<f64>,
    wavelet_levels: usize,
    resampled_spacing: f64,
    synthetic_image: bool,
}

impl FeatureExtractor {
    /// Build from config: probes the accelerator per the backend policy.
    ///
    /// * `Auto` — try to start the engine pool; on any failure fall back to
    ///   CPU silently (the paper's "gracefully falls back" behaviour; the
    ///   reason is logged to stderr).
    /// * `Accelerated` — engine start failures are hard errors.
    /// * `Cpu` — never probes.
    pub fn new(cfg: &PipelineConfig) -> Result<FeatureExtractor> {
        let pool = match cfg.backend {
            Backend::Cpu => None,
            Backend::Accelerated => Some(
                Self::probe(cfg)
                    .context("backend=accelerated but the accelerator probe failed")?,
            ),
            Backend::Auto => match Self::probe(cfg) {
                Ok(p) => Some(p),
                Err(err) => {
                    eprintln!(
                        "radpipe: accelerator unavailable ({err:#}); falling back to CPU"
                    );
                    None
                }
            },
        };
        let batcher = pool.as_ref().map(|p| {
            let backend: Arc<dyn crate::runtime::BatchBackend> = p.clone();
            Batcher::new(
                backend,
                BatchConfig {
                    batch_size: cfg.batch_size.max(1),
                    linger: Duration::from_millis(cfg.batch_linger_ms),
                },
            )
        });
        Ok(FeatureExtractor {
            pool,
            batcher,
            backend: cfg.backend,
            strategy: cfg.strategy,
            cpu_threads: cfg.cpu_threads,
            classes: cfg.feature_classes,
            bin_width: cfg.bin_width,
            bin_count: cfg.bin_count,
            glcm_distances: cfg.glcm_distances.clone(),
            gldm_alpha: cfg.gldm_alpha,
            image_types: cfg.image_types,
            log_sigmas: cfg.log_sigmas.clone(),
            wavelet_levels: cfg.wavelet_levels,
            resampled_spacing: cfg.resampled_spacing,
            synthetic_image: cfg.synthetic_image,
        })
    }

    fn probe(cfg: &PipelineConfig) -> Result<Arc<EnginePool>> {
        let pool = EnginePool::start(&cfg.artifact_dir, cfg.engine_count.max(1))?;
        // Touch every engine so PJRT init errors surface during the probe,
        // not mid-pipeline. A tiny request compiles the smallest bucket.
        pool.smoke_test().context("accelerator smoke test")?;
        Ok(Arc::new(pool))
    }

    /// True when the accelerated path is live.
    pub fn accelerated(&self) -> bool {
        self.pool.is_some()
    }

    pub fn engine_handle(&self) -> Option<EngineHandle> {
        self.pool.as_ref().map(|p| p.handle())
    }

    /// The engine pool, when the accelerated path is live.
    pub fn engine_pool(&self) -> Option<&EnginePool> {
        self.pool.as_deref()
    }

    /// Batching counters (None on the pure-CPU path).
    pub fn batch_stats(&self) -> Option<BatchStatsSnapshot> {
        self.batcher.as_ref().map(|b| b.stats())
    }

    /// Mask-only entry point: read the mask path, return the feature map
    /// (see `examples/quickstart.rs` for the 4-line usage). The volume
    /// format is detected from the extension (`.nii[.gz]`, `.rvol[.gz]`);
    /// unknown extensions are a clear error. Intensity classes need an
    /// image — use [`FeatureExtractor::execute_with_image`] — or the
    /// explicit `synthetic_image` opt-in.
    pub fn execute(&self, mask_path: &Path) -> Result<Extraction> {
        let t0 = Instant::now();
        let mask: VoxelGrid<u8> = crate::io::read_mask(mask_path)?;
        let read = t0.elapsed();
        let mut ex = self.execute_mask(&mask)?;
        ex.timing.read = read;
        Ok(ex)
    }

    /// PyRadiomics-style entry point over an (image, mask) pair of paths —
    /// `RadiomicsFeatureExtractor().execute(image, mask)`. The image is
    /// read with intensities preserved ([`crate::io::read_image`]) and
    /// auto-resampled onto the mask grid when the grids differ.
    pub fn execute_with_image(
        &self,
        image_path: &Path,
        mask_path: &Path,
    ) -> Result<Extraction> {
        let t0 = Instant::now();
        let mask: VoxelGrid<u8> = crate::io::read_mask(mask_path)?;
        let read = t0.elapsed();
        let t0 = Instant::now();
        let image: VoxelGrid<f32> = crate::io::read_image(image_path)?;
        let read_image = t0.elapsed();
        let mut ex = self.execute_case(&mask, Some(&image))?;
        ex.timing.read = read;
        ex.timing.read_image = read_image;
        Ok(ex)
    }

    /// Extraction over an in-memory mask (no image). Intensity classes
    /// require the `synthetic_image` opt-in on this path; without it the
    /// case fails with an error naming the remedies.
    pub fn execute_mask(&self, mask: &VoxelGrid<u8>) -> Result<Extraction> {
        self.execute_case(mask, None)
    }

    /// Align the case grids before extraction:
    ///
    /// * with `resampled_spacing > 0`, the mask is nearest-neighbour
    ///   resampled onto the isotropic target spacing;
    /// * an image whose grid (dims *or* spacing) differs from the mask
    ///   grid is trilinear-resampled onto it — a mismatch used to be a
    ///   hard error, but PyRadiomics-style datasets routinely ship scans
    ///   and segmentations on different grids. Degenerate inputs (empty
    ///   volumes, non-positive spacings) stay located errors.
    ///
    /// The image is dropped (not validated, not resampled) when no
    /// intensity feature class is enabled — shape-only runs must not pay
    /// an O(volume) resample whose result nothing reads.
    fn prepare_grids<'a>(
        &self,
        mask: &'a VoxelGrid<u8>,
        image: Option<&'a VoxelGrid<f32>>,
    ) -> Result<PreparedGrids<'a>> {
        let mut mask_c = Cow::Borrowed(mask);
        if self.resampled_spacing > 0.0 {
            let target = Vec3::splat(self.resampled_spacing);
            if mask.spacing != target {
                mask_c = Cow::Owned(
                    crate::imgproc::resample_mask(
                        mask,
                        target,
                        self.strategy,
                        self.cpu_threads,
                    )
                    .context("resample mask onto resampled_spacing")?,
                );
            }
        }
        let image_c = match image {
            None => None,
            Some(_) if !self.classes.needs_image() => None,
            Some(img) if img.dims == mask_c.dims && img.spacing == mask_c.spacing => {
                Some(Cow::Borrowed(img))
            }
            Some(img) => Some(Cow::Owned(
                crate::imgproc::resample_image_to_grid(
                    img,
                    mask_c.dims,
                    mask_c.spacing,
                    self.strategy,
                    self.cpu_threads,
                )
                .with_context(|| {
                    format!(
                        "auto-resample image (dims {}, spacing {:?}) onto the mask \
                         grid (dims {}, spacing {:?})",
                        img.dims, img.spacing, mask_c.dims, mask_c.spacing
                    )
                })?,
            )),
        };
        Ok((mask_c, image_c))
    }

    /// Extraction over a mask plus an optional intensity image. The image
    /// is only read when an intensity feature class (first-order or any
    /// texture matrix class) is enabled; an image on a different grid is
    /// automatically
    /// trilinear-resampled onto the mask grid (`prepare_grids`), and with
    /// `resampled_spacing > 0` the whole case moves to that isotropic
    /// grid first.
    pub fn execute_case(
        &self,
        mask: &VoxelGrid<u8>,
        image: Option<&VoxelGrid<f32>>,
    ) -> Result<Extraction> {
        let mut timing = CaseTiming::default();

        let t = Instant::now();
        let sp = crate::trace::span("stage.preprocess");
        let (mask_c, image_c) = self.prepare_grids(mask, image)?;
        let mask: &VoxelGrid<u8> = &mask_c;
        let (cropped, offset) = crop_to_roi(mask);
        let mask_stats = MaskStats::compute(&cropped);
        drop(sp);
        timing.preprocess = t.elapsed();

        let (features, path) = self.mesh_and_shape(&cropped, &mask_stats, &mut timing)?;

        let derived = if self.classes.needs_image() && mask_stats.count > 0 {
            // Stream one derived image at a time through feature
            // extraction: each volume is filtered, consumed and dropped
            // inside the visitor callback, so peak derived-image residency
            // stays at ~2 crop-sized volumes however many image types /
            // wavelet levels are configured. Filtering time (between
            // callbacks) is preprocessing; the callbacks themselves are
            // the texture phase.
            let t = Instant::now();
            let _sp = crate::trace::span("stage.derived");
            let cropped_image = match &image_c {
                Some(img) => crop_box(&**img, offset, cropped.dims),
                None if self.synthetic_image => {
                    crate::synth::synthesize_image(&cropped, SYNTH_IMAGE_SEED)
                }
                None => bail!(
                    "intensity feature classes are enabled but this case has no \
                     image volume; add `image=<path>` to its manifest entry (or \
                     pass one to execute_case), or explicitly opt in to the \
                     synthetic stand-in with --synthetic-image / \
                     `synthetic_image = true`"
                ),
            };
            let opts = self.imgproc_options();
            let mut derived = Vec::with_capacity(
                opts.image_types.image_count(opts.log_sigmas.len(), opts.wavelet_levels),
            );
            let mut feature_time = Duration::ZERO;
            for_each_derived_image(&cropped_image, &opts, |d| {
                let ft = Instant::now();
                let _sp = crate::trace::span_args(
                    "stage.texture",
                    &[("image", crate::trace::ArgV::Str(&d.name))],
                );
                let first_order = if self.classes.first_order {
                    compute_first_order_with(d.image, &cropped, self.discretization())
                } else {
                    None
                };
                let texture = if self.classes.texture() {
                    compute_texture(d.image, &cropped, &self.texture_options())
                        .with_context(|| format!("texture features of {}", d.name))?
                } else {
                    None
                };
                derived.push(DerivedImageFeatures { image: d.name, first_order, texture });
                feature_time += ft.elapsed();
                Ok(())
            })?;
            timing.preprocess += t.elapsed().saturating_sub(feature_time);
            timing.texture = feature_time;
            derived
        } else {
            Vec::new()
        };

        // legacy view: the original image's classes, when computed
        let (first_order, texture) = derived
            .iter()
            .find(|d| d.image == "original")
            .map(|d| (d.first_order.clone(), d.texture.clone()))
            .unwrap_or((None, None));

        Ok(Extraction { features, first_order, texture, derived, timing, path })
    }

    /// The derived-image knobs as an [`ImgprocOptions`] (single source of
    /// truth for the dispatcher and the benches).
    pub fn imgproc_options(&self) -> ImgprocOptions {
        ImgprocOptions {
            image_types: self.image_types,
            log_sigmas: self.log_sigmas.clone(),
            wavelet_levels: self.wavelet_levels,
            strategy: self.strategy,
            threads: self.cpu_threads,
        }
    }

    /// The configured gray-level binning — shared by first-order
    /// (Entropy/Uniformity histogram) and the texture matrices so one
    /// `bin_count`/`bin_width` knob governs every discretized feature.
    fn discretization(&self) -> Discretization {
        if self.bin_count > 0 {
            Discretization::BinCount(self.bin_count)
        } else {
            Discretization::BinWidth(self.bin_width)
        }
    }

    /// The texture knobs as a [`TextureOptions`] (single source of truth
    /// for the dispatcher and the pipeline feature stage).
    pub fn texture_options(&self) -> TextureOptions {
        TextureOptions {
            discretization: self.discretization(),
            distances: self.glcm_distances.clone(),
            gldm_alpha: self.gldm_alpha,
            strategy: self.strategy,
            threads: self.cpu_threads,
            glcm: self.classes.glcm,
            glrlm: self.classes.glrlm,
            glszm: self.classes.glszm,
            gldm: self.classes.gldm,
            ngtdm: self.classes.ngtdm,
        }
    }

    fn accelerated_diameters(
        &self,
        batcher: &Batcher,
        mesh: &crate::mc::Mesh,
    ) -> Result<(crate::features::Diameters, ExecTiming)> {
        if mesh.vertices.is_empty() {
            // nothing to offload; keep the artifact contract (non-empty)
            return Ok((crate::features::Diameters::EMPTY, ExecTiming::default()));
        }
        batcher.diameters(mesh.vertices_f32())
    }

    fn cpu_diameters(&self, mesh: &crate::mc::Mesh) -> crate::features::Diameters {
        // Single-thread strategy parity with PyRadiomics when threads == 1;
        // otherwise the configured optimised strategy.
        if self.cpu_threads == 1 {
            brute_force_diameters(&mesh.vertices)
        } else {
            let (mut d, _) = compute_diameters(self.strategy, &mesh.vertices, self.cpu_threads);
            // planar families via exact grouping (same semantics, cheaper)
            let planar = planar_diameters_grouped(&mesh.vertices);
            d.dxy_sq = d.dxy_sq.max(planar[0]);
            d.dyz_sq = d.dyz_sq.max(planar[1]);
            d.dxz_sq = d.dxz_sq.max(planar[2]);
            d
        }
    }

    /// The shape half of one extraction: marching cubes on the cropped
    /// ROI, diameters (accelerated with fallback per the backend policy),
    /// shape features. Fills `timing.marching/transfer/diameters/derive`.
    /// Shared by the binary-mask path and the per-label path so both
    /// produce bit-identical shape features.
    fn mesh_and_shape(
        &self,
        cropped: &VoxelGrid<u8>,
        mask_stats: &MaskStats,
        timing: &mut CaseTiming,
    ) -> Result<(ShapeFeatures, PathTaken)> {
        let t = Instant::now();
        let sp = crate::trace::span("stage.mesh");
        let mesh = mesh_roi(cropped);
        drop(sp);
        timing.marching = t.elapsed();

        let vertex_count = mesh.vertices.len();
        let sp = crate::trace::span_args(
            "stage.diameters",
            &[("verts", crate::trace::ArgV::Int(vertex_count as u64))],
        );
        let t_diam = Instant::now();
        let (diam, path) = if let Some(batcher) = &self.batcher {
            match self.accelerated_diameters(batcher, &mesh) {
                Ok((d, exec)) => {
                    timing.transfer = exec.transfer;
                    timing.diameters = exec.execute;
                    if exec.transfer > Duration::ZERO {
                        // engine-side upload time, surfaced on this case's
                        // timeline (the precise engine-thread placement is
                        // the engine.transfer span)
                        crate::trace::complete_span("stage.transfer", t_diam, exec.transfer, &[]);
                    }
                    (d, PathTaken::Accelerated)
                }
                Err(err) if self.backend == Backend::Auto => {
                    eprintln!("radpipe: accelerated diameters failed ({err:#}); CPU fallback");
                    let t = Instant::now();
                    let d = self.cpu_diameters(&mesh);
                    timing.diameters = t.elapsed();
                    (d, PathTaken::CpuFallback)
                }
                Err(err) => return Err(err),
            }
        } else {
            let t = Instant::now();
            let d = self.cpu_diameters(&mesh);
            timing.diameters = t.elapsed();
            (d, PathTaken::CpuFallback)
        };
        drop(sp);

        let t = Instant::now();
        let features =
            compute_shape_features(cropped, mask_stats, &mesh.stats, &diam, vertex_count);
        timing.derive = t.elapsed();
        Ok((features, path))
    }

    /// Per-label extraction from a label map: **one** shared
    /// read/resample/derive pass, N per-label feature extractions.
    ///
    /// Shared preparation — optional label-preserving resample, the union
    /// ROI crop over all labels, image alignment and one image crop to the
    /// union box, and (with a real image) the derived-image filtering —
    /// happens once per case. Its cost is attached to the **first
    /// successful label's** `preprocess` timing so whole-run stage totals
    /// stay truthful, and the `stage.preprocess` span is recorded once per
    /// case, not once per label.
    ///
    /// Each selected label then gets its own binary crop, mesh, diameters,
    /// shape and intensity features — bit-identical to extracting that
    /// label from its own binary mask for the `original` image type (the
    /// per-label crop boxes nest inside the union crop; see
    /// `crate::volume::crop_to_roi_labels`). LoG/wavelet images are
    /// filtered on the union crop, so their border values can differ from
    /// a standalone per-label run — documented in the README.
    ///
    /// Per-label failures (a selected label absent from the mask, a
    /// texture error) are isolated: that label's slot carries the error,
    /// the other labels complete. A whole-case failure (resample error,
    /// missing image without the synthetic opt-in) is the outer `Err`.
    pub fn execute_label_map(
        &self,
        case_id: &str,
        mask: &LabelMask,
        image: Option<&VoxelGrid<f32>>,
        labels: &[u16],
    ) -> Result<Vec<(u16, Result<Extraction>)>> {
        let t_shared = Instant::now();
        let sp = crate::trace::span("stage.preprocess");
        let mut grid_c: Cow<VoxelGrid<u16>> = Cow::Borrowed(&mask.grid);
        if self.resampled_spacing > 0.0 {
            let target = Vec3::splat(self.resampled_spacing);
            if mask.grid.spacing != target {
                grid_c = Cow::Owned(
                    crate::imgproc::resample_labels(
                        &mask.grid,
                        target,
                        self.strategy,
                        self.cpu_threads,
                    )
                    .context("resample label mask onto resampled_spacing")?,
                );
            }
        }
        let (ucrop, uoff) = crop_to_roi_labels(&grid_c);
        // Image alignment mirrors prepare_grids: the resampled label grid
        // has the same dims/spacing a resampled binary mask would have
        // (identical nearest-neighbour index math), so a standalone binary
        // run resamples the image onto the very same grid.
        let image_c: Option<Cow<VoxelGrid<f32>>> = match image {
            None => None,
            Some(_) if !self.classes.needs_image() => None,
            Some(img) if img.dims == grid_c.dims && img.spacing == grid_c.spacing => {
                Some(Cow::Borrowed(img))
            }
            Some(img) => Some(Cow::Owned(
                crate::imgproc::resample_image_to_grid(
                    img,
                    grid_c.dims,
                    grid_c.spacing,
                    self.strategy,
                    self.cpu_threads,
                )
                .with_context(|| {
                    format!(
                        "auto-resample image (dims {}, spacing {:?}) onto the mask \
                         grid (dims {}, spacing {:?})",
                        img.dims, img.spacing, grid_c.dims, grid_c.spacing
                    )
                })?,
            )),
        };
        let uimage = image_c.as_ref().map(|img| crop_box(&**img, uoff, ucrop.dims));
        drop(sp);
        let mut shared_preprocess = t_shared.elapsed();

        if self.classes.needs_image() && image.is_none() && !self.synthetic_image {
            bail!(
                "case {case_id}: intensity feature classes are enabled but this case \
                 has no image volume; add `image=<path>` to its manifest entry, or \
                 explicitly opt in to the synthetic stand-in with --synthetic-image / \
                 `synthetic_image = true`"
            );
        }

        // Per-label shape pass: binary crop, mesh, diameters, shape.
        struct LabelWork {
            label: u16,
            cropped: VoxelGrid<u8>,
            off_local: (usize, usize, usize),
            features: ShapeFeatures,
            timing: CaseTiming,
            path: PathTaken,
            derived: Vec<DerivedImageFeatures>,
            error: Option<anyhow::Error>,
        }
        let mut works: Vec<(u16, Result<LabelWork>)> = Vec::with_capacity(labels.len());
        for &label in labels {
            let work = (|| -> Result<LabelWork> {
                let t = Instant::now();
                let binary = ucrop.map(|v| u8::from(v == label));
                let (cropped, off_local) = crop_to_roi(&binary);
                let mask_stats = MaskStats::compute(&cropped);
                if mask_stats.count == 0 {
                    bail!(
                        "case {case_id} label {label}: the mask has no voxels with \
                         this label (selected via --labels / the manifest inventory)"
                    );
                }
                let mut timing = CaseTiming {
                    preprocess: t.elapsed(),
                    ..CaseTiming::default()
                };
                let (features, path) =
                    self.mesh_and_shape(&cropped, &mask_stats, &mut timing)?;
                Ok(LabelWork {
                    label,
                    cropped,
                    off_local,
                    features,
                    timing,
                    path,
                    derived: Vec::new(),
                    error: None,
                })
            })();
            works.push((label, work));
        }

        // Intensity pass. With a real image the derived images are
        // filtered ONCE on the union crop and every label extracts from
        // its own sub-crop inside the visitor callback; the synthetic
        // stand-in is a function of each label's own crop, so nothing can
        // be shared there and each label derives its own images.
        if self.classes.needs_image() {
            if let Some(uimg) = &uimage {
                let t = Instant::now();
                let _sp = crate::trace::span("stage.derived");
                let opts = self.imgproc_options();
                let mut feature_time = Duration::ZERO;
                for_each_derived_image(uimg, &opts, |d| {
                    for w in works.iter_mut().filter_map(|(_, r)| r.as_mut().ok()) {
                        if w.error.is_some() {
                            continue;
                        }
                        let ft = Instant::now();
                        let _sp = crate::trace::span_args(
                            "stage.texture",
                            &[("image", crate::trace::ArgV::Str(&d.name))],
                        );
                        let img_k = crop_box(d.image, w.off_local, w.cropped.dims);
                        let first_order = if self.classes.first_order {
                            compute_first_order_with(&img_k, &w.cropped, self.discretization())
                        } else {
                            None
                        };
                        let texture = if self.classes.texture() {
                            match compute_texture(&img_k, &w.cropped, &self.texture_options()) {
                                Ok(tx) => tx,
                                Err(e) => {
                                    w.error = Some(e.context(format!(
                                        "case {case_id} label {}: texture features of {}",
                                        w.label, d.name
                                    )));
                                    let dt = ft.elapsed();
                                    w.timing.texture += dt;
                                    feature_time += dt;
                                    continue;
                                }
                            }
                        } else {
                            None
                        };
                        w.derived.push(DerivedImageFeatures {
                            image: d.name.clone(),
                            first_order,
                            texture,
                        });
                        let dt = ft.elapsed();
                        w.timing.texture += dt;
                        feature_time += dt;
                    }
                    Ok(())
                })?;
                shared_preprocess += t.elapsed().saturating_sub(feature_time);
            } else if self.synthetic_image {
                for w in works.iter_mut().filter_map(|(_, r)| r.as_mut().ok()) {
                    let t = Instant::now();
                    let _sp = crate::trace::span("stage.derived");
                    let img = crate::synth::synthesize_image(&w.cropped, SYNTH_IMAGE_SEED);
                    let opts = self.imgproc_options();
                    let mut feature_time = Duration::ZERO;
                    let label = w.label;
                    let res = for_each_derived_image(&img, &opts, |d| {
                        let ft = Instant::now();
                        let _sp = crate::trace::span_args(
                            "stage.texture",
                            &[("image", crate::trace::ArgV::Str(&d.name))],
                        );
                        let first_order = if self.classes.first_order {
                            compute_first_order_with(d.image, &w.cropped, self.discretization())
                        } else {
                            None
                        };
                        let texture = if self.classes.texture() {
                            compute_texture(d.image, &w.cropped, &self.texture_options())
                                .with_context(|| {
                                    format!(
                                        "case {case_id} label {label}: texture features \
                                         of {}",
                                        d.name
                                    )
                                })?
                        } else {
                            None
                        };
                        w.derived.push(DerivedImageFeatures {
                            image: d.name,
                            first_order,
                            texture,
                        });
                        feature_time += ft.elapsed();
                        Ok(())
                    });
                    w.timing.texture += feature_time;
                    w.timing.preprocess += t.elapsed().saturating_sub(feature_time);
                    if let Err(e) = res {
                        w.error = Some(e);
                    }
                }
            }
        }

        // Assemble: shared prep time rides on the first successful label.
        let mut shared_left = Some(shared_preprocess);
        let mut out = Vec::with_capacity(works.len());
        for (label, work) in works {
            match work {
                Err(e) => out.push((label, Err(e))),
                Ok(w) => {
                    if let Some(e) = w.error {
                        out.push((label, Err(e)));
                        continue;
                    }
                    let mut timing = w.timing;
                    if let Some(shared) = shared_left.take() {
                        timing.preprocess += shared;
                    }
                    let (first_order, texture) = w
                        .derived
                        .iter()
                        .find(|d| d.image == "original")
                        .map(|d| (d.first_order.clone(), d.texture.clone()))
                        .unwrap_or((None, None));
                    out.push((
                        label,
                        Ok(Extraction {
                            features: w.features,
                            first_order,
                            texture,
                            derived: w.derived,
                            timing,
                            path: w.path,
                        }),
                    ));
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn sphere_mask(n: usize, r: f64) -> VoxelGrid<u8> {
        let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::new(0.8, 0.8, 2.0));
        let c = n as f64 / 2.0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                    if dx * dx + dy * dy + dz * dz <= r * r {
                        m.set(x, y, z, 1);
                    }
                }
            }
        }
        m
    }

    fn cpu_extractor() -> FeatureExtractor {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 1,
            ..Default::default()
        };
        FeatureExtractor::new(&cfg).unwrap()
    }

    #[test]
    fn cpu_backend_never_probes() {
        let ex = cpu_extractor();
        assert!(!ex.accelerated());
    }

    #[test]
    fn cpu_extraction_works_end_to_end() {
        let ex = cpu_extractor();
        let out = ex.execute_mask(&sphere_mask(16, 5.0)).unwrap();
        assert_eq!(out.path, PathTaken::CpuFallback);
        assert!(out.features.mesh_volume > 0.0);
        assert!(out.features.maximum_3d_diameter > 0.0);
        assert!(out.timing.marching > Duration::ZERO);
    }

    #[test]
    fn auto_with_bogus_artifacts_falls_back() {
        let cfg = PipelineConfig {
            backend: Backend::Auto,
            artifact_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
            cpu_threads: 1,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        assert!(!ex.accelerated(), "probe must fail on a missing manifest");
        let out = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert_eq!(out.path, PathTaken::CpuFallback);
    }

    #[test]
    fn accelerated_with_bogus_artifacts_errors() {
        let cfg = PipelineConfig {
            backend: Backend::Accelerated,
            artifact_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
            ..Default::default()
        };
        assert!(FeatureExtractor::new(&cfg).is_err());
    }

    #[test]
    fn cpu_strategy_path_matches_brute_force() {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 2,
            strategy: Strategy::BlockReduction,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let brute = cpu_extractor();
        let mask = sphere_mask(14, 4.5);
        let a = ex.execute_mask(&mask).unwrap();
        let b = brute.execute_mask(&mask).unwrap();
        assert_eq!(a.features.maximum_3d_diameter, b.features.maximum_3d_diameter);
        assert_eq!(a.features.maximum_2d_diameter_slice, b.features.maximum_2d_diameter_slice);
    }

    #[test]
    fn empty_mask_is_graceful() {
        let ex = cpu_extractor();
        let m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let out = ex.execute_mask(&m).unwrap();
        assert_eq!(out.features.voxel_count, 0);
        assert!(out.features.maximum_3d_diameter.is_nan());
    }

    #[test]
    fn execute_rejects_unknown_mask_extension() {
        let dir = std::env::temp_dir().join("radpipe_dispatch_fmt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mask.dat");
        std::fs::write(&path, b"whatever").unwrap();
        let ex = cpu_extractor();
        let err = ex.execute(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("unrecognised volume format"),
            "{err:#}"
        );
    }

    #[test]
    fn execute_reads_both_containers_via_detection() {
        use crate::io::{write_nifti, write_rvol};
        let dir = std::env::temp_dir().join("radpipe_dispatch_fmt2");
        std::fs::create_dir_all(&dir).unwrap();
        let mask = sphere_mask(12, 4.0);
        let p_rvol = dir.join("m.rvol.gz");
        let p_nii = dir.join("m.nii.gz");
        write_rvol(&p_rvol, &mask).unwrap();
        write_nifti(&p_nii, &mask).unwrap();
        let ex = cpu_extractor();
        let a = ex.execute(&p_rvol).unwrap();
        let b = ex.execute(&p_nii).unwrap();
        assert_eq!(a.features.voxel_count, b.features.voxel_count);
    }

    fn all_classes_cfg(cpu_threads: usize) -> PipelineConfig {
        PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads,
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            // these tests drive execute_mask without image volumes
            synthetic_image: true,
            ..Default::default()
        }
    }

    #[test]
    fn missing_image_without_the_optin_is_a_located_error() {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 1,
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            ..Default::default()
        };
        assert!(!cfg.synthetic_image, "opt-in must default off");
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let err = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("image="), "must name the manifest remedy: {msg}");
        assert!(msg.contains("--synthetic-image"), "must name the opt-in: {msg}");
        // an explicit image satisfies the requirement without the opt-in
        let mask = sphere_mask(12, 4.0);
        let img: VoxelGrid<f32> = VoxelGrid::zeros(mask.dims, mask.spacing);
        assert!(ex.execute_case(&mask, Some(&img)).is_ok());
        // shape-only configs never need an image at all
        let out = cpu_extractor().execute_mask(&mask).unwrap();
        assert!(out.first_order.is_none());
    }

    #[test]
    fn intensity_classes_ride_along_when_enabled() {
        let ex = FeatureExtractor::new(&all_classes_cfg(1)).unwrap();
        let out = ex.execute_mask(&sphere_mask(14, 5.0)).unwrap();
        let fo = out.first_order.expect("first-order enabled");
        assert!(fo.variance >= 0.0);
        let tex = out.texture.expect("texture enabled");
        assert_eq!(
            tex.named().len(),
            47,
            "9 GLCM + 11 GLRLM + 12 GLSZM + 10 GLDM + 5 NGTDM"
        );
        assert!(tex.named().iter().all(|(_, v)| v.is_finite()));
        assert!(out.timing.texture > Duration::ZERO);
        // shape path is untouched by the extra classes
        let plain = cpu_extractor().execute_mask(&sphere_mask(14, 5.0)).unwrap();
        assert_eq!(out.features.mesh_volume, plain.features.mesh_volume);
    }

    #[test]
    fn default_config_skips_intensity_classes() {
        let out = cpu_extractor().execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert!(out.first_order.is_none());
        assert!(out.texture.is_none());
        assert_eq!(out.timing.texture, Duration::ZERO);
    }

    #[test]
    fn texture_is_identical_for_one_and_many_threads() {
        let mask = sphere_mask(16, 5.5);
        let a = FeatureExtractor::new(&all_classes_cfg(1))
            .unwrap()
            .execute_mask(&mask)
            .unwrap();
        let b = FeatureExtractor::new(&all_classes_cfg(4))
            .unwrap()
            .execute_mask(&mask)
            .unwrap();
        assert_eq!(a.texture, b.texture, "bit-for-bit across thread counts");
        assert_eq!(a.first_order, b.first_order);
    }

    #[test]
    fn explicit_image_is_used_and_checked() {
        let mask = sphere_mask(12, 4.0);
        let mut img: VoxelGrid<f32> = VoxelGrid::zeros(mask.dims, mask.spacing);
        for z in 0..12 {
            for y in 0..12 {
                for x in 0..12 {
                    img.set(x, y, z, ((x + y + z) % 7) as f32 * 10.0);
                }
            }
        }
        let ex = FeatureExtractor::new(&all_classes_cfg(1)).unwrap();
        let with_img = ex.execute_case(&mask, Some(&img)).unwrap();
        let synth = ex.execute_case(&mask, None).unwrap();
        assert!(with_img.first_order.is_some());
        assert_ne!(
            with_img.first_order, synth.first_order,
            "explicit image must actually be read"
        );
        // a degenerate image is a clear located error, not a panic
        let empty: VoxelGrid<f32> = VoxelGrid::zeros(Dims::new(0, 3, 3), Vec3::splat(1.0));
        let err = ex.execute_case(&mask, Some(&empty)).unwrap_err();
        assert!(format!("{err:#}").contains("resample"), "{err:#}");
        let bad_spacing: VoxelGrid<f32> = VoxelGrid::zeros(mask.dims, Vec3::splat(0.0));
        let err = ex.execute_case(&mask, Some(&bad_spacing)).unwrap_err();
        assert!(format!("{err:#}").contains("spacing"), "{err:#}");
    }

    #[test]
    fn shape_only_runs_never_touch_the_image() {
        // no intensity class enabled → the image must be dropped before
        // any validation/resampling (shape-only runs pay nothing for it)
        let ex = cpu_extractor();
        let mask = sphere_mask(12, 4.0);
        let bogus: VoxelGrid<f32> = VoxelGrid::zeros(Dims::new(2, 2, 2), Vec3::splat(0.0));
        let out = ex.execute_case(&mask, Some(&bogus)).unwrap();
        assert!(out.first_order.is_none());
        assert!(out.derived.is_empty());
    }

    #[test]
    fn mismatched_image_grid_is_auto_resampled_onto_the_mask() {
        // mask spacing (0.8, 0.8, 2.0); build the image on a 1 mm grid
        // covering the same physical extent — used to be a hard error
        let mask = sphere_mask(12, 4.0);
        let idims = Dims::new(10, 10, 23);
        let mut img: VoxelGrid<f32> = VoxelGrid::zeros(idims, Vec3::splat(1.0));
        for z in 0..idims.z {
            for y in 0..idims.y {
                for x in 0..idims.x {
                    // linear-in-mm field: trilinear resampling is exact
                    img.set(x, y, z, (2 * x + 3 * y + z) as f32);
                }
            }
        }
        let ex = FeatureExtractor::new(&all_classes_cfg(1)).unwrap();
        let out = ex.execute_case(&mask, Some(&img)).unwrap();
        let fo = out.first_order.expect("auto-resampled image feeds first-order");
        // the same linear field sampled natively on the mask grid
        let mut native: VoxelGrid<f32> = VoxelGrid::zeros(mask.dims, mask.spacing);
        for z in 0..mask.dims.z {
            for y in 0..mask.dims.y {
                for x in 0..mask.dims.x {
                    let p = native.world(x, y, z);
                    native.set(x, y, z, (2.0 * p.x + 3.0 * p.y + p.z) as f32);
                }
            }
        }
        let want = ex.execute_case(&mask, Some(&native)).unwrap();
        let want_fo = want.first_order.unwrap();
        assert!(
            (fo.mean - want_fo.mean).abs() < 1e-3,
            "{} vs {}",
            fo.mean,
            want_fo.mean
        );
        // identical grids are passed through bit-for-bit (no resample)
        let same = ex.execute_case(&mask, Some(&native)).unwrap();
        assert_eq!(same.first_order, want.first_order);
    }

    #[test]
    fn resampled_spacing_reshapes_the_case_grid() {
        let mask = sphere_mask(16, 5.0); // spacing (0.8, 0.8, 2.0)
        let cfg = PipelineConfig {
            resampled_spacing: 1.0,
            ..all_classes_cfg(1)
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let out = ex.execute_mask(&mask).unwrap();
        assert!(out.features.voxel_count > 0);
        // voxel volume on the isotropic grid is 1 mm³, so VoxelVolume ≈
        // count × 1 and total volume stays within resampling error
        let native = FeatureExtractor::new(&all_classes_cfg(1))
            .unwrap()
            .execute_mask(&mask)
            .unwrap();
        let rel = (out.features.voxel_volume - native.features.voxel_volume).abs()
            / native.features.voxel_volume;
        assert!(rel < 0.25, "resampled volume off by {rel}");
        assert!(out.first_order.is_some());
    }

    #[test]
    fn derived_images_multiply_the_feature_vector() {
        let mask = sphere_mask(14, 5.0);
        let cfg = PipelineConfig {
            image_types: crate::imgproc::ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.0, 2.0],
            ..all_classes_cfg(1)
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let out = ex.execute_mask(&mask).unwrap();
        assert_eq!(out.derived.len(), 11, "original + 2 LoG + 8 wavelet");
        assert_eq!(out.derived[0].image, "original");
        assert_eq!(out.first_order, out.derived[0].first_order, "legacy view");
        for d in &out.derived {
            assert!(d.first_order.is_some(), "{}", d.image);
            assert!(d.texture.is_some(), "{}", d.image);
        }
        // qualified names follow the PyRadiomics convention
        let names: Vec<String> =
            out.derived.iter().flat_map(|d| d.named()).map(|(n, _)| n).collect();
        assert!(names.iter().any(|n| n == "Entropy"), "original keeps plain names");
        assert!(names.iter().any(|n| n == "log-sigma-1-0-mm_firstorder_Mean"));
        assert!(names.iter().any(|n| n == "log-sigma-2-0-mm_glcm_Contrast"));
        assert!(names.iter().any(|n| n == "wavelet-HHH_glrlm_RunPercentage"));
        assert!(names.iter().any(|n| n == "wavelet-LLH_glszm_ZoneEntropy"));
        assert!(names.iter().any(|n| n == "log-sigma-1-0-mm_gldm_DependenceEntropy"));
        assert!(names.iter().any(|n| n == "wavelet-HLL_ngtdm_Coarseness"));
        assert!(out.timing.preprocess > Duration::ZERO);
    }

    #[test]
    fn derived_features_are_thread_and_strategy_invariant() {
        let mask = sphere_mask(12, 4.0);
        let mk = |threads: usize, strategy: Strategy| {
            let cfg = PipelineConfig {
                image_types: crate::imgproc::ImageTypes::parse("all").unwrap(),
                log_sigmas: vec![1.5],
                strategy,
                ..all_classes_cfg(threads)
            };
            FeatureExtractor::new(&cfg).unwrap().execute_mask(&mask).unwrap().derived
        };
        let want = mk(1, Strategy::EqualSplit);
        assert_eq!(want.len(), 10);
        for strategy in Strategy::ALL {
            let got = mk(4, strategy);
            assert_eq!(got, want, "{strategy:?}");
        }
    }

    #[test]
    fn log_only_legacy_mirrors_are_empty_not_aliased() {
        // image_types = "log": there is no `original` entry, so the legacy
        // first_order/texture mirrors must be None — selecting entry 0
        // would silently alias a LoG image
        let cfg = PipelineConfig {
            image_types: crate::imgproc::ImageTypes::parse("log").unwrap(),
            log_sigmas: vec![1.0],
            ..all_classes_cfg(1)
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let out = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert_eq!(out.derived.len(), 1);
        assert_eq!(out.derived[0].image, "log-sigma-1-0-mm");
        assert!(out.derived[0].first_order.is_some());
        assert!(out.first_order.is_none(), "mirror must not alias a LoG image");
        assert!(out.texture.is_none());
    }

    #[test]
    fn wavelet_only_legacy_mirrors_are_empty_not_aliased() {
        let cfg = PipelineConfig {
            image_types: crate::imgproc::ImageTypes::parse("wavelet").unwrap(),
            ..all_classes_cfg(1)
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let out = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert_eq!(out.derived.len(), 8);
        assert_eq!(out.derived[0].image, "wavelet-LLL");
        assert!(out.derived.iter().all(|d| d.texture.is_some()));
        assert!(out.first_order.is_none(), "mirror must not alias wavelet-LLL");
        assert!(out.texture.is_none());
    }

    #[test]
    fn streaming_extraction_matches_the_materialised_flow() {
        // the streamed per-image features must equal recomputing them from
        // the collect-based derive_images bank (names and bits)
        use crate::imgproc::derive_images;
        let mask = sphere_mask(12, 4.0);
        let cfg = PipelineConfig {
            image_types: crate::imgproc::ImageTypes::parse("all").unwrap(),
            log_sigmas: vec![1.5],
            wavelet_levels: 2,
            ..all_classes_cfg(1)
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let out = ex.execute_mask(&mask).unwrap();
        assert_eq!(out.derived.len(), 18, "original + 1 LoG + 16 wavelet");

        let (cropped, _) = crate::volume::crop_to_roi(&mask);
        let img = crate::synth::synthesize_image(&cropped, SYNTH_IMAGE_SEED);
        let bank = derive_images(&img, &ex.imgproc_options()).unwrap();
        assert_eq!(bank.len(), out.derived.len());
        for (got, d) in out.derived.iter().zip(&bank) {
            assert_eq!(got.image, d.name);
            let fo = compute_first_order_with(&d.image, &cropped, ex.discretization());
            assert_eq!(got.first_order, fo, "{}", d.name);
            let tex = compute_texture(&d.image, &cropped, &ex.texture_options()).unwrap();
            assert_eq!(got.texture, tex, "{}", d.name);
        }
    }

    #[test]
    fn empty_mask_has_no_intensity_features() {
        let ex = FeatureExtractor::new(&all_classes_cfg(1)).unwrap();
        let m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let out = ex.execute_mask(&m).unwrap();
        assert!(out.first_order.is_none());
        assert!(out.texture.is_none());
    }

    #[test]
    fn batching_knobs_fall_back_with_auto_backend() {
        // engine_count / batch_size plumbing must not disturb the graceful
        // CPU fallback when no artifacts exist.
        let cfg = PipelineConfig {
            backend: Backend::Auto,
            artifact_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
            cpu_threads: 1,
            engine_count: 4,
            batch_size: 8,
            batch_linger_ms: 1,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        assert!(!ex.accelerated());
        assert!(ex.batch_stats().is_none(), "no batcher on the CPU path");
        let out = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert_eq!(out.path, PathTaken::CpuFallback);
    }

    /// Two disjoint blobs with different label ids in one 16³ grid.
    fn two_blob_labels() -> LabelMask {
        let mut g: VoxelGrid<u16> = VoxelGrid::zeros(Dims::new(16, 14, 12), Vec3::new(0.8, 0.8, 2.0));
        for z in 1..5 {
            for y in 2..7 {
                for x in 1..6 {
                    g.set(x, y, z, 1);
                }
            }
        }
        for z in 6..11 {
            for y in 7..13 {
                for x in 9..15 {
                    g.set(x, y, z, 3);
                }
            }
        }
        LabelMask::from_grid(g)
    }

    #[test]
    fn label_map_matches_per_label_binary_runs() {
        let lm = two_blob_labels();
        assert_eq!(lm.labels, vec![1, 3]);
        let ex = FeatureExtractor::new(&all_classes_cfg(1)).unwrap();
        let per_label = ex.execute_label_map("case-a", &lm, None, &[1, 3]).unwrap();
        assert_eq!(per_label.len(), 2);
        for (label, got) in per_label {
            let got = got.unwrap();
            let standalone = ex.execute_mask(&lm.binary(label)).unwrap();
            assert_eq!(got.features, standalone.features, "label {label} shape");
            assert_eq!(got.derived, standalone.derived, "label {label} intensity");
        }
    }

    #[test]
    fn label_map_with_real_image_matches_binary_runs() {
        let lm = two_blob_labels();
        let mut img: VoxelGrid<f32> = VoxelGrid::zeros(lm.grid.dims, lm.grid.spacing);
        let d = img.dims;
        for z in 0..d.z {
            for y in 0..d.y {
                for x in 0..d.x {
                    img.set(x, y, z, (x * 7 + y * 3 + z * 11) as f32 * 0.5 - 20.0);
                }
            }
        }
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 1,
            feature_classes: crate::config::FeatureClasses::parse("all").unwrap(),
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let per_label = ex.execute_label_map("case-b", &lm, Some(&img), &[1, 3]).unwrap();
        for (label, got) in per_label {
            let got = got.unwrap();
            let standalone = ex.execute_case(&lm.binary(label), Some(&img)).unwrap();
            assert_eq!(got.features, standalone.features, "label {label} shape");
            assert_eq!(got.derived, standalone.derived, "label {label} intensity");
        }
    }

    #[test]
    fn empty_selected_label_is_isolated_not_fatal() {
        let lm = two_blob_labels();
        let ex = FeatureExtractor::new(&all_classes_cfg(1)).unwrap();
        let out = ex.execute_label_map("case-c", &lm, None, &[1, 2, 3]).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out[0].1.is_ok(), "label 1 present");
        assert!(out[2].1.is_ok(), "label 3 present");
        let err = out[1].1.as_ref().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("case-c"), "carries the case id: {msg}");
        assert!(msg.contains("label 2"), "carries the label: {msg}");
        assert!(msg.contains("no voxels"), "{msg}");
    }

    #[test]
    fn shared_preprocess_rides_on_the_first_successful_label() {
        let lm = two_blob_labels();
        let ex = cpu_extractor();
        let out = ex.execute_label_map("case-d", &lm, None, &[1, 3]).unwrap();
        let t1 = &out[0].1.as_ref().unwrap().timing;
        assert!(t1.preprocess > Duration::ZERO);
        assert!(out[1].1.as_ref().unwrap().timing.marching > Duration::ZERO);
    }
}
