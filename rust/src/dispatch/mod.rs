//! The transparent dispatcher — the paper's §2 integration contribution.
//!
//! PyRadiomics-cuda swaps one call inside the C extension for a dispatcher
//! that probes for a CUDA device and falls back to the original CPU code.
//! Here the probe is: artifact manifest resolves **and** the PJRT engine
//! answers a warm-up request. The public entry point
//! [`FeatureExtractor::execute`] mirrors
//! `RadiomicsFeatureExtractor().execute(image, mask)` and returns the same
//! feature map regardless of the backend chosen — "no changes to existing
//! code" (§2), and tested to produce equal values on both paths.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{Backend, PipelineConfig};
use crate::features::{brute_force_diameters, compute_shape_features, ShapeFeatures};
use crate::mc::{mesh_roi, planar_diameters_grouped};
use crate::parallel::{compute_diameters, Strategy};
use crate::runtime::{
    BatchConfig, BatchStatsSnapshot, Batcher, EngineHandle, EnginePool, ExecTiming,
};
use crate::volume::{crop_to_roi, MaskStats, VoxelGrid};

/// Which path actually computed a result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathTaken {
    /// PJRT artifact executed on the engine.
    Accelerated,
    /// CPU fallback (requested or after probe/runtime failure).
    CpuFallback,
}

/// Per-phase timing breakdown of one case — the Table 2 row ingredients.
#[derive(Debug, Clone, Copy, Default)]
pub struct CaseTiming {
    pub read: Duration,
    pub preprocess: Duration,
    pub marching: Duration,
    pub transfer: Duration,
    pub diameters: Duration,
    pub derive: Duration,
}

impl CaseTiming {
    /// Post-read computation total (the paper's "Comp." denominator base).
    pub fn compute_total(&self) -> Duration {
        self.preprocess + self.marching + self.transfer + self.diameters + self.derive
    }

    pub fn total(&self) -> Duration {
        self.read + self.compute_total()
    }
}

/// One extraction result.
#[derive(Debug, Clone)]
pub struct Extraction {
    pub features: ShapeFeatures,
    pub timing: CaseTiming,
    pub path: PathTaken,
}

/// The PyRadiomics-compatible extractor with the transparent dispatcher.
///
/// The accelerated side is an [`EnginePool`] (`cfg.engine_count` engine
/// threads, round-robin sharded) fronted by a [`Batcher`] that groups
/// concurrent diameter requests by pad-bucket (`cfg.batch_size`,
/// `cfg.batch_linger_ms`). With the defaults (1 engine, batch size 1) the
/// behaviour is identical to per-case dispatch.
pub struct FeatureExtractor {
    pool: Option<Arc<EnginePool>>,
    batcher: Option<Batcher>,
    backend: Backend,
    strategy: Strategy,
    cpu_threads: usize,
}

impl FeatureExtractor {
    /// Build from config: probes the accelerator per the backend policy.
    ///
    /// * `Auto` — try to start the engine pool; on any failure fall back to
    ///   CPU silently (the paper's "gracefully falls back" behaviour; the
    ///   reason is logged to stderr).
    /// * `Accelerated` — engine start failures are hard errors.
    /// * `Cpu` — never probes.
    pub fn new(cfg: &PipelineConfig) -> Result<FeatureExtractor> {
        let pool = match cfg.backend {
            Backend::Cpu => None,
            Backend::Accelerated => Some(
                Self::probe(cfg)
                    .context("backend=accelerated but the accelerator probe failed")?,
            ),
            Backend::Auto => match Self::probe(cfg) {
                Ok(p) => Some(p),
                Err(err) => {
                    eprintln!(
                        "radpipe: accelerator unavailable ({err:#}); falling back to CPU"
                    );
                    None
                }
            },
        };
        let batcher = pool.as_ref().map(|p| {
            let backend: Arc<dyn crate::runtime::BatchBackend> = p.clone();
            Batcher::new(
                backend,
                BatchConfig {
                    batch_size: cfg.batch_size.max(1),
                    linger: Duration::from_millis(cfg.batch_linger_ms),
                },
            )
        });
        Ok(FeatureExtractor {
            pool,
            batcher,
            backend: cfg.backend,
            strategy: cfg.strategy,
            cpu_threads: cfg.cpu_threads,
        })
    }

    fn probe(cfg: &PipelineConfig) -> Result<Arc<EnginePool>> {
        let pool = EnginePool::start(&cfg.artifact_dir, cfg.engine_count.max(1))?;
        // Touch every engine so PJRT init errors surface during the probe,
        // not mid-pipeline. A tiny request compiles the smallest bucket.
        pool.smoke_test().context("accelerator smoke test")?;
        Ok(Arc::new(pool))
    }

    /// True when the accelerated path is live.
    pub fn accelerated(&self) -> bool {
        self.pool.is_some()
    }

    pub fn engine_handle(&self) -> Option<EngineHandle> {
        self.pool.as_ref().map(|p| p.handle())
    }

    /// The engine pool, when the accelerated path is live.
    pub fn engine_pool(&self) -> Option<&EnginePool> {
        self.pool.as_deref()
    }

    /// Batching counters (None on the pure-CPU path).
    pub fn batch_stats(&self) -> Option<BatchStatsSnapshot> {
        self.batcher.as_ref().map(|b| b.stats())
    }

    /// PyRadiomics-style entry point: read image+mask paths, return the
    /// feature map (see `examples/quickstart.rs` for the 4-line usage).
    /// The mask format is detected from the extension (`.nii[.gz]`,
    /// `.rvol[.gz]`); unknown extensions are a clear error.
    pub fn execute(&self, mask_path: &Path) -> Result<Extraction> {
        let t0 = Instant::now();
        let mask: VoxelGrid<u8> = crate::io::read_mask(mask_path)?;
        let read = t0.elapsed();
        let mut ex = self.execute_mask(&mask)?;
        ex.timing.read = read;
        Ok(ex)
    }

    /// Extraction over an in-memory mask (pipeline stages use this).
    pub fn execute_mask(&self, mask: &VoxelGrid<u8>) -> Result<Extraction> {
        let mut timing = CaseTiming::default();

        let t = Instant::now();
        let (cropped, _offset) = crop_to_roi(mask);
        let mask_stats = MaskStats::compute(&cropped);
        timing.preprocess = t.elapsed();

        let t = Instant::now();
        let mesh = mesh_roi(&cropped);
        timing.marching = t.elapsed();

        let vertex_count = mesh.vertices.len();
        let (diam, path) = if let Some(batcher) = &self.batcher {
            match self.accelerated_diameters(batcher, &mesh) {
                Ok((d, exec)) => {
                    timing.transfer = exec.transfer;
                    timing.diameters = exec.execute;
                    (d, PathTaken::Accelerated)
                }
                Err(err) if self.backend == Backend::Auto => {
                    eprintln!("radpipe: accelerated diameters failed ({err:#}); CPU fallback");
                    let t = Instant::now();
                    let d = self.cpu_diameters(&mesh);
                    timing.diameters = t.elapsed();
                    (d, PathTaken::CpuFallback)
                }
                Err(err) => return Err(err),
            }
        } else {
            let t = Instant::now();
            let d = self.cpu_diameters(&mesh);
            timing.diameters = t.elapsed();
            (d, PathTaken::CpuFallback)
        };

        let t = Instant::now();
        let features =
            compute_shape_features(&cropped, &mask_stats, &mesh.stats, &diam, vertex_count);
        timing.derive = t.elapsed();

        Ok(Extraction { features, timing, path })
    }

    fn accelerated_diameters(
        &self,
        batcher: &Batcher,
        mesh: &crate::mc::Mesh,
    ) -> Result<(crate::features::Diameters, ExecTiming)> {
        if mesh.vertices.is_empty() {
            // nothing to offload; keep the artifact contract (non-empty)
            return Ok((crate::features::Diameters::EMPTY, ExecTiming::default()));
        }
        batcher.diameters(mesh.vertices_f32())
    }

    fn cpu_diameters(&self, mesh: &crate::mc::Mesh) -> crate::features::Diameters {
        // Single-thread strategy parity with PyRadiomics when threads == 1;
        // otherwise the configured optimised strategy.
        if self.cpu_threads == 1 {
            brute_force_diameters(&mesh.vertices)
        } else {
            let (mut d, _) = compute_diameters(self.strategy, &mesh.vertices, self.cpu_threads);
            // planar families via exact grouping (same semantics, cheaper)
            let planar = planar_diameters_grouped(&mesh.vertices);
            d.dxy_sq = d.dxy_sq.max(planar[0]);
            d.dyz_sq = d.dyz_sq.max(planar[1]);
            d.dxz_sq = d.dxz_sq.max(planar[2]);
            d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::Vec3;
    use crate::volume::Dims;

    fn sphere_mask(n: usize, r: f64) -> VoxelGrid<u8> {
        let mut m = VoxelGrid::zeros(Dims::new(n, n, n), Vec3::new(0.8, 0.8, 2.0));
        let c = n as f64 / 2.0;
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let (dx, dy, dz) = (x as f64 - c, y as f64 - c, z as f64 - c);
                    if dx * dx + dy * dy + dz * dz <= r * r {
                        m.set(x, y, z, 1);
                    }
                }
            }
        }
        m
    }

    fn cpu_extractor() -> FeatureExtractor {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 1,
            ..Default::default()
        };
        FeatureExtractor::new(&cfg).unwrap()
    }

    #[test]
    fn cpu_backend_never_probes() {
        let ex = cpu_extractor();
        assert!(!ex.accelerated());
    }

    #[test]
    fn cpu_extraction_works_end_to_end() {
        let ex = cpu_extractor();
        let out = ex.execute_mask(&sphere_mask(16, 5.0)).unwrap();
        assert_eq!(out.path, PathTaken::CpuFallback);
        assert!(out.features.mesh_volume > 0.0);
        assert!(out.features.maximum_3d_diameter > 0.0);
        assert!(out.timing.marching > Duration::ZERO);
    }

    #[test]
    fn auto_with_bogus_artifacts_falls_back() {
        let cfg = PipelineConfig {
            backend: Backend::Auto,
            artifact_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
            cpu_threads: 1,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        assert!(!ex.accelerated(), "probe must fail on a missing manifest");
        let out = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert_eq!(out.path, PathTaken::CpuFallback);
    }

    #[test]
    fn accelerated_with_bogus_artifacts_errors() {
        let cfg = PipelineConfig {
            backend: Backend::Accelerated,
            artifact_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
            ..Default::default()
        };
        assert!(FeatureExtractor::new(&cfg).is_err());
    }

    #[test]
    fn cpu_strategy_path_matches_brute_force() {
        let cfg = PipelineConfig {
            backend: Backend::Cpu,
            cpu_threads: 2,
            strategy: Strategy::BlockReduction,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        let brute = cpu_extractor();
        let mask = sphere_mask(14, 4.5);
        let a = ex.execute_mask(&mask).unwrap();
        let b = brute.execute_mask(&mask).unwrap();
        assert_eq!(a.features.maximum_3d_diameter, b.features.maximum_3d_diameter);
        assert_eq!(a.features.maximum_2d_diameter_slice, b.features.maximum_2d_diameter_slice);
    }

    #[test]
    fn empty_mask_is_graceful() {
        let ex = cpu_extractor();
        let m = VoxelGrid::zeros(Dims::new(4, 4, 4), Vec3::splat(1.0));
        let out = ex.execute_mask(&m).unwrap();
        assert_eq!(out.features.voxel_count, 0);
        assert!(out.features.maximum_3d_diameter.is_nan());
    }

    #[test]
    fn execute_rejects_unknown_mask_extension() {
        let dir = std::env::temp_dir().join("radpipe_dispatch_fmt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mask.dat");
        std::fs::write(&path, b"whatever").unwrap();
        let ex = cpu_extractor();
        let err = ex.execute(&path).unwrap_err();
        assert!(
            format!("{err:#}").contains("unrecognised mask format"),
            "{err:#}"
        );
    }

    #[test]
    fn execute_reads_both_containers_via_detection() {
        use crate::io::{write_nifti, write_rvol};
        let dir = std::env::temp_dir().join("radpipe_dispatch_fmt2");
        std::fs::create_dir_all(&dir).unwrap();
        let mask = sphere_mask(12, 4.0);
        let p_rvol = dir.join("m.rvol.gz");
        let p_nii = dir.join("m.nii.gz");
        write_rvol(&p_rvol, &mask).unwrap();
        write_nifti(&p_nii, &mask).unwrap();
        let ex = cpu_extractor();
        let a = ex.execute(&p_rvol).unwrap();
        let b = ex.execute(&p_nii).unwrap();
        assert_eq!(a.features.voxel_count, b.features.voxel_count);
    }

    #[test]
    fn batching_knobs_fall_back_with_auto_backend() {
        // engine_count / batch_size plumbing must not disturb the graceful
        // CPU fallback when no artifacts exist.
        let cfg = PipelineConfig {
            backend: Backend::Auto,
            artifact_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
            cpu_threads: 1,
            engine_count: 4,
            batch_size: 8,
            batch_linger_ms: 1,
            ..Default::default()
        };
        let ex = FeatureExtractor::new(&cfg).unwrap();
        assert!(!ex.accelerated());
        assert!(ex.batch_stats().is_none(), "no batcher on the CPU path");
        let out = ex.execute_mask(&sphere_mask(12, 4.0)).unwrap();
        assert_eq!(out.path, PathTaken::CpuFallback);
    }
}
